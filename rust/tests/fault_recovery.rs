//! Fault-injection and recovery suite: the failpoint chaos harness
//! drives crashes into every pipeline site (`seal`, `compute`, `merge`,
//! `publish`, `wal_append`, `checkpoint`, `enqueue`) and asserts the
//! durability contract end to end:
//!
//! * **crash/recover equivalence** — for each site × {serial, cpu} ×
//!   shards {1, 4}: kill the service mid-stream, restart a fresh one on
//!   the same WAL dir, and require the epoch line to resume at or past
//!   the crash, the recovered graph to equal the WAL-implied edge set,
//!   and the recovered algorithm state to match its offline oracle after
//!   a second submission wave;
//! * **torn tails truncate, not fail** — a partially-written last record
//!   is physically truncated on replay and recovery proceeds from the
//!   surviving prefix;
//! * **supervised in-process restart** — with restart budget left, a
//!   crashing engine is rebuilt from checkpoint + WAL tail inside the
//!   same process and the service finishes the stream undegraded;
//! * **graceful degradation** — with no WAL (or budget exhausted) an
//!   engine panic flips the service read-only: the last published epoch
//!   keeps serving reads while writes get a typed [`SubmitError`];
//! * **overload shedding** — a stalled compute stage plus deadline
//!   submits sheds instead of blocking producers forever, and the shed
//!   count is visible in [`ServiceStats`].
//!
//! Every test holds a [`Scenario`] guard: the failpoint registry is
//! process-global, so chaos tests serialize against each other and the
//! registry is cleared even on panic-unwind. Real pipeline sites are
//! armed *only* in this binary — lib unit tests run many services
//! concurrently in one process and must never see an armed site.
//!
//! [`ServiceStats`]: starplat_dyn::stream::ServiceStats

use starplat_dyn::algorithms::{sssp, triangle, PrState};
use starplat_dyn::backend::cpu::CpuEngine;
use starplat_dyn::backend::{BackendKind, EngineOpts};
use starplat_dyn::coordinator::{stream_workload, Algo};
use starplat_dyn::graph::{generators, DynGraph, NodeId, Update, UpdateKind, UpdateStream};
use starplat_dyn::stream::{
    wal, GraphService, Ingest, MergePolicy, ServiceConfig, ShardedService, ShutdownError,
    SubmitError,
};
use starplat_dyn::util::failpoint::{self, Scenario};
use starplat_dyn::util::threadpool::Sched;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Generous bound for drains and degradation polls: chaos runs restart
/// with exponential backoff, so "quiet" can take a few seconds on a
/// loaded CI box. A pass never waits this long; only a genuine hang does.
const DRAIN: Duration = Duration::from_secs(60);

fn fresh_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("starplat-fault-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn graph() -> DynGraph {
    generators::uniform_random(200, 1200, 9, 7)
}

fn sssp_workload(g0: &DynGraph, seed: u64) -> Vec<Update> {
    UpdateStream::generate_percent(g0, 25.0, 1, 9, seed).updates
}

fn add(src: NodeId, dst: NodeId) -> Update {
    Update { kind: UpdateKind::Add, src, dst, weight: 1 }
}

/// Durable chaos config: small batches so a 300-update workload seals
/// enough of them to place a crash at any `~after` count, short restart
/// backoff so supervised-restart tests converge quickly.
fn durable_cfg(
    algo: Algo,
    dir: &Path,
    every: u64,
    max_restarts: u32,
    backend: BackendKind,
    engine_shards: usize,
) -> ServiceConfig {
    let mut c = ServiceConfig::new(algo);
    c.backend = backend;
    c.shards = 2;
    c.batch_capacity = 32;
    c.batch_deadline = Duration::from_millis(2);
    c.merge_policy = MergePolicy::Periodic { batches: 4 };
    c.engine_shards = engine_shards;
    // Engine knobs are single-cpu-engine-only: the serial backend and the
    // sharded fleet both require the default `EngineOpts`.
    if backend == BackendKind::Cpu && engine_shards <= 1 {
        c.engine.threads = Some(2);
    } else {
        c.engine = EngineOpts::default();
    }
    c.durability.wal_dir = Some(dir.to_path_buf());
    c.durability.checkpoint_every = every;
    c.durability.max_restarts = max_restarts;
    c.durability.restart_backoff = Duration::from_millis(5);
    c
}

/// No-WAL config for the degradation and shedding tests.
fn volatile_cfg(algo: Algo) -> ServiceConfig {
    let mut c = ServiceConfig::new(algo);
    c.engine.threads = Some(2);
    c.shards = 2;
    c.batch_capacity = 32;
    c.batch_deadline = Duration::from_millis(2);
    c
}

// --------------------------------------------------- crash/recover matrix

/// Per-site crash placements. `~after` counts are chosen so the site has
/// fired well inside a 300-update stream (≈10+ sealed batches at
/// capacity 32): merges happen every 4 batches, checkpoints every
/// `checkpoint_every` applied batches (the seed checkpoint is hit #1).
///
/// All legs but `checkpoint` run with `checkpoint_every = 1000`, i.e.
/// only the seed checkpoint: the WAL then holds the *entire* accepted
/// history, so recovery can be checked against the strongest oracle —
/// `g0` + every WAL record must equal the recovered edge set exactly.
/// The `checkpoint` leg needs a short cadence to reach its own site and
/// prunes the log, so it keeps the epoch/oracle checks only.
const CRASH_MATRIX: &[(&str, &str, u64)] = &[
    ("seal", "seal=panic~4", 1000),
    ("compute", "compute=err~4", 1000),
    ("merge", "merge=panic~1", 1000),
    ("publish", "publish=panic~4", 1000),
    ("wal-append", "wal_append=err~4", 1000),
    ("checkpoint", "checkpoint=err~1", 3),
];

/// Phase 1 of a crash/recover case: feed the workload into a service
/// whose restart budget is zero, so the first fired failpoint degrades it
/// deterministically. Returns the last epoch the dying service published
/// — the floor the recovered service must resume at or above.
fn feed_single(g0: &DynGraph, w: &[Update], cfg: ServiceConfig) -> u64 {
    let svc = GraphService::start(g0.clone(), cfg);
    for u in w {
        if !svc.submit(*u) {
            break; // poisoned mid-stream: the crash landed
        }
    }
    svc.drain_timeout(DRAIN).expect("drain (or poison-sweep) within the bound");
    let epoch = svc.epoch();
    match svc.try_shutdown() {
        Ok(_) => {} // the site never fired (legal for probabilistic specs)
        Err(ShutdownError::Degraded(d)) => {
            assert!(d.stats.degraded, "typed shutdown error implies degraded stats");
            assert!(d.stats.restarts >= 1, "a caught crash must be counted");
        }
        Err(e) => panic!("unexpected shutdown error: {e}"),
    }
    epoch
}

fn feed_sharded(g0: &DynGraph, w: &[Update], cfg: ServiceConfig) -> u64 {
    let svc = ShardedService::start(g0.clone(), cfg);
    for u in w {
        if !svc.submit(*u) {
            break;
        }
    }
    svc.drain_timeout(DRAIN).expect("drain (or poison-sweep) within the bound");
    let epoch = svc.epoch();
    match svc.try_shutdown() {
        Ok(_) => {}
        Err(ShutdownError::Degraded(d)) => {
            assert!(d.stats.degraded);
            assert!(d.stats.restarts >= 1);
        }
        Err(e) => panic!("unexpected shutdown error: {e}"),
    }
    epoch
}

/// Phase 2: recover on the same WAL dir, verify continuity + equivalence,
/// then prove the recovered service is fully live by pushing a second
/// wave through it and checking the end state against the static oracle.
fn recover_verify_sssp(
    g0: &DynGraph,
    w2: &[Update],
    cfg: ServiceConfig,
    dir: &Path,
    epoch_floor: u64,
    full_history: bool,
    sharded: bool,
) {
    let report = if sharded {
        let svc = ShardedService::try_start(g0.clone(), cfg).expect("sharded recovery start");
        check_recovered(svc.epoch(), svc.stats().recovered_batches, epoch_floor);
        for u in w2 {
            assert!(svc.submit(*u), "recovered service must accept writes");
        }
        svc.drain_timeout(DRAIN).expect("post-recovery drain");
        svc.shutdown().into_service_report()
    } else {
        let svc = GraphService::try_start(g0.clone(), cfg).expect("recovery start");
        check_recovered(svc.epoch(), svc.stats().recovered_batches, epoch_floor);
        for u in w2 {
            assert!(svc.submit(*u), "recovered service must accept writes");
        }
        svc.drain_timeout(DRAIN).expect("post-recovery drain");
        svc.shutdown()
    };
    assert_eq!(
        report.sssp().unwrap().dist,
        sssp::dijkstra_oracle(&report.graph, 0),
        "recovered dynamic SSSP must equal the static oracle on the recovered graph"
    );
    if full_history {
        // Only the seed checkpoint exists, so the WAL records the whole
        // accepted history: g0 + every record (phase 1 + phase 2) must
        // reproduce the recovered edge set exactly.
        let (records, _) = wal::replay(dir, 0).expect("full-history replay");
        let mut want = g0.clone();
        for r in &records {
            want.apply_deletions(&r.dels);
            want.apply_additions(&r.adds);
        }
        assert_eq!(
            report.graph.edges_sorted(),
            want.edges_sorted(),
            "recovered graph must equal the WAL-implied edge set"
        );
    }
}

fn check_recovered(epoch: u64, recovered: u64, epoch_floor: u64) {
    assert!(
        epoch >= epoch_floor,
        "epoch line must resume at or past the crash: {epoch} < {epoch_floor}"
    );
    assert!(recovered > 0, "recovery must have replayed a WAL tail");
}

fn crash_recover_case(tag: &str, spec: &str, every: u64, backend: BackendKind, shards: usize) {
    let _s = Scenario::new(spec);
    let kind = if shards > 1 { "sharded" } else { backend.capabilities().name };
    let dir = fresh_dir(&format!("{tag}-{kind}"));
    let g0 = graph();
    let w1 = sssp_workload(&g0, 13);
    let w2 = sssp_workload(&g0, 17);
    let cfg = durable_cfg(Algo::Sssp, &dir, every, 0, backend, shards);
    let epoch1 = if shards > 1 {
        feed_sharded(&g0, &w1, cfg.clone())
    } else {
        feed_single(&g0, &w1, cfg.clone())
    };
    // Disarm for recovery while still holding the Scenario guard: hit
    // counters persist across restarts, so a persistent `~after` spec
    // would re-fire during replay and crash the recovering process too.
    failpoint::clear();
    recover_verify_sssp(&g0, &w2, cfg, &dir, epoch1, every >= 1000, shards > 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_recover_matrix_single_cpu() {
    for (tag, spec, every) in CRASH_MATRIX {
        crash_recover_case(tag, spec, *every, BackendKind::Cpu, 1);
    }
}

#[test]
fn crash_recover_matrix_single_serial() {
    for (tag, spec, every) in CRASH_MATRIX {
        crash_recover_case(tag, spec, *every, BackendKind::Serial, 1);
    }
}

#[test]
fn crash_recover_matrix_sharded() {
    for (tag, spec, every) in CRASH_MATRIX {
        crash_recover_case(tag, spec, *every, BackendKind::Cpu, 4);
    }
}

// ----------------------------------------------- per-algorithm recovery

/// TC is exact under recovery: the recovered count must equal a full
/// static recount of the recovered graph.
#[test]
fn crash_recover_tc_exact_count() {
    let _s = Scenario::new("compute=panic~4");
    let dir = fresh_dir("tc");
    let g0 = triangle::symmetrize(&generators::uniform_random(120, 700, 5, 21));
    let w1 = stream_workload(Algo::Tc, &g0, 20.0, 23);
    let w2 = stream_workload(Algo::Tc, &g0, 10.0, 29);
    let cfg = durable_cfg(Algo::Tc, &dir, 1000, 0, BackendKind::Cpu, 1);

    let svc = GraphService::start(g0.clone(), cfg.clone());
    for u in &w1 {
        if !svc.submit(*u) {
            break;
        }
    }
    svc.drain_timeout(DRAIN).expect("drain");
    let epoch1 = svc.epoch();
    let _ = svc.try_shutdown();
    failpoint::clear();

    let svc = GraphService::try_start(g0.clone(), cfg).expect("tc recovery");
    check_recovered(svc.epoch(), svc.stats().recovered_batches, epoch1);
    for u in &w2 {
        assert!(svc.submit(*u));
    }
    svc.drain_timeout(DRAIN).expect("post-recovery drain");
    let report = svc.shutdown();
    assert_eq!(
        report.tc().unwrap().triangles,
        triangle::static_tc(&report.graph).triangles,
        "recovered TC must equal a static recount"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Dynamic PR is path-dependent, so recovery is checked the same way the
/// equivalence suite checks streaming: the recovered ranks must track a
/// static recompute of the recovered graph within the L1 tolerance.
#[test]
fn crash_recover_pr_tracks_static_recompute() {
    let _s = Scenario::new("publish=panic~4");
    let dir = fresh_dir("pr");
    let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 91);
    let n = g0.num_nodes();
    let w1 = stream_workload(Algo::Pr, &g0, 8.0, 93);
    let mut cfg = durable_cfg(Algo::Pr, &dir, 1000, 0, BackendKind::Cpu, 1);
    cfg.pr_beta = 1e-9;
    cfg.pr_max_iter = 200;

    let svc = GraphService::start(g0.clone(), cfg.clone());
    for u in &w1 {
        if !svc.submit(*u) {
            break;
        }
    }
    svc.drain_timeout(DRAIN).expect("drain");
    let epoch1 = svc.epoch();
    let _ = svc.try_shutdown();
    failpoint::clear();

    let svc = GraphService::try_start(g0.clone(), cfg).expect("pr recovery");
    check_recovered(svc.epoch(), svc.stats().recovered_batches, epoch1);
    svc.drain_timeout(DRAIN).expect("post-recovery drain");
    let report = svc.shutdown();

    let mut truth = PrState::new(n, 1e-9, 0.85, 200);
    let engine = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
    engine.pr_static(&report.graph, &mut truth);
    let st = report.pr().expect("pr state");
    let l1: f64 = st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 0.05, "recovered PR diverged from static recompute: L1={l1}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ torn tails

#[test]
fn torn_wal_tail_truncates_and_recovers() {
    let _s = Scenario::new("");
    let dir = fresh_dir("torn");
    let g0 = graph();
    let w1 = sssp_workload(&g0, 13);
    let cfg = durable_cfg(Algo::Sssp, &dir, 1000, 0, BackendKind::Cpu, 1);

    let svc = GraphService::start(g0.clone(), cfg.clone());
    for u in &w1 {
        assert!(svc.submit(*u));
    }
    svc.drain_timeout(DRAIN).expect("drain");
    let _ = svc.shutdown();
    let full = wal::last_seq(&dir).expect("clean log");
    assert!(full >= 2, "need at least two sealed batches, got {full}");

    // Chop bytes off the last record, as a crash mid-write would.
    wal::tear_tail(&dir, 5).expect("tear");
    let (records, info) = wal::replay(&dir, 0).expect("torn replay must not fail");
    assert!(info.truncated_bytes > 0, "the torn frame must be physically truncated");
    assert_eq!(records.last().expect("prefix survives").seq, full - 1);

    // Recovery proceeds from the surviving prefix.
    let svc =
        GraphService::try_start(g0.clone(), cfg).expect("torn tail must truncate, not fail");
    assert_eq!(svc.stats().recovered_batches, full - 1);
    let report = svc.shutdown();
    let mut want = g0.clone();
    for r in &records {
        want.apply_deletions(&r.dels);
        want.apply_additions(&r.adds);
    }
    assert_eq!(report.graph.edges_sorted(), want.edges_sorted());
    assert_eq!(report.sssp().unwrap().dist, sssp::dijkstra_oracle(&want, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------- supervised restart (live)

/// With restart budget and a WAL, a crashing engine is rebuilt *inside
/// the same process* and the stream finishes undegraded. The armed site
/// is `publish`: it is not on the replay path, so the restarted engine
/// comes back up cleanly and the test can disarm once it has observed a
/// supervised restart (hit counters persist, so the site would otherwise
/// re-fire on every subsequent live publish until the budget ran out).
#[test]
fn supervised_restart_recovers_in_process() {
    let _s = Scenario::new("publish=panic~4");
    let dir = fresh_dir("restart");
    let g0 = graph();
    let w1 = sssp_workload(&g0, 13);
    let cfg = durable_cfg(Algo::Sssp, &dir, 3, 10, BackendKind::Cpu, 1);

    let svc = GraphService::start(g0.clone(), cfg);
    let mut cleared = false;
    for u in &w1 {
        assert!(svc.submit(*u), "a supervised service must keep accepting writes");
        if !cleared && svc.stats().restarts > 0 {
            failpoint::clear();
            cleared = true;
        }
    }
    if !cleared {
        let t0 = Instant::now();
        while svc.stats().restarts == 0 && t0.elapsed() < DRAIN {
            std::thread::sleep(Duration::from_millis(2));
        }
        failpoint::clear();
    }
    svc.drain_timeout(DRAIN).expect("drain after supervised restart");
    let stats = svc.stats();
    assert!(!stats.degraded, "budgeted restart must not degrade the service");
    assert!(stats.restarts >= 1, "the crash must have been supervised");
    assert!(stats.recovered_batches >= 1, "restart must have replayed a WAL tail");
    let report = svc.shutdown();
    assert_eq!(
        report.sssp().unwrap().dist,
        sssp::dijkstra_oracle(&report.graph, 0),
        "post-restart state must match the static oracle"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- graceful degradation

fn assert_degraded_read_only<SUBMIT, DIST>(
    degraded: impl Fn() -> bool,
    submit_deadline: SUBMIT,
    dist: DIST,
    epoch: impl Fn() -> u64,
) where
    SUBMIT: Fn(Update, Duration) -> Result<(), SubmitError>,
    DIST: Fn(NodeId) -> Option<i64>,
{
    let t0 = Instant::now();
    while !degraded() && t0.elapsed() < DRAIN {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(degraded(), "third computed batch must exhaust a zero restart budget");
    // Reads keep serving the last published epoch...
    assert!(epoch() >= 3, "two batches published before the crash");
    assert_eq!(dist(0), Some(0), "snapshot reads must survive engine death");
    // ...while writes get the typed rejection.
    assert_eq!(
        submit_deadline(add(1, 2), Duration::from_millis(5)),
        Err(SubmitError::Poisoned),
        "writes into a degraded service must be rejected as Poisoned"
    );
}

#[test]
fn engine_death_without_wal_degrades_to_read_only() {
    let _s = Scenario::new("compute=panic~2");
    let g0 = graph();
    let w = sssp_workload(&g0, 13);
    let svc = GraphService::start(g0.clone(), volatile_cfg(Algo::Sssp));
    for u in &w {
        if !svc.submit(*u) {
            break;
        }
    }
    assert_degraded_read_only(
        || svc.degraded(),
        |u, d| svc.submit_deadline(u, d),
        |v| svc.dist(v),
        || svc.epoch(),
    );
    assert!(!svc.insert(3, 4, 1), "bool submits must also be rejected");
    svc.drain_timeout(DRAIN).expect("poison sweep settles the backlog");
    let ShutdownError::Degraded(d) =
        svc.try_shutdown().expect_err("degraded shutdown must be typed")
    else {
        panic!("expected Degraded");
    };
    assert!(d.stats.degraded);
    assert_eq!(d.stats.restarts, 1, "one caught crash, zero budget");
    // Shutdown is idempotent: the report is gone, the second call says so.
    assert!(
        matches!(svc.try_shutdown(), Err(ShutdownError::AlreadyShutDown)),
        "second shutdown must be AlreadyShutDown, not a panic"
    );
}

/// The sharded fleet funnels worker panics through the same supervisor:
/// a compute crash in the sharded coordinator leaves the service serving
/// reads in degraded mode instead of hanging producers.
#[test]
fn sharded_engine_death_degrades_to_read_only() {
    let _s = Scenario::new("compute=panic~2");
    let g0 = graph();
    let w = sssp_workload(&g0, 13);
    let mut cfg = volatile_cfg(Algo::Sssp);
    cfg.engine = EngineOpts::default();
    cfg.engine_shards = 4;
    let svc = ShardedService::start(g0.clone(), cfg);
    for u in &w {
        if !svc.submit(*u) {
            break;
        }
    }
    assert_degraded_read_only(
        || svc.degraded(),
        |u, d| svc.submit_deadline(u, d),
        |v| svc.dist(v),
        || svc.epoch(),
    );
    svc.drain_timeout(DRAIN).expect("poison sweep settles the backlog");
    let ShutdownError::Degraded(d) =
        svc.try_shutdown().expect_err("degraded shutdown must be typed")
    else {
        panic!("expected Degraded");
    };
    assert!(d.stats.degraded);
    assert_eq!(d.stats.restarts, 1);
    assert!(
        matches!(svc.try_shutdown(), Err(ShutdownError::AlreadyShutDown)),
        "second sharded shutdown must be AlreadyShutDown, not a panic"
    );
}

// ------------------------------------------------------ overload shedding

/// A stalled compute stage with tiny queues: deadline submits shed
/// instead of blocking, the count lands in stats, and the backlog drains
/// to a correct end state once the stall lifts.
#[test]
fn sustained_overload_sheds_with_deadline_submits() {
    let _s = Scenario::new("compute=delay:40");
    let g0 = graph();
    let w = sssp_workload(&g0, 13);
    let mut cfg = volatile_cfg(Algo::Sssp);
    cfg.shards = 1;
    cfg.shard_capacity = 8;
    cfg.batch_capacity = 8;
    let svc = GraphService::start(g0.clone(), cfg);
    let mut shed = 0u64;
    for u in w.iter().take(200) {
        match svc.submit_deadline(*u, Duration::from_millis(1)) {
            Ok(()) => {}
            Err(SubmitError::Shed) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "a 40ms/batch stall with 8-deep queues must shed 1ms submits");
    assert_eq!(svc.stats().shed, shed, "shed count must be visible in stats");
    failpoint::clear();
    svc.drain_timeout(DRAIN).expect("backlog drains once the stall lifts");
    let report = svc.shutdown();
    assert_eq!(
        report.sssp().unwrap().dist,
        sssp::dijkstra_oracle(&report.graph, 0),
        "accepted updates must still produce an oracle-exact state"
    );
}

/// The `enqueue` site sheds at the ingest edge with the typed error and
/// the shed counter, before any queue state changes. (Lives here rather
/// than in the lib tests: arming a real site in the lib-test process
/// would shed submissions of unrelated concurrently-running tests.)
#[test]
fn enqueue_failpoint_sheds_submissions() {
    let _s = Scenario::new("enqueue=err");
    let ing = Ingest::new(2, 64, false);
    assert_eq!(ing.try_submit(add(0, 1), None), Err(SubmitError::Shed));
    assert_eq!(ing.counters().shed, 1);
    assert_eq!(ing.queued(), 0, "shed submissions must not enqueue");
}
