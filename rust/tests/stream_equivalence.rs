//! Streaming ⇔ offline equivalence suite: the `GraphService` end-state
//! after draining a multi-producer update stream must equal the
//! coordinator's offline batch-mode result, and same-edge coalescing must
//! be observationally a no-op.
//!
//! The sharded half is the **cross-shard equivalence matrix** pinning the
//! `ShardedService`: for shards ∈ {1, 2, 4, 8}, sharded ≡ single-engine ≡
//! offline batch mode — *bitwise* for SSSP (unique fixed point +
//! deterministic parent repair) and TC (order-free integer counts),
//! oracle-equal for PR (float sums reassociate across shard boundaries) —
//! plus the cross-shard coalescing routing property and the epoch-stitch
//! reader test. The skewed legs rerun the matrix under zipfian hub-heavy
//! churn with the persistent fleet's in-phase stealing and churn-driven
//! rebalancing forced on, asserting at least one live migration per
//! multi-shard leg.
//!
//! The telemetry leg re-runs a sharded steal-on configuration with the
//! span tracer wired in and asserts the end state is bitwise identical
//! to the untraced run — instrumentation is observation-only.
//!
//! The backend half is the **cross-backend equivalence matrix** pinning
//! `serve --backend {serial,cpu,dist,xla}` through the `DynamicEngine`
//! trait: dist ≡ cpu *bitwise* for SSSP (distances AND parents — both
//! repair the SP tree with the same deterministic argmin) and TC, serial
//! bitwise on distances/counts, PR oracle-equal across all of them; the
//! xla leg runs when PJRT + artifacts are present and skips cleanly
//! otherwise.

use starplat_dyn::algorithms::{sssp, triangle, PrState};
use starplat_dyn::backend::cpu::CpuEngine;
use starplat_dyn::backend::{BackendKind, Direction, EngineOpts};
use starplat_dyn::coordinator::{
    run_stream_cell, run_stream_cell_workload, stream_workload, Algo,
};
use starplat_dyn::graph::{generators, DynGraph, NodeId, Update, UpdateKind, UpdateStream};
use starplat_dyn::stream::{
    GraphService, MergePolicy, ServiceConfig, ShardedGraph, ShardedService,
};
use starplat_dyn::util::propcheck::forall_checks;
use starplat_dyn::util::threadpool::Sched;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const SHARD_MATRIX: [usize; 4] = [1, 2, 4, 8];

/// Deterministic single-lane config: one producer + one shard + one engine
/// thread makes the service batching bit-identical to offline
/// `stream.batches()` chunking, so results can be compared exactly. The
/// exact tests trim their workload to a multiple of `batch`, so every
/// batch closes by *size* and the (long) deadline never shapes batching —
/// a scheduler stall can't shift batch boundaries and flake the bitwise
/// asserts.
fn exact_cfg(algo: Algo, batch: usize) -> ServiceConfig {
    let mut cfg = ServiceConfig::new(algo);
    cfg.engine.threads = Some(1);
    cfg.engine.sched = Some(Sched::Dynamic { chunk: 64 });
    cfg.shards = 1;
    cfg.batch_capacity = batch;
    cfg.batch_deadline = Duration::from_secs(60);
    cfg.merge_policy = MergePolicy::Never;
    cfg
}

/// [`exact_cfg`] for a non-default backend: same single-lane batching,
/// engine knobs only where the backend has them (the factory rejects
/// cpu knobs on other backends — that rejection has its own test).
fn exact_backend_cfg(algo: Algo, batch: usize, backend: BackendKind) -> ServiceConfig {
    let mut cfg = exact_cfg(algo, batch);
    cfg.backend = backend;
    if backend != BackendKind::Cpu {
        cfg.engine = EngineOpts::default();
    }
    cfg
}

/// Trim an update list to a whole number of `batch`-sized chunks.
fn trim_to_batches(mut updates: Vec<Update>, batch: usize) -> Vec<Update> {
    updates.truncate(updates.len() - updates.len() % batch);
    assert!(!updates.is_empty(), "workload must keep at least one full batch");
    updates
}

fn concurrent_cfg(algo: Algo) -> ServiceConfig {
    let mut cfg = ServiceConfig::new(algo);
    cfg.engine.threads = Some(2);
    cfg.shards = 4;
    cfg.batch_capacity = 64;
    cfg.batch_deadline = Duration::from_millis(2);
    cfg
}

/// [`concurrent_cfg`] with the engine knobs cleared — the sharded service
/// runs its own BSP fleet and rejects single-engine knobs.
fn concurrent_sharded_cfg(algo: Algo) -> ServiceConfig {
    let mut cfg = concurrent_cfg(algo);
    cfg.engine = EngineOpts::default();
    cfg
}

/// Apply a stream-workload update list to a graph (the offline ground
/// truth for multi-producer runs; order-independent for generated
/// conflict-free workloads).
fn apply_workload(g: &mut DynGraph, workload: &[Update], symmetric: bool) {
    for u in workload {
        match u.kind {
            UpdateKind::Delete => {
                g.delete_edge(u.src, u.dst);
                if symmetric {
                    g.delete_edge(u.dst, u.src);
                }
            }
            UpdateKind::Add => {
                g.add_edge(u.src, u.dst, u.weight);
                if symmetric {
                    g.add_edge(u.dst, u.src, u.weight);
                }
            }
        }
    }
}

/// Single-producer SSSP: the streamed end-state is *bitwise* equal to the
/// coordinator's offline batch-mode pipeline over the same batches.
#[test]
fn sssp_stream_equals_offline_batch_mode_exactly() {
    let g0 = generators::uniform_random(300, 1500, 9, 71);
    let batch = 64;
    let raw = UpdateStream::generate_percent(&g0, 12.0, batch, 9, 73);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);

    // offline batch mode (same engine shape: 1 thread, no merges)
    let engine = CpuEngine::new(1, Sched::Dynamic { chunk: 64 });
    let mut g = g0.clone();
    g.merge_period = 0;
    let mut offline = engine.sssp_static(&g, 0);
    for b in stream.batches() {
        engine.sssp_dynamic_batch(&mut g, &mut offline, &b);
    }

    // streaming
    let svc = GraphService::start(g0.clone(), exact_cfg(Algo::Sssp, batch));
    for u in &stream.updates {
        assert!(svc.submit(*u));
    }
    svc.drain();
    let report = svc.shutdown();

    assert_eq!(report.graph.edges_sorted(), g.edges_sorted());
    let st = report.sssp().expect("sssp service");
    assert_eq!(st.dist, offline.dist, "distances must match offline batch mode");
    assert_eq!(st.parent, offline.parent, "SP-tree parents must match");
    // …and both equal the independent oracle on the final graph
    assert_eq!(st.dist, sssp::dijkstra_oracle(&g, 0));
}

/// Single-producer PR: identical batching + single-thread engine ⇒ the
/// streamed ranks are bitwise equal to offline batch mode.
#[test]
fn pr_stream_equals_offline_batch_mode_exactly() {
    let g0 = generators::rmat(8, 1200, 0.57, 0.19, 0.19, 77);
    let n = g0.num_nodes();
    let batch = 64;
    let raw = UpdateStream::generate_percent(&g0, 8.0, batch, 9, 79);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);

    let engine = CpuEngine::new(1, Sched::Dynamic { chunk: 64 });
    let mut g = g0.clone();
    g.merge_period = 0;
    let mut offline = PrState::new(n, 1e-3, 0.85, 100);
    engine.pr_static(&g, &mut offline);
    for b in stream.batches() {
        engine.pr_dynamic_batch(&mut g, &mut offline, &b);
    }

    let svc = GraphService::start(g0.clone(), exact_cfg(Algo::Pr, batch));
    for u in &stream.updates {
        assert!(svc.submit(*u));
    }
    svc.drain();
    let report = svc.shutdown();

    assert_eq!(report.graph.edges_sorted(), g.edges_sorted());
    let st = report.pr().expect("pr service");
    assert_eq!(st.rank, offline.rank, "ranks must match offline batch mode bitwise");
}

/// Multi-producer SSSP: end-state equals the offline batch-mode result
/// (both equal the Dijkstra oracle on the fully-updated graph).
#[test]
fn sssp_multi_producer_stream_matches_offline() {
    let g0 = generators::uniform_random(400, 2000, 9, 81);
    let workload = stream_workload(Algo::Sssp, &g0, 10.0, 83);
    let (_, report) =
        run_stream_cell(Algo::Sssp, &g0, 10.0, 4, 1, concurrent_cfg(Algo::Sssp), 83).unwrap();

    let mut want = g0.clone();
    apply_workload(&mut want, &workload, false);
    assert_eq!(report.graph.edges_sorted(), want.edges_sorted());

    // offline batch mode over the same updates (producer interleaving is
    // immaterial: dynamic SSSP is exact for any batching/order)
    let engine = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
    let stream = UpdateStream::new(workload, 64);
    let mut g = g0.clone();
    let mut offline = engine.sssp_static(&g, 0);
    for b in stream.batches() {
        engine.sssp_dynamic_batch(&mut g, &mut offline, &b);
    }
    let st = report.sssp().expect("sssp service");
    assert_eq!(st.dist, offline.dist);
    assert_eq!(st.dist, sssp::dijkstra_oracle(&want, 0));
}

/// Multi-producer PR: streamed ranks and offline batch-mode ranks both
/// track the static recompute of the final graph.
#[test]
fn pr_multi_producer_stream_tracks_offline() {
    let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 91);
    let n = g0.num_nodes();
    let mut cfg = concurrent_cfg(Algo::Pr);
    cfg.pr_beta = 1e-9;
    cfg.pr_max_iter = 200;
    let workload = stream_workload(Algo::Pr, &g0, 8.0, 93);
    let (_, report) = run_stream_cell(Algo::Pr, &g0, 8.0, 4, 1, cfg, 93).unwrap();

    let mut want = g0.clone();
    apply_workload(&mut want, &workload, false);
    assert_eq!(report.graph.edges_sorted(), want.edges_sorted());

    let mut truth = PrState::new(n, 1e-9, 0.85, 200);
    let engine = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
    engine.pr_static(&want, &mut truth);

    let st = report.pr().expect("pr service");
    let l1: f64 = st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 0.05, "streamed PR diverged from static recompute: L1={l1}");

    // offline batch mode over the same updates, same tolerance
    let stream = UpdateStream::new(workload, 64);
    let mut g = g0.clone();
    let mut offline = PrState::new(n, 1e-9, 0.85, 200);
    engine.pr_static(&g, &mut offline);
    for b in stream.batches() {
        engine.pr_dynamic_batch(&mut g, &mut offline, &b);
    }
    let l1_off: f64 =
        offline.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1_off < 0.05, "offline PR diverged: L1={l1_off}");
}

/// Multi-producer TC: delta counting over streamed undirected updates is
/// exact — the end count equals a full static recount.
#[test]
fn tc_multi_producer_stream_counts_exactly() {
    let g0 = generators::uniform_random(80, 480, 5, 101);
    let (_, report) =
        run_stream_cell(Algo::Tc, &g0, 15.0, 4, 1, concurrent_cfg(Algo::Tc), 103).unwrap();
    let st = report.tc().expect("tc service");
    assert_eq!(
        st.triangles,
        triangle::static_tc(&report.graph).triangles,
        "streamed TC must equal a static recount of the final graph"
    );
    // and the final graph stayed symmetric (arcs applied in pairs)
    for (u, v, _) in report.graph.edges_sorted() {
        assert!(report.graph.has_edge(v, u), "asymmetric arc {u}->{v} after stream");
    }
}

/// Propcheck: an insert followed by a delete of the same (fresh) edge
/// submitted within one producer's stream is observationally a no-op —
/// the drained service state is identical to a run without the pair.
#[test]
fn prop_coalesced_insert_delete_pairs_are_noops() {
    forall_checks(0xC0A1, 6, |gen| {
        let n = gen.usize_in(40, 120);
        let e = gen.usize_in(n, n * 4);
        let seed = gen.rng().next_u64();
        let g0 = generators::uniform_random(n, e, 9, seed);
        let pct = 2.0 + gen.f64_unit() * 10.0;
        let base = UpdateStream::generate_percent(&g0, pct, 1, 9, seed ^ 0x11).updates;

        // edges never present in the run: not in g0, not added by `base`
        let mut forbidden: std::collections::HashSet<(NodeId, NodeId)> =
            g0.edges_sorted().iter().map(|&(u, v, _)| (u, v)).collect();
        for u in &base {
            forbidden.insert((u.src, u.dst));
        }
        let mut pairs = Vec::new();
        while pairs.len() < 8 {
            let u = gen.usize_in(0, n - 1) as NodeId;
            let v = gen.usize_in(0, n - 1) as NodeId;
            if u != v && forbidden.insert((u, v)) {
                pairs.push((u, v));
            }
        }

        // weave each add strictly before its delete into one producer lane
        let mut updates = base.clone();
        for &(u, v) in &pairs {
            let i = gen.usize_in(0, updates.len());
            updates.insert(i, Update { kind: UpdateKind::Add, src: u, dst: v, weight: 3 });
            let j = gen.usize_in(i + 1, updates.len());
            updates.insert(j, Update { kind: UpdateKind::Delete, src: u, dst: v, weight: 0 });
        }

        let run = |upds: &[Update]| {
            let mut cfg = concurrent_cfg(Algo::Sssp);
            cfg.batch_capacity = gen_batch(upds.len());
            let svc = GraphService::start(g0.clone(), cfg);
            for u in upds {
                assert!(svc.submit(*u));
            }
            svc.drain();
            svc.shutdown()
        };
        let with_pairs = run(&updates);
        let without_pairs = run(&base);

        assert_eq!(
            with_pairs.graph.edges_sorted(),
            without_pairs.graph.edges_sorted(),
            "coalesced pairs must leave no trace in the graph"
        );
        for &(u, v) in &pairs {
            assert!(!with_pairs.graph.has_edge(u, v), "pair edge {u}->{v} survived");
        }
        assert_eq!(
            with_pairs.sssp().unwrap().dist,
            sssp::dijkstra_oracle(&without_pairs.graph, 0),
            "properties must match the pair-free run"
        );
    });
}

fn gen_batch(len: usize) -> usize {
    (len / 7).clamp(8, 256)
}

// ------------------------------------------------------------ sharded

/// Single-lane SSSP matrix: for shards ∈ {1, 2, 4, 8}, the sharded service's
/// end-state is *bitwise* equal to the single-engine service and to the
/// offline batch pipeline over the same batches (and all equal the
/// Dijkstra oracle).
#[test]
fn sssp_sharded_matrix_bitwise_vs_single_engine_and_offline() {
    let g0 = generators::uniform_random(300, 1500, 9, 111);
    let batch = 64;
    let raw = UpdateStream::generate_percent(&g0, 12.0, batch, 9, 113);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);

    // offline batch mode
    let engine = CpuEngine::new(1, Sched::Dynamic { chunk: 64 });
    let mut g = g0.clone();
    g.merge_period = 0;
    let mut offline = engine.sssp_static(&g, 0);
    for b in stream.batches() {
        engine.sssp_dynamic_batch(&mut g, &mut offline, &b);
    }

    // single-engine service
    let svc = GraphService::start(g0.clone(), exact_cfg(Algo::Sssp, batch));
    for u in &stream.updates {
        assert!(svc.submit(*u));
    }
    svc.drain();
    let single = svc.shutdown();
    assert_eq!(single.sssp().unwrap().dist, offline.dist);

    for shards in SHARD_MATRIX {
        let mut cfg = exact_cfg(Algo::Sssp, batch);
        cfg.engine = EngineOpts::default();
        cfg.engine_shards = shards;
        let svc = ShardedService::start(g0.clone(), cfg);
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let report = svc.shutdown();
        assert_eq!(
            report.graph.edges_sorted(),
            g.edges_sorted(),
            "shards={shards}: end graphs diverged"
        );
        let st = report.sssp().expect("sssp service");
        assert_eq!(st.dist, offline.dist, "shards={shards}: dist vs offline");
        assert_eq!(st.dist, single.sssp().unwrap().dist, "shards={shards}: dist vs single");
        assert_eq!(st.parent, offline.parent, "shards={shards}: parents vs offline");
        assert_eq!(
            st.parent,
            single.sssp().unwrap().parent,
            "shards={shards}: parents vs single"
        );
        assert_eq!(st.dist, sssp::dijkstra_oracle(&g, 0), "shards={shards}: oracle");
        if shards > 1 {
            assert!(report.relay.rounds > 0, "shards={shards}: relay never ran");
        }
    }
}

/// Multi-producer SSSP matrix: random dynamic batches fanned over 4
/// producers, shards ∈ {1, 2, 4, 8} — every configuration lands bitwise on
/// the Dijkstra oracle of the fully-updated graph (conflict-free
/// workloads make the end graph batching-independent, and the SSSP fixed
/// point is unique).
#[test]
fn sssp_sharded_matrix_multi_producer_matches_oracle() {
    let g0 = generators::uniform_random(400, 2000, 9, 121);
    let workload = stream_workload(Algo::Sssp, &g0, 10.0, 123);
    let mut want = g0.clone();
    apply_workload(&mut want, &workload, false);
    let oracle = sssp::dijkstra_oracle(&want, 0);

    for shards in SHARD_MATRIX {
        let mut cfg = concurrent_sharded_cfg(Algo::Sssp);
        cfg.engine_shards = shards;
        let (cell, report) =
            run_stream_cell(Algo::Sssp, &g0, 10.0, 4, 1, cfg, 123).unwrap();
        assert_eq!(cell.shards, shards);
        assert_eq!(cell.stats.completed, cell.stats.submitted, "shards={shards}");
        assert_eq!(
            report.graph.edges_sorted(),
            want.edges_sorted(),
            "shards={shards}: end graphs diverged"
        );
        assert_eq!(report.sssp().unwrap().dist, oracle, "shards={shards}");
    }
}

/// TC matrix: multi-producer undirected updates, shards ∈ {1, 2, 4, 8} —
/// streamed delta counting is exact (equals a full static recount of the
/// final graph) for every shard count, which also makes the counts
/// bitwise equal across the matrix.
#[test]
fn tc_sharded_matrix_counts_exactly() {
    let g0 = generators::uniform_random(80, 480, 5, 131);
    let mut counts = Vec::new();
    for shards in SHARD_MATRIX {
        let mut cfg = concurrent_sharded_cfg(Algo::Tc);
        cfg.engine_shards = shards;
        let (_, report) = run_stream_cell(Algo::Tc, &g0, 15.0, 4, 1, cfg, 133).unwrap();
        let st = report.tc().expect("tc service");
        assert_eq!(
            st.triangles,
            triangle::static_tc(&report.graph).triangles,
            "shards={shards}: streamed TC must equal a static recount"
        );
        for (u, v, _) in report.graph.edges_sorted() {
            assert!(report.graph.has_edge(v, u), "shards={shards}: asymmetric {u}->{v}");
        }
        counts.push(st.triangles);
    }
    assert!(
        counts.windows(2).all(|w| w[0] == w[1]),
        "counts diverged across the shard matrix: {counts:?}"
    );
}

/// PR matrix: shards ∈ {1, 2, 4, 8} — streamed ranks track the static
/// recompute of the final graph at the usual dynamic-PR tolerance
/// (bitwise is not expected: float sums reassociate across shards).
#[test]
fn pr_sharded_matrix_tracks_static_recompute() {
    let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 141);
    let n = g0.num_nodes();
    let workload = stream_workload(Algo::Pr, &g0, 8.0, 143);
    let mut want = g0.clone();
    apply_workload(&mut want, &workload, false);
    let mut truth = PrState::new(n, 1e-9, 0.85, 200);
    let engine = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
    engine.pr_static(&want, &mut truth);

    for shards in SHARD_MATRIX {
        let mut cfg = concurrent_sharded_cfg(Algo::Pr);
        cfg.pr_beta = 1e-9;
        cfg.pr_max_iter = 200;
        cfg.engine_shards = shards;
        let (_, report) = run_stream_cell(Algo::Pr, &g0, 8.0, 4, 1, cfg, 143).unwrap();
        assert_eq!(report.graph.edges_sorted(), want.edges_sorted(), "shards={shards}");
        let st = report.pr().expect("pr service");
        let l1: f64 = st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 0.05, "shards={shards}: PR diverged, L1={l1}");
    }
}

// ------------------------------------------------- skewed + steal/rebalance

/// [`exact_cfg`] for the sharded service with the persistent-runtime
/// knobs forced hot: the resident fleet (on by default), in-phase work
/// stealing, and a rebalance threshold low enough that hub-heavy churn
/// trips at least one live migration mid-stream.
fn skew_cfg(algo: Algo, batch: usize, shards: usize) -> ServiceConfig {
    let mut cfg = exact_cfg(algo, batch);
    cfg.engine = EngineOpts::default();
    cfg.engine_shards = shards;
    cfg.steal = true;
    cfg.rebalance = Some(1.10);
    cfg
}

/// Zipfian hub-heavy churn trimmed to whole batches (size-closed
/// batching keeps the bitwise comparisons schedule-independent). Insert
/// sources concentrate on the 16 lowest vertex ids, so the seed-time
/// `edge_balanced` boundaries go stale as shard 0 grows.
fn skewed_stream(g0: &DynGraph, total: usize, batch: usize, seed: u64) -> UpdateStream {
    let raw = UpdateStream::generate_count_skewed(g0, total, batch, 9, seed, 16);
    UpdateStream::new(trim_to_batches(raw.updates, batch), batch)
}

/// Skewed SSSP matrix (persistent runtime): hub-heavy churn with
/// stealing and rebalancing on. For every shard count the end-state is
/// still *bitwise* equal to the single-engine service and offline batch
/// mode — distances AND parents — because stolen relax buckets are
/// applied by their owner and migration republishes under the epoch
/// stitch. Every shards > 1 leg must observe at least one live
/// rebalance: the hubs all live in shard 0's contiguous range, so its
/// edge mass provably overshoots the 1.10 imbalance threshold.
#[test]
fn sssp_sharded_skewed_matrix_bitwise_with_steal_and_rebalance() {
    let g0 = generators::rmat(9, 2400, 0.57, 0.19, 0.19, 211);
    let batch = 64;
    let stream = skewed_stream(&g0, 1600, batch, 213);

    // offline batch mode
    let engine = CpuEngine::new(1, Sched::Dynamic { chunk: 64 });
    let mut g = g0.clone();
    g.merge_period = 0;
    let mut offline = engine.sssp_static(&g, 0);
    for b in stream.batches() {
        engine.sssp_dynamic_batch(&mut g, &mut offline, &b);
    }

    // single-engine service
    let svc = GraphService::start(g0.clone(), exact_cfg(Algo::Sssp, batch));
    for u in &stream.updates {
        assert!(svc.submit(*u));
    }
    svc.drain();
    let single = svc.shutdown();
    assert_eq!(single.sssp().unwrap().dist, offline.dist);

    for shards in SHARD_MATRIX {
        let svc = ShardedService::start(g0.clone(), skew_cfg(Algo::Sssp, batch, shards));
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let report = svc.shutdown();
        assert_eq!(
            report.graph.edges_sorted(),
            g.edges_sorted(),
            "shards={shards}: end graphs diverged"
        );
        let st = report.sssp().expect("sssp service");
        assert_eq!(st.dist, offline.dist, "shards={shards}: dist vs offline");
        assert_eq!(st.parent, offline.parent, "shards={shards}: parents vs offline");
        assert_eq!(st.dist, single.sssp().unwrap().dist, "shards={shards}: dist vs single");
        assert_eq!(st.dist, sssp::dijkstra_oracle(&g, 0), "shards={shards}: oracle");
        if shards > 1 {
            assert!(report.relay.rounds > 0, "shards={shards}: relay never ran");
            assert!(
                report.stats.rebalances >= 1,
                "shards={shards}: hub churn never tripped a rebalance"
            );
            assert!(
                report.stats.migrated_vertices > 0,
                "shards={shards}: rebalance migrated no rows"
            );
            assert_eq!(
                report.stats.shard_loads.len(),
                shards,
                "shards={shards}: per-shard load stats missing"
            );
        }
    }
}

/// Skewed TC matrix: hub-heavy undirected churn with stealing and
/// rebalancing on — delta counting stays exact (equals a static recount
/// of the final graph) across at least one live migration per
/// multi-shard leg, and the counts agree across the whole matrix.
#[test]
fn tc_sharded_skewed_matrix_counts_exactly_across_migration() {
    let g0 = triangle::symmetrize(&generators::rmat(8, 900, 0.57, 0.19, 0.19, 221));
    let batch = 32;
    // one arc per undirected edge (the symmetric service expands each
    // into both arcs) — a directed generator run against a symmetrized
    // base can emit both arcs of one edge, so keep only the first
    let raw = UpdateStream::generate_count_skewed(&g0, 800, batch, 9, 223, 16);
    let mut seen = std::collections::HashSet::new();
    let undirected: Vec<Update> = raw
        .updates
        .into_iter()
        .filter(|u| seen.insert((u.src.min(u.dst), u.src.max(u.dst))))
        .collect();
    let updates = trim_to_batches(undirected, batch);

    let mut counts = Vec::new();
    for shards in SHARD_MATRIX {
        let (cell, report) = run_stream_cell_workload(
            g0.clone(),
            updates.clone(),
            2,
            1,
            skew_cfg(Algo::Tc, batch, shards),
        )
        .unwrap();
        assert_eq!(cell.shards, shards);
        let st = report.tc().expect("tc service");
        assert_eq!(
            st.triangles,
            triangle::static_tc(&report.graph).triangles,
            "shards={shards}: streamed TC must equal a static recount"
        );
        for (u, v, _) in report.graph.edges_sorted() {
            assert!(report.graph.has_edge(v, u), "shards={shards}: asymmetric {u}->{v}");
        }
        if shards > 1 {
            assert!(
                cell.stats.rebalances >= 1,
                "shards={shards}: hub churn never tripped a rebalance"
            );
        }
        counts.push(st.triangles);
    }
    assert!(
        counts.windows(2).all(|w| w[0] == w[1]),
        "counts diverged across the skewed shard matrix: {counts:?}"
    );
}

/// Skewed PR matrix: hub-heavy churn with stealing and rebalancing on —
/// streamed ranks keep tracking the static recompute of the final graph
/// (usual dynamic-PR tolerance) across at least one live migration per
/// multi-shard leg.
#[test]
fn pr_sharded_skewed_matrix_tracks_recompute_across_migration() {
    let g0 = generators::rmat(8, 1200, 0.57, 0.19, 0.19, 231);
    let n = g0.num_nodes();
    let batch = 64;
    let stream = skewed_stream(&g0, 1000, batch, 233);
    let mut want = g0.clone();
    stream.apply_all_static(&mut want);
    let mut truth = PrState::new(n, 1e-9, 0.85, 200);
    let engine = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
    engine.pr_static(&want, &mut truth);

    for shards in SHARD_MATRIX {
        let mut cfg = skew_cfg(Algo::Pr, batch, shards);
        cfg.pr_beta = 1e-9;
        cfg.pr_max_iter = 200;
        let svc = ShardedService::start(g0.clone(), cfg);
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let report = svc.shutdown();
        assert_eq!(report.graph.edges_sorted(), want.edges_sorted(), "shards={shards}");
        let st = report.pr().expect("pr service");
        let l1: f64 = st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 0.05, "shards={shards}: PR diverged across migration, L1={l1}");
        if shards > 1 {
            assert!(
                report.stats.rebalances >= 1,
                "shards={shards}: hub churn never tripped a rebalance"
            );
            assert!(report.stats.migrated_vertices > 0, "shards={shards}: no rows moved");
        }
    }
}

/// Ingest-routing property (satellite): insert→delete pairs of *shard-
/// crossing* edges (source and destination owned by different engine
/// shards) are observationally no-ops through the sharded service — the
/// coalescer cancels the insert before routing, and because an edge's
/// insert and delete share a source owner, routing can never reorder the
/// delete ahead of its insert (a reorder would resurrect the edge, which
/// the end-state asserts rule out).
#[test]
fn prop_cross_shard_coalesced_pairs_are_noops() {
    forall_checks(0xC0A2, 5, |gen| {
        let n = gen.usize_in(60, 140);
        let e = gen.usize_in(n, n * 4);
        let seed = gen.rng().next_u64();
        let g0 = generators::uniform_random(n, e, 9, seed);
        let shards = *gen.choose(&[2usize, 4]);
        // the service rebuilds this partition from the same seed graph,
        // so owners computed here match the service's routing
        let pm_probe = ShardedGraph::partition(&g0, shards);
        let pct = 2.0 + gen.f64_unit() * 8.0;
        let base = UpdateStream::generate_percent(&g0, pct, 1, 9, seed ^ 0x21).updates;

        let mut forbidden: std::collections::HashSet<(NodeId, NodeId)> =
            g0.edges_sorted().iter().map(|&(u, v, _)| (u, v)).collect();
        for u in &base {
            forbidden.insert((u.src, u.dst));
        }
        // fresh edges whose endpoints live on *different* engine shards
        let mut pairs = Vec::new();
        let mut attempts = 0;
        while pairs.len() < 6 && attempts < 10_000 {
            attempts += 1;
            let u = gen.usize_in(0, n - 1) as NodeId;
            let v = gen.usize_in(0, n - 1) as NodeId;
            if u != v
                && pm_probe.owner(u) != pm_probe.owner(v)
                && forbidden.insert((u, v))
            {
                pairs.push((u, v));
            }
        }
        assert!(!pairs.is_empty(), "no cross-shard pair found");

        // weave each add strictly before its delete
        let mut updates = base.clone();
        for &(u, v) in &pairs {
            let i = gen.usize_in(0, updates.len());
            updates.insert(i, Update { kind: UpdateKind::Add, src: u, dst: v, weight: 3 });
            let j = gen.usize_in(i + 1, updates.len());
            updates.insert(j, Update { kind: UpdateKind::Delete, src: u, dst: v, weight: 0 });
        }

        let run = |upds: &[Update]| {
            let mut cfg = concurrent_sharded_cfg(Algo::Sssp);
            cfg.engine_shards = shards;
            cfg.batch_capacity = gen_batch(upds.len());
            let svc = ShardedService::start(g0.clone(), cfg);
            for u in upds {
                assert!(svc.submit(*u));
            }
            svc.drain();
            svc.shutdown()
        };
        let with_pairs = run(&updates);
        let without_pairs = run(&base);

        assert_eq!(
            with_pairs.graph.edges_sorted(),
            without_pairs.graph.edges_sorted(),
            "coalesced cross-shard pairs must leave no trace"
        );
        for &(u, v) in &pairs {
            assert!(
                !with_pairs.graph.has_edge(u, v),
                "cross-shard pair edge {u}->{v} survived (delete reordered or lost)"
            );
        }
        assert_eq!(
            with_pairs.sssp().unwrap().dist,
            without_pairs.sssp().unwrap().dist,
            "properties must match the pair-free run"
        );
    });
}

/// Epoch-stitch test (satellite): a reader thread hammering snapshots
/// while the sharded engine propagates batches never observes two shards
/// at different epochs — every published table's per-shard stamps are
/// mutually equal and equal to the table's graph epoch.
#[test]
fn sharded_reader_never_observes_mixed_epochs() {
    let g0 = generators::uniform_random(200, 1000, 9, 151);
    let n = g0.num_nodes();
    let stream = UpdateStream::generate_percent(&g0, 20.0, 64, 9, 153);
    let mut cfg = concurrent_sharded_cfg(Algo::Sssp);
    cfg.engine_shards = 4;
    cfg.batch_capacity = 16; // many small batches → many publishes
    let svc = Arc::new(ShardedService::start(g0, cfg));
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    svc.with_snapshot(|t| {
                        assert_eq!(t.shard_epochs.len(), 4, "one stamp per shard");
                        assert!(
                            t.shard_epochs.iter().all(|&e| e == t.graph_epoch),
                            "mixed epochs in stitched view: {:?} vs graph epoch {}",
                            t.shard_epochs,
                            t.graph_epoch
                        );
                        assert_eq!(t.dist.len(), n, "property arrays always complete");
                    });
                    reads += 1;
                }
                reads
            })
        })
        .collect();
    for u in &stream.updates {
        svc.submit(*u);
    }
    svc.drain();
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        assert!(r.join().unwrap() > 0, "readers made progress");
    }
    let Ok(svc) = Arc::try_unwrap(svc) else { panic!("sole owner after readers joined") };
    let report = svc.shutdown();
    assert!(report.stats.batches > 1, "stitch exercised across multiple publishes");
}

// ------------------------------------------------------------ telemetry

/// Tracing is observation-only (tentpole invariant): the sharded service
/// re-run with the span tracer wired in (and stealing hot, so the
/// steal-span call sites execute too) lands *bitwise* on the untraced
/// run's end-state — distances AND parents — while the tracer actually
/// captures per-shard BSP phase spans and exports valid Chrome-trace
/// JSON. Instrumentation is wall-clock-only, so it must never perturb a
/// fixed point.
#[test]
fn sssp_traced_sharded_run_is_bitwise_identical_to_untraced() {
    use starplat_dyn::telemetry::{chrome_trace_json, validate_json, Tracer};

    let g0 = generators::uniform_random(300, 1500, 9, 241);
    let batch = 64;
    let raw = UpdateStream::generate_percent(&g0, 12.0, batch, 9, 243);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);

    let run = |tracer: Option<Arc<Tracer>>| {
        let mut cfg = exact_cfg(Algo::Sssp, batch);
        cfg.engine = EngineOpts::default();
        cfg.engine_shards = 4;
        cfg.steal = true;
        cfg.telemetry.tracer = tracer;
        let svc = ShardedService::start(g0.clone(), cfg);
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        svc.shutdown()
    };

    let plain = run(None);
    let tracer = Tracer::new();
    let traced = run(Some(Arc::clone(&tracer)));

    assert_eq!(
        traced.graph.edges_sorted(),
        plain.graph.edges_sorted(),
        "tracing changed the end graph"
    );
    let (t, p) = (traced.sssp().unwrap(), plain.sssp().unwrap());
    assert_eq!(t.dist, p.dist, "tracing perturbed the SSSP distances");
    assert_eq!(t.parent, p.parent, "tracing perturbed the SP-tree parents");
    assert_eq!(t.dist, sssp::dijkstra_oracle(&plain.graph, 0), "oracle");

    // the tracer observed the whole pipeline: every shard track has
    // spans, and the full batch lifecycle shows up across the tracks
    let mut stages = std::collections::HashSet::new();
    let mut shard_tracks = 0;
    for trk in tracer.tracks() {
        let snap = trk.snapshot();
        if trk.name().starts_with("shard-") {
            shard_tracks += 1;
            assert!(!snap.events.is_empty(), "{}: no spans recorded", trk.name());
        }
        for ev in &snap.events {
            stages.insert(ev.stage.name());
        }
    }
    assert_eq!(shard_tracks, 4, "one span track per engine shard");
    for want in ["enqueue", "form", "seal", "compute", "scatter", "gather", "barrier", "publish"]
    {
        assert!(stages.contains(want), "stage {want} never recorded (saw {stages:?})");
    }

    // ...and the export is loadable: structurally valid JSON with
    // complete ("X") events and the per-shard thread names
    let json = chrome_trace_json(&tracer);
    validate_json(&json).expect("chrome trace export must be valid JSON");
    assert!(json.contains("\"ph\":\"X\""), "no complete events in trace");
    assert!(json.contains("shard-0") && json.contains("shard-3"), "shard tracks missing");
}

// ------------------------------------------------------------ backends

/// The non-cpu in-process backends of the serve matrix (xla has its own
/// skip-aware leg below).
const BACKEND_MATRIX: [BackendKind; 2] = [BackendKind::Serial, BackendKind::Dist];

/// Backend matrix (tentpole): `serve --backend {serial,dist}` runs the
/// full ingest → batch → snapshot pipeline and lands **bitwise** on the
/// cpu service's SSSP distances; the dist leg also matches the SP-tree
/// parents bitwise (cpu and dist share the deterministic argmin parent
/// repair — serial's parents are relaxation-order and only tree-valid).
#[test]
fn sssp_backend_matrix_bitwise_vs_cpu_service() {
    let g0 = generators::uniform_random(250, 1200, 9, 161);
    let batch = 64;
    let raw = UpdateStream::generate_percent(&g0, 12.0, batch, 9, 163);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);

    let run = |backend: BackendKind| {
        let cfg = exact_backend_cfg(Algo::Sssp, batch, backend);
        let svc = GraphService::try_start(g0.clone(), cfg)
            .unwrap_or_else(|e| panic!("{backend:?} service failed to start: {e}"));
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        svc.shutdown()
    };

    let cpu = run(BackendKind::Cpu);
    let cpu_st = cpu.sssp().expect("cpu sssp service");
    assert_eq!(cpu_st.dist, sssp::dijkstra_oracle(&cpu.graph, 0), "cpu vs oracle");

    for backend in BACKEND_MATRIX {
        let rep = run(backend);
        assert_eq!(
            rep.graph.edges_sorted(),
            cpu.graph.edges_sorted(),
            "{backend:?}: end graphs diverged from cpu"
        );
        let st = rep.sssp().expect("sssp service");
        assert_eq!(st.dist, cpu_st.dist, "{backend:?}: distances vs cpu");
        if backend == BackendKind::Dist {
            assert_eq!(st.parent, cpu_st.parent, "dist: SP-tree parents vs cpu");
            // the serving stats must carry the modeled communication the
            // offline cells report, or cross-backend latency comparisons
            // would silently drop the dist backend's dominant cost
            assert!(
                rep.stats.modeled_comm_secs > 0.0,
                "dist service must drain modeled comm into its stats"
            );
        } else {
            assert_eq!(rep.stats.modeled_comm_secs, 0.0, "{backend:?}: no comm model");
        }
        // every backend's parents must still form a valid SP tree
        for v in 0..rep.graph.num_nodes() {
            let p = st.parent[v];
            if p >= 0 {
                let w = rep
                    .graph
                    .edge_weight(p as NodeId, v as NodeId)
                    .unwrap_or_else(|| panic!("{backend:?}: parent edge {p}->{v} missing"));
                assert_eq!(st.dist[v], st.dist[p as usize] + w as i64, "{backend:?}: v={v}");
            }
        }
    }
}

/// TC backend matrix: streamed delta counting is exact on every backend,
/// so the counts are bitwise equal to the cpu service's (and to a static
/// recount of the final graph).
#[test]
fn tc_backend_matrix_counts_bitwise_vs_cpu_service() {
    let g0 = triangle::symmetrize(&generators::uniform_random(60, 360, 5, 171));
    let workload = stream_workload(Algo::Tc, &g0, 15.0, 173);

    let run = |backend: BackendKind| {
        let mut cfg = exact_backend_cfg(Algo::Tc, 8, backend);
        assert!(cfg.symmetric);
        cfg.batch_capacity = 8;
        let svc = GraphService::try_start(g0.clone(), cfg)
            .unwrap_or_else(|e| panic!("{backend:?} service failed to start: {e}"));
        for u in &workload {
            assert!(svc.submit(*u));
        }
        svc.drain();
        svc.shutdown()
    };

    let cpu = run(BackendKind::Cpu);
    let cpu_count = cpu.tc().expect("cpu tc service").triangles;
    assert_eq!(cpu_count, triangle::static_tc(&cpu.graph).triangles, "cpu vs recount");

    for backend in BACKEND_MATRIX {
        let rep = run(backend);
        assert_eq!(
            rep.graph.edges_sorted(),
            cpu.graph.edges_sorted(),
            "{backend:?}: end graphs diverged from cpu"
        );
        assert_eq!(
            rep.tc().expect("tc service").triangles,
            cpu_count,
            "{backend:?}: triangle count vs cpu"
        );
    }
}

/// PR backend matrix: every backend's streamed ranks track the static
/// recompute of the final graph at the dynamic-PR tolerance (bitwise is
/// not expected — each backend associates its float sums differently).
#[test]
fn pr_backend_matrix_oracle_equal() {
    let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 181);
    let n = g0.num_nodes();
    // 8% of ~600 edges ≈ 48 updates — batch 16 keeps whole batches after
    // trimming (batch 64 would trim the workload to nothing)
    let batch = 16;
    let raw = UpdateStream::generate_percent(&g0, 8.0, batch, 9, 183);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);

    let mut want = g0.clone();
    stream.apply_all_static(&mut want);
    let mut truth = PrState::new(n, 1e-9, 0.85, 200);
    let engine = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
    engine.pr_static(&want, &mut truth);

    for backend in [BackendKind::Cpu, BackendKind::Serial, BackendKind::Dist] {
        let mut cfg = exact_backend_cfg(Algo::Pr, batch, backend);
        cfg.pr_beta = 1e-9;
        cfg.pr_max_iter = 200;
        let svc = GraphService::try_start(g0.clone(), cfg)
            .unwrap_or_else(|e| panic!("{backend:?} service failed to start: {e}"));
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let rep = svc.shutdown();
        assert_eq!(rep.graph.edges_sorted(), want.edges_sorted(), "{backend:?}");
        let st = rep.pr().expect("pr service");
        let l1: f64 = st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 0.05, "{backend:?}: PR diverged from static recompute, L1={l1}");
    }
}

/// The xla serve leg: runs end to end when PJRT + artifacts are present
/// (`--features pjrt` + `make artifacts`), and skips cleanly — a
/// structured startup error, no panic, no half-started service — when
/// they are not (the default dependency-free build).
#[test]
fn xla_backend_service_runs_or_skips_cleanly() {
    let g0 = generators::uniform_random(150, 700, 9, 191);
    let batch = 64;
    let raw = UpdateStream::generate_percent(&g0, 10.0, batch, 9, 193);
    let stream = UpdateStream::new(trim_to_batches(raw.updates, batch), batch);
    let cfg = exact_backend_cfg(Algo::Sssp, batch, BackendKind::Xla);
    match GraphService::try_start(g0.clone(), cfg) {
        Err(e) => {
            eprintln!("skipping xla serve leg: {e}");
        }
        Ok(svc) => {
            for u in &stream.updates {
                assert!(svc.submit(*u));
            }
            svc.drain();
            let rep = svc.shutdown();
            let mut want = g0.clone();
            stream.apply_all_static(&mut want);
            assert_eq!(rep.graph.edges_sorted(), want.edges_sorted());
            assert_eq!(
                rep.sssp().expect("sssp service").dist,
                sssp::dijkstra_oracle(&want, 0),
                "xla-served distances vs oracle"
            );
        }
    }
}

/// Knob plumbing (satellite): a cpu-only knob on a non-cpu serve backend
/// is a *startup error* naming the flag — never silently dropped — and
/// the sharded service rejects both non-cpu backends and engine knobs.
#[test]
fn backend_service_rejects_mismatched_knobs() {
    let g0 = generators::uniform_random(50, 200, 9, 195);

    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.backend = BackendKind::Dist;
    cfg.engine.direction = Some(Direction::Pull);
    let err = GraphService::try_start(g0.clone(), cfg)
        .err()
        .expect("dist + --direction must fail")
        .to_string();
    assert!(err.contains("--direction") && err.contains("dist"), "{err}");

    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.backend = BackendKind::Dist;
    cfg.engine_shards = 2;
    let err = ShardedService::try_start(g0.clone(), cfg)
        .err()
        .expect("sharded + non-cpu backend must fail")
        .to_string();
    assert!(err.contains("sharded") && err.contains("dist"), "{err}");

    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.engine.threads = Some(2);
    cfg.engine_shards = 2;
    let err = ShardedService::try_start(g0, cfg)
        .err()
        .expect("sharded + engine knobs must fail")
        .to_string();
    assert!(err.contains("--threads"), "{err}");
}
