//! Cross-module integration tests: the full equivalence matrix
//! (DSL interpreter ≡ serial oracle ≡ cpu ≡ dist ≡ xla), protocol-level
//! invariants, and failure injection on the DSL front-end.

use starplat_dyn::algorithms::{pagerank, sssp, triangle, PrState};
use starplat_dyn::backend::cpu::{CpuEngine, Direction};
use starplat_dyn::backend::dist::DistEngine;
use starplat_dyn::backend::xla::XlaEngine;
use starplat_dyn::coordinator::{run_cell, Algo};
use starplat_dyn::dsl::interp::{Interp, Value};
use starplat_dyn::dsl::{analyze, parse_program};
use starplat_dyn::graph::{generators, Partition, UpdateStream};
use starplat_dyn::util::propcheck::forall_checks;
use starplat_dyn::util::threadpool::Sched;

/// Every execution path must produce the same SSSP distances after the
/// same dynamic update stream.
#[test]
fn equivalence_matrix_dynamic_sssp() {
    let g0 = generators::rmat(8, 1400, 0.57, 0.19, 0.19, 404);
    let stream = UpdateStream::generate_percent(&g0, 8.0, 64, 9, 405);

    // ground truth
    let mut gt = g0.clone();
    stream.apply_all_static(&mut gt);
    let want = sssp::dijkstra_oracle(&gt, 0);

    // serial oracle
    let mut g = g0.clone();
    let mut st = sssp::static_sssp(&g, 0);
    for b in stream.batches() {
        sssp::dynamic_batch(&mut g, &mut st, &b);
    }
    assert_eq!(st.dist, want, "serial");

    // cpu engine (several configs)
    for threads in [1usize, 4] {
        let e = CpuEngine::new(threads, Sched::Dynamic { chunk: 64 });
        let mut g = g0.clone();
        let mut st = e.sssp_static(&g, 0);
        for b in stream.batches() {
            e.sssp_dynamic_batch(&mut g, &mut st, &b);
        }
        assert_eq!(st.dist, want, "cpu x{threads}");
    }

    // direction-forced + partition-affine cpu engines join the matrix:
    // push-only, pull-only, and adaptive must all be bitwise identical
    for (dir, sched) in [
        (Direction::Push, Sched::Partitioned),
        (Direction::Pull, Sched::Partitioned),
        (Direction::Adaptive { alpha: 0.05, beta: 0.01 }, Sched::Static),
    ] {
        let e = CpuEngine::new(4, sched).with_direction(dir);
        let mut g = g0.clone();
        let mut st = e.sssp_static(&g, 0);
        for b in stream.batches() {
            e.sssp_dynamic_batch(&mut g, &mut st, &b);
        }
        assert_eq!(st.dist, want, "cpu {dir:?}/{sched:?}");
    }

    // dist engine
    for ranks in [2usize, 8] {
        let e = DistEngine::new(ranks, Partition::Block);
        let mut g = g0.clone();
        let mut st = e.sssp_static(&g, 0);
        for b in stream.batches() {
            e.sssp_dynamic_batch(&mut g, &mut st, &b);
        }
        assert_eq!(st.dist, want, "dist x{ranks}");
    }

    // xla engine (PJRT) — needs the `pjrt` feature + `make artifacts`;
    // skipped (not failed) when either is absent.
    match XlaEngine::new() {
        Ok(e) => {
            let mut g = g0.clone();
            let mut st = e.sssp_static(&g, 0).unwrap();
            for b in stream.batches() {
                e.sssp_dynamic_batch(&mut g, &mut st, &b).unwrap();
            }
            assert_eq!(st.dist, want, "xla");
        }
        Err(e) => eprintln!("skipping xla leg: {e}"),
    }

    // DSL interpreter executing the shipped program
    let program =
        parse_program(&std::fs::read_to_string("dsl/sssp_dynamic.sp").unwrap()).unwrap();
    analyze(&program).unwrap();
    let mut interp = Interp::new(&program, g0.clone());
    let (_, props) = interp
        .run_dynamic(
            "DynSSSP",
            stream.clone(),
            &[("batchSize", Value::Int(64)), ("src", Value::Int(0))],
        )
        .unwrap();
    let dist: Vec<i64> = props["dist"]
        .iter()
        .map(|v| match v {
            Value::Int(i) => *i,
            other => panic!("{other:?}"),
        })
        .collect();
    assert_eq!(dist, want, "DSL interpreter");
}

/// The coordinator's measured cells must be self-consistent: same seeds
/// → same workloads, and all backends accept the same protocol.
#[test]
fn coordinator_runs_full_backend_matrix() {
    let g = generators::uniform_random(300, 1800, 9, 406);
    use starplat_dyn::backend::BackendKind::*;
    let xla_available = XlaEngine::new().is_ok();
    for backend in [Serial, Cpu, Dist, Xla] {
        if backend == Xla && !xla_available {
            eprintln!("skipping xla column of the backend matrix (pjrt unavailable)");
            continue;
        }
        for algo in [Algo::Sssp, Algo::Pr, Algo::Tc] {
            let cell = run_cell(algo, backend, &g, 4.0, usize::MAX / 2, 407)
                .unwrap_or_else(|e| panic!("{algo:?}/{backend:?}: {e}"));
            assert!(cell.static_secs > 0.0, "{algo:?}/{backend:?} static never ran");
            assert!(cell.dynamic_secs >= 0.0);
        }
    }
}

/// Dynamic PR on every backend must stay L1-close to a cold recompute.
#[test]
fn pr_dynamic_closeness_across_backends() {
    let g0 = generators::rmat(7, 700, 0.5, 0.2, 0.2, 408);
    let n = g0.num_nodes();
    let stream = UpdateStream::generate_percent(&g0, 4.0, usize::MAX / 2, 9, 409);
    let mut gt = g0.clone();
    stream.apply_all_static(&mut gt);
    let mut truth = PrState::new(n, 1e-10, 0.85, 300);
    pagerank::static_pagerank(&gt, &mut truth);

    // serial dynamic
    let mut g = g0.clone();
    let mut st = PrState::new(n, 1e-9, 0.85, 100);
    pagerank::static_pagerank(&g, &mut st);
    for b in stream.batches() {
        pagerank::dynamic_batch(&mut g, &mut st, &b);
    }
    let l1: f64 = st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 0.05, "serial dynamic PR drift {l1}");

    // xla dynamic (warm start on updated matrix converges to the truth);
    // skipped when the pjrt feature / artifacts are absent.
    match XlaEngine::new() {
        Ok(e) => {
            let mut g = g0.clone();
            let mut st = PrState::new(n, 1e-6, 0.85, 200);
            e.pr_static(&g, &mut st).unwrap();
            for b in stream.batches() {
                e.pr_dynamic_batch(&mut g, &mut st, &b).unwrap();
            }
            let l1: f64 =
                st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
            assert!(l1 < 0.01, "xla dynamic PR drift {l1}");
        }
        Err(e) => eprintln!("skipping xla dynamic PR leg: {e}"),
    }
}

/// Failure injection: malformed DSL programs must fail cleanly (parse or
/// sema), never panic.
#[test]
fn dsl_failure_injection() {
    let cases: &[(&str, &str)] = &[
        ("unterminated block", "Static f(Graph g) { int x = 1;"),
        ("batch in static", "Static f(Graph g, updates<g> u) { Batch(u:4) { } }"),
        ("unknown call", "Static f(Graph g) { ghost(g); }"),
        ("bad type", "Static f(Widget w) { }"),
        ("assign to literal", "Static f(Graph g) { 5 = 6; }"),
        ("bad fixedpoint", "Static f(Graph g) { fixedPoint while (x : !m) { } }"),
        ("stray char", "Static f(Graph g) { int x = $; }"),
        ("bad min arity", "Static f(Graph g) { <a, b> = <Min(1, 2), 3, 4>; }"),
    ];
    for (what, src) in cases {
        let failed = match parse_program(src) {
            Err(_) => true,
            Ok(p) => analyze(&p).is_err(),
        };
        assert!(failed, "{what}: should have been rejected:\n{src}");
    }
}

/// Interpreter failure injection: semantically broken programs error out
/// with context instead of corrupting state.
#[test]
fn interp_runtime_failure_injection() {
    let g = generators::uniform_random(10, 30, 5, 410);
    // infinite fixedPoint must hit the sweep guard
    let src = r#"
    Dynamic f(Graph g, updates<g> u, int batchSize) {
      propNode<bool> modified;
      g.attachNodeProperty(modified = True);
      bool fin = False;
      fixedPoint until (fin : !modified) {
        int x = 0;
      }
    }"#;
    let p = parse_program(src).unwrap();
    let mut i = Interp::new(&p, g.clone());
    let err = i
        .run_dynamic("f", UpdateStream::new(vec![], 1), &[("batchSize", Value::Int(1))])
        .unwrap_err();
    assert!(err.to_string().contains("sweeps"), "guard fired: {err}");
}

/// Protocol invariant: TC delta counting is exact under randomized
/// symmetric churn across all engines.
#[test]
fn prop_tc_exact_across_engines() {
    forall_checks(0x7C1, 10, |gen| {
        let n = gen.usize_in(10, 50);
        let seed = gen.rng().next_u64();
        let g0 = triangle::symmetrize(&generators::uniform_random(n, n * 3, 5, seed));
        let (dels, adds) = triangle::symmetric_updates(&g0, 10.0, 6, seed ^ 3);

        let mut g1 = g0.clone();
        let mut st1 = triangle::static_tc(&g1);
        let e = CpuEngine::new(2, Sched::Static);
        let mut g2 = g0.clone();
        let mut st2 = e.tc_static(&g2);
        for (d, a) in dels.iter().zip(&adds) {
            triangle::dynamic_batch(&mut g1, &mut st1, d, a);
            e.tc_dynamic_batch(&mut g2, &mut st2, d, a);
        }
        let truth = triangle::static_tc(&g1).triangles;
        assert_eq!(st1.triangles, truth);
        assert_eq!(st2.triangles, truth);
        assert_eq!(g1.edges_sorted(), g2.edges_sorted());
    });
}

/// Update streams must respect the requested percent and composition.
#[test]
fn prop_update_stream_protocol() {
    forall_checks(0x0E0, 20, |gen| {
        let n = gen.usize_in(20, 100);
        let g = generators::uniform_random(n, n * 4, 9, gen.rng().next_u64());
        let pct = gen.f64_unit() * 15.0 + 0.5;
        let s = UpdateStream::generate_percent(&g, pct, usize::MAX / 2, 9, 3);
        let want = ((g.num_edges() as f64) * pct / 100.0).round() as usize;
        assert_eq!(s.len(), want);
        assert_eq!(s.num_batches(), if want == 0 { 0 } else { 1 }, "single-batch protocol");
    });
}
