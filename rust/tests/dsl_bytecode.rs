//! Golden parity for the DSL → bytecode path: every shipped `dsl/*.sp`
//! program is compiled (`dsl::lower::compile`), executed through
//! `DynamicEngine::run_program` on the serial *and* cpu backends, and
//! checked against the hand-written kernels / oracles the interpreter
//! tests pin. Connected components has no hand-written kernel at all —
//! its oracle is a union-find over the final edge list — which is the
//! end-to-end proof that a new algorithm ships from a `.sp` file with
//! zero per-backend Rust.
//!
//! `negative_*` tests pin the typed-error surface: compile-time spans,
//! verifier rejections, unsupported backends, and the service-level
//! gating of `serve --program` (WAL, sharding, double shutdown).

use starplat_dyn::algorithms::{bfs, pagerank, sssp, triangle};
use starplat_dyn::backend::{make_engine, BackendKind, DynamicEngine, EngineOpts};
use starplat_dyn::coordinator::Algo;
use starplat_dyn::dsl::bytecode::{self, Phase, ProgState, Program, ScalarVal};
use starplat_dyn::dsl::lower;
use starplat_dyn::graph::{generators, DynGraph, NodeId, UpdateStream};
use starplat_dyn::stream::{GraphService, ProgramConfig, ServiceConfig, ShardedService, ShutdownError};
use std::sync::Arc;

fn compile_file(path: &str) -> Program {
    let src = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
    lower::compile(&src, None).unwrap_or_else(|e| panic!("{path}: {e}"))
}

fn engine(kind: BackendKind) -> Box<dyn DynamicEngine> {
    make_engine(kind, &EngineOpts::default()).unwrap()
}

/// The `run --program` protocol: Init on the starting graph, then the
/// batch segment once per update batch. Returns the final (graph, state).
fn run_prog(
    e: &dyn DynamicEngine,
    prog: &Program,
    g0: &DynGraph,
    stream: &UpdateStream,
    args: &[(String, ScalarVal)],
) -> (DynGraph, ProgState) {
    let mut g = g0.clone();
    let mut st = ProgState::new(prog, g.num_nodes(), args).unwrap();
    e.run_program(prog, Phase::Init, &mut g, &mut st).unwrap();
    let mut dels = Vec::new();
    let mut adds = Vec::new();
    for b in stream.batches() {
        b.split_into(&mut dels, &mut adds);
        e.run_program(prog, Phase::Batch { dels: &dels, adds: &adds }, &mut g, &mut st)
            .unwrap();
    }
    (g, st)
}

fn args(list: &[(&str, ScalarVal)]) -> Vec<(String, ScalarVal)> {
    list.iter().map(|(n, v)| (n.to_string(), *v)).collect()
}

/// Parents must form a valid shortest-path tree: `parent[v] = -1` iff
/// `v` is the source or unreached; otherwise the tree edge exists and is
/// tight (`dist[v] = dist[parent] + w`). Tie-breaks may differ between
/// implementations, so validity — not identity — is the invariant.
fn assert_valid_sp_tree(g: &DynGraph, dist: &[i64], parent: &[i64], src: NodeId) {
    const INF: i64 = i64::MAX / 4;
    for v in 0..g.num_nodes() {
        let p = parent[v];
        if v as NodeId == src || dist[v] >= INF {
            assert_eq!(p, -1, "node {v}: source/unreached must have parent -1");
            continue;
        }
        assert!(p >= 0, "node {v}: reached non-source must have a parent");
        let w = g
            .out_neighbors(p as NodeId)
            .find(|&(nbr, _)| nbr == v as NodeId)
            .map(|(_, w)| w)
            .unwrap_or_else(|| panic!("node {v}: tree edge {p}->{v} not in graph"));
        assert_eq!(
            dist[v],
            dist[p as usize] + w as i64,
            "node {v}: tree edge {p}->{v} is not tight"
        );
    }
}

#[test]
fn bytecode_sssp_matches_oracle_and_cpu_is_bitwise_equal_to_serial() {
    let prog = compile_file("dsl/sssp_dynamic.sp");
    let g0 = generators::uniform_random(60, 260, 9, 91);
    let stream = UpdateStream::generate_percent(&g0, 12.0, 8, 9, 92);
    let a = args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))]);

    let (gs, st_serial) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let (gc, st_cpu) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);

    // ground truth: dijkstra on the fully-updated graph
    let mut g2 = g0.clone();
    stream.apply_all_static(&mut g2);
    let want = sssp::dijkstra_oracle(&g2, 0);
    let dist = st_serial.prop_i64(&prog, "dist").unwrap();
    assert_eq!(dist, want, "bytecode DynSSSP != dijkstra oracle");
    assert_eq!(gs.edges_sorted(), g2.edges_sorted(), "updateCSR drifted from static apply");

    // the cpu engine's slot-deterministic Par fold must be bitwise equal
    assert_eq!(dist, st_cpu.prop_i64(&prog, "dist").unwrap(), "serial != cpu dist");
    let parent = st_serial.prop_i64(&prog, "parent").unwrap();
    assert_eq!(parent, st_cpu.prop_i64(&prog, "parent").unwrap(), "serial != cpu parent");
    assert_valid_sp_tree(&gc, &dist, &parent, 0);

    // the same stream through the hand-written cpu kernel lands on the
    // same distances (its parents may tie-break differently)
    let ke = engine(BackendKind::Cpu);
    let mut gk = g0.clone();
    let mut kst = ke.sssp_static(&gk, 0).unwrap();
    for b in stream.batches() {
        ke.sssp_dynamic_batch(&mut gk, &mut kst, &b).unwrap();
    }
    assert_eq!(dist, kst.dist, "bytecode != hand-written cpu kernel dist");
    assert_valid_sp_tree(&gk, &kst.dist, &kst.parent, 0);
}

#[test]
fn bytecode_static_sssp_on_grid_matches_dijkstra() {
    let prog = compile_file("dsl/sssp_dynamic.sp");
    let g0 = generators::road_grid(7, 7, 9, 93);
    let stream = UpdateStream::new(vec![], 8); // no updates: Init only
    let a = args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(3))]);
    let (g, st) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let dist = st.prop_i64(&prog, "dist").unwrap();
    assert_eq!(dist, sssp::dijkstra_oracle(&g0, 3));
    assert_valid_sp_tree(&g, &dist, &st.prop_i64(&prog, "parent").unwrap(), 3);
}

#[test]
fn bytecode_pagerank_tracks_reference_pipeline() {
    let prog = compile_file("dsl/pagerank_dynamic.sp");
    let g0 = generators::rmat(6, 220, 0.5, 0.2, 0.2, 94);
    let n = g0.num_nodes();
    let stream = UpdateStream::generate_percent(&g0, 6.0, 16, 9, 95);
    let a = args(&[
        ("beta", ScalarVal::F(1e-9)),
        ("delta", ScalarVal::F(0.85)),
        ("maxIter", ScalarVal::I(100)),
        ("batchSize", ScalarVal::I(16)),
    ]);

    let (_, st_serial) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let (_, st_cpu) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);
    let got = st_serial.prop_f64(&prog, "pageRank").unwrap();
    assert_eq!(
        got,
        st_cpu.prop_f64(&prog, "pageRank").unwrap(),
        "serial != cpu pageRank (slot fold must be deterministic)"
    );

    let mut g = g0.clone();
    let mut st = pagerank::PrState::new(n, 1e-9, 0.85, 100);
    pagerank::static_pagerank(&g, &mut st);
    for b in stream.batches() {
        pagerank::dynamic_batch(&mut g, &mut st, &b);
    }
    let l1: f64 = got.iter().zip(&st.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-6, "bytecode PR drifted from reference pipeline: l1={l1}");
}

#[test]
fn bytecode_tc_matches_recount_on_updated_graph() {
    use starplat_dyn::graph::{Update, UpdateKind};
    let prog = compile_file("dsl/tc_dynamic.sp");
    let g0 = triangle::symmetrize(&generators::uniform_random(30, 160, 5, 96));
    let (dels, adds) = triangle::symmetric_updates(&g0, 14.0, 4, 97);
    let mut upd = Vec::new();
    for (db, ab) in dels.iter().zip(&adds) {
        for &(u, v) in db {
            upd.push(Update { kind: UpdateKind::Delete, src: u, dst: v, weight: 1 });
        }
        for &(u, v, w) in ab {
            upd.push(Update { kind: UpdateKind::Add, src: u, dst: v, weight: w });
        }
    }
    let total = upd.len().max(1);
    let stream = UpdateStream::new(upd, total);
    let a = args(&[("batchSize", ScalarVal::I(total as i64))]);
    let (g, st) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);
    let got = match st.result(&prog) {
        Some(ScalarVal::I(t)) => t,
        other => panic!("DynTC must return an int triangle count, got {other:?}"),
    };
    assert_eq!(got, triangle::static_tc(&g).triangles, "delta TC != recount");
}

#[test]
fn bytecode_bfs_matches_hand_written() {
    let prog = compile_file("dsl/bfs_dynamic.sp");
    let g0 = generators::uniform_random(50, 180, 3, 99);
    let stream = UpdateStream::generate_percent(&g0, 10.0, 8, 3, 100);
    let a = args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))]);
    let (_, st) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let mut g2 = g0.clone();
    stream.apply_all_static(&mut g2);
    let want = bfs::static_bfs(&g2, 0);
    assert_eq!(st.prop_i64(&prog, "level").unwrap(), want.level, "bytecode BFS != kernel");
}

// ---------------------------------------------------------- connected
// components: the algorithm with no hand-written kernel anywhere in the
// crate. Oracle: union-find over the final edge list, labeling each
// component with its minimum vertex id.

fn cc_oracle(g: &DynGraph) -> Vec<i64> {
    let n = g.num_nodes();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut [usize], mut x: usize) -> usize {
        while p[x] != x {
            p[x] = p[p[x]];
            x = p[x];
        }
        x
    }
    for (u, v, _) in g.edges_sorted() {
        let (ru, rv) = (find(&mut parent, u as usize), find(&mut parent, v as usize));
        if ru != rv {
            parent[ru.max(rv)] = ru.min(rv);
        }
    }
    let mut label = vec![i64::MAX; n];
    for v in 0..n {
        let r = find(&mut parent, v);
        label[r] = label[r].min(v as i64);
    }
    (0..n).map(|v| label[find(&mut parent, v)]).collect()
}

#[test]
fn bytecode_cc_matches_union_find_oracle() {
    let prog = compile_file("dsl/cc_dynamic.sp");
    let g0 = generators::uniform_random(80, 320, 5, 101);
    // mixed stream: deletion batches exercise the full-recompute branch,
    // add-only batches the monotone re-flood
    let stream = UpdateStream::generate_percent(&g0, 10.0, 16, 9, 102);
    let a = args(&[("batchSize", ScalarVal::I(16))]);

    let (gs, st_serial) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let (_, st_cpu) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);

    let mut g2 = g0.clone();
    stream.apply_all_static(&mut g2);
    assert_eq!(gs.edges_sorted(), g2.edges_sorted());
    let comp = st_serial.prop_i64(&prog, "comp").unwrap();
    assert_eq!(comp, cc_oracle(&g2), "bytecode DynCC != union-find oracle");
    assert_eq!(comp, st_cpu.prop_i64(&prog, "comp").unwrap(), "serial != cpu comp");
}

/// The `serve --program` path end-to-end: a [`GraphService`] seeded with
/// the compiled CC program ingests live updates, publishes `comp` through
/// the snapshot cell, and reports the final program state on shutdown —
/// all without a single CC-specific line of backend Rust.
#[test]
fn cc_program_serves_end_to_end() {
    let prog = Arc::new(compile_file("dsl/cc_dynamic.sp"));
    let g0 = generators::uniform_random(120, 500, 5, 103);
    let workload = UpdateStream::generate_percent(&g0, 8.0, 1, 9, 104).updates;

    for backend in [BackendKind::Serial, BackendKind::Cpu] {
        let mut cfg = ServiceConfig::new(Algo::Sssp); // algo is ignored with a program
        cfg.backend = backend;
        cfg.batch_capacity = 64;
        cfg.batch_deadline = std::time::Duration::from_millis(2);
        cfg.program = Some(ProgramConfig {
            prog: Arc::clone(&prog),
            args: args(&[("batchSize", ScalarVal::I(64))]),
        });
        let svc = GraphService::try_start(g0.clone(), cfg).unwrap();
        for u in workload.iter().copied() {
            svc.submit(u);
        }
        svc.drain();
        let published = svc.with_snapshot(|t| {
            t.prog_ints
                .iter()
                .find(|(name, _)| name.as_str() == "comp")
                .map(|(_, v)| v.clone())
        });
        let report = svc.try_shutdown().unwrap();
        let st = report.program().expect("program service reports program state");
        let comp = st.prop_i64(&prog, "comp").unwrap();
        assert_eq!(comp, cc_oracle(&report.graph), "served CC != oracle ({backend:?})");
        let published = published.unwrap_or_else(|| {
            panic!("snapshot must publish the comp property ({backend:?})")
        });
        // the snapshot was taken after the last applied batch == final state
        assert_eq!(published, comp, "published snapshot != final state ({backend:?})");
    }
}

// ------------------------------------------------------------ negative
// paths: typed errors with spans, capability gating, service gating.

#[test]
fn negative_undefined_property_error_carries_span() {
    let src = "Dynamic f(Graph g, updates<g> u, int batchSize) {\n  Batch(u : batchSize) {\n    forall (v in g.nodes()) { v.ghost = 1; }\n  }\n}";
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(err.contains("ghost"), "names the property: {err}");
    assert!(err.contains("line 3:"), "carries the source line: {err}");
}

#[test]
fn negative_hook_outside_batch_is_rejected_with_span() {
    let src = "Dynamic f(Graph g, updates<g> u, int batchSize) {\n  OnAdd (x in u.currentBatch(1)) { int q = 0; }\n}";
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(
        err.contains("inside a Batch"),
        "hook placement must be a sema error: {err}"
    );
    assert!(err.contains("line 2:"), "carries the source line: {err}");
}

#[test]
fn negative_verifier_rejects_corrupted_program() {
    let mut prog = compile_file("dsl/cc_dynamic.sp");
    prog.init.push(bytecode::Instr::Jump { target: 999_999 });
    let err = bytecode::verify(&prog).unwrap_err().to_string();
    assert!(err.contains("jump target"), "unexpected verifier message: {err}");
}

#[test]
fn negative_dist_backend_rejects_programs() {
    let prog = compile_file("dsl/cc_dynamic.sp");
    let e = engine(BackendKind::Dist);
    assert!(!e.capabilities().supports_programs);
    let mut g = generators::uniform_random(10, 40, 5, 105);
    let mut st =
        ProgState::new(&prog, g.num_nodes(), &args(&[("batchSize", ScalarVal::I(4))])).unwrap();
    let err = e.run_program(&prog, Phase::Init, &mut g, &mut st).unwrap_err().to_string();
    assert!(
        err.contains("does not support DSL bytecode programs"),
        "unexpected: {err}"
    );
}

#[test]
fn negative_serve_program_rejects_wal() {
    let prog = Arc::new(compile_file("dsl/cc_dynamic.sp"));
    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.program =
        Some(ProgramConfig { prog, args: args(&[("batchSize", ScalarVal::I(8))]) });
    cfg.durability.wal_dir = Some(std::env::temp_dir().join("starplat-prog-wal-negative"));
    let g = generators::uniform_random(10, 40, 5, 106);
    let err = GraphService::try_start(g, cfg).unwrap_err().to_string();
    assert!(err.contains("--wal"), "program+wal must be rejected up front: {err}");
}

#[test]
fn negative_sharded_service_rejects_programs() {
    let prog = Arc::new(compile_file("dsl/cc_dynamic.sp"));
    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.engine_shards = 2;
    cfg.program =
        Some(ProgramConfig { prog, args: args(&[("batchSize", ScalarVal::I(8))]) });
    let g = generators::uniform_random(40, 160, 5, 107);
    let err = ShardedService::try_start(g, cfg).unwrap_err().to_string();
    assert!(
        err.contains("single-engine"),
        "sharded+program must be rejected up front: {err}"
    );
}

#[test]
fn negative_second_shutdown_is_typed_not_a_panic() {
    let g = generators::uniform_random(30, 120, 5, 108);
    let svc = GraphService::try_start(g, ServiceConfig::new(Algo::Sssp)).unwrap();
    svc.drain();
    svc.try_shutdown().expect("healthy first shutdown succeeds");
    assert!(
        matches!(svc.try_shutdown(), Err(ShutdownError::AlreadyShutDown)),
        "second shutdown must be AlreadyShutDown"
    );
}
