//! Golden parity for the DSL → bytecode path: every shipped `dsl/*.sp`
//! program is compiled (`dsl::lower::compile`), executed through
//! `DynamicEngine::run_program` on the serial *and* cpu backends, and
//! checked against the hand-written kernels / oracles the interpreter
//! tests pin. Connected components has no hand-written kernel at all —
//! its oracle is a union-find over the final edge list — which is the
//! end-to-end proof that a new algorithm ships from a `.sp` file with
//! zero per-backend Rust.
//!
//! `negative_*` tests pin the typed-error surface: compile-time spans,
//! verifier rejections, unsupported backends, and the service-level
//! gating of `serve --program` (WAL, sharding, double shutdown).

use starplat_dyn::algorithms::{bfs, pagerank, sssp, triangle};
use starplat_dyn::backend::{make_engine, BackendKind, DynamicEngine, EngineOpts};
use starplat_dyn::coordinator::Algo;
use starplat_dyn::dsl::bytecode::{self, Phase, ProgState, Program, ScalarVal};
use starplat_dyn::dsl::lower;
use starplat_dyn::graph::{generators, DynGraph, NodeId, UpdateStream};
use starplat_dyn::stream::{GraphService, ProgramConfig, ServiceConfig, ShardedService, ShutdownError};
use std::sync::Arc;

fn compile_file(path: &str) -> Program {
    let src = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
    lower::compile(&src, None).unwrap_or_else(|e| panic!("{path}: {e}"))
}

fn engine(kind: BackendKind) -> Box<dyn DynamicEngine> {
    make_engine(kind, &EngineOpts::default()).unwrap()
}

/// The `run --program` protocol: Init on the starting graph, then the
/// batch segment once per update batch. Returns the final (graph, state).
fn run_prog(
    e: &dyn DynamicEngine,
    prog: &Program,
    g0: &DynGraph,
    stream: &UpdateStream,
    args: &[(String, ScalarVal)],
) -> (DynGraph, ProgState) {
    let mut g = g0.clone();
    let mut st = ProgState::new(prog, g.num_nodes(), args).unwrap();
    e.run_program(prog, Phase::Init, &mut g, &mut st).unwrap();
    let mut dels = Vec::new();
    let mut adds = Vec::new();
    for b in stream.batches() {
        b.split_into(&mut dels, &mut adds);
        e.run_program(prog, Phase::Batch { dels: &dels, adds: &adds }, &mut g, &mut st)
            .unwrap();
    }
    (g, st)
}

fn args(list: &[(&str, ScalarVal)]) -> Vec<(String, ScalarVal)> {
    list.iter().map(|(n, v)| (n.to_string(), *v)).collect()
}

/// Parents must form a valid shortest-path tree: `parent[v] = -1` iff
/// `v` is the source or unreached; otherwise the tree edge exists and is
/// tight (`dist[v] = dist[parent] + w`). Tie-breaks may differ between
/// implementations, so validity — not identity — is the invariant.
fn assert_valid_sp_tree(g: &DynGraph, dist: &[i64], parent: &[i64], src: NodeId) {
    const INF: i64 = i64::MAX / 4;
    for v in 0..g.num_nodes() {
        let p = parent[v];
        if v as NodeId == src || dist[v] >= INF {
            assert_eq!(p, -1, "node {v}: source/unreached must have parent -1");
            continue;
        }
        assert!(p >= 0, "node {v}: reached non-source must have a parent");
        let w = g
            .out_neighbors(p as NodeId)
            .find(|&(nbr, _)| nbr == v as NodeId)
            .map(|(_, w)| w)
            .unwrap_or_else(|| panic!("node {v}: tree edge {p}->{v} not in graph"));
        assert_eq!(
            dist[v],
            dist[p as usize] + w as i64,
            "node {v}: tree edge {p}->{v} is not tight"
        );
    }
}

#[test]
fn bytecode_sssp_matches_oracle_and_cpu_is_bitwise_equal_to_serial() {
    let prog = compile_file("dsl/sssp_dynamic.sp");
    let g0 = generators::uniform_random(60, 260, 9, 91);
    let stream = UpdateStream::generate_percent(&g0, 12.0, 8, 9, 92);
    let a = args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))]);

    let (gs, st_serial) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let (gc, st_cpu) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);

    // ground truth: dijkstra on the fully-updated graph
    let mut g2 = g0.clone();
    stream.apply_all_static(&mut g2);
    let want = sssp::dijkstra_oracle(&g2, 0);
    let dist = st_serial.prop_i64(&prog, "dist").unwrap();
    assert_eq!(dist, want, "bytecode DynSSSP != dijkstra oracle");
    assert_eq!(gs.edges_sorted(), g2.edges_sorted(), "updateCSR drifted from static apply");

    // the cpu engine's slot-deterministic Par fold must be bitwise equal
    assert_eq!(dist, st_cpu.prop_i64(&prog, "dist").unwrap(), "serial != cpu dist");
    let parent = st_serial.prop_i64(&prog, "parent").unwrap();
    assert_eq!(parent, st_cpu.prop_i64(&prog, "parent").unwrap(), "serial != cpu parent");
    assert_valid_sp_tree(&gc, &dist, &parent, 0);

    // the same stream through the hand-written cpu kernel lands on the
    // same distances (its parents may tie-break differently)
    let ke = engine(BackendKind::Cpu);
    let mut gk = g0.clone();
    let mut kst = ke.sssp_static(&gk, 0).unwrap();
    for b in stream.batches() {
        ke.sssp_dynamic_batch(&mut gk, &mut kst, &b).unwrap();
    }
    assert_eq!(dist, kst.dist, "bytecode != hand-written cpu kernel dist");
    assert_valid_sp_tree(&gk, &kst.dist, &kst.parent, 0);
}

#[test]
fn bytecode_static_sssp_on_grid_matches_dijkstra() {
    let prog = compile_file("dsl/sssp_dynamic.sp");
    let g0 = generators::road_grid(7, 7, 9, 93);
    let stream = UpdateStream::new(vec![], 8); // no updates: Init only
    let a = args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(3))]);
    let (g, st) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let dist = st.prop_i64(&prog, "dist").unwrap();
    assert_eq!(dist, sssp::dijkstra_oracle(&g0, 3));
    assert_valid_sp_tree(&g, &dist, &st.prop_i64(&prog, "parent").unwrap(), 3);
}

#[test]
fn bytecode_pagerank_tracks_reference_pipeline() {
    let prog = compile_file("dsl/pagerank_dynamic.sp");
    let g0 = generators::rmat(6, 220, 0.5, 0.2, 0.2, 94);
    let n = g0.num_nodes();
    let stream = UpdateStream::generate_percent(&g0, 6.0, 16, 9, 95);
    let a = args(&[
        ("beta", ScalarVal::F(1e-9)),
        ("delta", ScalarVal::F(0.85)),
        ("maxIter", ScalarVal::I(100)),
        ("batchSize", ScalarVal::I(16)),
    ]);

    let (_, st_serial) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let (_, st_cpu) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);
    let got = st_serial.prop_f64(&prog, "pageRank").unwrap();
    assert_eq!(
        got,
        st_cpu.prop_f64(&prog, "pageRank").unwrap(),
        "serial != cpu pageRank (slot fold must be deterministic)"
    );

    let mut g = g0.clone();
    let mut st = pagerank::PrState::new(n, 1e-9, 0.85, 100);
    pagerank::static_pagerank(&g, &mut st);
    for b in stream.batches() {
        pagerank::dynamic_batch(&mut g, &mut st, &b);
    }
    let l1: f64 = got.iter().zip(&st.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-6, "bytecode PR drifted from reference pipeline: l1={l1}");
}

#[test]
fn bytecode_tc_matches_recount_on_updated_graph() {
    use starplat_dyn::graph::{Update, UpdateKind};
    let prog = compile_file("dsl/tc_dynamic.sp");
    let g0 = triangle::symmetrize(&generators::uniform_random(30, 160, 5, 96));
    let (dels, adds) = triangle::symmetric_updates(&g0, 14.0, 4, 97);
    let mut upd = Vec::new();
    for (db, ab) in dels.iter().zip(&adds) {
        for &(u, v) in db {
            upd.push(Update { kind: UpdateKind::Delete, src: u, dst: v, weight: 1 });
        }
        for &(u, v, w) in ab {
            upd.push(Update { kind: UpdateKind::Add, src: u, dst: v, weight: w });
        }
    }
    let total = upd.len().max(1);
    let stream = UpdateStream::new(upd, total);
    let a = args(&[("batchSize", ScalarVal::I(total as i64))]);
    let (g, st) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);
    let got = match st.result(&prog) {
        Some(ScalarVal::I(t)) => t,
        other => panic!("DynTC must return an int triangle count, got {other:?}"),
    };
    assert_eq!(got, triangle::static_tc(&g).triangles, "delta TC != recount");
}

#[test]
fn bytecode_bfs_matches_hand_written() {
    let prog = compile_file("dsl/bfs_dynamic.sp");
    let g0 = generators::uniform_random(50, 180, 3, 99);
    let stream = UpdateStream::generate_percent(&g0, 10.0, 8, 3, 100);
    let a = args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))]);
    let (_, st) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let mut g2 = g0.clone();
    stream.apply_all_static(&mut g2);
    let want = bfs::static_bfs(&g2, 0);
    assert_eq!(st.prop_i64(&prog, "level").unwrap(), want.level, "bytecode BFS != kernel");
}

// ---------------------------------------------------------- connected
// components: the algorithm with no hand-written kernel anywhere in the
// crate. Oracle: union-find over the final edge list, labeling each
// component with its minimum vertex id.

fn cc_oracle(g: &DynGraph) -> Vec<i64> {
    let n = g.num_nodes();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut [usize], mut x: usize) -> usize {
        while p[x] != x {
            p[x] = p[p[x]];
            x = p[x];
        }
        x
    }
    for (u, v, _) in g.edges_sorted() {
        let (ru, rv) = (find(&mut parent, u as usize), find(&mut parent, v as usize));
        if ru != rv {
            parent[ru.max(rv)] = ru.min(rv);
        }
    }
    let mut label = vec![i64::MAX; n];
    for v in 0..n {
        let r = find(&mut parent, v);
        label[r] = label[r].min(v as i64);
    }
    (0..n).map(|v| label[find(&mut parent, v)]).collect()
}

#[test]
fn bytecode_cc_matches_union_find_oracle() {
    let prog = compile_file("dsl/cc_dynamic.sp");
    let g0 = generators::uniform_random(80, 320, 5, 101);
    // mixed stream: deletion batches exercise the full-recompute branch,
    // add-only batches the monotone re-flood
    let stream = UpdateStream::generate_percent(&g0, 10.0, 16, 9, 102);
    let a = args(&[("batchSize", ScalarVal::I(16))]);

    let (gs, st_serial) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
    let (_, st_cpu) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);

    let mut g2 = g0.clone();
    stream.apply_all_static(&mut g2);
    assert_eq!(gs.edges_sorted(), g2.edges_sorted());
    let comp = st_serial.prop_i64(&prog, "comp").unwrap();
    assert_eq!(comp, cc_oracle(&g2), "bytecode DynCC != union-find oracle");
    assert_eq!(comp, st_cpu.prop_i64(&prog, "comp").unwrap(), "serial != cpu comp");
}

/// The `serve --program` path end-to-end: a [`GraphService`] seeded with
/// the compiled CC program ingests live updates, publishes `comp` through
/// the snapshot cell, and reports the final program state on shutdown —
/// all without a single CC-specific line of backend Rust.
#[test]
fn cc_program_serves_end_to_end() {
    let prog = Arc::new(compile_file("dsl/cc_dynamic.sp"));
    let g0 = generators::uniform_random(120, 500, 5, 103);
    let workload = UpdateStream::generate_percent(&g0, 8.0, 1, 9, 104).updates;

    for backend in [BackendKind::Serial, BackendKind::Cpu] {
        let mut cfg = ServiceConfig::new(Algo::Sssp); // algo is ignored with a program
        cfg.backend = backend;
        cfg.batch_capacity = 64;
        cfg.batch_deadline = std::time::Duration::from_millis(2);
        cfg.program = Some(ProgramConfig {
            prog: Arc::clone(&prog),
            args: args(&[("batchSize", ScalarVal::I(64))]),
        });
        let svc = GraphService::try_start(g0.clone(), cfg).unwrap();
        for u in workload.iter().copied() {
            svc.submit(u);
        }
        svc.drain();
        let published = svc.with_snapshot(|t| {
            t.prog_ints
                .iter()
                .find(|(name, _)| name.as_str() == "comp")
                .map(|(_, v)| v.clone())
        });
        let report = svc.try_shutdown().unwrap();
        let st = report.program().expect("program service reports program state");
        let comp = st.prop_i64(&prog, "comp").unwrap();
        assert_eq!(comp, cc_oracle(&report.graph), "served CC != oracle ({backend:?})");
        let published = published.unwrap_or_else(|| {
            panic!("snapshot must publish the comp property ({backend:?})")
        });
        // the snapshot was taken after the last applied batch == final state
        assert_eq!(published, comp, "published snapshot != final state ({backend:?})");
    }
}

// ------------------------------------------------------------ negative
// paths: typed errors with spans, capability gating, service gating.

#[test]
fn negative_undefined_property_error_carries_span() {
    let src = "Dynamic f(Graph g, updates<g> u, int batchSize) {\n  Batch(u : batchSize) {\n    forall (v in g.nodes()) { v.ghost = 1; }\n  }\n}";
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(err.contains("ghost"), "names the property: {err}");
    assert!(err.contains("line 3:"), "carries the source line: {err}");
}

#[test]
fn negative_hook_outside_batch_is_rejected_with_span() {
    let src = "Dynamic f(Graph g, updates<g> u, int batchSize) {\n  OnAdd (x in u.currentBatch(1)) { int q = 0; }\n}";
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(
        err.contains("inside a Batch"),
        "hook placement must be a sema error: {err}"
    );
    assert!(err.contains("line 2:"), "carries the source line: {err}");
}

#[test]
fn negative_verifier_rejects_corrupted_program() {
    let mut prog = compile_file("dsl/cc_dynamic.sp");
    prog.init.push(bytecode::Instr::Jump { target: 999_999 });
    let err = bytecode::verify(&prog).unwrap_err().to_string();
    assert!(err.contains("jump target"), "unexpected verifier message: {err}");
}

#[test]
fn negative_dist_backend_rejects_programs() {
    let prog = compile_file("dsl/cc_dynamic.sp");
    let e = engine(BackendKind::Dist);
    assert!(!e.capabilities().supports_programs);
    let mut g = generators::uniform_random(10, 40, 5, 105);
    let mut st =
        ProgState::new(&prog, g.num_nodes(), &args(&[("batchSize", ScalarVal::I(4))])).unwrap();
    let err = e.run_program(&prog, Phase::Init, &mut g, &mut st).unwrap_err().to_string();
    assert!(
        err.contains("does not support DSL bytecode programs"),
        "unexpected: {err}"
    );
    // the rejection is analysis-driven: it names the blocking construct
    // (cc's neighbor-indexed CAS-min relax), not just a capability bit.
    assert!(err.contains("comp"), "names the property: {err}");
    assert!(err.contains("neighbor"), "names the access shape: {err}");
    assert!(err.contains("line "), "carries the loop's source span: {err}");
}

#[test]
fn negative_run_program_cell_admission_names_construct() {
    // coordinator-level admission fires before any static solve is paid
    // for, with the same certificate-driven message.
    let prog = compile_file("dsl/sssp_dynamic.sp");
    let g = generators::uniform_random(20, 80, 5, 115);
    let err = starplat_dyn::coordinator::run_program_cell(
        BackendKind::Dist,
        &g,
        5.0,
        8,
        42,
        EngineOpts::default(),
        &prog,
        &args(&[("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))]),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("does not support DSL bytecode programs"), "unexpected: {err}");
    assert!(err.contains("dist"), "names the property or backend: {err}");
}

#[test]
fn negative_serve_program_rejects_wal() {
    let prog = Arc::new(compile_file("dsl/cc_dynamic.sp"));
    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.program =
        Some(ProgramConfig { prog, args: args(&[("batchSize", ScalarVal::I(8))]) });
    cfg.durability.wal_dir = Some(std::env::temp_dir().join("starplat-prog-wal-negative"));
    let g = generators::uniform_random(10, 40, 5, 106);
    let err = GraphService::try_start(g, cfg).unwrap_err().to_string();
    assert!(err.contains("--wal"), "program+wal must be rejected up front: {err}");
}

#[test]
fn negative_sharded_service_rejects_programs() {
    let prog = Arc::new(compile_file("dsl/cc_dynamic.sp"));
    let mut cfg = ServiceConfig::new(Algo::Sssp);
    cfg.engine_shards = 2;
    cfg.program =
        Some(ProgramConfig { prog, args: args(&[("batchSize", ScalarVal::I(8))]) });
    let g = generators::uniform_random(40, 160, 5, 107);
    let err = ShardedService::try_start(g, cfg).unwrap_err().to_string();
    assert!(
        err.contains("single-engine"),
        "sharded+program must be rejected up front: {err}"
    );
}

#[test]
fn negative_second_shutdown_is_typed_not_a_panic() {
    let g = generators::uniform_random(30, 120, 5, 108);
    let svc = GraphService::try_start(g, ServiceConfig::new(Algo::Sssp)).unwrap();
    svc.drain();
    svc.try_shutdown().expect("healthy first shutdown succeeds");
    assert!(
        matches!(svc.try_shutdown(), Err(ShutdownError::AlreadyShutDown)),
        "second shutdown must be AlreadyShutDown"
    );
}

// ------------------------------------------------------------- analysis
// race rejection: hand-written racy programs, each refused with the
// expected diagnostic code and the offending loop's source span.

#[test]
fn negative_plain_neighbor_write_is_a_write_write_race() {
    let src = "\
Dynamic RacyPush(Graph g, updates<g> u, propNode<int> x, int batchSize) {
  g.attachNodeProperty(x = 0);
  Batch(u : batchSize) {
    forall (v in g.nodes()) {
      forall (nbr in g.neighbors(v)) {
        nbr.x = v.x + 1;
      }
    }
  }
}";
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(err.contains("R001"), "write-write race code: {err}");
    assert!(err.contains("\"x\""), "names the property: {err}");
    assert!(err.contains("neighbor"), "names the access shape: {err}");
    assert!(err.contains("line 5:"), "spans the offending loop: {err}");
}

#[test]
fn negative_non_monotone_min_companion_is_rejected() {
    let src = "\
Dynamic RacyMin(Graph g, updates<g> u, propNode<int> comp, propNode<int> hops, int batchSize) {
  g.attachNodeProperty(comp = 0, hops = 0);
  Batch(u : batchSize) {
    forall (v in g.nodes()) {
      forall (nbr in g.neighbors(v)) {
        <nbr.comp, nbr.hops> = <Min(nbr.comp, v.comp), v.comp + 1>;
      }
    }
  }
}";
    // `hops` is neither a constant flag nor the relax source (`v.comp + 1`
    // is not the CAS-min's source vertex), so its final value depends on
    // which relax wins — a schedule-dependent companion.
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(err.contains("R002"), "companion race code: {err}");
    assert!(err.contains("\"hops\""), "names the companion property: {err}");
    assert!(err.contains("line 5:"), "spans the offending loop: {err}");
}

#[test]
fn negative_read_after_racy_write_is_rejected() {
    let src = "\
Dynamic RacyRead(Graph g, updates<g> u, propNode<int> x, propNode<int> y, int batchSize) {
  g.attachNodeProperty(x = 0, y = 0);
  Batch(u : batchSize) {
    forall (v in g.nodes()) {
      v.x = v.x + 2;
      forall (nbr in g.neighbors(v)) {
        if (nbr.x > 0) {
          v.y = 1;
        }
      }
    }
  }
}";
    // every iteration both increments its own `x` and reads neighbors'
    // `x`: the reads observe in-flight values of a non-monotone store.
    let err = lower::compile(src, None).unwrap_err().to_string();
    assert!(err.contains("R003"), "read-write race code: {err}");
    assert!(err.contains("\"x\""), "names the property: {err}");
    assert!(err.contains("neighbor"), "names the racy read shape: {err}");
    assert!(err.contains("line 4:"), "spans the enclosing parallel loop: {err}");
}

#[test]
fn uninitialized_batch_read_lints_but_compiles() {
    let src = "\
Dynamic ColdRead(Graph g, updates<g> u, propNode<int> x, propNode<int> y, int batchSize) {
  g.attachNodeProperty(y = 0);
  Batch(u : batchSize) {
    forall (v in g.nodes()) {
      v.y = v.x;
    }
  }
}";
    // `x` is read in the batch segment but never written: a warning (the
    // zero-fill is well-defined), not a rejection.
    let prog = lower::compile(src, None).expect("lints must not block compilation");
    assert_eq!(prog.facts.lints.len(), 1, "exactly one lint: {:?}", prog.facts.lints);
    let l = &prog.facts.lints[0];
    assert_eq!(l.code, "L001");
    assert_eq!(l.seg, "on_batch");
    assert!(l.message.contains("\"x\""), "names the property: {}", l.message);
    assert_eq!(l.span.line, 4, "spans the reading loop: {}", l);
    // and `y` is written but never read anywhere — dead.
    assert_eq!(prog.facts.dead_props, vec!["y".to_string()]);
}

/// Propcheck-style sweep: on every shipped `.sp`, the analysis-driven
/// lowering (inferred RepairParents, certificate attached) must keep the
/// serial and cpu backends bitwise identical across seeds, carry a clean
/// deterministic certificate, and emit valid facts JSON.
#[test]
fn sweep_shipped_programs_certificates_and_serial_cpu_parity() {
    let shipped: [(&str, Vec<(&str, ScalarVal)>); 5] = [
        (
            "dsl/sssp_dynamic.sp",
            vec![("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))],
        ),
        (
            "dsl/bfs_dynamic.sp",
            vec![("batchSize", ScalarVal::I(8)), ("src", ScalarVal::I(0))],
        ),
        (
            "dsl/pagerank_dynamic.sp",
            vec![
                ("batchSize", ScalarVal::I(8)),
                ("beta", ScalarVal::F(1e-6)),
                ("delta", ScalarVal::F(0.85)),
                ("maxIter", ScalarVal::I(50)),
            ],
        ),
        ("dsl/tc_dynamic.sp", vec![("batchSize", ScalarVal::I(8))]),
        ("dsl/cc_dynamic.sp", vec![("batchSize", ScalarVal::I(8))]),
    ];
    for (path, arglist) in shipped {
        let prog = compile_file(path);
        let f = &prog.facts;
        assert!(f.certified && f.deterministic, "{path}: clean certificate expected");
        assert!(f.relax_only_cross_vertex_writes, "{path}: shipped programs are relax-only");
        assert!(f.batch_monotone, "{path}: cross-vertex batch writes are monotone");
        assert!(f.f64_fold_order_safe, "{path}: slot folds are index-ordered");
        assert!(f.lints.is_empty(), "{path}: no lints expected: {:?}", f.lints);
        assert_eq!(f.unreachable_instrs, 0, "{path}: all instructions reachable");
        assert!(!f.loops.is_empty(), "{path}: certificate covers the Par loops");
        starplat_dyn::telemetry::trace::validate_json(&f.to_json())
            .unwrap_or_else(|e| panic!("{path}: invalid facts JSON: {e}"));

        // repair schedule: inferred from the IR, mirrored at both tails.
        let want_repairs: &[(&str, &str, bool)] = match path {
            "dsl/sssp_dynamic.sp" => &[("dist", "parent", false)],
            "dsl/bfs_dynamic.sp" => &[("level", "parent", true)],
            _ => &[],
        };
        let got: Vec<(&str, &str, bool)> = f
            .repairs
            .iter()
            .zip(&f.repair_names)
            .map(|(r, (d, p))| (d.as_str(), p.as_str(), r.unit_weight))
            .collect();
        assert_eq!(got, want_repairs, "{path}: inferred repair schedule");
        for seg in [&prog.init, &prog.on_batch] {
            let tail_repairs = seg
                .iter()
                .filter(|i| matches!(i, bytecode::Instr::RepairParents { .. }))
                .count();
            assert_eq!(tail_repairs, f.repairs.len(), "{path}: RepairParents at segment tail");
        }

        // bitwise serial ≡ cpu over multiple update streams.
        for seed in [7u64, 11] {
            let g0 = generators::uniform_random(60, 240, 5, seed);
            let stream = UpdateStream::generate_percent(&g0, 10.0, 8, 9, seed + 1);
            let a = args(&arglist);
            let (_, st_s) = run_prog(&*engine(BackendKind::Serial), &prog, &g0, &stream, &a);
            let (_, st_c) = run_prog(&*engine(BackendKind::Cpu), &prog, &g0, &stream, &a);
            for p in &prog.props {
                match p.ty {
                    bytecode::Ty::Int => assert_eq!(
                        st_s.prop_i64(&prog, &p.name),
                        st_c.prop_i64(&prog, &p.name),
                        "{path} seed {seed}: serial != cpu on int prop {}",
                        p.name
                    ),
                    bytecode::Ty::Float => {
                        let bits = |st: &ProgState| {
                            st.prop_f64(&prog, &p.name)
                                .map(|v| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>())
                        };
                        assert_eq!(
                            bits(&st_s),
                            bits(&st_c),
                            "{path} seed {seed}: serial != cpu bits on float prop {}",
                            p.name
                        );
                    }
                    bytecode::Ty::Bool => {}
                }
            }
            assert_eq!(
                st_s.result(&prog),
                st_c.result(&prog),
                "{path} seed {seed}: serial != cpu result"
            );
        }
    }
}
