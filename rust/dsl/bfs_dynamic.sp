// Dynamic BFS — the paper's §1 motivating example, expressed as the
// unit-weight instance of the SSSP pipeline: levels only ever decrease
// on insertion, and a deleted tree edge invalidates its subtree before a
// pull-style re-relaxation.

Static staticBFS(Graph g, propNode<int> level, propNode<int> parent, propNode<bool> modified, int src) {
  propNode<bool> modified_nxt;
  g.attachNodeProperty(level = INF, parent = -1, modified = False, modified_nxt = False);
  src.level = 0;
  src.modified = True;
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        <nbr.level, nbr.parent, nbr.modified_nxt> = <Min(nbr.level, v.level + 1), v, True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Incremental(Graph g, propNode<int> level, propNode<int> parent, propNode<bool> modified) {
  propNode<bool> modified_nxt;
  g.attachNodeProperty(modified_nxt = False);
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        <nbr.level, nbr.parent, nbr.modified_nxt> = <Min(nbr.level, v.level + 1), v, True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Decremental(Graph g, propNode<int> level, propNode<int> parent, propNode<bool> modified) {
  bool changed = True;
  while (changed) {
    changed = False;
    forall (v in g.nodes().filter(modified == False)) {
      if (v.parent > -1) {
        if (v.parent.modified == True) {
          v.level = INF;
          v.modified = True;
          changed = True;
        }
      }
    }
  }
  forall (v in g.nodes()) {
    if (v.level < INF) {
      v.modified = True;
    } else {
      v.modified = False;
      v.parent = -1;
    }
  }
  propNode<bool> modified_nxt;
  g.attachNodeProperty(modified_nxt = False);
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        <nbr.level, nbr.parent, nbr.modified_nxt> = <Min(nbr.level, v.level + 1), v, True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Dynamic DynBFS(Graph g, updates<g> updateBatch, propNode<int> level, propNode<int> parent, propNode<bool> modified, int batchSize, int src) {
  staticBFS(g, level, parent, modified, src);
  Batch(updateBatch : batchSize) {
    OnDelete (u in updateBatch.currentBatch(0)) {
      int del_src = u.source;
      int del_dst = u.destination;
      if (del_dst.parent == del_src) {
        del_dst.level = INF;
        del_dst.parent = -1;
        del_dst.modified = True;
      }
    }
    g.updateCSRDel(updateBatch);
    Decremental(g, level, parent, modified);
    OnAdd (u in updateBatch.currentBatch(1)) {
      int add_src = u.source;
      int add_dst = u.destination;
      if (add_src.level < INF) {
        <add_dst.level, add_dst.parent, add_dst.modified> = <Min(add_dst.level, add_src.level + 1), add_src, True>;
      }
    }
    g.updateCSRAdd(updateBatch);
    Incremental(g, level, parent, modified);
  }
}
