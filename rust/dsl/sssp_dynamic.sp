// Appendix Fig. 21: Dynamic SSSP in StarPlat Dynamic.
//
// staticSSSP   — Bellman-Ford fixed point over modified frontiers;
// Incremental  — push relaxation seeded by the OnAdd preprocessing;
// Decremental  — SP-tree invalidation cascade + re-relaxation;
// DynSSSP      — the Batch driver (OnDelete → updateCSRDel → Decremental →
//                OnAdd → updateCSRAdd → Incremental).

Static staticSSSP(Graph g, propNode<int> dist, propNode<int> parent, propNode<bool> modified, int src) {
  propNode<bool> modified_nxt;
  g.attachNodeProperty(dist = INF, parent = -1, modified = False, modified_nxt = False);
  src.dist = 0;
  src.modified = True;
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.parent, nbr.modified_nxt> = <Min(nbr.dist, v.dist + e.weight), v, True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Incremental(Graph g, propNode<int> dist, propNode<int> parent, propNode<bool> modified) {
  propNode<bool> modified_nxt;
  g.attachNodeProperty(modified_nxt = False);
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.parent, nbr.modified_nxt> = <Min(nbr.dist, v.dist + e.weight), v, True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Decremental(Graph g, propNode<int> dist, propNode<int> parent, propNode<bool> modified) {
  // phase 1: cascade invalidation down the former SP tree
  bool changed = True;
  while (changed) {
    changed = False;
    forall (v in g.nodes().filter(modified == False)) {
      if (v.parent > -1) {
        if (v.parent.modified == True) {
          v.dist = INF;
          v.modified = True;
          changed = True;
        }
      }
    }
  }
  // phase 2: re-seed from every still-valid vertex and relax to a fixed
  // point — invalidated vertices re-derive their distances from intact ones
  forall (v in g.nodes()) {
    if (v.dist < INF) {
      v.modified = True;
    } else {
      v.modified = False;
      v.parent = -1;
    }
  }
  propNode<bool> modified_nxt;
  g.attachNodeProperty(modified_nxt = False);
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.parent, nbr.modified_nxt> = <Min(nbr.dist, v.dist + e.weight), v, True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Dynamic DynSSSP(Graph g, updates<g> updateBatch, propNode<int> dist, propNode<int> parent, propNode<bool> modified, int batchSize, int src) {
  staticSSSP(g, dist, parent, modified, src);
  Batch(updateBatch : batchSize) {
    OnDelete (u in updateBatch.currentBatch(0)) {
      int del_src = u.source;
      int del_dst = u.destination;
      if (del_dst.parent == del_src) {
        del_dst.dist = INF;
        del_dst.parent = -1;
        del_dst.modified = True;
      }
    }
    g.updateCSRDel(updateBatch);
    Decremental(g, dist, parent, modified);
    OnAdd (u in updateBatch.currentBatch(1)) {
      int add_src = u.source;
      int add_dst = u.destination;
      if (add_src.dist < INF) {
        <add_dst.dist, add_dst.parent, add_dst.modified> = <Min(add_dst.dist, add_src.dist + u.weight), add_src, True>;
      }
    }
    g.updateCSRAdd(updateBatch);
    Incremental(g, dist, parent, modified);
  }
}
