// Appendix Fig. 19: Dynamic Triangle Counting in StarPlat Dynamic.
//
// staticTC     — node-iterator count (u < v < w once per triangle);
// Incremental  — delta count over the batch's added arcs (run after
//                updateCSRAdd);
// Decremental  — delta count over the batch's deleted arcs (run before
//                updateCSRDel, while the graph is intact);
// DynTC        — the Batch driver with the 1/2, 1/4, 1/6 multiplicity
//                corrections folded into the handlers' returns.

Static staticTC(Graph g) {
  long triangle_count = 0;
  forall (v in g.nodes()) {
    forall (u in g.neighbors(v).filter(u < v)) {
      forall (w in g.neighbors(v).filter(w > v)) {
        if (g.is_an_edge(u, w)) {
          triangle_count += 1;
        }
      }
    }
  }
  return triangle_count;
}

Incremental(Graph g, updates<g> addBatch) {
  long count1 = 0;
  long count2 = 0;
  long count3 = 0;
  for (u in addBatch) {
    int v1 = u.source;
    int v2 = u.destination;
    if (v1 != v2) {
      forall (v3 in g.neighbors(v1).filter(v3 != v1 && v3 != v2)) {
        if (g.is_an_edge(v2, v3) || g.is_an_edge(v3, v2)) {
          int k = 1;
          if (addBatch.contains(v1, v3)) {
            k = k + 1;
          }
          if (addBatch.contains(v2, v3)) {
            k = k + 1;
          }
          if (k == 1) {
            count1 += 1;
          }
          if (k == 2) {
            count2 += 1;
          }
          if (k > 2) {
            count3 += 1;
          }
        }
      }
    }
  }
  return count1 / 2 + count2 / 4 + count3 / 6;
}

Decremental(Graph g, updates<g> delBatch) {
  long count1 = 0;
  long count2 = 0;
  long count3 = 0;
  for (u in delBatch) {
    int v1 = u.source;
    int v2 = u.destination;
    if (v1 != v2) {
      forall (v3 in g.neighbors(v1).filter(v3 != v1 && v3 != v2)) {
        if (g.is_an_edge(v2, v3) || g.is_an_edge(v3, v2)) {
          int k = 1;
          if (delBatch.contains(v1, v3)) {
            k = k + 1;
          }
          if (delBatch.contains(v2, v3)) {
            k = k + 1;
          }
          if (k == 1) {
            count1 += 1;
          }
          if (k == 2) {
            count2 += 1;
          }
          if (k > 2) {
            count3 += 1;
          }
        }
      }
    }
  }
  return count1 / 2 + count2 / 4 + count3 / 6;
}

Dynamic DynTC(Graph g, updates<g> updateBatch, int batchSize) {
  long triangle_count = staticTC(g);
  Batch(updateBatch : batchSize) {
    updates<g> delBatch = updateBatch.currentBatch(0);
    updates<g> addBatch = updateBatch.currentBatch(1);
    triangle_count = triangle_count - Decremental(g, delBatch);
    g.updateCSRDel(updateBatch);
    g.updateCSRAdd(updateBatch);
    triangle_count = triangle_count + Incremental(g, addBatch);
  }
  return triangle_count;
}
