// Appendix Fig. 20: Dynamic PageRank in StarPlat Dynamic.
//
// staticPR     — double-buffered pull sweeps until the summed rank
//                movement drops below beta (or maxIter);
// Incremental / Decremental — identical restricted sweeps over the
//                flagged subset (the flag closure is computed by the
//                driver with propagateNodeFlags);
// DynPR        — the Batch driver: flag targets → propagateNodeFlags →
//                updateCSR → restricted recompute, deletions then adds.

Static staticPR(Graph g, propNode<float> pageRank, propNode<float> pageRank_nxt, float beta, float delta, int maxIter) {
  float num_nodes = g.num_nodes();
  g.attachNodeProperty(pageRank = 1.0 / num_nodes);
  int iterCount = 0;
  float diff = 0.0;
  do {
    diff = 0.0;
    forall (v in g.nodes()) {
      float sum = 0.0;
      for (w in g.nodes_to(v)) {
        if (g.count_outNbrs(w) > 0) {
          sum = sum + w.pageRank / g.count_outNbrs(w);
        }
      }
      float val = (1.0 - delta) / num_nodes + delta * sum;
      float d = val - v.pageRank;
      if (d < 0.0) {
        d = 0.0 - d;
      }
      diff = diff + d;
      v.pageRank_nxt = val;
    }
    pageRank = pageRank_nxt;
    iterCount = iterCount + 1;
  } while (diff > beta && iterCount < maxIter);
}

Incremental(Graph g, propNode<float> pageRank, propNode<float> pageRank_nxt, propNode<bool> modified, float beta, float delta, int maxIter) {
  int active = 0;
  forall (v in g.nodes().filter(modified == True)) {
    active = active + 1;
  }
  if (active > 0) {
    float num_nodes = g.num_nodes();
    int iterCount = 0;
    float diff = 0.0;
    do {
      diff = 0.0;
      forall (v in g.nodes().filter(modified == True)) {
        float sum = 0.0;
        for (w in g.nodes_to(v)) {
          if (g.count_outNbrs(w) > 0) {
            sum = sum + w.pageRank / g.count_outNbrs(w);
          }
        }
        float val = (1.0 - delta) / num_nodes + delta * sum;
        float d = val - v.pageRank;
        if (d < 0.0) {
          d = 0.0 - d;
        }
        diff = diff + d;
        v.pageRank_nxt = val;
      }
      forall (v in g.nodes().filter(modified == True)) {
        v.pageRank = v.pageRank_nxt;
      }
      iterCount = iterCount + 1;
    } while (diff > beta && iterCount < maxIter);
  }
}

Decremental(Graph g, propNode<float> pageRank, propNode<float> pageRank_nxt, propNode<bool> modified, float beta, float delta, int maxIter) {
  int active = 0;
  forall (v in g.nodes().filter(modified == True)) {
    active = active + 1;
  }
  if (active > 0) {
    float num_nodes = g.num_nodes();
    int iterCount = 0;
    float diff = 0.0;
    do {
      diff = 0.0;
      forall (v in g.nodes().filter(modified == True)) {
        float sum = 0.0;
        for (w in g.nodes_to(v)) {
          if (g.count_outNbrs(w) > 0) {
            sum = sum + w.pageRank / g.count_outNbrs(w);
          }
        }
        float val = (1.0 - delta) / num_nodes + delta * sum;
        float d = val - v.pageRank;
        if (d < 0.0) {
          d = 0.0 - d;
        }
        diff = diff + d;
        v.pageRank_nxt = val;
      }
      forall (v in g.nodes().filter(modified == True)) {
        v.pageRank = v.pageRank_nxt;
      }
      iterCount = iterCount + 1;
    } while (diff > beta && iterCount < maxIter);
  }
}

Dynamic DynPR(Graph g, updates<g> updateBatch, propNode<float> pageRank, float beta, float delta, int maxIter, int batchSize) {
  propNode<float> pageRank_nxt;
  propNode<bool> modified;
  staticPR(g, pageRank, pageRank_nxt, beta, delta, maxIter);
  Batch(updateBatch : batchSize) {
    g.attachNodeProperty(modified = False);
    OnDelete (u in updateBatch.currentBatch(0)) {
      int del_dst = u.destination;
      del_dst.modified = True;
    }
    g.propagateNodeFlags(modified);
    g.updateCSRDel(updateBatch);
    Decremental(g, pageRank, pageRank_nxt, modified, beta, delta, maxIter);
    g.attachNodeProperty(modified = False);
    OnAdd (u in updateBatch.currentBatch(1)) {
      int add_dst = u.destination;
      add_dst.modified = True;
    }
    g.propagateNodeFlags(modified);
    g.updateCSRAdd(updateBatch);
    Incremental(g, pageRank, pageRank_nxt, modified, beta, delta, maxIter);
  }
}
