/* Dynamic connected components (label propagation to the minimum
 * vertex id, treating edges as undirected via a symmetric exchange).
 *
 * This algorithm ships with NO hand-written Rust kernel: it exists to
 * prove the DSL → bytecode path end-to-end. `run --program` /
 * `serve --program` lower this file and execute it on the serial or
 * cpu engine directly.
 *
 * Maintenance strategy: edge additions only ever merge components, so
 * the incremental pass re-floods from the stale labels (monotone, thus
 * correct). Any deletion may split a component — labels are not
 * recoverable incrementally from a min-label flood — so the driver
 * falls back to a full recompute for batches containing deletions.
 */

Static staticCC(Graph g, propNode<int> comp, propNode<bool> modified) {
  forall (v in g.nodes()) {
    v.comp = v;
  }
  fixedPoint until (finished : !modified) {
    g.attachNodeProperty(modified = False);
    forall (v in g.nodes()) {
      forall (nbr in g.neighbors(v)) {
        <nbr.comp, nbr.modified> = <Min(nbr.comp, v.comp), True>;
        <v.comp, v.modified> = <Min(v.comp, nbr.comp), True>;
      }
    }
  }
}

Incremental(Graph g, propNode<int> comp, propNode<bool> modified) {
  /* same flood, seeded from the surviving labels */
  fixedPoint until (finished : !modified) {
    g.attachNodeProperty(modified = False);
    forall (v in g.nodes()) {
      forall (nbr in g.neighbors(v)) {
        <nbr.comp, nbr.modified> = <Min(nbr.comp, v.comp), True>;
        <v.comp, v.modified> = <Min(v.comp, nbr.comp), True>;
      }
    }
  }
}

Dynamic DynCC(Graph g, updates<g> updateBatch, propNode<int> comp, propNode<bool> modified, int batchSize) {
  staticCC(g, comp, modified);
  Batch(updateBatch : batchSize) {
    int dels = 0;
    OnDelete (u in updateBatch.currentBatch(0)) {
      dels += 1;
    }
    g.updateCSRDel(updateBatch);
    g.updateCSRAdd(updateBatch);
    if (dels > 0) {
      staticCC(g, comp, modified);
    } else {
      Incremental(g, comp, modified);
    }
  }
}
