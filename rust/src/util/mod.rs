//! Small self-contained utilities used across the crate.
//!
//! The offline crates.io snapshot available to this build lacks `rand`,
//! `rayon`, `criterion`, and `proptest`, so this module provides minimal,
//! well-tested replacements: a splitmix64/xoshiro RNG, a scoped thread pool,
//! a timing helper, streaming statistics, and a tiny property-testing
//! harness (`propcheck`).

pub mod barrier;
pub mod error;
pub mod failpoint;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod sync_slice;
pub mod threadpool;
pub mod timer;

pub use barrier::{PhaseBarrier, ShardFleet};
pub use error::{Context, Error, Result};
pub use propcheck::{forall_checks, Gen};
pub use rng::Rng;
pub use stats::Summary;
pub use sync_slice::SyncSlice;
pub use threadpool::ThreadPool;
pub use timer::Timer;
