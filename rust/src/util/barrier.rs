//! Reusable phase barrier and the persistent shard worker fleet.
//!
//! The sharded streaming engine (PR 4) ran every BSP phase under a fresh
//! `std::thread::scope`, paying thread spawn/join a dozen-plus times per
//! batch. This module provides the two primitives that replace it:
//!
//! * [`PhaseBarrier`] — a reusable sense-reversing barrier (the sense is
//!   the parity of a monotonically increasing generation counter). Waiters
//!   spin briefly to catch short phases without a syscall, then park on a
//!   condvar. Tracked waits accumulate idle nanoseconds so barrier
//!   imbalance is observable in bench output.
//! * [`ShardFleet`] — long-lived pinned workers, one per shard, spawned
//!   once and living until the fleet is dropped. Phase closures are
//!   delivered over per-shard channels; the coordinator and every worker
//!   then meet at the shared [`PhaseBarrier`], so a phase's borrows never
//!   outlive [`ShardFleet::run`].
//!
//! Disjoint mutable access inside a phase uses the same idioms as the
//! scoped version: [`SyncSlice`](crate::util::SyncSlice) for owner-range
//! writes and per-shard result slots.

use crate::telemetry::{Stage, Track};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Spin iterations before a waiter parks on the condvar. Phases in the
/// sharded engine are typically tens of microseconds, so a short spin
/// catches the common case; long stragglers park instead of burning a
/// core.
const SPIN_ROUNDS: u32 = 4096;

/// A reusable barrier for a fixed party count.
///
/// Classic sense-reversing design: each cohort is identified by the
/// generation counter (its parity is the "sense"); the last arrival resets
/// the arrival count and advances the generation, releasing everyone
/// spinning or parked on the old value. The barrier is immediately
/// reusable — parties may re-enter `wait` for the next phase while
/// stragglers from the previous one are still waking up.
#[derive(Debug)]
pub struct PhaseBarrier {
    parties: usize,
    arrived: AtomicUsize,
    generation: AtomicU64,
    lock: Mutex<()>,
    cvar: Condvar,
    wait_nanos: AtomicU64,
}

impl PhaseBarrier {
    pub fn new(parties: usize) -> Self {
        PhaseBarrier {
            parties: parties.max(1),
            arrived: AtomicUsize::new(0),
            generation: AtomicU64::new(0),
            lock: Mutex::new(()),
            cvar: Condvar::new(),
            wait_nanos: AtomicU64::new(0),
        }
    }

    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Wait for all parties. Does not record idle time.
    pub fn wait(&self) {
        self.wait_inner(false);
    }

    /// Wait for all parties, accumulating the time spent waiting into the
    /// barrier's idle counter (see [`wait_nanos`](Self::wait_nanos)).
    pub fn wait_tracked(&self) {
        self.wait_inner(true);
    }

    /// Total nanoseconds spent in tracked waits across all parties — the
    /// per-phase load-imbalance signal surfaced in `RelayStats`.
    pub fn wait_nanos(&self) -> u64 {
        self.wait_nanos.load(Ordering::Relaxed)
    }

    fn wait_inner(&self, record: bool) {
        let start = if record { Some(Instant::now()) } else { None };
        let gen = self.generation.load(Ordering::Acquire);
        let prev = self.arrived.fetch_add(1, Ordering::AcqRel);
        if prev + 1 == self.parties {
            // Last arrival: reset for the next cohort *before* advancing
            // the generation (released parties may re-enter immediately),
            // then advance under the lock so a parked waiter cannot miss
            // the notify between its generation check and `cvar.wait`.
            self.arrived.store(0, Ordering::Release);
            {
                let _g = self.lock.lock().unwrap();
                self.generation.fetch_add(1, Ordering::Release);
            }
            self.cvar.notify_all();
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                spins += 1;
                if spins < SPIN_ROUNDS {
                    std::hint::spin_loop();
                } else {
                    let mut g = self.lock.lock().unwrap();
                    while self.generation.load(Ordering::Acquire) == gen {
                        g = self.cvar.wait(g).unwrap();
                    }
                    break;
                }
            }
        }
        if let Some(t0) = start {
            self.wait_nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// An erased phase closure. Raw pointers carry no lifetime; safety comes
/// from the run protocol: the coordinator does not return from
/// [`ShardFleet::run`] until every worker has passed the phase barrier,
/// so the pointee outlives every dereference.
struct JobMsg(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared by all workers) and the run
// protocol bounds its lifetime; sending the pointer itself is just
// sending an address.
unsafe impl Send for JobMsg {}

enum FleetMsg {
    Run(JobMsg),
    Stop,
}

/// Persistent shard workers: one pinned thread per shard, fed phase
/// closures over per-shard channels, synchronized by a shared
/// [`PhaseBarrier`].
///
/// Between phases workers block on their channel (parked in `recv`), so an
/// idle fleet costs nothing. Dropping the fleet sends `Stop` to every
/// worker and joins them.
#[derive(Debug)]
pub struct ShardFleet {
    senders: Vec<Sender<FleetMsg>>,
    barrier: Arc<PhaseBarrier>,
    panicked: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl ShardFleet {
    /// Spawn `workers` resident threads (named `shard-<r>`).
    pub fn new(workers: usize) -> Self {
        Self::with_tracks(workers, Vec::new())
    }

    /// Spawn `workers` resident threads, giving worker `r` the span
    /// track `tracks[r]` to record its barrier-wait spans into (an empty
    /// vec disables tracking; the tracks line up with the per-shard
    /// tracks the sharded engine records its phase spans into).
    pub fn with_tracks(workers: usize, tracks: Vec<Arc<Track>>) -> Self {
        let workers = workers.max(1);
        // Parties = workers + the coordinator: `run` returns only once
        // every worker has finished the phase.
        let barrier = Arc::new(PhaseBarrier::new(workers + 1));
        let panicked = Arc::new(AtomicBool::new(false));
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for rank in 0..workers {
            let (tx, rx) = channel::<FleetMsg>();
            let b = Arc::clone(&barrier);
            let p = Arc::clone(&panicked);
            let trk = tracks.get(rank).cloned();
            let h = std::thread::Builder::new()
                .name(format!("shard-{rank}"))
                .spawn(move || worker_loop(rank, rx, b, p, trk))
                .expect("spawn shard fleet worker");
            senders.push(tx);
            handles.push(h);
        }
        ShardFleet { senders, barrier, panicked, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Execute one phase: every worker `r` runs `job(r)` concurrently;
    /// returns once all workers have passed the barrier. Panics (after all
    /// workers finish the phase) if any worker's closure panicked.
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        let ptr = job as *const (dyn Fn(usize) + Sync);
        for tx in &self.senders {
            tx.send(FleetMsg::Run(JobMsg(ptr))).expect("shard fleet worker alive");
        }
        self.barrier.wait();
        if self.panicked.load(Ordering::Acquire) {
            panic!("shard fleet worker panicked during a phase");
        }
    }

    /// Cumulative worker idle time at the phase barrier, in nanoseconds.
    pub fn wait_nanos(&self) -> u64 {
        self.barrier.wait_nanos()
    }
}

fn worker_loop(
    rank: usize,
    rx: Receiver<FleetMsg>,
    barrier: Arc<PhaseBarrier>,
    panicked: Arc<AtomicBool>,
    track: Option<Arc<Track>>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            FleetMsg::Run(JobMsg(ptr)) => {
                // SAFETY: the coordinator keeps the closure alive until it
                // passes the same barrier we hit below (see JobMsg).
                let job = unsafe { &*ptr };
                if catch_unwind(AssertUnwindSafe(|| job(rank))).is_err() {
                    panicked.store(true, Ordering::Release);
                }
                let at_barrier = Instant::now();
                barrier.wait_tracked();
                if let Some(t) = &track {
                    // phase closures record into the same track from this
                    // thread, so the single-writer contract holds
                    t.record(Stage::Barrier, at_barrier);
                }
            }
            FleetMsg::Stop => break,
        }
    }
}

impl Drop for ShardFleet {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(FleetMsg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SyncSlice;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn barrier_is_reusable_across_generations() {
        let parties = 4;
        let barrier = Arc::new(PhaseBarrier::new(parties));
        let rounds = 50usize;
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..parties)
            .map(|_| {
                let b = Arc::clone(&barrier);
                let c = Arc::clone(&counter);
                std::thread::spawn(move || {
                    for round in 0..rounds {
                        c.fetch_add(1, Ordering::SeqCst);
                        b.wait();
                        // after the barrier every party of this round has
                        // incremented: the count is at least parties*(round+1)
                        assert!(c.load(Ordering::SeqCst) >= parties * (round + 1));
                        b.wait();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), parties * rounds);
    }

    #[test]
    fn single_party_barrier_never_blocks() {
        let b = PhaseBarrier::new(1);
        for _ in 0..10 {
            b.wait();
        }
    }

    #[test]
    fn fleet_runs_phases_with_borrowed_state() {
        let fleet = ShardFleet::new(4);
        let mut out = vec![0usize; 4];
        for phase in 0..32 {
            let s = SyncSlice::new(&mut out);
            fleet.run(&|r| {
                // SAFETY: each worker writes only its own slot.
                unsafe { s.set(r, r * 10 + phase) };
            });
        }
        assert_eq!(out, vec![31, 41, 51, 61]);
    }

    #[test]
    fn fleet_workers_share_a_work_queue() {
        let fleet = ShardFleet::new(3);
        let n = 3000usize;
        let mut buf = vec![0u32; n];
        {
            let s = SyncSlice::new(&mut buf);
            let cursor = AtomicUsize::new(0);
            fleet.run(&|_r| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: fetch_add hands each index to exactly one worker.
                unsafe { s.set(i, (i as u32) ^ 7) };
            });
        }
        assert!(buf.iter().enumerate().all(|(i, &v)| v == (i as u32) ^ 7));
    }

    #[test]
    fn fleet_tracks_barrier_wait_under_imbalance() {
        let fleet = ShardFleet::new(2);
        fleet.run(&|r| {
            if r == 0 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        });
        // worker 1 finished instantly and waited ~5ms for worker 0
        assert!(fleet.wait_nanos() > 0, "idle worker accumulates barrier wait");
    }

    #[test]
    fn fleet_records_barrier_spans_per_worker() {
        let tracer = crate::telemetry::Tracer::new();
        let tracks: Vec<_> = (0..2).map(|r| tracer.track(&format!("shard-{r}"), 64)).collect();
        let fleet = ShardFleet::with_tracks(2, tracks.clone());
        for _ in 0..3 {
            fleet.run(&|_r| {});
        }
        drop(fleet); // joins workers: safe to snapshot
        for t in &tracks {
            let snap = t.snapshot();
            assert_eq!(snap.events.len(), 3, "one barrier span per phase");
            assert!(snap.events.iter().all(|e| e.stage == crate::telemetry::Stage::Barrier));
        }
    }

    #[test]
    #[should_panic(expected = "shard fleet worker panicked")]
    fn fleet_propagates_worker_panics() {
        let fleet = ShardFleet::new(2);
        fleet.run(&|r| {
            if r == 1 {
                panic!("boom");
            }
        });
    }
}
