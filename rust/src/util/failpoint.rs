//! Compiled-in failpoint registry for chaos testing.
//!
//! A **failpoint** is a named site in the pipeline (`enqueue`, `seal`,
//! `compute`, `merge`, `publish`, `wal_append`, `checkpoint`) where a
//! fault can be injected at runtime: a panic (crash the hosting thread),
//! a typed error (exercise the `Result` plumbing), or a delay (stall a
//! stage to provoke timeouts and backpressure). Sites are always compiled
//! in — there is no feature flag to forget in CI — but the disabled fast
//! path is a single relaxed atomic load, so an un-armed registry costs
//! nothing measurable on the hot paths.
//!
//! Activation grammar (env `FAILPOINTS` or `serve --failpoints`):
//!
//! ```text
//! spec    := entry (';' entry)*
//! entry   := site '=' action ['@' prob] ['~' after]
//! action  := 'panic' | 'err' | 'delay:<ms>' | 'off'
//! ```
//!
//! `@prob` fires the action with probability `prob` per hit (default 1.0,
//! deterministic per-site PRNG); `~after` skips the first `after` hits —
//! `seal=panic~3` crashes on the 4th sealed batch, which is how the
//! recovery tests place a crash at a chosen batch boundary.
//!
//! Tests that arm failpoints must hold a [`Scenario`] guard: it
//! serializes chaos tests against each other (the registry is global and
//! `cargo test` is multi-threaded) and clears the registry on drop even
//! if the test panics.

use crate::util::error::{bail, Result};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// The pipeline sites wired up in this crate, for `--help` text and spec
/// validation (unknown names are rejected to catch typos).
pub const SITES: &[&str] =
    &["enqueue", "seal", "compute", "merge", "publish", "wal_append", "checkpoint"];

/// What an armed failpoint does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// `panic!` in the hosting thread (caught by the engine supervisor).
    Panic,
    /// Return a typed error from [`hit`].
    Err,
    /// Sleep for the given number of milliseconds, then succeed.
    Delay(u64),
}

#[derive(Debug)]
struct Entry {
    action: Action,
    /// Fire probability per eligible hit (1.0 = always).
    prob: f64,
    /// Skip this many hits before the failpoint becomes eligible.
    after: u64,
    hits: u64,
    rng: Rng,
}

static ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<HashMap<String, Entry>> {
    static REG: OnceLock<Mutex<HashMap<String, Entry>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

fn lock_registry() -> MutexGuard<'static, HashMap<String, Entry>> {
    // A panic action leaves the mutex poisoned by design; the map itself
    // is always in a consistent state, so recover the guard.
    registry().lock().unwrap_or_else(|e| e.into_inner())
}

/// Parse and install a failpoint spec, replacing the current
/// configuration. An empty spec clears everything (same as [`clear`]).
pub fn configure(spec: &str) -> Result<()> {
    let mut map = HashMap::new();
    for entry in spec.split([';', ',']).map(str::trim).filter(|e| !e.is_empty()) {
        let (site, rhs) = entry
            .split_once('=')
            .ok_or_else(|| crate::anyhow!("failpoint entry {entry:?} is missing `=`"))?;
        let site = site.trim();
        // `test-*` names are accepted for unit tests that exercise the
        // registry itself without arming a live pipeline site (lib tests
        // run concurrently in one process; arming a real site here would
        // crash an unrelated service test mid-flight).
        if !SITES.contains(&site) && !site.starts_with("test-") {
            bail!("unknown failpoint site {site:?} (known: {})", SITES.join(", "));
        }
        let (rhs, after) = match rhs.split_once('~') {
            Some((a, n)) => (
                a,
                n.trim()
                    .parse::<u64>()
                    .map_err(|_| crate::anyhow!("failpoint {site}: bad ~after count {n:?}"))?,
            ),
            None => (rhs, 0),
        };
        let (action, prob) = match rhs.split_once('@') {
            Some((a, p)) => (
                a,
                p.trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|p| (0.0..=1.0).contains(p))
                    .ok_or_else(|| {
                        crate::anyhow!("failpoint {site}: bad @prob {p:?} (want 0..=1)")
                    })?,
            ),
            None => (rhs, 1.0),
        };
        let action = match action.trim() {
            "panic" => Action::Panic,
            "err" => Action::Err,
            "off" => continue,
            a => match a.strip_prefix("delay:") {
                Some(ms) => Action::Delay(ms.trim().parse::<u64>().map_err(|_| {
                    crate::anyhow!("failpoint {site}: bad delay millis {ms:?}")
                })?),
                None => bail!(
                    "failpoint {site}: unknown action {a:?} (panic|err|delay:<ms>|off)"
                ),
            },
        };
        // Deterministic per-site probability stream: same spec, same firing
        // pattern, independent of which thread hits the site.
        let seed = site.bytes().fold(0xfa11u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
        map.insert(
            site.to_string(),
            Entry { action, prob, after, hits: 0, rng: Rng::new(seed) },
        );
    }
    let armed = !map.is_empty();
    *lock_registry() = map;
    ARMED.store(armed, Ordering::Release);
    Ok(())
}

/// Install the spec from the `FAILPOINTS` environment variable, if set.
pub fn configure_from_env() -> Result<()> {
    match std::env::var("FAILPOINTS") {
        Ok(spec) => configure(&spec),
        Err(_) => Ok(()),
    }
}

/// Disarm and remove every failpoint.
pub fn clear() {
    ARMED.store(false, Ordering::Release);
    lock_registry().clear();
}

/// Whether any failpoint is currently armed (serve banner).
pub fn armed() -> bool {
    ARMED.load(Ordering::Acquire)
}

/// Evaluate the named site. The un-armed fast path is one relaxed atomic
/// load. Returns `Err` for an armed `err` action, panics for `panic`,
/// sleeps for `delay`, and returns `Ok(())` otherwise.
#[inline]
pub fn hit(name: &str) -> Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    hit_slow(name)
}

#[cold]
fn hit_slow(name: &str) -> Result<()> {
    let action = {
        let mut map = lock_registry();
        let Some(e) = map.get_mut(name) else { return Ok(()) };
        e.hits += 1;
        if e.hits <= e.after {
            return Ok(());
        }
        if e.prob < 1.0 && !e.rng.chance(e.prob) {
            return Ok(());
        }
        e.action
    };
    match action {
        Action::Panic => panic!("failpoint {name} fired: panic"),
        Action::Err => bail!("failpoint {name} fired: err"),
        Action::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
    }
}

/// RAII guard for chaos tests: takes a global lock so concurrently
/// running tests cannot see each other's failpoints, installs `spec`,
/// and clears the registry when dropped (including on panic-unwind).
pub struct Scenario {
    _guard: MutexGuard<'static, ()>,
}

impl Scenario {
    pub fn new(spec: &str) -> Scenario {
        static SCENARIO: Mutex<()> = Mutex::new(());
        let guard = SCENARIO.lock().unwrap_or_else(|e| e.into_inner());
        configure(spec).expect("failpoint scenario spec");
        Scenario { _guard: guard }
    }
}

impl Drop for Scenario {
    fn drop(&mut self) {
        clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests only arm `test-*` sites: lib tests share one process,
    // and arming a real pipeline site here would crash an unrelated
    // service test running concurrently. Real-site chaos lives in the
    // `fault_recovery` integration binary.

    #[test]
    fn unarmed_hits_are_free_and_ok() {
        let _s = Scenario::new("");
        assert!(!armed());
        assert!(hit("seal").is_ok());
        assert!(hit("no-such-site").is_ok());
    }

    #[test]
    fn err_action_fires_and_clears_on_drop() {
        {
            let _s = Scenario::new("test-a=err");
            assert!(armed());
            let e = hit("test-a").unwrap_err().to_string();
            assert!(e.contains("failpoint test-a"), "{e}");
            // Other sites stay clean.
            assert!(hit("test-b").is_ok());
        }
        assert!(!armed());
        assert!(hit("test-a").is_ok());
    }

    #[test]
    fn after_skips_initial_hits() {
        let _s = Scenario::new("test-after=err~2");
        assert!(hit("test-after").is_ok());
        assert!(hit("test-after").is_ok());
        assert!(hit("test-after").is_err());
        assert!(hit("test-after").is_err());
    }

    #[test]
    fn panic_action_panics() {
        let _s = Scenario::new("test-boom=panic");
        let r = std::panic::catch_unwind(|| hit("test-boom"));
        assert!(r.is_err());
    }

    #[test]
    fn probability_is_deterministic_and_partial() {
        let count = |spec: &str| {
            let _s = Scenario::new(spec);
            (0..1000).filter(|_| hit("test-prob").is_err()).count()
        };
        let a = count("test-prob=err@0.3");
        let b = count("test-prob=err@0.3");
        assert_eq!(a, b, "same spec must fire identically");
        assert!(a > 150 && a < 450, "p=0.3 over 1000 hits fired {a} times");
    }

    #[test]
    fn delay_action_sleeps_then_succeeds() {
        let _s = Scenario::new("test-slow=delay:10");
        let t0 = std::time::Instant::now();
        assert!(hit("test-slow").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(8));
    }

    #[test]
    fn spec_parse_errors_are_typed() {
        // hold the guard: a failed `configure` never installs anything,
        // but serializing keeps the registry stable for concurrent tests
        let _s = Scenario::new("");
        for bad in ["seal", "seal=explode", "nosite=panic", "seal=err@7", "seal=delay:x"] {
            assert!(configure(bad).is_err(), "{bad:?} should be rejected");
        }
    }
}
