//! Unsynchronized shared-slice writes for disjoint-index parallel loops.
//!
//! The thread pool's `parallel_for` contract already guarantees each index
//! is processed by exactly one worker; [`SyncSlice`] lets those workers
//! write results straight into a caller-owned buffer without per-element
//! atomics or a mutex. It is the enabling primitive for the allocation-free
//! engine scratch (`backend::cpu::EngineScratch`) and the parallel diff-CSR
//! merge.

use std::cell::UnsafeCell;

/// A `&mut [T]` that can be shared across scoped worker threads.
///
/// # Safety contract
/// Every call to [`set`](Self::set) / [`slice_mut`](Self::slice_mut) must
/// target an index (or range) that no other thread touches during the same
/// parallel region, and the buffer must not be read until the region ends.
pub struct SyncSlice<'a, T> {
    data: &'a [UnsafeCell<T>],
}

unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        // SAFETY: UnsafeCell<T> is #[repr(transparent)] over T, so the
        // slice layouts are identical; the &mut borrow guarantees we hold
        // the only reference for 'a.
        let data =
            unsafe { &*(slice as *mut [T] as *const [UnsafeCell<T>]) };
        SyncSlice { data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i` must not be written or read by any other thread during the
    /// current parallel region.
    #[inline]
    pub unsafe fn set(&self, i: usize, val: T) {
        *self.data[i].get() = val;
    }

    /// Borrow a mutable sub-range.
    ///
    /// # Safety
    /// The range must be disjoint from every range/index any other thread
    /// accesses during the current parallel region.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        let ptr = self.data[start].get();
        std::slice::from_raw_parts_mut(ptr, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::{Sched, ThreadPool};

    #[test]
    fn parallel_disjoint_writes_land() {
        let n = 4096;
        let mut buf = vec![0u64; n];
        {
            let s = SyncSlice::new(&mut buf);
            let pool = ThreadPool::new(4);
            pool.parallel_for(n, Sched::Dynamic { chunk: 64 }, |i| {
                // SAFETY: each index visited exactly once (pool contract).
                unsafe { s.set(i, (i * 3) as u64) };
            });
        }
        assert!(buf.iter().enumerate().all(|(i, &v)| v == (i * 3) as u64));
    }

    #[test]
    fn disjoint_subranges_are_independent() {
        let mut buf = vec![0u32; 100];
        {
            let s = SyncSlice::new(&mut buf);
            let pool = ThreadPool::new(3);
            pool.parallel_for(10, Sched::Static, |chunk| {
                // SAFETY: chunks [10*chunk, 10*chunk+10) are pairwise disjoint.
                let sub = unsafe { s.slice_mut(chunk * 10, 10) };
                for (j, slot) in sub.iter_mut().enumerate() {
                    *slot = (chunk * 10 + j) as u32;
                }
            });
        }
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i as u32));
    }
}
