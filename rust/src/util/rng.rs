//! Deterministic pseudo-random number generation (splitmix64 core).
//!
//! All randomness in the library (graph generators, update streams,
//! property tests) flows through [`Rng`] so every experiment is exactly
//! reproducible from a seed.

/// A small, fast, deterministic PRNG (splitmix64).
///
/// Statistical quality is more than sufficient for workload generation;
/// it is *not* cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point by mixing the seed once.
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform value in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free mapping is fine here:
        // the tiny modulo bias (< 2^-32 for our bounds) is irrelevant for
        // workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n). O(k) expected for
    /// k << n via rejection, falls back to shuffle for dense samples.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct values from {n}");
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below_usize(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_stays_in_bounds() {
        let mut r = Rng::new(42);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_complete() {
        let mut r = Rng::new(3);
        let s = r.sample_distinct(100, 100);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 100);
        let s2 = r.sample_distinct(1000, 5);
        assert_eq!(s2.len(), 5);
        let set2: std::collections::HashSet<_> = s2.iter().collect();
        assert_eq!(set2.len(), 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
