//! A scoped work-sharing thread pool: the "OpenMP runtime" of the `cpu`
//! backend.
//!
//! Supports the two scheduling policies the paper evaluates (Table 6) —
//! *dynamic* (atomic chunk-stealing, OpenMP `schedule(dynamic)`) and
//! *static* (pre-computed contiguous ranges, `schedule(static)`) — plus
//! the NUMA-motivated **partition-affine** schedule:
//!
//! [`Sched::Partitioned`] derives each worker's range from a
//! [`PartitionMap`](crate::graph::partition::PartitionMap) block partition
//! of the loop domain. For loops over the vertex set (the dense pull
//! sweeps, the diff-CSR merge compaction) this means worker `t` owns the
//! *same contiguous CSR shard on every round of every fixed point* — the
//! dist/rank/flag cachelines and the adjacency ranges a worker touches
//! stay with that worker, which is what a first-touch NUMA allocation
//! rewards.
//!
//! Scope note, to keep claims honest: for a plain `0..n` loop,
//! `Partitioned` today produces the *identical ranges* `Static` does
//! (both are the ceil-division block split), so their per-loop timings
//! should agree to noise; the meaningful perf comparison is either one
//! vs `Dynamic`. What `Partitioned` adds is the *contract*, not a new
//! split: the shards come from the same [`PartitionMap`] the graph layer
//! uses for vertex ownership, and the engine hands its schedule to
//! [`DynGraph::set_merge_sched`](crate::graph::DynGraph) so diff-block
//! merge compaction walks the same shards as the sweeps. Planned
//! follow-ups (degree-balanced shard boundaries, first-touch scratch
//! init — see ROADMAP) change `Partitioned` without touching `Static`.
//!
//! Built on `std::thread::scope`, so closures may borrow from the caller's
//! stack — no `Arc` plumbing required in the hot loops.
//!
//! The pool spawns fresh scoped workers per `parallel_for` call, which is
//! the right trade for the single-engine backend's coarse loops. The
//! *sharded streaming* runtime has the opposite profile — a dozen-plus
//! short BSP phases per batch — and runs instead on the resident
//! [`ShardFleet`](crate::util::barrier::ShardFleet) (sibling module
//! `util::barrier`): long-lived pinned shard workers fed phase closures
//! over channels and synchronized by a reusable sense-reversing
//! [`PhaseBarrier`](crate::util::barrier::PhaseBarrier).

use crate::graph::partition::{Partition, PartitionMap};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Loop-scheduling policy for `parallel_for`, mirroring OpenMP's
/// `schedule(dynamic)` / `schedule(static)` clauses plus the
/// partition-affine static schedule (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sched {
    /// Chunked self-scheduling from a shared atomic counter.
    Dynamic { chunk: usize },
    /// Contiguous equal ranges fixed up-front per thread.
    Static,
    /// Partition-affine: worker `t` owns the `t`-th contiguous block of a
    /// [`PartitionMap`](crate::graph::partition::PartitionMap) over the
    /// loop domain — the same shard every round, every loop.
    Partitioned,
}

impl Default for Sched {
    fn default() -> Self {
        Sched::Dynamic { chunk: 512 }
    }
}

impl Sched {
    pub fn describe(&self) -> String {
        match *self {
            Sched::Dynamic { chunk } => format!("dynamic:{chunk}"),
            Sched::Static => "static".to_string(),
            Sched::Partitioned => "partitioned".to_string(),
        }
    }
}

impl std::str::FromStr for Sched {
    type Err = String;

    /// `dynamic[:<chunk>]` | `static` | `partitioned`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "dynamic" => {
                let chunk = arg
                    .unwrap_or("512")
                    .parse::<usize>()
                    .map_err(|e| format!("bad dynamic chunk: {e}"))?;
                Ok(Sched::Dynamic { chunk: chunk.max(1) })
            }
            "static" => Ok(Sched::Static),
            "partitioned" => Ok(Sched::Partitioned),
            other => {
                Err(format!("unknown schedule {other:?} (dynamic[:<chunk>]|static|partitioned)"))
            }
        }
    }
}

/// A parallel execution context with a fixed logical thread count.
///
/// The pool spawns threads per call via `std::thread::scope`; on the
/// evaluation machine (1 hardware core) this still exercises the same
/// synchronization structure the paper's OpenMP code has (atomics,
/// double-buffering), which is what the dynamic-vs-static comparison
/// measures.
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` logical workers (min 1).
    pub fn new(threads: usize) -> Self {
        ThreadPool { threads: threads.max(1) }
    }

    /// Pool sized to the machine.
    pub fn host() -> Self {
        Self::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The contiguous range worker `t` of `workers` owns under a static
    /// split of `0..n`. `Partitioned` routes through [`PartitionMap`] so
    /// loop sharding and graph-level vertex ownership are the same map;
    /// `Static` computes the equivalent ceil-division split directly.
    fn static_range(sched: Sched, n: usize, workers: usize, t: usize) -> std::ops::Range<usize> {
        match sched {
            Sched::Partitioned => {
                PartitionMap::new(n, workers, Partition::Block).owned_range(t)
            }
            _ => {
                let per = n.div_ceil(workers);
                (t * per).min(n)..((t + 1) * per).min(n)
            }
        }
    }

    /// Parallel `for i in 0..n { body(i) }` with the given schedule.
    ///
    /// `body` must be safe to run concurrently for distinct `i` — that is
    /// exactly the contract the DSL's `forall` has after race analysis has
    /// inserted atomics.
    pub fn parallel_for<F>(&self, n: usize, sched: Sched, body: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        if self.threads == 1 {
            for i in 0..n {
                body(i);
            }
            return;
        }
        match sched {
            Sched::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for _ in 0..self.threads {
                        s.spawn(|| loop {
                            let start = next.fetch_add(chunk, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            let end = (start + chunk).min(n);
                            for i in start..end {
                                body(i);
                            }
                        });
                    }
                });
            }
            Sched::Static | Sched::Partitioned => {
                std::thread::scope(|s| {
                    for t in 0..self.threads {
                        let r = Self::static_range(sched, n, self.threads, t);
                        if r.is_empty() {
                            continue;
                        }
                        let body = &body;
                        s.spawn(move || {
                            for i in r {
                                body(i);
                            }
                        });
                    }
                });
            }
        }
    }

    /// Parallel `for` with **per-worker mutable state**: worker `t` gets
    /// exclusive `&mut` access to `states[t]` for the whole loop. This is
    /// the primitive behind allocation-free hot loops (per-thread frontier
    /// buffers merged by prefix sum instead of a global `Mutex`) and the
    /// parallel diff-CSR merge's reusable gather buffers.
    ///
    /// `states` must provide at least one element; at most
    /// `min(threads, states.len())` workers run.
    pub fn parallel_for_with<S, F>(&self, n: usize, sched: Sched, states: &mut [S], body: F)
    where
        S: Send,
        F: Fn(&mut S, usize) + Sync,
    {
        assert!(!states.is_empty(), "parallel_for_with needs at least one state");
        if n == 0 {
            return;
        }
        let workers = self.threads.min(states.len());
        if workers == 1 {
            let st = &mut states[0];
            for i in 0..n {
                body(st, i);
            }
            return;
        }
        match sched {
            Sched::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let next = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for st in states.iter_mut().take(workers) {
                        let body = &body;
                        let next = &next;
                        s.spawn(move || loop {
                            let start = next.fetch_add(chunk, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            let end = (start + chunk).min(n);
                            for i in start..end {
                                body(st, i);
                            }
                        });
                    }
                });
            }
            Sched::Static | Sched::Partitioned => {
                std::thread::scope(|s| {
                    for (t, st) in states.iter_mut().take(workers).enumerate() {
                        let r = Self::static_range(sched, n, workers, t);
                        if r.is_empty() {
                            continue;
                        }
                        let body = &body;
                        s.spawn(move || {
                            for i in r {
                                body(st, i);
                            }
                        });
                    }
                });
            }
        }
    }

    /// Parallel map-reduce: each worker folds its indices with `fold`,
    /// partials are combined with `combine`.
    pub fn parallel_reduce<T, F, C>(&self, n: usize, init: T, fold: F, combine: C) -> T
    where
        T: Send + Clone,
        F: Fn(T, usize) -> T + Sync,
        C: Fn(T, T) -> T,
    {
        if n == 0 {
            return init;
        }
        if self.threads == 1 {
            let mut acc = init;
            for i in 0..n {
                acc = fold(acc, i);
            }
            return acc;
        }
        let per = n.div_ceil(self.threads);
        let mut partials: Vec<Option<T>> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..self.threads {
                let start = t * per;
                let end = ((t + 1) * per).min(n);
                if start >= end {
                    continue;
                }
                let fold = &fold;
                let local = init.clone();
                handles.push(s.spawn(move || {
                    let mut acc = local;
                    for i in start..end {
                        acc = fold(acc, i);
                    }
                    acc
                }));
            }
            for h in handles {
                partials.push(Some(h.join().expect("worker panicked")));
            }
        });
        let mut acc = init;
        for p in partials.into_iter().flatten() {
            acc = combine(acc, p);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_visits_each_index_once_dynamic() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, Sched::Dynamic { chunk: 64 }, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_visits_each_index_once_static() {
        let pool = ThreadPool::new(3);
        let n = 1001; // deliberately not divisible
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, Sched::Static, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_is_noop() {
        ThreadPool::new(2).parallel_for(0, Sched::Static, |_| panic!("must not run"));
        ThreadPool::new(2).parallel_for(0, Sched::Partitioned, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_for_visits_each_index_once_partitioned() {
        let pool = ThreadPool::new(4);
        let n = 1003; // deliberately not divisible
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, Sched::Partitioned, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    /// The partition-affine contract: worker `t` sees exactly the indices
    /// of block shard `t`, contiguous and stable across repeated loops.
    #[test]
    fn partitioned_workers_own_stable_contiguous_shards() {
        let pool = ThreadPool::new(3);
        let n = 1000usize;
        let pm = crate::graph::partition::PartitionMap::new(
            n,
            3,
            crate::graph::partition::Partition::Block,
        );
        for _round in 0..3 {
            let mut locals: Vec<Vec<usize>> = vec![Vec::new(); pool.threads()];
            pool.parallel_for_with(n, Sched::Partitioned, &mut locals, |buf, i| buf.push(i));
            for (t, shard) in locals.iter().enumerate() {
                assert!(
                    shard.windows(2).all(|w| w[1] == w[0] + 1),
                    "worker {t} shard not contiguous"
                );
                for &i in shard {
                    assert_eq!(pm.owner(i as u32), t, "index {i} not owned by worker {t}");
                }
                assert_eq!(shard.len(), pm.owned_count(t));
            }
        }
    }

    #[test]
    fn sched_parses() {
        assert_eq!("static".parse::<Sched>().unwrap(), Sched::Static);
        assert_eq!("partitioned".parse::<Sched>().unwrap(), Sched::Partitioned);
        assert_eq!("dynamic".parse::<Sched>().unwrap(), Sched::Dynamic { chunk: 512 });
        assert_eq!("dynamic:64".parse::<Sched>().unwrap(), Sched::Dynamic { chunk: 64 });
        assert!("guided".parse::<Sched>().is_err());
        assert_eq!("partitioned".parse::<Sched>().unwrap().describe(), "partitioned");
    }

    #[test]
    fn parallel_for_with_partitions_state_and_covers_indices() {
        for sched in [Sched::Dynamic { chunk: 32 }, Sched::Static, Sched::Partitioned] {
            let pool = ThreadPool::new(4);
            let n = 5000usize;
            let mut locals: Vec<Vec<usize>> = vec![Vec::new(); pool.threads()];
            pool.parallel_for_with(n, sched, &mut locals, |buf, i| buf.push(i));
            let mut all: Vec<usize> = locals.into_iter().flatten().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "{sched:?}");
        }
    }

    #[test]
    fn parallel_for_with_single_state_runs_serial() {
        let pool = ThreadPool::new(4);
        let mut acc = [0u64];
        pool.parallel_for_with(100, Sched::Static, &mut acc, |a, i| *a += i as u64);
        assert_eq!(acc[0], 4950);
    }

    #[test]
    fn reduce_sums_correctly() {
        let pool = ThreadPool::new(4);
        let n = 5000usize;
        let total = pool.parallel_reduce(n, 0u64, |acc, i| acc + i as u64, |a, b| a + b);
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn single_thread_pool_matches_serial() {
        let pool = ThreadPool::new(1);
        let total = pool.parallel_reduce(100, 0u64, |a, i| a + i as u64, |a, b| a + b);
        assert_eq!(total, 4950);
    }
}
