//! Wall-clock timing helpers used by the coordinator and the bench harness.

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

impl Timer {
    /// Start (or restart) the clock.
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn total_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap()` (or construction), and reset the lap.
    pub fn lap_secs(&mut self) -> f64 {
        let now = Instant::now();
        let d = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        d
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly until `min_time` has elapsed or `max_iters` runs were
/// done (whichever first, but always at least once), returning the *median*
/// per-run seconds. This is the measurement core of the local bench harness
/// (the offline crates.io snapshot has no criterion).
pub fn measure(min_time: Duration, max_iters: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::new();
    let t0 = Instant::now();
    loop {
        let ti = Instant::now();
        f();
        samples.push(ti.elapsed().as_secs_f64());
        if samples.len() >= max_iters || t0.elapsed() >= min_time {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value_and_positive_time() {
        let (v, s) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn measure_respects_max_iters() {
        let mut count = 0;
        let med = measure(Duration::from_secs(10), 3, || count += 1);
        assert_eq!(count, 3);
        assert!(med >= 0.0);
    }

    #[test]
    fn lap_accumulates() {
        let mut t = Timer::start();
        let a = t.lap_secs();
        let b = t.lap_secs();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(t.total_secs() >= a);
    }
}
