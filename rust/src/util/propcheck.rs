//! A minimal property-based testing harness (the offline crates.io snapshot
//! has no `proptest`/`quickcheck`).
//!
//! Usage:
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath link flags)
//! use starplat_dyn::util::propcheck::{forall_checks, Gen};
//! forall_checks(0xBEEF, 100, |g: &mut Gen| {
//!     let n = g.usize_in(1, 50);
//!     let v = g.vec_u32(n, 1000);
//!     let mut s = v.clone();
//!     s.sort_unstable();
//!     assert!(s.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```
//!
//! On failure the panic message includes the case index and seed so the
//! exact case can be replayed.

use super::rng::Rng;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Case index (0-based) for diagnostics.
    pub case: usize,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below_usize(hi - lo + 1)
    }

    /// i64 in `[lo, hi]` inclusive.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec of `n` u32s each below `bound`.
    pub fn vec_u32(&mut self, n: usize, bound: u32) -> Vec<u32> {
        (0..n).map(|_| self.rng.below(bound as u64) as u32).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
}

/// Run `prop` against `cases` random inputs derived from `seed`.
///
/// Each case gets an independent sub-generator, so adding draws to one case
/// doesn't perturb later cases.
pub fn forall_checks<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen),
{
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let sub = master.fork();
        let mut g = Gen { rng: sub, case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        let _ = &mut g; // keep the generator alive across the unwind check
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall_checks(1, 50, |g| {
            let x = g.usize_in(0, 100);
            assert!(x <= 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case() {
        forall_checks(2, 50, |g| {
            let x = g.usize_in(0, 10);
            assert!(x < 10, "x was {x}");
        });
    }

    #[test]
    fn cases_are_independent_of_draw_count() {
        // Record the first draw of case 5 with two different case-0 bodies.
        let mut first_a = None;
        let mut first_b = None;
        forall_checks(3, 6, |g| {
            if g.case == 0 {
                let _ = g.usize_in(0, 9);
            }
            if g.case == 5 && first_a.is_none() {
                first_a = Some(g.usize_in(0, 1_000_000));
            }
        });
        forall_checks(3, 6, |g| {
            if g.case == 0 {
                // draw a different number of values
                let _ = g.usize_in(0, 9);
                let _ = g.usize_in(0, 9);
                let _ = g.usize_in(0, 9);
            }
            if g.case == 5 && first_b.is_none() {
                first_b = Some(g.usize_in(0, 1_000_000));
            }
        });
        assert_eq!(first_a, first_b);
    }
}
