//! Minimal error handling standing in for `anyhow` (the offline crates.io
//! snapshot has none of the usual error crates).
//!
//! Provides the subset of the `anyhow` surface this crate uses:
//! [`Error`], [`Result`], the [`anyhow!`](crate::anyhow) and
//! [`bail!`](crate::bail) macros, and a [`Context`] extension trait for
//! `Result`/`Option`.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error` it deliberately does
/// **not** implement `std::error::Error`, which leaves room for the blanket
/// `From<E: std::error::Error>` conversion that makes `?` work on io/parse
/// errors.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::new(e.to_string())
    }
}

/// `anyhow::Result` analogue.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (`anyhow::Context` analogue).
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::new(format!("{msg}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::new(msg.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::new(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::new(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::new(format!("{}", $err))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`](crate::anyhow).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

// Re-export the crate-root macros under this module's path so call sites can
// `use crate::util::error::{anyhow, bail}` exactly like with the real crate.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_square(s: &str) -> Result<i64> {
        let v: i64 = s.parse()?; // From<ParseIntError> via the blanket impl
        if v < 0 {
            bail!("negative input {v}");
        }
        Ok(v * v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_square("4").unwrap(), 16);
        assert!(parse_square("zzz").is_err());
        let e = parse_square("-3").unwrap_err();
        assert!(e.to_string().contains("negative input -3"));
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let b = anyhow!("x={} y={}", 1, 2);
        assert_eq!(b.to_string(), "x=1 y=2");
        let msg = String::from("wrapped");
        let c = anyhow!(msg);
        assert_eq!(c.to_string(), "wrapped");
        let d = anyhow!("inline {0}", 7);
        assert_eq!(d.to_string(), "inline 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        let some: Option<i32> = Some(5);
        assert_eq!(some.context("unused").unwrap(), 5);
    }
}
