//! Streaming summary statistics for bench reporting.

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Summary::new();
        for &x in xs {
            s.push(x);
        }
        s
    }
}

/// Percentile of a slice (linear interpolation). `q` in `[0, 1]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile of an **already-sorted** slice (linear interpolation) —
/// callers extracting several percentiles sort once and index repeatedly.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    assert!(!v.is_empty());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        // sample stddev of 1..4 = sqrt(5/3)
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentile_endpoints_and_median() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
    }

    #[test]
    fn percentile_sorted_matches_unsorted_entry() {
        let xs = [9.0, 2.0, 7.0, 4.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(percentile(&xs, q), percentile_sorted(&sorted, q));
        }
    }
}
