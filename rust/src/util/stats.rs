//! Streaming summary statistics for bench reporting.

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Summary::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    /// Fold another accumulator into this one (parallel Welford / Chan
    /// et al. combine), so per-worker summaries merge without losing
    /// variance: `a.merge(&b)` ≡ pushing every sample of `b` into `a`.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let (na, nb) = (self.n as f64, other.n as f64);
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * na * nb / n as f64;
        self.mean += d * nb / n as f64;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n = n;
    }
}

/// Percentile of a slice (linear interpolation). `q` in `[0, 1]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile of an **already-sorted** slice (linear interpolation) —
/// callers extracting several percentiles sort once and index repeatedly.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    assert!(!v.is_empty());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        // sample stddev of 1..4 = sqrt(5/3)
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn merge_matches_from_slice_of_concatenation() {
        let a = [1.5, -2.0, 7.25, 0.0, 3.0];
        let b = [100.0, -42.5, 9.0];
        let mut merged = Summary::from_slice(&a);
        merged.merge(&Summary::from_slice(&b));
        let cat: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
        let whole = Summary::from_slice(&cat);
        assert_eq!(merged.count(), whole.count());
        assert!((merged.mean() - whole.mean()).abs() < 1e-12);
        assert!((merged.stddev() - whole.stddev()).abs() < 1e-10);
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
    }

    #[test]
    fn merge_handles_empty_sides() {
        let xs = [2.0, 4.0, 8.0];
        let mut left_empty = Summary::new();
        left_empty.merge(&Summary::from_slice(&xs));
        assert_eq!(left_empty.count(), 3);
        assert!((left_empty.mean() - Summary::from_slice(&xs).mean()).abs() < 1e-12);
        assert_eq!(left_empty.min(), 2.0);

        let mut right_empty = Summary::from_slice(&xs);
        right_empty.merge(&Summary::new());
        assert_eq!(right_empty.count(), 3);
        assert_eq!(right_empty.max(), 8.0);

        let mut both = Summary::new();
        both.merge(&Summary::new());
        assert_eq!(both.count(), 0);
        assert!(both.mean().is_nan());
    }

    #[test]
    fn many_way_merge_keeps_variance() {
        // fold 8 per-worker chunks and compare against the flat pass
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37 % 101) as f64) - 50.0).collect();
        let mut folded = Summary::new();
        for chunk in xs.chunks(125) {
            folded.merge(&Summary::from_slice(chunk));
        }
        let whole = Summary::from_slice(&xs);
        assert_eq!(folded.count(), whole.count());
        assert!((folded.mean() - whole.mean()).abs() < 1e-10);
        assert!((folded.stddev() - whole.stddev()).abs() < 1e-9);
    }

    #[test]
    fn percentile_endpoints_and_median() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
    }

    #[test]
    fn percentile_sorted_matches_unsorted_entry() {
        let xs = [9.0, 2.0, 7.0, 4.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(percentile(&xs, q), percentile_sorted(&sorted, q));
        }
    }
}
