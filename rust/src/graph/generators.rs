//! Synthetic graph generators reproducing the *shapes* of the paper's
//! Table 1 suite (the original multi-hundred-million-edge datasets are
//! proprietary downloads; see DESIGN.md §2 for the substitution argument).
//!
//! * [`rmat`] — recursive-matrix generator with the paper's parameters
//!   (a=0.57, b=0.19, c=0.19, d=0.05) for skewed social-network analogues;
//! * [`uniform_random`] — Green-Marl-style uniform random graph;
//! * [`road_grid`] — 2-D grid with perturbed weights: large diameter,
//!   max degree ≤ 8, the road-network regime (usaroad / germany-osm);
//! * [`table1_suite`] — the ten named graphs at reproduction scale.

use super::csr::Csr;
use super::diffcsr::DynGraph;
use super::{NodeId, Weight};
use crate::util::Rng;
use std::collections::HashSet;

/// De-duplicated directed edge accumulation helper.
struct EdgeSet {
    seen: HashSet<(NodeId, NodeId)>,
    edges: Vec<(NodeId, NodeId, Weight)>,
}

impl EdgeSet {
    fn new(cap: usize) -> Self {
        EdgeSet { seen: HashSet::with_capacity(cap * 2), edges: Vec::with_capacity(cap) }
    }

    fn insert(&mut self, u: NodeId, v: NodeId, w: Weight) -> bool {
        if u == v || !self.seen.insert((u, v)) {
            return false;
        }
        self.edges.push((u, v, w));
        true
    }
}

/// RMAT generator (SNAP parameterization). Produces ~`m` distinct directed
/// edges over `n = 2^scale` vertices with a skewed degree distribution.
pub fn rmat(scale: u32, m: usize, a: f64, b: f64, c: f64, seed: u64) -> DynGraph {
    let n = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut es = EdgeSet::new(m);
    let mut attempts = 0usize;
    while es.edges.len() < m && attempts < m * 32 {
        attempts += 1;
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        let w = 1 + rng.below(10) as Weight;
        es.insert(u as NodeId, v as NodeId, w);
    }
    DynGraph::from_csr(Csr::from_edges(n, &es.edges))
}

/// Uniform random directed graph: `m` distinct edges over `n` vertices,
/// weights in `[1, max_w]`.
pub fn uniform_random(n: usize, m: usize, max_w: Weight, seed: u64) -> DynGraph {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let mut es = EdgeSet::new(m);
    let cap = m.min(n * (n - 1));
    let mut attempts = 0usize;
    while es.edges.len() < cap && attempts < cap * 64 + 1024 {
        attempts += 1;
        let u = rng.below_usize(n) as NodeId;
        let v = rng.below_usize(n) as NodeId;
        let w = 1 + rng.below(max_w.max(1) as u64) as Weight;
        es.insert(u, v, w);
    }
    DynGraph::from_csr(Csr::from_edges(n, &es.edges))
}

/// Road-network analogue: a `rows × cols` 4-connected grid (both edge
/// directions) with a small fraction of random "highway" diagonals.
/// Large diameter (rows+cols), max degree ≤ 8+ε — the usaroad/germany-osm
/// regime that drives the paper's anomalies.
pub fn road_grid(rows: usize, cols: usize, max_w: Weight, seed: u64) -> DynGraph {
    let n = rows * cols;
    let mut rng = Rng::new(seed);
    let id = |r: usize, c: usize| (r * cols + c) as NodeId;
    let mut es = EdgeSet::new(n * 4);
    for r in 0..rows {
        for c in 0..cols {
            let w1 = 1 + rng.below(max_w.max(1) as u64) as Weight;
            let w2 = 1 + rng.below(max_w.max(1) as u64) as Weight;
            if c + 1 < cols {
                es.insert(id(r, c), id(r, c + 1), w1);
                es.insert(id(r, c + 1), id(r, c), w1);
            }
            if r + 1 < rows {
                es.insert(id(r, c), id(r + 1, c), w2);
                es.insert(id(r + 1, c), id(r, c), w2);
            }
        }
    }
    // sparse highways: ~0.5% of n extra shortcut pairs
    for _ in 0..(n / 200) {
        let a = rng.below_usize(n) as NodeId;
        let b = rng.below_usize(n) as NodeId;
        let w = 1 + rng.below(max_w.max(1) as u64) as Weight;
        es.insert(a, b, w);
        es.insert(b, a, w);
    }
    DynGraph::from_csr(Csr::from_edges(n, &es.edges))
}

/// One named graph of the reproduction suite.
#[derive(Debug, Clone)]
pub struct NamedGraph {
    /// Paper short name (Table 1): TW, SW, OK, WK, LJ, PK, US, GR, RM, UR.
    pub short: &'static str,
    /// Long name of the original dataset this stands in for.
    pub long: &'static str,
    pub graph: DynGraph,
}

/// Scale factor for the suite: `1.0` ≈ 10–60 k vertices per graph
/// (≈1000× smaller than the paper, same shape). Use smaller for tests.
pub fn table1_suite(scale: f64, seed: u64) -> Vec<NamedGraph> {
    let s = |x: usize| ((x as f64 * scale).round() as usize).max(16);
    // (short, long, kind): kind 0 = rmat-ish social, 1 = uniform, 2 = road
    let mk = |short: &'static str, long: &'static str, g: DynGraph| NamedGraph {
        short,
        long,
        graph: g,
    };
    let rmat_scale = |target_nodes: usize| -> u32 {
        (usize::BITS - target_nodes.next_power_of_two().leading_zeros() - 1).max(4)
    };
    vec![
        // social networks: skewed (paper: avg degree 4–76, huge max degree)
        mk("TW", "twitter-2010", rmat(rmat_scale(s(21_200)), s(265_000), 0.57, 0.19, 0.19, seed ^ 1)),
        mk("SW", "soc-sinaweibo", rmat(rmat_scale(s(58_600)), s(261_000), 0.57, 0.19, 0.19, seed ^ 2)),
        mk("OK", "orkut", rmat(rmat_scale(s(3_000)), s(234_000), 0.45, 0.22, 0.22, seed ^ 3)),
        mk("WK", "wikipedia-ru", rmat(rmat_scale(s(3_300)), s(93_000), 0.57, 0.19, 0.19, seed ^ 4)),
        mk("LJ", "livejournal", rmat(rmat_scale(s(4_800)), s(69_000), 0.48, 0.21, 0.21, seed ^ 5)),
        mk("PK", "soc-pokec", rmat(rmat_scale(s(1_600)), s(30_600), 0.48, 0.21, 0.21, seed ^ 6)),
        // road networks: grid, avg degree 2, large diameter
        mk("US", "usaroad", {
            let side = (s(24_000) as f64).sqrt() as usize;
            road_grid(side.max(4), side.max(4), 10, seed ^ 7)
        }),
        mk("GR", "germany-osm", {
            let side = (s(11_500) as f64).sqrt() as usize;
            road_grid(side.max(4), side.max(4), 10, seed ^ 8)
        }),
        // synthetic
        mk("RM", "rmat876", rmat(rmat_scale(s(16_700)), s(87_600), 0.57, 0.19, 0.19, seed ^ 9)),
        mk("UR", "uniform-random", uniform_random(s(10_000), s(80_000), 10, seed ^ 10)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(10, 4000, 0.57, 0.19, 0.19, 42);
        assert_eq!(g.num_nodes(), 1024);
        assert!(g.num_edges() > 3000, "got {}", g.num_edges());
        let max_deg = (0..g.num_nodes() as NodeId).map(|v| g.out_degree(v)).max().unwrap();
        let avg = g.num_edges() as f64 / g.num_nodes() as f64;
        assert!(
            (max_deg as f64) > avg * 8.0,
            "rmat should be skewed: max={max_deg} avg={avg:.1}"
        );
    }

    #[test]
    fn uniform_is_not_skewed() {
        let g = uniform_random(1000, 8000, 10, 7);
        let max_deg = (0..1000u32).map(|v| g.out_degree(v)).max().unwrap();
        let avg = g.num_edges() as f64 / 1000.0;
        assert!((max_deg as f64) < avg * 5.0, "max={max_deg} avg={avg:.1}");
    }

    #[test]
    fn road_grid_low_degree_symmetric() {
        let g = road_grid(20, 30, 10, 3);
        assert_eq!(g.num_nodes(), 600);
        let max_deg = (0..600u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_deg <= 9, "road max degree bounded: {max_deg}");
        // spot-check symmetry of grid edges
        for (u, v, _) in g.edges_sorted().into_iter().take(100) {
            assert!(g.has_edge(v, u), "grid edge {u}->{v} missing reverse");
        }
    }

    #[test]
    fn no_self_loops_or_duplicates() {
        for g in [
            rmat(8, 800, 0.57, 0.19, 0.19, 1),
            uniform_random(100, 500, 10, 2),
            road_grid(8, 8, 10, 3),
        ] {
            let edges = g.edges_sorted();
            let set: HashSet<_> = edges.iter().map(|&(u, v, _)| (u, v)).collect();
            assert_eq!(set.len(), edges.len(), "duplicate edges");
            assert!(edges.iter().all(|&(u, v, _)| u != v), "self loop");
        }
    }

    #[test]
    fn suite_has_ten_named_graphs() {
        let suite = table1_suite(0.02, 11);
        assert_eq!(suite.len(), 10);
        let names: Vec<_> = suite.iter().map(|g| g.short).collect();
        assert_eq!(names, vec!["TW", "SW", "OK", "WK", "LJ", "PK", "US", "GR", "RM", "UR"]);
        for g in &suite {
            assert!(g.graph.num_edges() > 0, "{} is empty", g.short);
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = rmat(8, 500, 0.57, 0.19, 0.19, 9).edges_sorted();
        let b = rmat(8, 500, 0.57, 0.19, 0.19, 9).edges_sorted();
        assert_eq!(a, b);
    }
}
