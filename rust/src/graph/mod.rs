//! Graph substrate: CSR, the paper's diff-CSR dynamic representation,
//! update streams, generators, loaders, and vertex partitioning.
//!
//! Terminology follows the paper (§3.5): the base structure is a CSR with
//! tombstoned deletions (`TOMBSTONE` sentinel standing in for the paper's
//! ∞ marker); insertions reuse vacant slots when possible and otherwise go
//! to an auxiliary *diff-CSR* chain that can be merged back periodically.

pub mod csr;
pub mod diffcsr;
pub mod generators;
pub mod loaders;
pub mod partition;
pub mod updates;

pub use csr::{Csr, TOMBSTONE};
pub use diffcsr::DynGraph;
pub use partition::Partition;
pub use updates::{Update, UpdateKind, UpdateMix, UpdateStream};

/// Vertex id type used throughout (graphs here are ≤ 2^32 vertices).
pub type NodeId = u32;
/// Edge weight type (paper uses integer weights for SSSP).
pub type Weight = i32;
