//! The paper's diff-CSR dynamic graph representation (§3.5), plus the
//! in-edge (transpose) mirror needed by pull-style algorithms
//! (PageRank's `nodes_to`, decremental SSSP).
//!
//! A [`DynGraph`] holds:
//!  * `fwd`: base CSR with tombstoned deletions + a chain of diff blocks
//!    holding insertions that found no vacant slot;
//!  * `bwd`: the same structure for the transposed graph, kept in sync;
//!  * live out-degrees (the paper's `count_outNbrs`, which must not count
//!    tombstones).
//!
//! # Flat diff-block layout
//!
//! Each sealed [`DiffBlock`] is a compact CSR over the full vertex set
//! (per-block `offsets`/`coords`/`weights` arrays, ranges sorted by
//! destination), built once at [`seal_batch`](DiffCsr) time from the
//! batch's staged overflow inserts. Compared to the map-of-vecs layout this
//! replaces, neighbor iteration over a block is two array reads and a
//! contiguous scan instead of a hash probe per vertex per block, and
//! membership tests are binary searches.
//!
//! Inserts staged during the *current* batch live in a small `pending`
//! edge list (visible to all read paths) until the batch is sealed.
//!
//! A per-vertex **overflow bitmap** records which sources have any edge
//! outside the base CSR; `out_neighbors`/`in_neighbors`/`has_edge` consult
//! it first and skip the entire diff chain for untouched vertices — the
//! common case under point updates, and the reason diff-chain traversal
//! throughput stays within noise of the merged CSR (see
//! `benches/microbench.rs`, tracked in `BENCH_microbench.json`).
//!
//! After a configurable number of batches the diff chain is merged back
//! into a fresh compact CSR (`merge`), exactly as §3.5 describes. The
//! merge's per-vertex gather/sort/compact is embarrassingly parallel and
//! runs on the engine thread pool when one is attached
//! ([`DynGraph::set_merge_pool`]).

use super::csr::{Csr, TOMBSTONE};
use super::{NodeId, Weight};
use crate::util::sync_slice::SyncSlice;
use crate::util::threadpool::{Sched, ThreadPool};

/// One sealed auxiliary diff block: a compact CSR over the same vertex set
/// holding the edges of one batch that did not fit a vacant base slot.
#[derive(Debug, Clone)]
pub struct DiffBlock {
    /// Flat per-block storage; ranges sorted, tombstones at range tails.
    pub csr: Csr,
    /// Number of live (non-tombstoned) entries.
    pub live: usize,
}

impl DiffBlock {
    /// Tombstone `u -> v` inside this block. Returns true if found.
    fn delete(&mut self, u: NodeId, v: NodeId) -> bool {
        if self.csr.delete_edge(u, v) {
            self.live -= 1;
            true
        } else {
            false
        }
    }
}

/// One direction (out-edges or in-edges) of the dynamic structure.
#[derive(Debug, Clone)]
pub struct DiffCsr {
    pub base: Csr,
    pub diffs: Vec<DiffBlock>,
    /// Overflow inserts of the currently-open batch (sealed into a
    /// [`DiffBlock`] by `seal_batch`).
    pending: Vec<(NodeId, NodeId, Weight)>,
    /// Bit `v` set ⇒ vertex `v` may have edges in `diffs`/`pending`.
    /// Conservative (never cleared by deletes), reset on merge.
    overflow: Vec<u64>,
}

impl DiffCsr {
    fn new(base: Csr) -> Self {
        let n = base.num_nodes();
        DiffCsr { base, diffs: Vec::new(), pending: Vec::new(), overflow: vec![0; n.div_ceil(64)] }
    }

    #[inline]
    fn has_overflow(&self, v: NodeId) -> bool {
        (self.overflow[(v >> 6) as usize] >> (v & 63)) & 1 != 0
    }

    #[inline]
    fn set_overflow(&mut self, v: NodeId) {
        self.overflow[(v >> 6) as usize] |= 1u64 << (v & 63);
    }

    /// Live neighbors of `u`. Untouched vertices (overflow bit clear) pay
    /// only for the base-CSR scan — the diff chain is skipped entirely.
    #[inline]
    fn neighbors(&self, u: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        let overflow = self.has_overflow(u);
        let diffs: &[DiffBlock] = if overflow { &self.diffs } else { &[] };
        let pending: &[(NodeId, NodeId, Weight)] = if overflow { &self.pending } else { &[] };
        self.base
            .neighbors(u)
            .chain(diffs.iter().flat_map(move |d| d.csr.neighbors(u)))
            .chain(pending.iter().filter(move |e| e.0 == u).map(|e| (e.1, e.2)))
    }

    /// Membership + weight lookup: O(log deg) binary searches over the
    /// base range and each block range (newest first), pending last-in
    /// wins semantics preserved by checking it before sealed blocks.
    fn find(&self, u: NodeId, v: NodeId) -> Option<Weight> {
        if let Some(s) = self.base.find_edge(u, v) {
            return Some(self.base.weights[s]);
        }
        if !self.has_overflow(u) {
            return None;
        }
        if let Some(e) = self.pending.iter().find(|e| e.0 == u && e.1 == v) {
            return Some(e.2);
        }
        for d in self.diffs.iter().rev() {
            if let Some(s) = d.csr.find_edge(u, v) {
                return Some(d.csr.weights[s]);
            }
        }
        None
    }

    fn delete(&mut self, u: NodeId, v: NodeId) -> bool {
        if self.base.delete_edge(u, v) {
            return true;
        }
        if !self.has_overflow(u) {
            return false;
        }
        if let Some(i) = self.pending.iter().position(|e| e.0 == u && e.1 == v) {
            self.pending.swap_remove(i);
            return true;
        }
        for d in self.diffs.iter_mut().rev() {
            if d.delete(u, v) {
                return true;
            }
        }
        false
    }

    /// Insert preferring a vacant base slot, else stage into the pending
    /// overflow list — the §3.5 placement policy.
    fn insert(&mut self, u: NodeId, v: NodeId, w: Weight) {
        if self.base.try_insert_in_place(u, v, w) {
            return;
        }
        self.pending.push((u, v, w));
        self.set_overflow(u);
    }

    /// Batches big enough to repay the parallel seal's thread spawns; below
    /// this the serial [`Csr::from_edges`] build wins outright.
    const SEAL_PARALLEL_MIN: usize = 4096;

    /// Seal the current batch's overflow inserts into a flat diff block
    /// (per-block offset/coords/weights arrays, ranges sorted).
    ///
    /// Cost note: building the block via [`Csr::from_edges`] is O(n) in
    /// the vertex count (full offsets array per block), traded for O(1)
    /// range lookup on every subsequent read. For graphs where n greatly
    /// exceeds batch size a touched-vertex mini-CSR would seal cheaper;
    /// tracked in ROADMAP.md (merge-policy tuning).
    ///
    /// Shard-local seal (ROADMAP follow-up to the partition-affine
    /// schedule): with a pool and a large enough batch, each worker builds
    /// the contiguous slice of the new block's `coords`/`weights` that its
    /// partition shard owns — under [`Sched::Partitioned`] the same
    /// contiguous vertex shard it owns in the fixed-point sweeps and the
    /// merge compaction. The result is bitwise identical to the serial
    /// path: `pending` is pre-sorted by `(src, dst)` (destinations are
    /// unique per source — `add_edge` rejects duplicates), so each range is
    /// already in the sorted order [`Csr::from_edges`] establishes, and the
    /// parallel phase is a pure disjoint copy. The offsets count/prefix-sum
    /// stays serial (batch-sized + O(n)); only the payload copy shards.
    fn seal_batch_with(&mut self, pool: Option<&ThreadPool>, sched: Sched) {
        if self.pending.is_empty() {
            return;
        }
        let n = self.base.num_nodes();
        let total = self.pending.len();
        match pool {
            Some(pool)
                if pool.threads() > 1 && n > 0 && total >= Self::SEAL_PARALLEL_MIN =>
            {
                self.pending.sort_unstable();
                let mut offsets = vec![0u32; n + 1];
                for &(u, _, _) in &self.pending {
                    offsets[u as usize + 1] += 1;
                }
                for i in 0..n {
                    offsets[i + 1] += offsets[i];
                }
                let mut coords = vec![TOMBSTONE; total];
                let mut weights: Vec<Weight> = vec![0; total];
                {
                    let csl = SyncSlice::new(&mut coords);
                    let wsl = SyncSlice::new(&mut weights);
                    let pending = &self.pending;
                    let offs = &offsets;
                    pool.parallel_for(n, sched, |v| {
                        let start = offs[v] as usize;
                        let len = (offs[v + 1] - offs[v]) as usize;
                        if len == 0 {
                            return;
                        }
                        // SAFETY: [start, start+len) ranges are disjoint
                        // across vertices (prefix-sum offsets).
                        let cdst = unsafe { csl.slice_mut(start, len) };
                        let wdst = unsafe { wsl.slice_mut(start, len) };
                        for (i, &(_, d, w)) in
                            pending[start..start + len].iter().enumerate()
                        {
                            cdst[i] = d;
                            wdst[i] = w;
                        }
                    });
                }
                self.pending.clear();
                self.diffs
                    .push(DiffBlock { csr: Csr { offsets, coords, weights }, live: total });
            }
            _ => {
                let csr = Csr::from_edges(n, &self.pending);
                self.pending.clear();
                self.diffs.push(DiffBlock { csr, live: total });
            }
        }
    }

    /// Number of vertices with their overflow bit set — the cheap "how hot
    /// is the diff chain" signal (conservative upper bound on the vertices
    /// whose reads pay for chain traversal). Maintained for free by
    /// `set_overflow`; reset on merge.
    fn overflow_count(&self) -> usize {
        self.overflow.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Live edges currently held outside the base CSR (sealed blocks plus
    /// the open pending list).
    fn diff_live(&self) -> usize {
        self.diffs.iter().map(|d| d.live).sum::<usize>() + self.pending.len()
    }

    fn live_edges(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let n = self.base.num_nodes();
        let mut out = Vec::new();
        for u in 0..n as NodeId {
            for (v, w) in self.neighbors(u) {
                out.push((u, v, w));
            }
        }
        out
    }

    /// Compact everything into a fresh tombstone-free CSR. With a pool the
    /// per-vertex count/gather/sort phases run work-shared across its
    /// workers (prefix-sum offsets in between) under the caller's schedule
    /// — [`Sched::Partitioned`] keeps each worker on the same contiguous
    /// vertex shard the engine's dense sweeps assign it; serial otherwise.
    fn merge_with(&mut self, pool: Option<&ThreadPool>, sched: Sched) {
        self.seal_batch_with(pool, sched);
        let n = self.base.num_nodes();
        match pool {
            Some(pool) if pool.threads() > 1 && n > 0 => {
                // Phase 1: live degree per vertex (disjoint writes).
                let mut counts = vec![0u32; n + 1];
                {
                    let cs = SyncSlice::new(&mut counts[1..]);
                    let base = &self.base;
                    let diffs = &self.diffs;
                    pool.parallel_for(n, sched, |v| {
                        let u = v as NodeId;
                        let mut c = base.live_degree(u);
                        for d in diffs {
                            c += d.csr.live_degree(u);
                        }
                        // SAFETY: index v written by exactly one worker.
                        unsafe { cs.set(v, c as u32) };
                    });
                }
                // Phase 2: serial prefix sum → offsets.
                for i in 0..n {
                    counts[i + 1] += counts[i];
                }
                let total = counts[n] as usize;
                let offsets = counts;
                // Phase 3: gather + per-range sort into the new arrays,
                // one disjoint range per vertex, per-worker reusable
                // gather buffers (no steady-state allocation).
                let mut coords = vec![TOMBSTONE; total];
                let mut weights: Vec<Weight> = vec![0; total];
                {
                    let csl = SyncSlice::new(&mut coords);
                    let wsl = SyncSlice::new(&mut weights);
                    let base = &self.base;
                    let diffs = &self.diffs;
                    let offs = &offsets;
                    let mut gather: Vec<Vec<(NodeId, Weight)>> =
                        (0..pool.threads()).map(|_| Vec::new()).collect();
                    pool.parallel_for_with(
                        n,
                        sched,
                        &mut gather,
                        |buf, v| {
                            let u = v as NodeId;
                            let start = offs[v] as usize;
                            let len = (offs[v + 1] - offs[v]) as usize;
                            if len == 0 {
                                return;
                            }
                            buf.clear();
                            buf.extend(base.neighbors(u));
                            for d in diffs {
                                buf.extend(d.csr.neighbors(u));
                            }
                            buf.sort_unstable_by_key(|p| p.0);
                            // SAFETY: [start, start+len) ranges are disjoint
                            // across vertices (prefix-sum offsets).
                            let cdst = unsafe { csl.slice_mut(start, len) };
                            let wdst = unsafe { wsl.slice_mut(start, len) };
                            for (i, &(c, w)) in buf.iter().enumerate() {
                                cdst[i] = c;
                                wdst[i] = w;
                            }
                        },
                    );
                }
                self.base = Csr { offsets, coords, weights };
            }
            _ => {
                let edges = self.live_edges();
                self.base = Csr::from_edges(n, &edges);
            }
        }
        self.diffs.clear();
        self.overflow.iter_mut().for_each(|w| *w = 0);
    }
}

/// The full dynamic graph: forward + backward diff-CSR kept in sync,
/// live out-degree cache, and merge policy.
#[derive(Debug, Clone)]
pub struct DynGraph {
    fwd: DiffCsr,
    bwd: DiffCsr,
    out_degree: Vec<u32>,
    in_degree: Vec<u32>,
    batches_since_merge: usize,
    /// Count of sealed update batches applied since construction — the
    /// graph's *epoch*. The streaming layer pairs this with published
    /// property snapshots so readers can tell which graph version a
    /// property view belongs to.
    epoch: u64,
    /// Merge the diff chain into the base CSR after this many batches
    /// (§3.5: "after a configurable number of batches"). 0 disables the
    /// built-in periodic policy (the streaming batcher drives merges
    /// explicitly via the overflow-bitmap signal instead).
    pub merge_period: usize,
    /// Pool used to parallelize `merge` compaction (engines attach theirs
    /// via [`set_merge_pool`](Self::set_merge_pool)); `None` ⇒ serial.
    merge_pool: Option<ThreadPool>,
    /// Schedule for the merge's per-vertex phases. Engines running
    /// partition-affine ([`Sched::Partitioned`]) hand theirs over via
    /// [`set_merge_sched`](Self::set_merge_sched) so each worker compacts
    /// the CSR shard it owns in the fixed-point sweeps.
    merge_sched: Sched,
}

impl DynGraph {
    /// Wrap a static CSR (computes the transpose and degree caches).
    pub fn from_csr(base: Csr) -> Self {
        let mut base = base;
        base.sort_adjacencies(); // establish the sorted invariant
        let bwd = base.transpose();
        let n = base.num_nodes();
        let mut out_degree = vec![0u32; n];
        let mut in_degree = vec![0u32; n];
        for v in 0..n as NodeId {
            out_degree[v as usize] = base.live_degree(v) as u32;
            in_degree[v as usize] = bwd.live_degree(v) as u32;
        }
        DynGraph {
            fwd: DiffCsr::new(base),
            bwd: DiffCsr::new(bwd),
            out_degree,
            in_degree,
            batches_since_merge: 0,
            epoch: 0,
            merge_period: 8,
            merge_pool: None,
            merge_sched: Sched::Dynamic { chunk: 2048 },
        }
    }

    /// Build from an edge list.
    pub fn from_edges(n: usize, edges: &[(NodeId, NodeId, Weight)]) -> Self {
        Self::from_csr(Csr::from_edges(n, edges))
    }

    /// Attach a thread pool for parallel merge compaction.
    pub fn set_merge_pool(&mut self, pool: ThreadPool) {
        self.merge_pool = Some(pool);
    }

    /// Set the schedule the parallel merge phases run under (engines pass
    /// their own so [`Sched::Partitioned`] shard ownership carries over
    /// from the fixed-point sweeps into compaction).
    pub fn set_merge_sched(&mut self, sched: Sched) {
        self.merge_sched = sched;
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.fwd.base.num_nodes()
    }

    /// Live edge count.
    pub fn num_edges(&self) -> usize {
        self.out_degree.iter().map(|&d| d as usize).sum()
    }

    /// Live out-degree of `v` (`g.count_outNbrs` in the DSL).
    #[inline]
    pub fn out_degree(&self, v: NodeId) -> u32 {
        self.out_degree[v as usize]
    }

    #[inline]
    pub fn in_degree(&self, v: NodeId) -> u32 {
        self.in_degree[v as usize]
    }

    /// Live out-neighbors `(dest, weight)` (`g.neighbors`).
    #[inline]
    pub fn out_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.fwd.neighbors(v)
    }

    /// Live in-neighbors `(src, weight)` (`g.nodes_to`).
    #[inline]
    pub fn in_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.bwd.neighbors(v)
    }

    /// `g.is_an_edge(u, v)` — binary search in the base range and each
    /// diff block; O(log deg) instead of the O(deg) scan this replaced.
    #[inline]
    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.fwd.find(u, v).is_some()
    }

    /// `g.get_edge(u, v).weight`.
    pub fn edge_weight(&self, u: NodeId, v: NodeId) -> Option<Weight> {
        self.fwd.find(u, v)
    }

    /// Delete edge `u -> v` from both directions. Returns true if present.
    pub fn delete_edge(&mut self, u: NodeId, v: NodeId) -> bool {
        if self.fwd.delete(u, v) {
            let ok = self.bwd.delete(v, u);
            debug_assert!(ok, "fwd/bwd desync on delete {u}->{v}");
            self.out_degree[u as usize] -= 1;
            self.in_degree[v as usize] -= 1;
            true
        } else {
            false
        }
    }

    /// Add edge `u -> v` (no-op returning false if already present —
    /// the update generator produces simple graphs).
    pub fn add_edge(&mut self, u: NodeId, v: NodeId, w: Weight) -> bool {
        if self.has_edge(u, v) {
            return false;
        }
        self.fwd.insert(u, v, w);
        self.bwd.insert(v, u, w);
        self.out_degree[u as usize] += 1;
        self.in_degree[v as usize] += 1;
        true
    }

    /// `g.updateCSRDel(batch)` — apply all deletions of a batch.
    pub fn apply_deletions(&mut self, dels: &[(NodeId, NodeId)]) -> usize {
        self.apply_deletions_iter(dels.iter().copied())
    }

    /// Iterator-driven variant of [`apply_deletions`](Self::apply_deletions)
    /// — lets `Batch::deletions()` feed the graph without materializing a
    /// deletion vector.
    pub fn apply_deletions_iter<I>(&mut self, dels: I) -> usize
    where
        I: IntoIterator<Item = (NodeId, NodeId)>,
    {
        let mut applied = 0;
        for (u, v) in dels {
            if self.delete_edge(u, v) {
                applied += 1;
            }
        }
        applied
    }

    /// `g.updateCSRAdd(batch)` — apply all insertions of a batch, then seal
    /// the diff block, advance the graph epoch, and maybe merge per the
    /// built-in periodic merge policy.
    pub fn apply_additions(&mut self, adds: &[(NodeId, NodeId, Weight)]) -> usize {
        self.apply_additions_iter(adds.iter().copied())
    }

    /// Iterator-driven variant of [`apply_additions`](Self::apply_additions).
    pub fn apply_additions_iter<I>(&mut self, adds: I) -> usize
    where
        I: IntoIterator<Item = (NodeId, NodeId, Weight)>,
    {
        let mut applied = 0;
        for (u, v, w) in adds {
            if self.add_edge(u, v, w) {
                applied += 1;
            }
        }
        // Seal under the merge pool/schedule: shard-local for big batches,
        // serial (and identical) otherwise.
        let pool = self.merge_pool.clone();
        self.fwd.seal_batch_with(pool.as_ref(), self.merge_sched);
        self.bwd.seal_batch_with(pool.as_ref(), self.merge_sched);
        self.epoch += 1;
        self.batches_since_merge += 1;
        if self.merge_period > 0 && self.batches_since_merge >= self.merge_period {
            self.merge();
        }
        applied
    }

    /// Graph epoch: number of sealed update batches applied so far.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Vertices whose overflow bit is set (forward side): the conservative
    /// count of sources whose reads traverse the diff chain. This is the
    /// "chain is cold/hot" statistic the streaming batcher's adaptive merge
    /// policy keys on — O(n/64) to compute, maintained for free by inserts.
    pub fn overflow_touched(&self) -> usize {
        self.fwd.overflow_count()
    }

    /// Live edges currently held outside the base CSRs (both directions'
    /// sealed diff blocks plus open pending lists).
    pub fn diff_live_edges(&self) -> usize {
        self.fwd.diff_live() + self.bwd.diff_live()
    }

    /// Compact both directions into fresh tombstone-free CSRs (parallel
    /// when a merge pool is attached).
    pub fn merge(&mut self) {
        let pool = self.merge_pool.clone();
        self.fwd.merge_with(pool.as_ref(), self.merge_sched);
        self.bwd.merge_with(pool.as_ref(), self.merge_sched);
        self.batches_since_merge = 0;
    }

    /// Number of live diff blocks (forward side), for ablation metrics.
    /// The currently-open (unsealed) batch counts as one block.
    pub fn diff_chain_len(&self) -> usize {
        self.fwd.diffs.iter().filter(|d| d.live > 0).count()
            + usize::from(!self.fwd.pending.is_empty())
    }

    /// Remove and return vertex `v`'s live out-row `(dest, weight)`, sorted
    /// by destination. Built for shard migration (churn-driven
    /// rebalancing): the returned row is exactly what
    /// [`ingest_row`](Self::ingest_row) needs to recreate `v`'s ownership
    /// in another shard's `DynGraph`. Goes through
    /// [`delete_edge`](Self::delete_edge), so the backward mirror and both
    /// degree caches stay consistent. Epoch-neutral (only
    /// [`apply_additions`](Self::apply_additions) seals batches).
    pub fn extract_row(&mut self, v: NodeId) -> Vec<(NodeId, Weight)> {
        let mut row: Vec<(NodeId, Weight)> = self.out_neighbors(v).collect();
        row.sort_unstable();
        for &(d, _) in &row {
            let ok = self.delete_edge(v, d);
            debug_assert!(ok, "extract_row: live neighbor {v}->{d} must delete");
        }
        row
    }

    /// Insert a migrated out-row for vertex `v` (the counterpart of
    /// [`extract_row`](Self::extract_row)). Returns the number of edges
    /// inserted (edges already present are skipped, matching
    /// [`add_edge`](Self::add_edge) semantics). Inserts that find no vacant
    /// base slot stage in the pending overflow list and are sealed by the
    /// next batch's `apply_additions` — epoch-neutral here.
    pub fn ingest_row(&mut self, v: NodeId, row: &[(NodeId, Weight)]) -> usize {
        let mut inserted = 0;
        for &(d, w) in row {
            if self.add_edge(v, d, w) {
                inserted += 1;
            }
        }
        inserted
    }

    /// All live edges (sorted) — used by tests/oracles.
    pub fn edges_sorted(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let mut e = self.fwd.live_edges();
        e.sort_unstable();
        e
    }

    /// Borrow the forward base CSR (read paths that want raw slot access,
    /// e.g. the cpu engine hot loop).
    pub fn fwd_base(&self) -> &Csr {
        &self.fwd.base
    }

    /// Borrow the backward base CSR.
    pub fn bwd_base(&self) -> &Csr {
        &self.bwd.base
    }

    /// Forward diff blocks (hot-loop access for engines).
    pub fn fwd_diffs(&self) -> &[DiffBlock] {
        &self.fwd.diffs
    }

    /// Backward diff blocks.
    pub fn bwd_diffs(&self) -> &[DiffBlock] {
        &self.bwd.diffs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_checks;
    use std::collections::BTreeMap;

    fn paper_example() -> DynGraph {
        // Fig. 6: A..F = 0..5; edges of G0 (weights all 1).
        // A->B, B->C, B->D, C->A, D->E, E->F, F->D  (7 edges, 6 vertices)
        DynGraph::from_edges(
            6,
            &[(0, 1, 1), (1, 2, 1), (1, 3, 1), (2, 0, 1), (3, 4, 1), (4, 5, 1), (5, 3, 1)],
        )
    }

    #[test]
    fn figure6_delete_then_add() {
        let mut g = paper_example();
        assert_eq!(g.num_edges(), 7);
        // delete B->D, add E->C (the paper's ΔG)
        assert!(g.delete_edge(1, 3));
        assert!(!g.has_edge(1, 3));
        assert_eq!(g.out_degree(1), 1);
        assert!(g.add_edge(4, 2, 1));
        assert!(g.has_edge(4, 2));
        assert_eq!(g.num_edges(), 7);
        // E had no vacant slot, so the new edge must live in a diff block…
        assert_eq!(g.diff_chain_len(), 1);
        // …and a subsequent B->E insert can reuse B's vacancy in-place.
        assert!(g.add_edge(1, 4, 1));
        assert_eq!(g.diff_chain_len(), 1, "vacant slot reused, no new diff entry");
        assert_eq!(g.fwd_base().live_degree(1), 2);
    }

    #[test]
    fn in_neighbors_mirror_out_neighbors() {
        let mut g = paper_example();
        g.delete_edge(1, 3);
        g.add_edge(4, 2, 9);
        let ins: Vec<_> = g.in_neighbors(2).map(|(u, _)| u).collect();
        assert!(ins.contains(&1) && ins.contains(&4));
        assert_eq!(g.in_degree(3), 1, "only F->D remains");
    }

    #[test]
    fn merge_preserves_graph() {
        let mut g = paper_example();
        g.delete_edge(1, 3);
        g.add_edge(4, 2, 9);
        g.add_edge(0, 5, 4);
        let before = g.edges_sorted();
        g.merge();
        assert_eq!(g.edges_sorted(), before);
        assert_eq!(g.diff_chain_len(), 0);
        assert_eq!(g.fwd_base().count_live(), g.fwd_base().num_slots(), "no tombstones");
    }

    #[test]
    fn parallel_merge_matches_serial() {
        let mk = || {
            let mut g = crate::graph::generators::uniform_random(300, 1500, 9, 99);
            g.merge_period = 0;
            let stream =
                crate::graph::UpdateStream::generate_percent(&g, 25.0, 64, 9, 100);
            for b in stream.batches() {
                g.apply_deletions_iter(b.deletions());
                g.apply_additions_iter(b.additions());
            }
            g
        };
        let mut serial = mk();
        let mut parallel = mk();
        assert!(serial.diff_chain_len() > 0, "chain must be dirty before merge");
        serial.merge();
        parallel.set_merge_pool(ThreadPool::new(4));
        parallel.merge();
        // partition-affine merge must compact identically too
        let mut affine = mk();
        affine.set_merge_pool(ThreadPool::new(4));
        affine.set_merge_sched(Sched::Partitioned);
        affine.merge();
        assert_eq!(serial.edges_sorted(), affine.edges_sorted());
        assert_eq!(affine.fwd_base().count_live(), affine.fwd_base().num_slots());
        assert_eq!(serial.edges_sorted(), parallel.edges_sorted());
        assert_eq!(parallel.diff_chain_len(), 0);
        assert_eq!(
            parallel.fwd_base().count_live(),
            parallel.fwd_base().num_slots(),
            "parallel merge is tombstone-free"
        );
        // per-range sorted invariant holds on the parallel-built CSR
        for v in 0..parallel.num_nodes() as NodeId {
            let nb: Vec<NodeId> = parallel.fwd_base().neighbors(v).map(|(c, _)| c).collect();
            assert!(nb.windows(2).all(|w| w[0] < w[1] || w[0] == w[1]), "sorted {v}");
        }
    }

    /// Shard-local seal satellite: a batch big enough to take the parallel
    /// seal path must produce diff blocks *bitwise identical* to the serial
    /// `Csr::from_edges` path, in both directions.
    #[test]
    fn parallel_seal_matches_serial_bitwise() {
        // from_edges gives exactly-sized (vacancy-free) base ranges, so
        // every fresh insert overflows into the pending list
        let g0 = crate::graph::generators::uniform_random(300, 600, 9, 77);
        let existing: std::collections::HashSet<(NodeId, NodeId)> =
            g0.edges_sorted().iter().map(|&(u, v, _)| (u, v)).collect();
        let mut adds: Vec<(NodeId, NodeId, Weight)> = Vec::new();
        'outer: for u in 0..300u32 {
            for k in 1..300u32 {
                let v = (u + k) % 300;
                if !existing.contains(&(u, v)) {
                    adds.push((u, v, 1 + ((u * 7 + v) % 9) as Weight));
                    if adds.len() > DiffCsr::SEAL_PARALLEL_MIN {
                        break 'outer;
                    }
                }
            }
        }
        assert!(adds.len() > DiffCsr::SEAL_PARALLEL_MIN, "batch must hit the parallel gate");

        let mut serial = g0.clone();
        serial.merge_period = 0;
        serial.apply_additions(&adds);

        let mut sharded = g0.clone();
        sharded.merge_period = 0;
        sharded.set_merge_pool(ThreadPool::new(4));
        sharded.set_merge_sched(Sched::Partitioned);
        sharded.apply_additions(&adds);

        assert_eq!(serial.fwd_diffs().len(), 1, "one sealed block");
        assert_eq!(sharded.fwd_diffs().len(), 1);
        for (s, p) in serial.fwd_diffs().iter().zip(sharded.fwd_diffs()) {
            assert_eq!(s.csr, p.csr, "forward sealed block diverged");
            assert_eq!(s.live, p.live);
        }
        for (s, p) in serial.bwd_diffs().iter().zip(sharded.bwd_diffs()) {
            assert_eq!(s.csr, p.csr, "backward sealed block diverged");
            assert_eq!(s.live, p.live);
        }
        assert_eq!(serial.edges_sorted(), sharded.edges_sorted());
        // the dynamic-sched parallel seal must agree too (disjoint per-
        // vertex ranges make the copy schedule-independent)
        let mut dynsched = g0.clone();
        dynsched.merge_period = 0;
        dynsched.set_merge_pool(ThreadPool::new(3));
        dynsched.apply_additions(&adds);
        assert_eq!(serial.fwd_diffs()[0].csr, dynsched.fwd_diffs()[0].csr);
    }

    #[test]
    fn add_existing_edge_is_rejected() {
        let mut g = paper_example();
        assert!(!g.add_edge(0, 1, 3));
        assert_eq!(g.num_edges(), 7);
    }

    #[test]
    fn delete_then_readd_roundtrip() {
        let mut g = paper_example();
        assert!(g.delete_edge(0, 1));
        assert!(g.add_edge(0, 1, 42));
        assert_eq!(g.edge_weight(0, 1), Some(42));
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.in_degree(1), 1);
    }

    #[test]
    fn pending_edges_visible_and_deletable_before_seal() {
        let mut g = paper_example();
        // E (4) has a full base range: this insert stages in `pending`
        assert!(g.add_edge(4, 2, 7));
        assert_eq!(g.edge_weight(4, 2), Some(7), "pending edge readable");
        let outs: Vec<_> = g.out_neighbors(4).map(|(v, _)| v).collect();
        assert!(outs.contains(&2) && outs.contains(&5));
        // delete it again before any seal — must come out of pending
        assert!(g.delete_edge(4, 2));
        assert!(!g.has_edge(4, 2));
        assert_eq!(g.diff_chain_len(), 0, "pending drained");
    }

    #[test]
    fn batch_application_counts() {
        let mut g = paper_example();
        let d = g.apply_deletions(&[(1, 3), (1, 3), (9 % 6, 0)]); // second is dup
        assert_eq!(d, 1);
        let a = g.apply_additions(&[(4, 2, 1), (0, 1, 1)]); // second exists
        assert_eq!(a, 1);
    }

    #[test]
    fn merge_period_triggers_auto_merge() {
        let mut g = paper_example();
        g.merge_period = 2;
        g.apply_additions(&[(4, 2, 1)]);
        assert_eq!(g.diff_chain_len(), 1);
        g.apply_additions(&[(4, 0, 1)]);
        assert_eq!(g.diff_chain_len(), 0, "merged after 2 batches");
    }

    #[test]
    fn epoch_counts_sealed_batches() {
        let mut g = paper_example();
        assert_eq!(g.epoch(), 0);
        g.apply_deletions(&[(1, 3)]);
        assert_eq!(g.epoch(), 0, "deletions alone do not seal a batch");
        g.apply_additions(&[(4, 2, 1)]);
        assert_eq!(g.epoch(), 1);
        g.apply_additions(&[]);
        assert_eq!(g.epoch(), 2, "empty addition set still seals the batch");
        g.merge();
        assert_eq!(g.epoch(), 2, "merge is epoch-neutral");
    }

    #[test]
    fn overflow_signal_tracks_chain_heat() {
        let mut g = paper_example();
        g.merge_period = 0;
        assert_eq!(g.overflow_touched(), 0);
        assert_eq!(g.diff_live_edges(), 0);
        // E (4) has a full base range: insert overflows into the chain
        g.apply_additions(&[(4, 2, 1)]);
        assert!(g.overflow_touched() >= 1, "source of an overflow insert is flagged");
        assert!(g.diff_live_edges() >= 1);
        g.merge();
        assert_eq!(g.overflow_touched(), 0, "merge resets the bitmap");
        assert_eq!(g.diff_live_edges(), 0);
    }

    /// Migration roundtrip: extracting a row from one replica and ingesting
    /// it into another must move the edges exactly — edge set, both degree
    /// caches, in-neighbor mirrors, and epochs all preserved.
    #[test]
    fn extract_ingest_row_migrates_between_graphs() {
        let full = crate::graph::generators::uniform_random(60, 300, 9, 33);
        let n = full.num_nodes();
        // Split ownership: graph A holds rows of sources < 30, B the rest
        // (both over the full vertex space, like shards).
        let all = full.edges_sorted();
        let ea: Vec<_> = all.iter().copied().filter(|&(u, _, _)| u < 30).collect();
        let eb: Vec<_> = all.iter().copied().filter(|&(u, _, _)| u >= 30).collect();
        let mut ga = DynGraph::from_edges(n, &ea);
        let mut gb = DynGraph::from_edges(n, &eb);
        ga.merge_period = 0;
        gb.merge_period = 0;
        let epoch_a = ga.epoch();
        let epoch_b = gb.epoch();

        // Migrate sources 10..20 from A to B.
        let mut moved_edges = 0usize;
        for v in 10..20u32 {
            let row = ga.extract_row(v);
            assert!(row.windows(2).all(|w| w[0].0 < w[1].0), "row sorted, no dups");
            moved_edges += gb.ingest_row(v, &row);
            assert_eq!(ga.out_degree(v), 0, "row fully drained from A");
            assert_eq!(gb.out_degree(v) as usize, row.len(), "row fully landed in B");
        }
        assert_eq!(ga.epoch(), epoch_a, "extract is epoch-neutral");
        assert_eq!(gb.epoch(), epoch_b, "ingest is epoch-neutral");

        // The union must equal the original graph, with the moved rows in B.
        let mut merged = ga.edges_sorted();
        merged.extend(gb.edges_sorted());
        merged.sort_unstable();
        assert_eq!(merged, all, "no edge lost or duplicated by migration");
        let in_b: usize = (10..20u32).map(|v| gb.out_degree(v) as usize).sum();
        assert_eq!(in_b, moved_edges);
        // In-neighbor mirrors follow the move: B now reports the migrated
        // sources among its in-neighbors.
        for &(u, v, w) in all.iter().filter(|&&(u, _, _)| (10..20).contains(&u)) {
            assert!(gb.has_edge(u, v));
            assert_eq!(gb.edge_weight(u, v), Some(w));
            assert!(gb.in_neighbors(v).any(|(s, sw)| s == u && sw == w));
            assert!(!ga.has_edge(u, v));
        }
        // Empty rows are fine in both directions.
        let empty = ga.extract_row(10);
        assert!(empty.is_empty());
        assert_eq!(gb.ingest_row(10, &empty), 0);
    }

    /// Reference model: adjacency map. diff-CSR must stay equivalent under
    /// arbitrary interleaved update sequences.
    #[test]
    fn prop_diffcsr_equals_model() {
        forall_checks(0xD1FF, 60, |gen| {
            let n = gen.usize_in(2, 24);
            let mut model: BTreeMap<(NodeId, NodeId), Weight> = BTreeMap::new();
            let mut init = Vec::new();
            for _ in 0..gen.usize_in(0, 40) {
                let u = gen.usize_in(0, n - 1) as NodeId;
                let v = gen.usize_in(0, n - 1) as NodeId;
                let w = gen.i64_in(1, 50) as Weight;
                if !model.contains_key(&(u, v)) {
                    model.insert((u, v), w);
                    init.push((u, v, w));
                }
            }
            let mut g = DynGraph::from_edges(n, &init);
            g.merge_period = gen.usize_in(0, 3);
            for _ in 0..gen.usize_in(0, 60) {
                let u = gen.usize_in(0, n - 1) as NodeId;
                let v = gen.usize_in(0, n - 1) as NodeId;
                if gen.bool() {
                    let w = gen.i64_in(1, 50) as Weight;
                    let fresh = !model.contains_key(&(u, v));
                    assert_eq!(g.add_edge(u, v, w), fresh);
                    model.entry((u, v)).or_insert(w);
                } else {
                    let present = model.remove(&(u, v)).is_some();
                    assert_eq!(g.delete_edge(u, v), present);
                }
                if gen.chance(0.05) {
                    g.merge();
                }
            }
            let want: Vec<_> = model.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
            assert_eq!(g.edges_sorted(), want, "edge sets diverged");
            // degree caches must agree with the model
            for v in 0..n as NodeId {
                let od = model.keys().filter(|&&(a, _)| a == v).count() as u32;
                let id = model.keys().filter(|&&(_, b)| b == v).count() as u32;
                assert_eq!(g.out_degree(v), od);
                assert_eq!(g.in_degree(v), id);
            }
        });
    }

    /// Flat-layout property test (batch API): drive random insert/delete
    /// streams through `apply_deletions`/`apply_additions` — exercising
    /// staging, `seal_batch`, the overflow bitmap, and `merge()`
    /// boundaries — and assert `edges_sorted()`, both degree caches, and
    /// `has_edge` over the full vertex square agree with a naive edge-list
    /// oracle *after every batch*, not just at the end.
    #[test]
    fn prop_flat_diffcsr_matches_edge_list_oracle() {
        forall_checks(0xF1A7, 40, |gen| {
            let n = gen.usize_in(2, 14);
            let mut oracle: BTreeMap<(NodeId, NodeId), Weight> = BTreeMap::new();
            let mut init = Vec::new();
            for _ in 0..gen.usize_in(0, 30) {
                let u = gen.usize_in(0, n - 1) as NodeId;
                let v = gen.usize_in(0, n - 1) as NodeId;
                let w = gen.i64_in(1, 9) as Weight;
                if !oracle.contains_key(&(u, v)) {
                    oracle.insert((u, v), w);
                    init.push((u, v, w));
                }
            }
            let mut g = DynGraph::from_edges(n, &init);
            g.merge_period = gen.usize_in(0, 4);
            if gen.bool() {
                g.set_merge_pool(ThreadPool::new(gen.usize_in(2, 4)));
            }
            let batches = gen.usize_in(1, 8);
            for _ in 0..batches {
                // one batch: some deletions of live edges, some additions
                let mut dels = Vec::new();
                for _ in 0..gen.usize_in(0, 4) {
                    if oracle.is_empty() {
                        break;
                    }
                    let keys: Vec<_> = oracle.keys().copied().collect();
                    let &(u, v) = gen.choose(&keys);
                    if oracle.remove(&(u, v)).is_some() {
                        dels.push((u, v));
                    }
                }
                let mut adds = Vec::new();
                for _ in 0..gen.usize_in(0, 6) {
                    let u = gen.usize_in(0, n - 1) as NodeId;
                    let v = gen.usize_in(0, n - 1) as NodeId;
                    let w = gen.i64_in(1, 9) as Weight;
                    if !oracle.contains_key(&(u, v)) {
                        oracle.insert((u, v), w);
                        adds.push((u, v, w));
                    }
                }
                assert_eq!(g.apply_deletions(&dels), dels.len());
                assert_eq!(g.apply_additions(&adds), adds.len());
                if gen.chance(0.2) {
                    g.merge();
                }

                // full agreement with the oracle mid-stream
                let want: Vec<_> = oracle.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
                assert_eq!(g.edges_sorted(), want, "edge list diverged mid-stream");
                for u in 0..n as NodeId {
                    let od = oracle.keys().filter(|&&(a, _)| a == u).count() as u32;
                    let id = oracle.keys().filter(|&&(_, b)| b == u).count() as u32;
                    assert_eq!(g.out_degree(u), od, "out_degree({u})");
                    assert_eq!(g.in_degree(u), id, "in_degree({u})");
                    for v in 0..n as NodeId {
                        assert_eq!(
                            g.has_edge(u, v),
                            oracle.contains_key(&(u, v)),
                            "has_edge({u},{v})"
                        );
                        assert_eq!(g.edge_weight(u, v), oracle.get(&(u, v)).copied());
                    }
                }
            }
        });
    }
}
