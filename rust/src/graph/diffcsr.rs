//! The paper's diff-CSR dynamic graph representation (§3.5), plus the
//! in-edge (transpose) mirror needed by pull-style algorithms
//! (PageRank's `nodes_to`, decremental SSSP).
//!
//! A [`DynGraph`] holds:
//!  * `fwd`: base CSR with tombstoned deletions + a chain of diff blocks
//!    holding insertions that found no vacant slot;
//!  * `bwd`: the same structure for the transposed graph, kept in sync;
//!  * live out-degrees (the paper's `count_outNbrs`, which must not count
//!    tombstones).
//!
//! After a configurable number of batches the diff chain is merged back
//! into a fresh compact CSR (`merge`), exactly as §3.5 describes.

use super::csr::{Csr, TOMBSTONE};
use super::{NodeId, Weight};
use std::collections::HashMap;

/// One auxiliary diff block: a small CSR over the same vertex set holding
/// edges added in one batch that did not fit a vacant base slot.
#[derive(Debug, Clone, Default)]
pub struct DiffBlock {
    /// Per-vertex adjacency (kept as a map-of-vecs; blocks are small —
    /// bounded by the batch's insert count).
    pub adj: HashMap<NodeId, Vec<(NodeId, Weight)>>,
    /// Number of live entries (deletions may tombstone diff entries too).
    pub live: usize,
}

impl DiffBlock {
    fn insert(&mut self, u: NodeId, v: NodeId, w: Weight) {
        self.adj.entry(u).or_default().push((v, w));
        self.live += 1;
    }

    /// Tombstone `u -> v` inside this block. Returns true if found.
    fn delete(&mut self, u: NodeId, v: NodeId) -> bool {
        if let Some(list) = self.adj.get_mut(&u) {
            if let Some(slot) = list.iter_mut().find(|e| e.0 == v) {
                slot.0 = TOMBSTONE;
                self.live -= 1;
                return true;
            }
        }
        false
    }

    fn neighbors(&self, u: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.adj.get(&u).into_iter().flatten().copied().filter(|e| e.0 != TOMBSTONE)
    }
}

/// One direction (out-edges or in-edges) of the dynamic structure.
#[derive(Debug, Clone)]
pub struct DiffCsr {
    pub base: Csr,
    pub diffs: Vec<DiffBlock>,
}

impl DiffCsr {
    fn new(base: Csr) -> Self {
        DiffCsr { base, diffs: Vec::new() }
    }

    fn neighbors(&self, u: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.base.neighbors(u).chain(self.diffs.iter().flat_map(move |d| d.neighbors(u)))
    }

    fn find(&self, u: NodeId, v: NodeId) -> Option<Weight> {
        self.neighbors(u).find(|&(n, _)| n == v).map(|(_, w)| w)
    }

    fn delete(&mut self, u: NodeId, v: NodeId) -> bool {
        if self.base.delete_edge(u, v) {
            return true;
        }
        for d in self.diffs.iter_mut().rev() {
            if d.delete(u, v) {
                return true;
            }
        }
        false
    }

    /// Insert preferring a vacant base slot, else the current diff block
    /// (creating one if needed) — the §3.5 placement policy.
    fn insert(&mut self, u: NodeId, v: NodeId, w: Weight) {
        if self.base.try_insert_in_place(u, v, w) {
            return;
        }
        if self.diffs.is_empty() {
            self.diffs.push(DiffBlock::default());
        }
        self.diffs.last_mut().unwrap().insert(u, v, w);
    }

    /// Start a new diff block for the next batch's overflow inserts.
    fn seal_batch(&mut self) {
        if self.diffs.last().map(|d| !d.adj.is_empty()).unwrap_or(false) {
            self.diffs.push(DiffBlock::default());
        }
    }

    fn live_edges(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let n = self.base.num_nodes();
        let mut out = Vec::new();
        for u in 0..n as NodeId {
            for (v, w) in self.neighbors(u) {
                out.push((u, v, w));
            }
        }
        out
    }

    /// Compact everything into a fresh tombstone-free CSR.
    fn merge(&mut self) {
        let n = self.base.num_nodes();
        let edges = self.live_edges();
        self.base = Csr::from_edges(n, &edges);
        self.diffs.clear();
    }
}

/// The full dynamic graph: forward + backward diff-CSR kept in sync,
/// live out-degree cache, and merge policy.
#[derive(Debug, Clone)]
pub struct DynGraph {
    fwd: DiffCsr,
    bwd: DiffCsr,
    out_degree: Vec<u32>,
    in_degree: Vec<u32>,
    batches_since_merge: usize,
    /// Merge the diff chain into the base CSR after this many batches
    /// (§3.5: "after a configurable number of batches"). 0 disables.
    pub merge_period: usize,
}

impl DynGraph {
    /// Wrap a static CSR (computes the transpose and degree caches).
    pub fn from_csr(base: Csr) -> Self {
        let bwd = base.transpose();
        let n = base.num_nodes();
        let mut out_degree = vec![0u32; n];
        let mut in_degree = vec![0u32; n];
        for v in 0..n as NodeId {
            out_degree[v as usize] = base.live_degree(v) as u32;
            in_degree[v as usize] = bwd.live_degree(v) as u32;
        }
        DynGraph {
            fwd: DiffCsr::new(base),
            bwd: DiffCsr::new(bwd),
            out_degree,
            in_degree,
            batches_since_merge: 0,
            merge_period: 8,
        }
    }

    /// Build from an edge list.
    pub fn from_edges(n: usize, edges: &[(NodeId, NodeId, Weight)]) -> Self {
        Self::from_csr(Csr::from_edges(n, edges))
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.fwd.base.num_nodes()
    }

    /// Live edge count.
    pub fn num_edges(&self) -> usize {
        self.out_degree.iter().map(|&d| d as usize).sum()
    }

    /// Live out-degree of `v` (`g.count_outNbrs` in the DSL).
    #[inline]
    pub fn out_degree(&self, v: NodeId) -> u32 {
        self.out_degree[v as usize]
    }

    #[inline]
    pub fn in_degree(&self, v: NodeId) -> u32 {
        self.in_degree[v as usize]
    }

    /// Live out-neighbors `(dest, weight)` (`g.neighbors`).
    #[inline]
    pub fn out_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.fwd.neighbors(v)
    }

    /// Live in-neighbors `(src, weight)` (`g.nodes_to`).
    #[inline]
    pub fn in_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.bwd.neighbors(v)
    }

    /// `g.is_an_edge(u, v)`.
    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.fwd.find(u, v).is_some()
    }

    /// `g.get_edge(u, v).weight`.
    pub fn edge_weight(&self, u: NodeId, v: NodeId) -> Option<Weight> {
        self.fwd.find(u, v)
    }

    /// Delete edge `u -> v` from both directions. Returns true if present.
    pub fn delete_edge(&mut self, u: NodeId, v: NodeId) -> bool {
        if self.fwd.delete(u, v) {
            let ok = self.bwd.delete(v, u);
            debug_assert!(ok, "fwd/bwd desync on delete {u}->{v}");
            self.out_degree[u as usize] -= 1;
            self.in_degree[v as usize] -= 1;
            true
        } else {
            false
        }
    }

    /// Add edge `u -> v` (no-op returning false if already present —
    /// the update generator produces simple graphs).
    pub fn add_edge(&mut self, u: NodeId, v: NodeId, w: Weight) -> bool {
        if self.has_edge(u, v) {
            return false;
        }
        self.fwd.insert(u, v, w);
        self.bwd.insert(v, u, w);
        self.out_degree[u as usize] += 1;
        self.in_degree[v as usize] += 1;
        true
    }

    /// `g.updateCSRDel(batch)` — apply all deletions of a batch.
    pub fn apply_deletions(&mut self, dels: &[(NodeId, NodeId)]) -> usize {
        dels.iter().filter(|&&(u, v)| self.delete_edge(u, v)).count()
    }

    /// `g.updateCSRAdd(batch)` — apply all insertions of a batch, then seal
    /// the diff block and maybe merge per the merge policy.
    pub fn apply_additions(&mut self, adds: &[(NodeId, NodeId, Weight)]) -> usize {
        let applied = adds.iter().filter(|&&(u, v, w)| self.add_edge(u, v, w)).count();
        self.fwd.seal_batch();
        self.bwd.seal_batch();
        self.batches_since_merge += 1;
        if self.merge_period > 0 && self.batches_since_merge >= self.merge_period {
            self.merge();
        }
        applied
    }

    /// Compact both directions into fresh tombstone-free CSRs.
    pub fn merge(&mut self) {
        self.fwd.merge();
        self.bwd.merge();
        self.batches_since_merge = 0;
    }

    /// Number of live diff blocks (forward side), for ablation metrics.
    pub fn diff_chain_len(&self) -> usize {
        self.fwd.diffs.iter().filter(|d| !d.adj.is_empty()).count()
    }

    /// All live edges (sorted) — used by tests/oracles.
    pub fn edges_sorted(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let mut e = self.fwd.live_edges();
        e.sort_unstable();
        e
    }

    /// Borrow the forward base CSR (read paths that want raw slot access,
    /// e.g. the cpu engine hot loop).
    pub fn fwd_base(&self) -> &Csr {
        &self.fwd.base
    }

    /// Borrow the backward base CSR.
    pub fn bwd_base(&self) -> &Csr {
        &self.bwd.base
    }

    /// Forward diff blocks (hot-loop access for engines).
    pub fn fwd_diffs(&self) -> &[DiffBlock] {
        &self.fwd.diffs
    }

    /// Backward diff blocks.
    pub fn bwd_diffs(&self) -> &[DiffBlock] {
        &self.bwd.diffs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_checks;
    use std::collections::BTreeMap;

    fn paper_example() -> DynGraph {
        // Fig. 6: A..F = 0..5; edges of G0 (weights all 1).
        // A->B, B->C, B->D, C->A, D->E, E->F, F->D  (7 edges, 6 vertices)
        DynGraph::from_edges(
            6,
            &[(0, 1, 1), (1, 2, 1), (1, 3, 1), (2, 0, 1), (3, 4, 1), (4, 5, 1), (5, 3, 1)],
        )
    }

    #[test]
    fn figure6_delete_then_add() {
        let mut g = paper_example();
        assert_eq!(g.num_edges(), 7);
        // delete B->D, add E->C (the paper's ΔG)
        assert!(g.delete_edge(1, 3));
        assert!(!g.has_edge(1, 3));
        assert_eq!(g.out_degree(1), 1);
        assert!(g.add_edge(4, 2, 1));
        assert!(g.has_edge(4, 2));
        assert_eq!(g.num_edges(), 7);
        // E had no vacant slot, so the new edge must live in a diff block…
        assert_eq!(g.diff_chain_len(), 1);
        // …and a subsequent B->E insert can reuse B's vacancy in-place.
        assert!(g.add_edge(1, 4, 1));
        assert_eq!(g.diff_chain_len(), 1, "vacant slot reused, no new diff entry");
        assert_eq!(g.fwd_base().live_degree(1), 2);
    }

    #[test]
    fn in_neighbors_mirror_out_neighbors() {
        let mut g = paper_example();
        g.delete_edge(1, 3);
        g.add_edge(4, 2, 9);
        let ins: Vec<_> = g.in_neighbors(2).map(|(u, _)| u).collect();
        assert!(ins.contains(&1) && ins.contains(&4));
        assert_eq!(g.in_degree(3), 1, "only F->D remains");
    }

    #[test]
    fn merge_preserves_graph() {
        let mut g = paper_example();
        g.delete_edge(1, 3);
        g.add_edge(4, 2, 9);
        g.add_edge(0, 5, 4);
        let before = g.edges_sorted();
        g.merge();
        assert_eq!(g.edges_sorted(), before);
        assert_eq!(g.diff_chain_len(), 0);
        assert_eq!(g.fwd_base().count_live(), g.fwd_base().num_slots(), "no tombstones");
    }

    #[test]
    fn add_existing_edge_is_rejected() {
        let mut g = paper_example();
        assert!(!g.add_edge(0, 1, 3));
        assert_eq!(g.num_edges(), 7);
    }

    #[test]
    fn delete_then_readd_roundtrip() {
        let mut g = paper_example();
        assert!(g.delete_edge(0, 1));
        assert!(g.add_edge(0, 1, 42));
        assert_eq!(g.edge_weight(0, 1), Some(42));
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.in_degree(1), 1);
    }

    #[test]
    fn batch_application_counts() {
        let mut g = paper_example();
        let d = g.apply_deletions(&[(1, 3), (1, 3), (9 % 6, 0)]); // second is dup
        assert_eq!(d, 1);
        let a = g.apply_additions(&[(4, 2, 1), (0, 1, 1)]); // second exists
        assert_eq!(a, 1);
    }

    #[test]
    fn merge_period_triggers_auto_merge() {
        let mut g = paper_example();
        g.merge_period = 2;
        g.apply_additions(&[(4, 2, 1)]);
        assert_eq!(g.diff_chain_len(), 1);
        g.apply_additions(&[(4, 0, 1)]);
        assert_eq!(g.diff_chain_len(), 0, "merged after 2 batches");
    }

    /// Reference model: adjacency map. diff-CSR must stay equivalent under
    /// arbitrary interleaved update sequences.
    #[test]
    fn prop_diffcsr_equals_model() {
        forall_checks(0xD1FF, 60, |gen| {
            let n = gen.usize_in(2, 24);
            let mut model: BTreeMap<(NodeId, NodeId), Weight> = BTreeMap::new();
            let mut init = Vec::new();
            for _ in 0..gen.usize_in(0, 40) {
                let u = gen.usize_in(0, n - 1) as NodeId;
                let v = gen.usize_in(0, n - 1) as NodeId;
                let w = gen.i64_in(1, 50) as Weight;
                if !model.contains_key(&(u, v)) {
                    model.insert((u, v), w);
                    init.push((u, v, w));
                }
            }
            let mut g = DynGraph::from_edges(n, &init);
            g.merge_period = gen.usize_in(0, 3);
            for _ in 0..gen.usize_in(0, 60) {
                let u = gen.usize_in(0, n - 1) as NodeId;
                let v = gen.usize_in(0, n - 1) as NodeId;
                if gen.bool() {
                    let w = gen.i64_in(1, 50) as Weight;
                    let fresh = !model.contains_key(&(u, v));
                    assert_eq!(g.add_edge(u, v, w), fresh);
                    model.entry((u, v)).or_insert(w);
                } else {
                    let present = model.remove(&(u, v)).is_some();
                    assert_eq!(g.delete_edge(u, v), present);
                }
                if gen.chance(0.05) {
                    g.merge();
                }
            }
            let want: Vec<_> = model.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
            assert_eq!(g.edges_sorted(), want, "edge sets diverged");
            // degree caches must agree with the model
            for v in 0..n as NodeId {
                let od = model.keys().filter(|&&(a, _)| a == v).count() as u32;
                let id = model.keys().filter(|&&(_, b)| b == v).count() as u32;
                assert_eq!(g.out_degree(v), od);
                assert_eq!(g.in_degree(v), id);
            }
        });
    }
}
