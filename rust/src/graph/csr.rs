//! Compressed Sparse Row storage with tombstoned deletion.
//!
//! `offsets[v]..offsets[v+1]` indexes `coords`/`weights`; a deleted edge is
//! marked by writing [`TOMBSTONE`] into `coords` (the paper's ∞ sentinel),
//! which avoids the cascading element shifts and cross-thread
//! synchronization an in-place CSR delete would need (§3.5).

use super::{NodeId, Weight};

/// Sentinel marking a vacated (deleted) slot in `coords`.
pub const TOMBSTONE: NodeId = NodeId::MAX;

/// A CSR graph (directed; weighted). Slots may be tombstoned.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// `n + 1` entries; `offsets[v]` is the start of `v`'s slot range.
    pub offsets: Vec<u32>,
    /// Destination vertex per slot, or [`TOMBSTONE`].
    pub coords: Vec<NodeId>,
    /// Weight per slot (undefined for tombstoned slots).
    pub weights: Vec<Weight>,
}

impl Csr {
    /// Build from an edge list. Self-contained counting sort; parallel
    /// edges are kept as-is (the generators de-duplicate when needed).
    pub fn from_edges(n: usize, edges: &[(NodeId, NodeId, Weight)]) -> Csr {
        let mut counts = vec![0u32; n + 1];
        for &(u, _, _) in edges {
            debug_assert!((u as usize) < n);
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut coords = vec![TOMBSTONE; edges.len()];
        let mut weights = vec![0; edges.len()];
        let mut cursor = offsets.clone();
        for &(u, v, w) in edges {
            let slot = cursor[u as usize] as usize;
            cursor[u as usize] += 1;
            coords[slot] = v;
            weights[slot] = w;
        }
        Csr { offsets, coords, weights }
    }

    /// An empty graph over `n` vertices.
    pub fn empty(n: usize) -> Csr {
        Csr { offsets: vec![0; n + 1], coords: Vec::new(), weights: Vec::new() }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total slots (live + tombstoned).
    #[inline]
    pub fn num_slots(&self) -> usize {
        self.coords.len()
    }

    /// Count of live (non-tombstoned) edges. O(slots).
    pub fn count_live(&self) -> usize {
        self.coords.iter().filter(|&&c| c != TOMBSTONE).count()
    }

    /// Slot range of `v`.
    #[inline]
    pub fn slot_range(&self, v: NodeId) -> std::ops::Range<usize> {
        self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize
    }

    /// Iterate live out-edges of `v` as `(dest, weight)`.
    #[inline]
    pub fn neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.slot_range(v).filter_map(move |s| {
            let c = self.coords[s];
            (c != TOMBSTONE).then(|| (c, self.weights[s]))
        })
    }

    /// Degree counting live slots only. O(degree).
    pub fn live_degree(&self, v: NodeId) -> usize {
        self.slot_range(v).filter(|&s| self.coords[s] != TOMBSTONE).count()
    }

    /// Find the slot of edge `u -> v`, if live.
    pub fn find_edge(&self, u: NodeId, v: NodeId) -> Option<usize> {
        self.slot_range(u).find(|&s| self.coords[s] == v)
    }

    /// Tombstone edge `u -> v`. Returns `true` if an edge was deleted.
    pub fn delete_edge(&mut self, u: NodeId, v: NodeId) -> bool {
        if let Some(s) = self.find_edge(u, v) {
            self.coords[s] = TOMBSTONE;
            true
        } else {
            false
        }
    }

    /// Try to insert `u -> v` into a vacant (tombstoned) slot of `u`.
    /// Returns `false` if `u`'s range has no vacancy (caller falls back to
    /// the diff-CSR).
    pub fn try_insert_in_place(&mut self, u: NodeId, v: NodeId, w: Weight) -> bool {
        for s in self.slot_range(u) {
            if self.coords[s] == TOMBSTONE {
                self.coords[s] = v;
                self.weights[s] = w;
                return true;
            }
        }
        false
    }

    /// The transposed graph (in-edges become out-edges). Tombstones are
    /// dropped.
    pub fn transpose(&self) -> Csr {
        let n = self.num_nodes();
        let mut edges = Vec::with_capacity(self.count_live());
        for u in 0..n as NodeId {
            for (v, w) in self.neighbors(u) {
                edges.push((v, u, w));
            }
        }
        Csr::from_edges(n, &edges)
    }

    /// Collect all live edges.
    pub fn edges(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let mut out = Vec::with_capacity(self.count_live());
        for u in 0..self.num_nodes() as NodeId {
            for (v, w) in self.neighbors(u) {
                out.push((u, v, w));
            }
        }
        out
    }

    /// Sort each adjacency range by destination (tombstones last). Enables
    /// binary-search `is_an_edge` (the TC inner loop variant in §6.4).
    pub fn sort_adjacencies(&mut self) {
        let n = self.num_nodes();
        for u in 0..n as NodeId {
            let r = self.slot_range(u);
            let mut pairs: Vec<(NodeId, Weight)> =
                r.clone().map(|s| (self.coords[s], self.weights[s])).collect();
            pairs.sort_unstable_by_key(|p| p.0);
            for (i, s) in r.enumerate() {
                self.coords[s] = pairs[i].0;
                self.weights[s] = pairs[i].1;
            }
        }
    }

    /// Binary-search membership test; requires `sort_adjacencies` first.
    pub fn has_edge_sorted(&self, u: NodeId, v: NodeId) -> bool {
        let r = self.slot_range(u);
        let slice = &self.coords[r];
        slice.binary_search(&v).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // 0->1(5), 0->2(3), 1->2(1), 2->0(2), 3->1(7)
        Csr::from_edges(4, &[(0, 1, 5), (0, 2, 3), (1, 2, 1), (2, 0, 2), (3, 1, 7)])
    }

    #[test]
    fn from_edges_builds_correct_adjacency() {
        let g = sample();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.count_live(), 5);
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 5), (2, 3)]);
        let n3: Vec<_> = g.neighbors(3).collect();
        assert_eq!(n3, vec![(1, 7)]);
        let n2: Vec<_> = g.neighbors(2).collect();
        assert_eq!(n2, vec![(0, 2)]);
    }

    #[test]
    fn empty_graph() {
        let g = Csr::empty(3);
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.count_live(), 0);
        assert_eq!(g.neighbors(1).count(), 0);
    }

    #[test]
    fn delete_tombstones_without_shifting() {
        let mut g = sample();
        let slots_before = g.num_slots();
        assert!(g.delete_edge(0, 2));
        assert_eq!(g.num_slots(), slots_before, "no shift");
        assert_eq!(g.count_live(), 4);
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 5)]);
        assert!(!g.delete_edge(0, 2), "double delete is a no-op");
    }

    #[test]
    fn insert_reuses_vacant_slot() {
        let mut g = sample();
        g.delete_edge(0, 1);
        assert!(g.try_insert_in_place(0, 3, 9), "vacancy available");
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(3, 9), (2, 3)]);
        assert!(!g.try_insert_in_place(0, 1, 1), "no vacancy left");
    }

    #[test]
    fn transpose_inverts_edges() {
        let g = sample();
        let t = g.transpose();
        let mut e: Vec<_> = t.edges();
        e.sort_unstable();
        assert_eq!(e, vec![(0, 2, 2), (1, 0, 5), (1, 3, 7), (2, 0, 3), (2, 1, 1)]);
    }

    #[test]
    fn transpose_skips_tombstones() {
        let mut g = sample();
        g.delete_edge(3, 1);
        let t = g.transpose();
        assert!(t.edges().iter().all(|&(u, v, _)| !(u == 1 && v == 3)));
    }

    #[test]
    fn sorted_membership() {
        let mut g = sample();
        g.sort_adjacencies();
        assert!(g.has_edge_sorted(0, 1));
        assert!(g.has_edge_sorted(0, 2));
        assert!(!g.has_edge_sorted(0, 3));
        assert!(!g.has_edge_sorted(1, 0));
    }

    #[test]
    fn live_degree_ignores_tombstones() {
        let mut g = sample();
        assert_eq!(g.live_degree(0), 2);
        g.delete_edge(0, 1);
        assert_eq!(g.live_degree(0), 1);
    }
}
