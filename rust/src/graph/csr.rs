//! Compressed Sparse Row storage with tombstoned deletion and a
//! **sorted-adjacency invariant**.
//!
//! `offsets[v]..offsets[v+1]` indexes `coords`/`weights`; a deleted edge is
//! marked by writing [`TOMBSTONE`] into `coords` (the paper's ∞ sentinel),
//! which avoids the cascading element shifts and cross-thread
//! synchronization an in-place CSR delete would need (§3.5).
//!
//! Every adjacency range is kept sorted by destination with all tombstones
//! compacted at the tail (TOMBSTONE = `u32::MAX` sorts last naturally).
//! The invariant is established by [`Csr::from_edges`] and preserved by
//! [`Csr::delete_edge`] / [`Csr::try_insert_in_place`] with an O(degree)
//! in-range shift — deg-bounded `memmove`s on contiguous memory, which the
//! profiling in `benches/microbench.rs` shows are far cheaper than the
//! pointer-chasing they replace. In exchange every membership probe
//! (`find_edge`, [`Csr::has_edge_sorted`]) and live-degree query becomes a
//! binary search: O(log deg) instead of O(deg). Triangle counting's
//! per-wedge `is_an_edge` probes are the big winner (§6.4).

use super::{NodeId, Weight};

/// Sentinel marking a vacated (deleted) slot in `coords`.
pub const TOMBSTONE: NodeId = NodeId::MAX;

/// A CSR graph (directed; weighted). Slots may be tombstoned; each range is
/// sorted by destination with tombstones at the tail.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// `n + 1` entries; `offsets[v]` is the start of `v`'s slot range.
    pub offsets: Vec<u32>,
    /// Destination vertex per slot, or [`TOMBSTONE`].
    pub coords: Vec<NodeId>,
    /// Weight per slot (undefined for tombstoned slots).
    pub weights: Vec<Weight>,
}

impl Csr {
    /// Build from an edge list. Self-contained counting sort; parallel
    /// edges are kept as-is (the generators de-duplicate when needed).
    /// Each adjacency range is sorted by destination on construction.
    pub fn from_edges(n: usize, edges: &[(NodeId, NodeId, Weight)]) -> Csr {
        let mut counts = vec![0u32; n + 1];
        for &(u, _, _) in edges {
            debug_assert!((u as usize) < n);
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut coords = vec![TOMBSTONE; edges.len()];
        let mut weights = vec![0; edges.len()];
        let mut cursor = offsets.clone();
        for &(u, v, w) in edges {
            let slot = cursor[u as usize] as usize;
            cursor[u as usize] += 1;
            coords[slot] = v;
            weights[slot] = w;
        }
        let mut csr = Csr { offsets, coords, weights };
        csr.sort_adjacencies();
        csr
    }

    /// An empty graph over `n` vertices.
    pub fn empty(n: usize) -> Csr {
        Csr { offsets: vec![0; n + 1], coords: Vec::new(), weights: Vec::new() }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total slots (live + tombstoned).
    #[inline]
    pub fn num_slots(&self) -> usize {
        self.coords.len()
    }

    /// Count of live (non-tombstoned) edges. O(n log deg) thanks to the
    /// tombstones-at-tail invariant.
    pub fn count_live(&self) -> usize {
        (0..self.num_nodes() as NodeId).map(|v| self.live_degree(v)).sum()
    }

    /// Slot range of `v`.
    #[inline]
    pub fn slot_range(&self, v: NodeId) -> std::ops::Range<usize> {
        self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize
    }

    /// End (exclusive) of the live prefix of `v`'s range: the first
    /// tombstoned slot, found by binary search.
    #[inline]
    pub fn live_end(&self, v: NodeId) -> usize {
        let r = self.slot_range(v);
        let live = self.coords[r.clone()].partition_point(|&c| c != TOMBSTONE);
        r.start + live
    }

    /// Iterate live out-edges of `v` as `(dest, weight)`, in ascending
    /// destination order. Stops at the first tombstone — live slots form a
    /// contiguous sorted prefix.
    #[inline]
    pub fn neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        let r = self.slot_range(v);
        self.coords[r.clone()]
            .iter()
            .zip(&self.weights[r])
            .take_while(|&(&c, _)| c != TOMBSTONE)
            .map(|(&c, &w)| (c, w))
    }

    /// Degree counting live slots only. O(log degree).
    #[inline]
    pub fn live_degree(&self, v: NodeId) -> usize {
        self.live_end(v) - self.slot_range(v).start
    }

    /// Find the slot of edge `u -> v`, if live. O(log degree).
    pub fn find_edge(&self, u: NodeId, v: NodeId) -> Option<usize> {
        let r = self.slot_range(u);
        let live = &self.coords[r.start..self.live_end(u)];
        live.binary_search(&v).ok().map(|i| r.start + i)
    }

    /// Tombstone edge `u -> v`. Returns `true` if an edge was deleted.
    /// Restores the sorted invariant by shifting the live tail left one
    /// slot and parking the tombstone at the end of the live prefix.
    pub fn delete_edge(&mut self, u: NodeId, v: NodeId) -> bool {
        let Some(s) = self.find_edge(u, v) else {
            return false;
        };
        let le = self.live_end(u);
        self.coords.copy_within(s + 1..le, s);
        self.weights.copy_within(s + 1..le, s);
        self.coords[le - 1] = TOMBSTONE;
        true
    }

    /// Try to insert `u -> v` into a vacant (tombstoned) slot of `u`,
    /// keeping the range sorted (binary-search position + right shift).
    /// Returns `false` if `u`'s range has no vacancy (caller falls back to
    /// the diff-CSR).
    pub fn try_insert_in_place(&mut self, u: NodeId, v: NodeId, w: Weight) -> bool {
        let r = self.slot_range(u);
        let le = self.live_end(u);
        if le == r.end {
            return false; // no vacancy
        }
        let pos = r.start + self.coords[r.start..le].partition_point(|&c| c < v);
        self.coords.copy_within(pos..le, pos + 1);
        self.weights.copy_within(pos..le, pos + 1);
        self.coords[pos] = v;
        self.weights[pos] = w;
        true
    }

    /// The transposed graph (in-edges become out-edges). Tombstones are
    /// dropped.
    pub fn transpose(&self) -> Csr {
        let n = self.num_nodes();
        let mut edges = Vec::with_capacity(self.count_live());
        for u in 0..n as NodeId {
            for (v, w) in self.neighbors(u) {
                edges.push((v, u, w));
            }
        }
        Csr::from_edges(n, &edges)
    }

    /// Collect all live edges.
    pub fn edges(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let mut out = Vec::with_capacity(self.count_live());
        for u in 0..self.num_nodes() as NodeId {
            for (v, w) in self.neighbors(u) {
                out.push((u, v, w));
            }
        }
        out
    }

    /// Sort each adjacency range by destination (tombstones last — they are
    /// `u32::MAX`). Establishes the invariant the mutating operations then
    /// maintain incrementally; callers normally never need this.
    pub fn sort_adjacencies(&mut self) {
        let n = self.num_nodes();
        let mut pairs: Vec<(NodeId, Weight)> = Vec::new();
        for u in 0..n as NodeId {
            let r = self.slot_range(u);
            if r.len() <= 1 {
                continue;
            }
            // already sorted? (common after from_edges on sorted input)
            if self.coords[r.clone()].windows(2).all(|w| w[0] <= w[1]) {
                continue;
            }
            pairs.clear();
            pairs.extend(r.clone().map(|s| (self.coords[s], self.weights[s])));
            pairs.sort_unstable_by_key(|p| p.0);
            for (i, s) in r.enumerate() {
                self.coords[s] = pairs[i].0;
                self.weights[s] = pairs[i].1;
            }
        }
    }

    /// Binary-search membership test. O(log degree); the sorted invariant
    /// is maintained by all mutating operations, so this is always valid.
    #[inline]
    pub fn has_edge_sorted(&self, u: NodeId, v: NodeId) -> bool {
        let r = self.slot_range(u);
        self.coords[r].binary_search(&v).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // 0->1(5), 0->2(3), 1->2(1), 2->0(2), 3->1(7)
        Csr::from_edges(4, &[(0, 1, 5), (0, 2, 3), (1, 2, 1), (2, 0, 2), (3, 1, 7)])
    }

    #[test]
    fn from_edges_builds_correct_adjacency() {
        let g = sample();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.count_live(), 5);
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 5), (2, 3)]);
        let n3: Vec<_> = g.neighbors(3).collect();
        assert_eq!(n3, vec![(1, 7)]);
        let n2: Vec<_> = g.neighbors(2).collect();
        assert_eq!(n2, vec![(0, 2)]);
    }

    #[test]
    fn from_edges_sorts_each_range() {
        // edges for vertex 0 arrive out of order
        let g = Csr::from_edges(3, &[(0, 2, 9), (0, 1, 4), (1, 0, 1)]);
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 4), (2, 9)], "range sorted by destination");
    }

    #[test]
    fn empty_graph() {
        let g = Csr::empty(3);
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.count_live(), 0);
        assert_eq!(g.neighbors(1).count(), 0);
    }

    #[test]
    fn delete_tombstones_without_shifting() {
        let mut g = sample();
        let slots_before = g.num_slots();
        assert!(g.delete_edge(0, 2));
        assert_eq!(g.num_slots(), slots_before, "no global shift");
        assert_eq!(g.count_live(), 4);
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 5)]);
        assert!(!g.delete_edge(0, 2), "double delete is a no-op");
    }

    #[test]
    fn delete_keeps_live_prefix_sorted() {
        let mut g = Csr::from_edges(2, &[(0, 1, 1), (0, 3, 3), (0, 5, 5), (0, 7, 7)]);
        assert!(g.delete_edge(0, 3));
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 1), (5, 5), (7, 7)]);
        assert!(g.has_edge_sorted(0, 5));
        assert!(!g.has_edge_sorted(0, 3));
        // tombstone parked at the tail of the live prefix
        assert_eq!(g.live_degree(0), 3);
        assert_eq!(g.coords[3], TOMBSTONE);
    }

    #[test]
    fn insert_reuses_vacant_slot_in_sorted_position() {
        let mut g = sample();
        g.delete_edge(0, 1);
        assert!(g.try_insert_in_place(0, 3, 9), "vacancy available");
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(2, 3), (3, 9)], "insert lands in sorted position");
        assert!(!g.try_insert_in_place(0, 1, 1), "no vacancy left");
    }

    #[test]
    fn insert_below_existing_shifts_right() {
        let mut g = Csr::from_edges(2, &[(0, 2, 2), (0, 4, 4), (0, 6, 6)]);
        g.delete_edge(0, 6);
        assert!(g.try_insert_in_place(0, 1, 1));
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 1), (2, 2), (4, 4)]);
        assert!(g.has_edge_sorted(0, 1));
    }

    #[test]
    fn transpose_inverts_edges() {
        let g = sample();
        let t = g.transpose();
        let mut e: Vec<_> = t.edges();
        e.sort_unstable();
        assert_eq!(e, vec![(0, 2, 2), (1, 0, 5), (1, 3, 7), (2, 0, 3), (2, 1, 1)]);
    }

    #[test]
    fn transpose_skips_tombstones() {
        let mut g = sample();
        g.delete_edge(3, 1);
        let t = g.transpose();
        assert!(t.edges().iter().all(|&(u, v, _)| !(u == 1 && v == 3)));
    }

    #[test]
    fn sorted_membership() {
        let g = sample();
        assert!(g.has_edge_sorted(0, 1));
        assert!(g.has_edge_sorted(0, 2));
        assert!(!g.has_edge_sorted(0, 3));
        assert!(!g.has_edge_sorted(1, 0));
    }

    #[test]
    fn live_degree_ignores_tombstones() {
        let mut g = sample();
        assert_eq!(g.live_degree(0), 2);
        g.delete_edge(0, 1);
        assert_eq!(g.live_degree(0), 1);
    }

    #[test]
    fn churn_preserves_invariant() {
        // hammer one vertex with deletes + in-place inserts; the live
        // prefix must stay sorted and probes exact throughout
        let mut g = Csr::from_edges(
            2,
            &[(0, 1, 1), (0, 2, 2), (0, 3, 3), (0, 4, 4), (0, 5, 5), (0, 6, 6)],
        );
        let mut live: Vec<NodeId> = vec![1, 2, 3, 4, 5, 6];
        let script: &[(bool, NodeId)] =
            &[(false, 3), (false, 6), (true, 10), (false, 1), (true, 0), (true, 3)];
        for &(insert, v) in script {
            if insert {
                assert!(g.try_insert_in_place(0, v, v as Weight + 1));
                live.push(v);
            } else {
                assert!(g.delete_edge(0, v));
                live.retain(|&x| x != v);
            }
            live.sort_unstable();
            let got: Vec<NodeId> = g.neighbors(0).map(|(c, _)| c).collect();
            assert_eq!(got, live, "sorted live prefix after churn");
            for probe in 0..12u32 {
                assert_eq!(g.has_edge_sorted(0, probe), live.contains(&probe));
            }
        }
    }
}
