//! Edge-list file IO: load graphs and update streams from disk, and save
//! them, so experiments can be re-run against fixed inputs.
//!
//! Format (text, one record per line, `#` comments allowed):
//!   graph file:   `u v [w]`
//!   update file:  `a u v w`  or  `d u v`

use super::diffcsr::DynGraph;
use super::updates::{Update, UpdateKind, UpdateStream};
use super::{NodeId, Weight};
use crate::util::error::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Load a directed weighted edge list. `n` is inferred as max id + 1.
pub fn load_edge_list(path: &Path) -> Result<DynGraph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut edges: Vec<(NodeId, NodeId, Weight)> = Vec::new();
    let mut max_id: NodeId = 0;
    for (lineno, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: NodeId = it
            .next()
            .context("missing src")?
            .parse()
            .with_context(|| format!("line {}", lineno + 1))?;
        let v: NodeId = it
            .next()
            .context("missing dst")?
            .parse()
            .with_context(|| format!("line {}", lineno + 1))?;
        let w: Weight = match it.next() {
            Some(s) => s.parse().with_context(|| format!("line {}", lineno + 1))?,
            None => 1,
        };
        max_id = max_id.max(u).max(v);
        edges.push((u, v, w));
    }
    if edges.is_empty() {
        bail!("no edges in {}", path.display());
    }
    Ok(DynGraph::from_edges(max_id as usize + 1, &edges))
}

/// Save a graph as a weighted edge list.
pub fn save_edge_list(g: &DynGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# {} nodes, {} edges", g.num_nodes(), g.num_edges())?;
    for (u, v, wt) in g.edges_sorted() {
        writeln!(w, "{u} {v} {wt}")?;
    }
    Ok(())
}

/// Load an update stream (`a u v w` / `d u v` lines).
pub fn load_updates(path: &Path, batch_size: usize) -> Result<UpdateStream> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut updates = Vec::new();
    for (lineno, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let tag = it.next().context("missing tag")?;
        let ctx = || format!("line {}", lineno + 1);
        let u: NodeId = it.next().context("missing src")?.parse().with_context(ctx)?;
        let v: NodeId = it.next().context("missing dst")?.parse().with_context(ctx)?;
        match tag {
            "a" => {
                let w: Weight = match it.next() {
                    Some(s) => s.parse().with_context(ctx)?,
                    None => 1,
                };
                updates.push(Update { kind: UpdateKind::Add, src: u, dst: v, weight: w });
            }
            "d" => {
                let w: Weight = match it.next() {
                    Some(s) => s.parse().with_context(ctx)?,
                    None => 0,
                };
                updates.push(Update { kind: UpdateKind::Delete, src: u, dst: v, weight: w });
            }
            other => bail!("line {}: unknown tag {other:?}", lineno + 1),
        }
    }
    Ok(UpdateStream::new(updates, batch_size))
}

/// Save an update stream.
pub fn save_updates(s: &UpdateStream, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for u in &s.updates {
        match u.kind {
            UpdateKind::Add => writeln!(w, "a {} {} {}", u.src, u.dst, u.weight)?,
            UpdateKind::Delete => writeln!(w, "d {} {} {}", u.src, u.dst, u.weight)?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("starplat_dyn_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn graph_roundtrip() {
        let g = generators::uniform_random(50, 200, 10, 5);
        let p = tmp("g_roundtrip.el");
        save_edge_list(&g, &p).unwrap();
        let g2 = load_edge_list(&p).unwrap();
        assert_eq!(g.edges_sorted(), g2.edges_sorted());
    }

    #[test]
    fn updates_roundtrip() {
        let g = generators::uniform_random(50, 200, 10, 6);
        let s = UpdateStream::generate_percent(&g, 10.0, 16, 10, 2);
        let p = tmp("u_roundtrip.txt");
        save_updates(&s, &p).unwrap();
        let s2 = load_updates(&p, 16).unwrap();
        assert_eq!(s.updates, s2.updates);
    }

    #[test]
    fn comments_and_default_weight() {
        let p = tmp("commented.el");
        std::fs::write(&p, "# header\n0 1\n1 2 7\n\n# tail\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.edge_weight(0, 1), Some(1));
        assert_eq!(g.edge_weight(1, 2), Some(7));
    }

    #[test]
    fn bad_tag_is_error() {
        let p = tmp("bad.upd");
        std::fs::write(&p, "x 1 2\n").unwrap();
        assert!(load_updates(&p, 4).is_err());
    }
}
