//! Vertex partitioning for the distributed (`dist`) backend.
//!
//! The paper's MPI backend stores the graph "in a distributed manner across
//! all the processes, where each node is owned by a particular process. A
//! process stores only those edges for which the source node is owned by
//! that process" (§3.6). Both the contiguous block partition (StarPlat's
//! default) and a hash partition (for the ablation) are provided.

use super::NodeId;

/// Assignment of vertices to ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Contiguous blocks of `ceil(n/ranks)` vertices per rank.
    Block,
    /// `v % ranks` round-robin (better balance for sorted-degree graphs).
    Hash,
}

/// A concrete partitioning of `n` vertices over `ranks` ranks.
#[derive(Debug, Clone)]
pub struct PartitionMap {
    pub n: usize,
    pub ranks: usize,
    pub kind: Partition,
    per_block: usize,
}

impl PartitionMap {
    pub fn new(n: usize, ranks: usize, kind: Partition) -> Self {
        assert!(ranks >= 1);
        PartitionMap { n, ranks, kind, per_block: n.div_ceil(ranks.max(1)) }
    }

    /// Which rank owns vertex `v`.
    #[inline]
    pub fn owner(&self, v: NodeId) -> usize {
        match self.kind {
            Partition::Block => (v as usize / self.per_block.max(1)).min(self.ranks - 1),
            Partition::Hash => v as usize % self.ranks,
        }
    }

    /// The vertices owned by `rank`, in ascending order.
    pub fn owned(&self, rank: usize) -> Vec<NodeId> {
        match self.kind {
            Partition::Block => {
                let lo = rank * self.per_block;
                let hi = ((rank + 1) * self.per_block).min(self.n);
                (lo..hi).map(|v| v as NodeId).collect()
            }
            Partition::Hash => {
                (rank..self.n).step_by(self.ranks).map(|v| v as NodeId).collect()
            }
        }
    }

    /// The contiguous index range owned by `rank`. Only meaningful for
    /// [`Partition::Block`] (hash shards are not contiguous); the
    /// thread pool's partition-affine schedule
    /// ([`Sched::Partitioned`](crate::util::threadpool::Sched)) uses this
    /// as the allocation-free form of [`owned`](Self::owned).
    #[inline]
    pub fn owned_range(&self, rank: usize) -> std::ops::Range<usize> {
        debug_assert!(
            self.kind == Partition::Block,
            "owned_range is only defined for block partitions"
        );
        let lo = (rank * self.per_block).min(self.n);
        let hi = ((rank + 1) * self.per_block).min(self.n);
        lo..hi
    }

    /// Number of vertices owned by `rank`.
    pub fn owned_count(&self, rank: usize) -> usize {
        match self.kind {
            Partition::Block => {
                let lo = rank * self.per_block;
                let hi = ((rank + 1) * self.per_block).min(self.n);
                hi.saturating_sub(lo)
            }
            Partition::Hash => {
                if rank < self.n {
                    (self.n - rank).div_ceil(self.ranks)
                } else {
                    0
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_checks;

    #[test]
    fn block_partition_covers_all_vertices_once() {
        let p = PartitionMap::new(103, 4, Partition::Block);
        let mut seen = vec![0u32; 103];
        for r in 0..4 {
            for v in p.owned(r) {
                assert_eq!(p.owner(v), r, "owner() and owned() agree");
                seen[v as usize] += 1;
            }
            assert_eq!(p.owned(r).len(), p.owned_count(r));
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn hash_partition_covers_all_vertices_once() {
        let p = PartitionMap::new(97, 5, Partition::Hash);
        let mut seen = vec![0u32; 97];
        for r in 0..5 {
            for v in p.owned(r) {
                assert_eq!(p.owner(v), r);
                seen[v as usize] += 1;
            }
            assert_eq!(p.owned(r).len(), p.owned_count(r));
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn owned_range_matches_owned_for_block() {
        for (n, ranks) in [(103usize, 4usize), (7, 7), (5, 8), (1, 1), (64, 2)] {
            let p = PartitionMap::new(n, ranks, Partition::Block);
            for r in 0..ranks {
                let want: Vec<usize> = p.owned(r).iter().map(|&v| v as usize).collect();
                let got: Vec<usize> = p.owned_range(r).collect();
                assert_eq!(got, want, "n={n} ranks={ranks} rank={r}");
            }
        }
    }

    #[test]
    fn prop_partitions_exact_cover() {
        forall_checks(0xC0FE, 40, |g| {
            let n = g.usize_in(1, 500);
            let ranks = g.usize_in(1, 16);
            let kind = if g.bool() { Partition::Block } else { Partition::Hash };
            let p = PartitionMap::new(n, ranks, kind);
            let mut count = 0usize;
            for r in 0..ranks {
                for v in p.owned(r) {
                    assert_eq!(p.owner(v), r);
                    count += 1;
                }
            }
            assert_eq!(count, n);
        });
    }
}
