//! Vertex partitioning for the distributed (`dist`) backend.
//!
//! The paper's MPI backend stores the graph "in a distributed manner across
//! all the processes, where each node is owned by a particular process. A
//! process stores only those edges for which the source node is owned by
//! that process" (§3.6). Both the contiguous block partition (StarPlat's
//! default) and a hash partition (for the ablation) are provided.

use super::NodeId;

/// Assignment of vertices to ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Contiguous blocks of `ceil(n/ranks)` vertices per rank.
    Block,
    /// `v % ranks` round-robin (better balance for sorted-degree graphs).
    Hash,
    /// Contiguous blocks whose boundaries equalize *edge mass* (out-degree
    /// prefix sums) instead of vertex counts — the ROADMAP's
    /// degree-balanced follow-up to [`Partition::Block`]. Build via
    /// [`PartitionMap::edge_balanced`]; [`PartitionMap::new`] has no
    /// degree information and falls back to vertex-balanced blocks.
    EdgeBalanced,
}

/// A concrete partitioning of `n` vertices over `ranks` ranks.
#[derive(Debug, Clone)]
pub struct PartitionMap {
    pub n: usize,
    pub ranks: usize,
    pub kind: Partition,
    per_block: usize,
    /// Block boundaries for [`Partition::EdgeBalanced`]: rank `r` owns
    /// `bounds[r]..bounds[r+1]` (length `ranks + 1`, monotone, covers
    /// `0..n`). Empty for the closed-form kinds.
    bounds: Vec<usize>,
}

impl PartitionMap {
    pub fn new(n: usize, ranks: usize, kind: Partition) -> Self {
        assert!(ranks >= 1);
        // Without degree information, EdgeBalanced degenerates to the
        // vertex-balanced block split (same contiguous-ownership contract).
        let kind = if kind == Partition::EdgeBalanced { Partition::Block } else { kind };
        PartitionMap { n, ranks, kind, per_block: n.div_ceil(ranks.max(1)), bounds: Vec::new() }
    }

    /// Contiguous blocks with edge-mass-balanced boundaries: boundary `r`
    /// is placed at the first vertex whose out-degree prefix sum reaches
    /// `r/ranks` of the total edge mass (each vertex also counts `1` so
    /// zero-degree tails still spread across ranks). Ownership stays
    /// contiguous — the same contract [`Partition::Block`] gives the
    /// partition-affine schedule — but a skewed graph no longer parks all
    /// its hubs on rank 0's shard.
    ///
    /// The split is a pure function of the degree vector: each boundary is
    /// `prefix.partition_point(|&m| m < target)` over an explicit inclusive
    /// prefix-sum array, i.e. the *smallest* vertex index whose cumulative
    /// mass reaches the rank's target. The earlier incremental scan
    /// resolved ties (runs of zero-mass plateau vertices around a target)
    /// by whatever position the previous boundary's loop had stopped at,
    /// so boundary placement depended on evaluation order; the closed form
    /// makes online re-partitioning (churn-driven rebalancing) reproducible
    /// byte-for-byte.
    pub fn edge_balanced(n: usize, ranks: usize, out_degree: &[u32]) -> Self {
        assert!(ranks >= 1);
        assert_eq!(out_degree.len(), n, "one degree per vertex");
        // prefix[v] = total mass of vertices 0..v (exclusive; length n+1)
        let mut prefix = Vec::with_capacity(n + 1);
        let mut acc: u64 = 0;
        prefix.push(0);
        for &d in out_degree {
            acc += d as u64 + 1;
            prefix.push(acc);
        }
        let total = acc;
        let mut bounds = Vec::with_capacity(ranks + 1);
        bounds.push(0);
        for r in 1..ranks {
            let target = total * r as u64 / ranks as u64;
            // Smallest v whose first-v-vertices mass reaches the target:
            // everything strictly below the boundary belongs to earlier
            // ranks. `target <= total = prefix[n]`, so the result is <= n.
            bounds.push(prefix.partition_point(|&m| m < target));
        }
        bounds.push(n);
        debug_assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
        PartitionMap {
            n,
            ranks,
            kind: Partition::EdgeBalanced,
            per_block: n.div_ceil(ranks),
            bounds,
        }
    }

    /// Which rank owns vertex `v`.
    #[inline]
    pub fn owner(&self, v: NodeId) -> usize {
        match self.kind {
            Partition::Block => (v as usize / self.per_block.max(1)).min(self.ranks - 1),
            Partition::Hash => v as usize % self.ranks,
            Partition::EdgeBalanced => {
                // first boundary strictly above v, minus one
                self.bounds.partition_point(|&b| b <= v as usize) - 1
            }
        }
    }

    /// The vertices owned by `rank`, in ascending order.
    pub fn owned(&self, rank: usize) -> Vec<NodeId> {
        match self.kind {
            Partition::Block | Partition::EdgeBalanced => {
                self.owned_range(rank).map(|v| v as NodeId).collect()
            }
            Partition::Hash => {
                (rank..self.n).step_by(self.ranks).map(|v| v as NodeId).collect()
            }
        }
    }

    /// The contiguous index range owned by `rank`. Only meaningful for
    /// the contiguous kinds ([`Partition::Block`] /
    /// [`Partition::EdgeBalanced`]; hash shards are not contiguous); the
    /// thread pool's partition-affine schedule
    /// ([`Sched::Partitioned`](crate::util::threadpool::Sched)) uses this
    /// as the allocation-free form of [`owned`](Self::owned).
    #[inline]
    pub fn owned_range(&self, rank: usize) -> std::ops::Range<usize> {
        debug_assert!(
            self.kind != Partition::Hash,
            "owned_range is only defined for contiguous partitions"
        );
        if self.kind == Partition::EdgeBalanced {
            return self.bounds[rank]..self.bounds[rank + 1];
        }
        let lo = (rank * self.per_block).min(self.n);
        let hi = ((rank + 1) * self.per_block).min(self.n);
        lo..hi
    }

    /// Number of vertices owned by `rank`.
    pub fn owned_count(&self, rank: usize) -> usize {
        match self.kind {
            Partition::Block | Partition::EdgeBalanced => self.owned_range(rank).len(),
            Partition::Hash => {
                if rank < self.n {
                    (self.n - rank).div_ceil(self.ranks)
                } else {
                    0
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_checks;

    #[test]
    fn block_partition_covers_all_vertices_once() {
        let p = PartitionMap::new(103, 4, Partition::Block);
        let mut seen = vec![0u32; 103];
        for r in 0..4 {
            for v in p.owned(r) {
                assert_eq!(p.owner(v), r, "owner() and owned() agree");
                seen[v as usize] += 1;
            }
            assert_eq!(p.owned(r).len(), p.owned_count(r));
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn hash_partition_covers_all_vertices_once() {
        let p = PartitionMap::new(97, 5, Partition::Hash);
        let mut seen = vec![0u32; 97];
        for r in 0..5 {
            for v in p.owned(r) {
                assert_eq!(p.owner(v), r);
                seen[v as usize] += 1;
            }
            assert_eq!(p.owned(r).len(), p.owned_count(r));
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn owned_range_matches_owned_for_block() {
        for (n, ranks) in [(103usize, 4usize), (7, 7), (5, 8), (1, 1), (64, 2)] {
            let p = PartitionMap::new(n, ranks, Partition::Block);
            for r in 0..ranks {
                let want: Vec<usize> = p.owned(r).iter().map(|&v| v as usize).collect();
                let got: Vec<usize> = p.owned_range(r).collect();
                assert_eq!(got, want, "n={n} ranks={ranks} rank={r}");
            }
        }
    }

    #[test]
    fn edge_balanced_covers_all_vertices_once_and_balances_mass() {
        // heavily skewed degrees: first 8 vertices carry almost all edges
        let mut deg = vec![1u32; 96];
        let mut hubs = vec![100u32; 8];
        hubs.append(&mut deg);
        let p = PartitionMap::edge_balanced(104, 4, &hubs);
        let mut seen = vec![0u32; 104];
        for r in 0..4 {
            let range = p.owned_range(r);
            for v in range.clone() {
                assert_eq!(p.owner(v as NodeId), r, "owner/owned_range agree");
                seen[v] += 1;
            }
            assert_eq!(p.owned(r).len(), p.owned_count(r));
            assert_eq!(p.owned(r).len(), range.len());
        }
        assert!(seen.iter().all(|&c| c == 1), "exact cover");
        // the mass-balanced split must not park every hub on rank 0: the
        // vertex-balanced split would give rank 0 vertices 0..26 (all 8
        // hubs); edge balancing must cut far earlier.
        assert!(
            p.owned_range(0).len() < 8,
            "rank 0 owns {} vertices — hubs not spread",
            p.owned_range(0).len()
        );
        // per-rank edge mass within 2 hub-weights of the ideal quarter
        let total: u64 = hubs.iter().map(|&d| d as u64 + 1).sum();
        for r in 0..4 {
            let mass: u64 = p.owned_range(r).map(|v| hubs[v] as u64 + 1).sum();
            assert!(
                mass <= total / 4 + 202,
                "rank {r} mass {mass} vs ideal {}",
                total / 4
            );
        }
    }

    #[test]
    fn edge_balanced_degenerates_gracefully() {
        // all-zero degrees: falls back to (roughly) vertex-balanced blocks
        let p = PartitionMap::edge_balanced(10, 3, &[0; 10]);
        let mut count = 0;
        for r in 0..3 {
            count += p.owned_count(r);
        }
        assert_eq!(count, 10);
        // one rank: owns everything
        let p1 = PartitionMap::edge_balanced(7, 1, &[5; 7]);
        assert_eq!(p1.owned_range(0), 0..7);
        // more ranks than vertices: trailing ranks own empty ranges
        let p8 = PartitionMap::edge_balanced(3, 8, &[1; 3]);
        let mut seen = vec![0u32; 3];
        for r in 0..8 {
            for v in p8.owned(r) {
                assert_eq!(p8.owner(v), r);
                seen[v as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        // new() with EdgeBalanced but no degrees falls back to Block
        let pb = PartitionMap::new(100, 4, Partition::EdgeBalanced);
        assert_eq!(pb.kind, Partition::Block);
    }

    #[test]
    fn edge_balanced_boundaries_are_deterministic_and_minimal() {
        // Uniform plateau: every boundary must land exactly on the closed
        // form `first v with mass(0..v) >= total*r/ranks`, independent of
        // scan order. Pins the deterministic prefix-sum split.
        let p = PartitionMap::edge_balanced(100, 4, &[0; 100]);
        let bounds: Vec<usize> = (0..4).map(|r| p.owned_range(r).start).collect();
        assert_eq!(bounds, vec![0, 25, 50, 75]);
        assert_eq!(p.owned_range(3), 75..100);

        // Skewed case: check minimality of every boundary against a
        // from-scratch prefix scan (no dependence on earlier boundaries).
        let deg: Vec<u32> = [40u32, 0, 0, 3, 3, 3, 0, 0, 12, 1, 0, 7].to_vec();
        let n = deg.len();
        let ranks = 5;
        let p = PartitionMap::edge_balanced(n, ranks, &deg);
        let total: u64 = deg.iter().map(|&d| d as u64 + 1).sum();
        for r in 1..ranks {
            let target = total * r as u64 / ranks as u64;
            let b = p.owned_range(r).start;
            let mass = |v: usize| -> u64 { deg[..v].iter().map(|&d| d as u64 + 1).sum() };
            assert!(mass(b) >= target, "rank {r}: boundary {b} reaches target");
            assert!(
                b == 0 || mass(b - 1) < target,
                "rank {r}: boundary {b} is the smallest qualifying vertex"
            );
        }
        // Identical inputs give identical boundaries (pure function).
        let q = PartitionMap::edge_balanced(n, ranks, &deg);
        for r in 0..ranks {
            assert_eq!(p.owned_range(r), q.owned_range(r));
        }
    }

    #[test]
    fn prop_edge_balanced_exact_cover() {
        forall_checks(0xEB01, 30, |g| {
            let n = g.usize_in(1, 400);
            let ranks = g.usize_in(1, 16);
            let deg: Vec<u32> =
                (0..n).map(|_| g.usize_in(0, 50) as u32).collect();
            let p = PartitionMap::edge_balanced(n, ranks, &deg);
            let mut count = 0usize;
            let mut prev_end = 0usize;
            for r in 0..ranks {
                let range = p.owned_range(r);
                assert_eq!(range.start, prev_end, "ranges contiguous in rank order");
                prev_end = range.end;
                for v in range {
                    assert_eq!(p.owner(v as NodeId), r);
                    count += 1;
                }
            }
            assert_eq!(prev_end, n);
            assert_eq!(count, n);
        });
    }

    #[test]
    fn prop_partitions_exact_cover() {
        forall_checks(0xC0FE, 40, |g| {
            let n = g.usize_in(1, 500);
            let ranks = g.usize_in(1, 16);
            let kind = if g.bool() { Partition::Block } else { Partition::Hash };
            let p = PartitionMap::new(n, ranks, kind);
            let mut count = 0usize;
            for r in 0..ranks {
                for v in p.owned(r) {
                    assert_eq!(p.owner(v), r);
                    count += 1;
                }
            }
            assert_eq!(count, n);
        });
    }
}
