//! Update streams and batching (the DSL's `updates<g>` +
//! `Batch(updateList:batchSize)` + `currentBatch()` machinery).
//!
//! The experimental protocol of §6 is implemented by
//! [`UpdateStream::generate_percent`]: given a graph and an update
//! percentage `p`, produce `p% · |E|` updates split between deletions of
//! existing edges and insertions of fresh edges, applied batch-wise.

use super::diffcsr::DynGraph;
use super::{NodeId, Weight};
use crate::util::Rng;

/// Kind of a single structural update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateKind {
    Add,
    Delete,
}

/// One edge update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Update {
    pub kind: UpdateKind,
    pub src: NodeId,
    pub dst: NodeId,
    /// Weight for additions (ignored for deletions).
    pub weight: Weight,
}

/// Mix of update kinds in a generated stream (§3.3.1: fully dynamic,
/// incremental-only, or decremental-only processing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateMix {
    /// half deletions, half insertions (the §6 protocol)
    Full,
    /// insertions only
    IncrementalOnly,
    /// deletions only
    DecrementalOnly,
}

/// A sequence of updates processed in batches of `batch_size`
/// (`Batch(allUpdates:batchSize)` in the DSL).
#[derive(Debug, Clone)]
pub struct UpdateStream {
    pub updates: Vec<Update>,
    pub batch_size: usize,
}

/// A view of one batch, pre-split into the deletion and addition subsets
/// (`currentBatch(0)` / `currentBatch(1)` in the DSL's TC/PR drivers).
#[derive(Debug, Clone)]
pub struct Batch<'a> {
    pub updates: &'a [Update],
}

impl<'a> Batch<'a> {
    /// The deletions of this batch as `(src, dst)`. Allocation-free: the
    /// iterator walks the underlying update slice directly (callers that
    /// need a slice collect; hot loops use [`split_into`](Self::split_into)
    /// with reusable buffers instead).
    pub fn deletions(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.updates
            .iter()
            .filter(|u| u.kind == UpdateKind::Delete)
            .map(|u| (u.src, u.dst))
    }

    /// The additions of this batch as `(src, dst, weight)`; allocation-free
    /// like [`deletions`](Self::deletions).
    pub fn additions(&self) -> impl Iterator<Item = (NodeId, NodeId, Weight)> + '_ {
        self.updates
            .iter()
            .filter(|u| u.kind == UpdateKind::Add)
            .map(|u| (u.src, u.dst, u.weight))
    }

    /// Split the batch into caller-provided deletion/addition buffers
    /// (cleared first). The streaming hot loop reuses two buffers across
    /// batches so batch decomposition allocates nothing in steady state.
    pub fn split_into(
        &self,
        dels: &mut Vec<(NodeId, NodeId)>,
        adds: &mut Vec<(NodeId, NodeId, Weight)>,
    ) {
        dels.clear();
        adds.clear();
        for u in self.updates {
            match u.kind {
                UpdateKind::Delete => dels.push((u.src, u.dst)),
                UpdateKind::Add => adds.push((u.src, u.dst, u.weight)),
            }
        }
    }

    pub fn len(&self) -> usize {
        self.updates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.updates.is_empty()
    }
}

impl UpdateStream {
    pub fn new(updates: Vec<Update>, batch_size: usize) -> Self {
        UpdateStream { updates, batch_size: batch_size.max(1) }
    }

    /// Number of batches.
    pub fn num_batches(&self) -> usize {
        self.updates.len().div_ceil(self.batch_size)
    }

    /// Iterate batches in order.
    pub fn batches(&self) -> impl Iterator<Item = Batch<'_>> {
        self.updates.chunks(self.batch_size).map(|c| Batch { updates: c })
    }

    pub fn len(&self) -> usize {
        self.updates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.updates.is_empty()
    }

    /// §6 protocol: generate `percent`% of `|E|` updates against `g`.
    ///
    /// Half are deletions sampled from the *current live* edge set (without
    /// replacement), half are insertions of edges not currently present
    /// (endpoints uniform; weights in `[1, max_w]`). Deterministic in
    /// `seed`.
    pub fn generate_percent(
        g: &DynGraph,
        percent: f64,
        batch_size: usize,
        max_w: Weight,
        seed: u64,
    ) -> UpdateStream {
        Self::generate_percent_mix(g, percent, batch_size, max_w, seed, UpdateMix::Full)
    }

    /// §3.3.1: partially-dynamic workloads — incremental-only or
    /// decremental-only streams for applications that process a single
    /// update kind.
    pub fn generate_percent_mix(
        g: &DynGraph,
        percent: f64,
        batch_size: usize,
        max_w: Weight,
        seed: u64,
        mix: UpdateMix,
    ) -> UpdateStream {
        let m = g.num_edges();
        let total = ((m as f64) * percent / 100.0).round() as usize;
        Self::generate_count_mix(g, total, batch_size, max_w, seed, mix)
    }

    /// Generate an exact number of updates (used by tests and sweeps).
    pub fn generate_count(
        g: &DynGraph,
        total: usize,
        batch_size: usize,
        max_w: Weight,
        seed: u64,
    ) -> UpdateStream {
        Self::generate_count_mix(g, total, batch_size, max_w, seed, UpdateMix::Full)
    }

    /// Exact count with an update-kind mix.
    pub fn generate_count_mix(
        g: &DynGraph,
        total: usize,
        batch_size: usize,
        max_w: Weight,
        seed: u64,
        mix: UpdateMix,
    ) -> UpdateStream {
        let mut rng = Rng::new(seed);
        let n = g.num_nodes();
        let n_del = match mix {
            UpdateMix::Full => total / 2,
            UpdateMix::IncrementalOnly => 0,
            UpdateMix::DecrementalOnly => total,
        };
        let n_add = total - n_del;

        // Deletions: sample distinct live edges.
        let live = g.edges_sorted();
        let n_del = n_del.min(live.len());
        let idx = rng.sample_distinct(live.len().max(1), if live.is_empty() { 0 } else { n_del });
        let mut updates: Vec<Update> = idx
            .into_iter()
            .map(|i| {
                let (u, v, w) = live[i];
                Update { kind: UpdateKind::Delete, src: u, dst: v, weight: w }
            })
            .collect();

        // Additions: fresh, non-self, non-duplicate edges.
        let mut present: std::collections::HashSet<(NodeId, NodeId)> =
            live.iter().map(|&(u, v, _)| (u, v)).collect();
        // Deleted edges become insertable again only after their batch; to
        // keep the stream simple we never re-add a deleted edge.
        let mut added = 0usize;
        let mut attempts = 0usize;
        while added < n_add && attempts < n_add * 64 + 1024 {
            attempts += 1;
            let u = rng.below_usize(n) as NodeId;
            let v = rng.below_usize(n) as NodeId;
            if u == v || present.contains(&(u, v)) {
                continue;
            }
            present.insert((u, v));
            updates.push(Update {
                kind: UpdateKind::Add,
                src: u,
                dst: v,
                weight: 1 + rng.below(max_w.max(1) as u64) as Weight,
            });
            added += 1;
        }
        // Interleave adds/deletes deterministically so every batch contains
        // both kinds (the paper's batches are mixed).
        rng.shuffle(&mut updates);
        UpdateStream::new(updates, batch_size)
    }

    /// Skewed (zipfian hub-heavy) churn: like
    /// [`generate_count`](Self::generate_count), but addition *sources* are
    /// drawn zipf-like (exponent 1) over the `hubs` lowest vertex ids —
    /// rank `i` is chosen with probability ∝ `1/(i+1)` — so insert mass
    /// piles onto a handful of contiguous hub rows. Deletions still sample
    /// the live edge set uniformly. This is the adversarial workload for
    /// the sharded runtime: seed-time `edge_balanced` boundaries go stale
    /// as the hubs grow, exercising in-phase stealing and churn-driven
    /// rebalancing. Deterministic in `seed`.
    pub fn generate_count_skewed(
        g: &DynGraph,
        total: usize,
        batch_size: usize,
        max_w: Weight,
        seed: u64,
        hubs: usize,
    ) -> UpdateStream {
        let mut rng = Rng::new(seed);
        let n = g.num_nodes();
        let hubs = hubs.clamp(1, n.max(1));
        let n_del = total / 2;
        let n_add = total - n_del;

        let live = g.edges_sorted();
        let n_del = n_del.min(live.len());
        let idx = rng.sample_distinct(live.len().max(1), if live.is_empty() { 0 } else { n_del });
        let mut updates: Vec<Update> = idx
            .into_iter()
            .map(|i| {
                let (u, v, w) = live[i];
                Update { kind: UpdateKind::Delete, src: u, dst: v, weight: w }
            })
            .collect();

        let mut present: std::collections::HashSet<(NodeId, NodeId)> =
            live.iter().map(|&(u, v, _)| (u, v)).collect();
        let mut added = 0usize;
        let mut attempts = 0usize;
        while added < n_add && attempts < n_add * 64 + 1024 {
            attempts += 1;
            // Zipf(1) over hub ranks by rejection: accept rank i with
            // probability 1/(i+1).
            let u = loop {
                let i = rng.below_usize(hubs);
                if rng.below(i as u64 + 1) == 0 {
                    break i as NodeId;
                }
            };
            let v = rng.below_usize(n) as NodeId;
            if u == v || present.contains(&(u, v)) {
                continue;
            }
            present.insert((u, v));
            updates.push(Update {
                kind: UpdateKind::Add,
                src: u,
                dst: v,
                weight: 1 + rng.below(max_w.max(1) as u64) as Weight,
            });
            added += 1;
        }
        rng.shuffle(&mut updates);
        UpdateStream::new(updates, batch_size)
    }

    /// Apply the whole stream *statically*: mutate `g` up-front with no
    /// per-batch processing (the paper's static-algorithm protocol, where
    /// properties are then recomputed from scratch).
    pub fn apply_all_static(&self, g: &mut DynGraph) {
        for batch in self.batches() {
            g.apply_deletions_iter(batch.deletions());
            g.apply_additions_iter(batch.additions());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::propcheck::forall_checks;

    fn small_graph(seed: u64) -> DynGraph {
        generators::uniform_random(200, 800, 10, seed)
    }

    #[test]
    fn generate_percent_counts() {
        let g = small_graph(1);
        let m = g.num_edges();
        let s = UpdateStream::generate_percent(&g, 10.0, 64, 10, 7);
        let want = ((m as f64) * 0.10).round() as usize;
        assert_eq!(s.len(), want);
        let dels = s.updates.iter().filter(|u| u.kind == UpdateKind::Delete).count();
        assert_eq!(dels, want / 2);
    }

    #[test]
    fn batching_covers_stream_in_order() {
        let g = small_graph(2);
        let s = UpdateStream::generate_percent(&g, 5.0, 7, 10, 3);
        let n: usize = s.batches().map(|b| b.len()).sum();
        assert_eq!(n, s.len());
        assert_eq!(s.num_batches(), s.len().div_ceil(7));
        let flat: Vec<Update> = s.batches().flat_map(|b| b.updates.to_vec()).collect();
        assert_eq!(flat, s.updates);
    }

    #[test]
    fn deletions_exist_additions_fresh() {
        let g = small_graph(3);
        let s = UpdateStream::generate_percent(&g, 8.0, 32, 10, 11);
        for u in &s.updates {
            match u.kind {
                UpdateKind::Delete => assert!(g.has_edge(u.src, u.dst), "delete of absent edge"),
                UpdateKind::Add => {
                    assert!(!g.has_edge(u.src, u.dst), "add of existing edge");
                    assert!(u.src != u.dst);
                    assert!(u.weight >= 1);
                }
            }
        }
    }

    #[test]
    fn apply_all_static_matches_batchwise() {
        let g0 = small_graph(4);
        let s = UpdateStream::generate_percent(&g0, 12.0, 16, 10, 13);
        let mut a = g0.clone();
        s.apply_all_static(&mut a);
        let mut b = g0.clone();
        for batch in s.batches() {
            b.apply_deletions_iter(batch.deletions());
            b.apply_additions_iter(batch.additions());
        }
        assert_eq!(a.edges_sorted(), b.edges_sorted());
    }

    #[test]
    fn split_into_matches_iterators_and_reuses_buffers() {
        let g = small_graph(7);
        let s = UpdateStream::generate_percent(&g, 10.0, 16, 10, 21);
        let mut dels = Vec::new();
        let mut adds = Vec::new();
        for batch in s.batches() {
            batch.split_into(&mut dels, &mut adds);
            assert_eq!(dels, batch.deletions().collect::<Vec<_>>());
            assert_eq!(adds, batch.additions().collect::<Vec<_>>());
            assert_eq!(dels.len() + adds.len(), batch.len());
        }
        // buffers survive the loop with capacity retained — the streaming
        // hot loop relies on this to stay allocation-free per batch
        let cap = (dels.capacity(), adds.capacity());
        for batch in s.batches() {
            batch.split_into(&mut dels, &mut adds);
        }
        assert!(dels.capacity() >= cap.0 && adds.capacity() >= cap.1);
    }

    #[test]
    fn deterministic_in_seed() {
        let g = small_graph(5);
        let a = UpdateStream::generate_percent(&g, 6.0, 8, 10, 99);
        let b = UpdateStream::generate_percent(&g, 6.0, 8, 10, 99);
        assert_eq!(a.updates, b.updates);
    }

    #[test]
    fn mixes_generate_only_requested_kinds() {
        let g = small_graph(9);
        let inc =
            UpdateStream::generate_percent_mix(&g, 10.0, 8, 9, 4, UpdateMix::IncrementalOnly);
        assert!(!inc.is_empty());
        assert!(inc.updates.iter().all(|u| u.kind == UpdateKind::Add));
        let dec =
            UpdateStream::generate_percent_mix(&g, 10.0, 8, 9, 4, UpdateMix::DecrementalOnly);
        assert!(!dec.is_empty());
        assert!(dec.updates.iter().all(|u| u.kind == UpdateKind::Delete));
        // both modes still apply cleanly
        let mut ga = g.clone();
        inc.apply_all_static(&mut ga);
        assert_eq!(ga.num_edges(), g.num_edges() + inc.len());
        let mut gd = g.clone();
        dec.apply_all_static(&mut gd);
        assert_eq!(gd.num_edges(), g.num_edges() - dec.len());
    }

    #[test]
    fn skewed_stream_concentrates_additions_on_hubs() {
        let g = small_graph(12);
        let s = UpdateStream::generate_count_skewed(&g, 400, 32, 9, 17, 8);
        let adds: Vec<&Update> =
            s.updates.iter().filter(|u| u.kind == UpdateKind::Add).collect();
        assert!(!adds.is_empty());
        // every addition source is a hub, fresh, non-self
        for u in &adds {
            assert!(u.src < 8, "hub-heavy source");
            assert!(u.src != u.dst);
            assert!(!g.has_edge(u.src, u.dst));
        }
        // zipf skew: hub 0 strictly dominates the tail hub
        let c0 = adds.iter().filter(|u| u.src == 0).count();
        let c7 = adds.iter().filter(|u| u.src == 7).count();
        assert!(c0 > c7, "zipf head {c0} must beat tail {c7}");
        // deletions still target live edges; stream applies cleanly
        let mut ga = g.clone();
        for b in s.batches() {
            ga.apply_deletions_iter(b.deletions());
            ga.apply_additions_iter(b.additions());
        }
        // deterministic in seed
        let t = UpdateStream::generate_count_skewed(&g, 400, 32, 9, 17, 8);
        assert_eq!(s.updates, t.updates);
    }

    #[test]
    fn prop_stream_is_applicable_without_conflicts() {
        forall_checks(0x5EED, 25, |gen| {
            let n = gen.usize_in(10, 80);
            let e = gen.usize_in(n, n * 4);
            let g0 = generators::uniform_random(n, e, 10, gen.rng().next_u64());
            let pct = gen.f64_unit() * 20.0;
            let s = UpdateStream::generate_percent(&g0, pct, gen.usize_in(1, 32), 10, 5);
            let mut g = g0.clone();
            let mut applied_del = 0;
            let mut applied_add = 0;
            for batch in s.batches() {
                applied_del += g.apply_deletions_iter(batch.deletions());
                applied_add += g.apply_additions_iter(batch.additions());
            }
            let dels = s.updates.iter().filter(|u| u.kind == UpdateKind::Delete).count();
            assert_eq!(applied_del, dels, "every generated deletion applies");
            assert_eq!(applied_add, s.len() - dels, "every generated addition applies");
            assert_eq!(g.num_edges(), g0.num_edges() - applied_del + applied_add);
        });
    }
}
