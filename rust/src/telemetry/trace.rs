//! Chrome-trace-event / Perfetto JSON export.
//!
//! Renders every [`Tracer`] track as one trace "thread": a `M`
//! (metadata) `thread_name` event naming the track, then the retained
//! spans as `X` (complete) events with microsecond `ts`/`dur` relative
//! to the tracer anchor. The output loads directly in
//! <https://ui.perfetto.dev> (or `chrome://tracing`). JSON is
//! hand-rolled like the bench writers — the crate is zero-dep.

use super::span::Tracer;
use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// Render the whole trace as a JSON string. Call only after the span
/// writers have quiesced (service shutdown joins every pipeline
/// thread), per the `Track::snapshot` contract.
pub fn chrome_trace_json(tracer: &Tracer) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    for track in tracer.tracks() {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            track.tid(),
            track.name(),
        );
        let mut snap = track.snapshot();
        // single-writer tracks record in chronological order already;
        // sort defensively so the strictly-ordered-ts invariant holds
        // even for lock-serialized multi-writer tracks (ingest lanes)
        snap.events.sort_by_key(|e| e.start_ns);
        if snap.dropped > 0 {
            let _ = write!(
                out,
                ",\n{{\"name\":\"dropped {} spans (ring wrapped)\",\"ph\":\"i\",\"s\":\"t\",\
                 \"pid\":1,\"tid\":{},\"ts\":0.000}}",
                snap.dropped,
                track.tid(),
            );
        }
        for ev in &snap.events {
            let _ = write!(
                out,
                ",\n{{\"name\":\"{}\",\"cat\":\"pipeline\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                 \"ts\":{:.3},\"dur\":{:.3}}}",
                ev.stage.name(),
                track.tid(),
                ev.start_ns as f64 / 1e3,
                ev.dur_ns as f64 / 1e3,
            );
        }
    }
    out.push_str("\n]}\n");
    out
}

/// Export the trace to `path` (the `serve --trace-out` sink).
pub fn write_chrome_trace(path: &Path, tracer: &Tracer) -> io::Result<()> {
    std::fs::write(path, chrome_trace_json(tracer))
}

/// Minimal JSON syntax checker (objects, arrays, strings, numbers,
/// booleans, null) so tests can assert well-formedness without a JSON
/// dependency. Returns the byte offset and message on failure.
pub fn validate_json(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut i = 0usize;
    skip_ws(b, &mut i);
    parse_value(b, &mut i)?;
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing data at byte {i}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<(), String> {
    if *i >= b.len() {
        return Err(format!("unexpected end of input at byte {i}", i = *i));
    }
    match b[*i] {
        b'{' => parse_object(b, i),
        b'[' => parse_array(b, i),
        b'"' => parse_string(b, i),
        b't' => parse_lit(b, i, b"true"),
        b'f' => parse_lit(b, i, b"false"),
        b'n' => parse_lit(b, i, b"null"),
        b'-' | b'0'..=b'9' => parse_number(b, i),
        c => Err(format!("unexpected byte {c:?} at {i}", i = *i)),
    }
}

fn parse_lit(b: &[u8], i: &mut usize, lit: &[u8]) -> Result<(), String> {
    if b.len() - *i >= lit.len() && &b[*i..*i + lit.len()] == lit {
        *i += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at byte {i}", i = *i))
    }
}

fn parse_number(b: &[u8], i: &mut usize) -> Result<(), String> {
    let start = *i;
    if b[*i] == b'-' {
        *i += 1;
    }
    let mut saw_digit = false;
    while *i < b.len() && b[*i].is_ascii_digit() {
        *i += 1;
        saw_digit = true;
    }
    if !saw_digit {
        return Err(format!("bad number at byte {start}"));
    }
    if *i < b.len() && b[*i] == b'.' {
        *i += 1;
        let mut frac = false;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
            frac = true;
        }
        if !frac {
            return Err(format!("bad fraction at byte {start}"));
        }
    }
    if *i < b.len() && (b[*i] == b'e' || b[*i] == b'E') {
        *i += 1;
        if *i < b.len() && (b[*i] == b'+' || b[*i] == b'-') {
            *i += 1;
        }
        let mut exp = false;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
            exp = true;
        }
        if !exp {
            return Err(format!("bad exponent at byte {start}"));
        }
    }
    Ok(())
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<(), String> {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return Ok(());
            }
            b'\\' => {
                *i += 2; // escape + escaped byte (\\uXXXX digits parse as chars)
            }
            _ => *i += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn parse_object(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // '{'
    skip_ws(b, i);
    if *i < b.len() && b[*i] == b'}' {
        *i += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, i);
        if *i >= b.len() || b[*i] != b'"' {
            return Err(format!("expected object key at byte {i}", i = *i));
        }
        parse_string(b, i)?;
        skip_ws(b, i);
        if *i >= b.len() || b[*i] != b':' {
            return Err(format!("expected ':' at byte {i}", i = *i));
        }
        *i += 1;
        skip_ws(b, i);
        parse_value(b, i)?;
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => *i += 1,
            Some(b'}') => {
                *i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or '}}' at byte {i}", i = *i)),
        }
    }
}

fn parse_array(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // '['
    skip_ws(b, i);
    if *i < b.len() && b[*i] == b']' {
        *i += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, i);
        parse_value(b, i)?;
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => *i += 1,
            Some(b']') => {
                *i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or ']' at byte {i}", i = *i)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::span::{Stage, Tracer};
    use super::*;

    #[test]
    fn validator_accepts_and_rejects() {
        validate_json("{\"a\":[1,2.5,-3e2,\"x\\\"y\",true,null],\"b\":{}}").unwrap();
        validate_json("  [ ]  ").unwrap();
        assert!(validate_json("{\"a\":}").is_err());
        assert!(validate_json("[1,]").is_err());
        assert!(validate_json("{\"a\":1} extra").is_err());
        assert!(validate_json("\"open").is_err());
    }

    /// Pull `(tid, ts)` out of each emitted `X` event by scanning the
    /// exporter's own fixed field layout.
    fn x_events(json: &str) -> Vec<(u64, f64)> {
        let mut out = Vec::new();
        for chunk in json.split("{\"name\":").skip(1) {
            if !chunk.contains("\"ph\":\"X\"") {
                continue;
            }
            let tid = chunk.split("\"tid\":").nth(1).unwrap();
            let tid: u64 = tid[..tid.find(',').unwrap()].parse().unwrap();
            let ts = chunk.split("\"ts\":").nth(1).unwrap();
            let ts: f64 = ts[..ts.find(',').unwrap()].parse().unwrap();
            out.push((tid, ts));
        }
        out
    }

    #[test]
    fn golden_trace_is_wellformed_ordered_and_named() {
        let tracer = Tracer::new();
        let engine = tracer.track("engine", 16);
        let shard = tracer.track("shard-0", 4); // will wrap
        engine.record_raw(Stage::Compute, 1_000, 500);
        engine.record_raw(Stage::Publish, 2_000, 100);
        for i in 0..6u64 {
            shard.record_raw(Stage::Scatter, i * 100, 50);
        }
        let json = chrome_trace_json(&tracer);

        validate_json(&json).expect("trace JSON parses");
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"args\":{\"name\":\"engine\"}"));
        assert!(json.contains("\"args\":{\"name\":\"shard-0\"}"));
        assert!(json.contains("\"name\":\"compute\""));
        assert!(json.contains("\"name\":\"scatter\""));
        assert!(json.contains("dropped 2 spans"));

        // every span is a complete (X) event: 2 engine + 4 retained shard
        let evs = x_events(&json);
        assert_eq!(evs.len(), 6);
        // strictly ordered ts within each track
        for tid in [engine.tid(), shard.tid()] {
            let ts: Vec<f64> = evs.iter().filter(|(t, _)| *t == tid).map(|(_, v)| *v).collect();
            assert!(!ts.is_empty());
            for w in ts.windows(2) {
                assert!(w[0] <= w[1], "ts out of order on tid {tid}: {ts:?}");
            }
        }
        // ns → µs conversion: engine compute starts at 1.0µs
        assert!(json.contains("\"ts\":1.000,\"dur\":0.500"));
    }
}
