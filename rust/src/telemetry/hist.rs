//! Fixed-memory log2-bucketed latency histogram.
//!
//! HdrHistogram-style bucketing over `u64` nanoseconds: values below
//! `2^SUB_BITS` get exact unit buckets; above that, each power-of-two
//! range is split into `2^SUB_BITS` linear sub-buckets, so the relative
//! quantization error is bounded by `2^-SUB_BITS` (≈3.1% width, ≤1.6%
//! at the bucket midpoint we report). The whole table is 1920
//! `AtomicU64`s (~15 KiB) covering the full `u64` range — recording is
//! one relaxed `fetch_add` per counter, no allocation, no lock, and no
//! sampling, which is what makes the p999 accurate where the old
//! 65k-sample reservoir was not.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-bucket bits per power-of-two range.
const SUB_BITS: u32 = 5;
const SUB_COUNT: usize = 1 << SUB_BITS; // 32
/// Highest index is reached at `v = u64::MAX`: shift 58, sub 31.
const BUCKETS: usize = (64 - SUB_BITS as usize - 1) * SUB_COUNT + SUB_COUNT + SUB_COUNT;

fn bucket_index(v: u64) -> usize {
    if v < SUB_COUNT as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - SUB_BITS;
    let sub = ((v >> shift) as usize) & (SUB_COUNT - 1);
    (shift as usize) * SUB_COUNT + SUB_COUNT + sub
}

/// Midpoint of the bucket's value range (exact for the unit buckets).
fn bucket_value(idx: usize) -> u64 {
    if idx < SUB_COUNT {
        return idx as u64;
    }
    let shift = (idx / SUB_COUNT - 1) as u32;
    let sub = (idx % SUB_COUNT) as u64;
    let low = (SUB_COUNT as u64 + sub) << shift;
    low + ((1u64 << shift) >> 1)
}

/// A concurrent fixed-memory histogram of nanosecond durations.
pub struct LogHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl LogHistogram {
    #[allow(clippy::new_without_default)]
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (nanoseconds). Lock-free; safe from any thread.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration given in seconds.
    #[inline]
    pub fn record_secs(&self, secs: f64) {
        let ns = if secs <= 0.0 { 0.0 } else { (secs * 1e9).round() };
        self.record(if ns >= u64::MAX as f64 { u64::MAX } else { ns as u64 });
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean (the sum is kept exactly, not re-quantized), seconds.
    pub fn mean_secs(&self) -> f64 {
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64 / 1e9
    }

    pub fn max_secs(&self) -> f64 {
        self.max.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Nearest-rank percentile in nanoseconds: rank `ceil(q·(n−1))`
    /// (0-based), the same formula the sort-based oracle in the tests
    /// uses, so both select the same sample — the histogram's answer is
    /// that sample's bucket midpoint, within ±1.6% of the exact value.
    pub fn percentile(&self, q: f64) -> u64 {
        // snapshot the counters so a concurrent writer can't make the
        // cumulative walk disagree with the total
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let n: u64 = counts.iter().sum();
        if n == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * (n - 1) as f64).ceil() as u64;
        let mut cum = 0u64;
        for (idx, &c) in counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_value(idx);
            }
        }
        bucket_value(BUCKETS - 1)
    }

    pub fn percentile_secs(&self, q: f64) -> f64 {
        self.percentile(q) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_continuous() {
        // every bucket boundary in the small/transition range
        let mut prev = 0usize;
        for v in 0..4096u64 {
            let idx = bucket_index(v);
            assert!(idx == prev || idx == prev + 1, "gap at v={v}: {prev} -> {idx}");
            prev = idx;
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(31), 31);
        assert_eq!(bucket_index(32), 32);
        assert_eq!(bucket_index(63), 63);
        assert_eq!(bucket_index(64), 64);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact() {
        let h = LogHistogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 32);
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(1.0), 31);
        // rank = ceil(0.5 * 31) = 16
        assert_eq!(h.percentile(0.5), 16);
    }

    /// Deterministic LCG for adversarial sample generation.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0 >> 33
        }
        fn uniform(&mut self, lo: u64, hi: u64) -> u64 {
            lo + self.next() % (hi - lo)
        }
    }

    fn check_against_oracle(samples: &[u64], tol: f64) {
        let h = LogHistogram::new();
        for &s in samples {
            h.record(s);
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        let n = sorted.len() as f64;
        for &q in &[0.5, 0.9, 0.99, 0.999, 1.0] {
            let rank = (q * (n - 1.0)).ceil() as usize;
            let exact = sorted[rank];
            let approx = h.percentile(q);
            if exact < SUB_COUNT as u64 {
                assert_eq!(approx, exact, "q={q}");
            } else {
                let rel = (approx as f64 - exact as f64).abs() / exact as f64;
                assert!(rel <= tol, "q={q}: exact={exact} approx={approx} rel={rel:.4}");
            }
        }
        // exact mean (sum kept exactly)
        let mean = samples.iter().map(|&v| v as f64).sum::<f64>() / n;
        assert!((h.mean_secs() * 1e9 - mean).abs() <= 1.0);
        assert_eq!(h.max_secs(), *sorted.last().unwrap() as f64 / 1e9);
    }

    #[test]
    fn percentiles_within_bound_on_adversarial_distributions() {
        // the bucket-midpoint bound: half of a 1/32 relative bucket
        // width, with slack for the rank sitting at a bucket edge
        let tol = 0.04;
        let mut rng = Lcg(42);

        // uniform latencies around 1ms
        let uniform: Vec<u64> = (0..20_000).map(|_| rng.uniform(500_000, 2_000_000)).collect();
        check_against_oracle(&uniform, tol);

        // heavy-tailed: mostly microseconds, 0.5% hundred-millisecond outliers
        let heavy: Vec<u64> = (0..20_000)
            .map(|_| {
                if rng.next() % 200 == 0 {
                    rng.uniform(100_000_000, 400_000_000)
                } else {
                    rng.uniform(1_000, 50_000)
                }
            })
            .collect();
        check_against_oracle(&heavy, tol);

        // bimodal at two far-apart modes
        let bimodal: Vec<u64> = (0..20_000)
            .map(|_| if rng.next() % 2 == 0 { rng.uniform(100, 200) } else { rng.uniform(1 << 30, 1 << 31) })
            .collect();
        check_against_oracle(&bimodal, tol);

        // powers of two ± 1: every sample hugs a bucket boundary
        let edges: Vec<u64> = (0..15_000)
            .map(|i| {
                let p = 10 + (i % 20) as u32;
                match i % 3 {
                    0 => (1u64 << p) - 1,
                    1 => 1u64 << p,
                    _ => (1u64 << p) + 1,
                }
            })
            .collect();
        check_against_oracle(&edges, tol);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = std::sync::Arc::new(LogHistogram::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let h = std::sync::Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record(t * 1_000_000 + i);
                }
            }));
        }
        for hd in handles {
            hd.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }
}
