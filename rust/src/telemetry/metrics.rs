//! Named metrics registry: counters, gauges, and latency histograms.
//!
//! The registry is the *naming* layer — the handles it returns are
//! plain `Arc`'d atomics, cloned out once at startup so the hot path
//! never touches the registry lock. `snapshot_json()` renders every
//! registered metric as one JSON object in registration order, which is
//! what the `--stats-every` sampler emits and what a future schedule
//! autotuner would poll.

use super::hist::LogHistogram;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic event counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value-wins gauge (an `f64` stored as bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Arc<LogHistogram>),
}

/// Registration-ordered name → metric table.
pub struct MetricsRegistry {
    inner: Mutex<Vec<(String, Metric)>>,
}

impl MetricsRegistry {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry { inner: Mutex::new(Vec::new()) })
    }

    /// Get-or-create; panics if `name` is already registered as a
    /// different metric kind (a wiring bug, not a runtime condition).
    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(n, _)| n == name) {
            match m {
                Metric::Counter(c) => return c.clone(),
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let c = Counter(Arc::new(AtomicU64::new(0)));
        inner.push((name.to_string(), Metric::Counter(c.clone())));
        c
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(n, _)| n == name) {
            match m {
                Metric::Gauge(g) => return g.clone(),
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let g = Gauge(Arc::new(AtomicU64::new(0f64.to_bits())));
        inner.push((name.to_string(), Metric::Gauge(g.clone())));
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<LogHistogram> {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(n, _)| n == name) {
            match m {
                Metric::Histogram(h) => return Arc::clone(h),
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let h = Arc::new(LogHistogram::new());
        inner.push((name.to_string(), Metric::Histogram(Arc::clone(&h))));
        h
    }

    /// One JSON object with every metric, registration order preserved.
    /// Histograms render as nested objects with millisecond quantiles.
    pub fn snapshot_json(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::from("{");
        for (i, (name, m)) in inner.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            match m {
                Metric::Counter(c) => {
                    let _ = write!(out, "\"{name}\":{}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = write!(out, "\"{name}\":{:.6}", g.get());
                }
                Metric::Histogram(h) => {
                    let _ = write!(
                        out,
                        "\"{name}\":{{\"count\":{},\"mean_ms\":{:.4},\"p50_ms\":{:.4},\
                         \"p99_ms\":{:.4},\"p999_ms\":{:.4},\"max_ms\":{:.4}}}",
                        h.count(),
                        h.mean_secs() * 1e3,
                        h.percentile_secs(0.50) * 1e3,
                        h.percentile_secs(0.99) * 1e3,
                        h.percentile_secs(0.999) * 1e3,
                        h.max_secs() * 1e3,
                    );
                }
            }
        }
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_returns_the_same_metric() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("batches");
        let b = reg.counter("batches");
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        let g = reg.gauge("epoch");
        g.set(7.0);
        assert_eq!(reg.gauge("epoch").get(), 7.0);
        let h = reg.histogram("lat");
        h.record(1000);
        assert_eq!(reg.histogram("lat").count(), 1);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry::new();
        reg.counter("x");
        reg.gauge("x");
    }

    #[test]
    fn snapshot_json_is_valid_and_ordered() {
        let reg = MetricsRegistry::new();
        reg.counter("batches").add(12);
        reg.gauge("epoch").set(3.5);
        reg.histogram("batch_latency").record_secs(0.002);
        let json = reg.snapshot_json();
        super::super::trace::validate_json(&json).expect("valid json");
        let b = json.find("\"batches\"").unwrap();
        let e = json.find("\"epoch\"").unwrap();
        let l = json.find("\"batch_latency\"").unwrap();
        assert!(b < e && e < l, "registration order preserved: {json}");
        assert!(json.contains("\"batches\":12"));
        assert!(json.contains("\"count\":1"));
    }
}
