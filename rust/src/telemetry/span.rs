//! Lock-free per-thread span recording.
//!
//! A [`Tracer`] owns a set of [`Track`]s — one per pipeline thread
//! (ingest lane, batcher, engine, each shard worker). A track is a
//! bounded ring of [`SpanEvent`]s written by exactly one logical writer
//! at a time with no locks on the hot path: recording a span is two
//! `Instant` reads, one slot write, and one `Release` store. When the
//! ring wraps, the oldest spans are overwritten (the count of dropped
//! spans is retained so exports can say so).
//!
//! Timestamps are monotonic nanoseconds relative to the tracer's anchor
//! `Instant`, so spans from different threads land on one comparable
//! timeline without any clock-sync machinery.
//!
//! # Writer contract
//!
//! `Track::record*` calls MUST be serialized per track: either a single
//! thread owns the track for its lifetime (the shard-worker and engine
//! tracks), or successive writers are ordered by an external
//! happens-before edge — a mutex (the ingest-lane tracks record under
//! the shard queue lock) or thread join (the spawn-per-phase runtime
//! joins every phase before the next one writes). `snapshot()` must
//! only be called after synchronizing with the last writer (service
//! shutdown joins every pipeline thread before the trace export reads
//! anything). This is the same single-writer `UnsafeCell` idiom as
//! `util::threadpool::SyncSlice`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What a span measures. `name()` is the label shown in Perfetto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Producer enqueue into an ingest lane (includes the coalesce scan).
    Enqueue,
    /// Batch formation: the batcher pulling/coalescing until close.
    Form,
    /// Batch seal: draining the closed batch into update buffers +
    /// routing by owner shard.
    Seal,
    /// Whole-batch engine propagation (all BSP rounds).
    Compute,
    /// Per-shard relax scatter over the owned frontier (push rounds).
    Scatter,
    /// One stolen frontier chunk processed on the thief's thread.
    Steal,
    /// Per-shard gather: owner-applying relayed relax messages.
    Gather,
    /// Owner-writes dense sweep (pull phase, parent repair, PR sweep).
    Pull,
    /// Worker idle at the phase barrier.
    Barrier,
    /// Diff-CSR merge compaction.
    Merge,
    /// Shard re-partitioning + diff-CSR row migration.
    Rebalance,
    /// Epoch snapshot publish.
    Publish,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Enqueue => "enqueue",
            Stage::Form => "form",
            Stage::Seal => "seal",
            Stage::Compute => "compute",
            Stage::Scatter => "scatter",
            Stage::Steal => "steal",
            Stage::Gather => "gather",
            Stage::Pull => "pull",
            Stage::Barrier => "barrier",
            Stage::Merge => "merge",
            Stage::Rebalance => "rebalance",
            Stage::Publish => "publish",
        }
    }
}

/// One recorded span: a stage plus `[start, start + dur)` in
/// nanoseconds relative to the tracer anchor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub stage: Stage,
    pub start_ns: u64,
    pub dur_ns: u64,
}

const ZERO_SPAN: SpanEvent = SpanEvent { stage: Stage::Enqueue, start_ns: 0, dur_ns: 0 };

/// The result of reading a track after its writer quiesced: the
/// retained spans oldest-first (recording order == chronological order,
/// because the single writer records spans as they end).
#[derive(Debug, Clone)]
pub struct TrackSnapshot {
    pub events: Vec<SpanEvent>,
    /// Spans ever recorded, including overwritten ones.
    pub total: usize,
    /// Spans lost to ring wraparound (`total - events.len()`).
    pub dropped: usize,
}

/// A bounded single-writer span ring bound to one pipeline thread.
pub struct Track {
    name: String,
    /// Trace "thread id" (1-based registration index under pid 1).
    tid: u64,
    anchor: Instant,
    cap: usize,
    ring: UnsafeCell<Box<[SpanEvent]>>,
    /// Total spans ever recorded; slot `total % cap` is written *before*
    /// the `Release` store, so a reader's `Acquire` load sees complete
    /// slots for everything it counts.
    total: AtomicUsize,
}

// SAFETY: the ring is written through `&self`, but the writer contract
// (module docs) serializes all `record*` calls per track and requires
// `snapshot()` to synchronize with the last writer, so there are never
// two unsynchronized accesses to the same slot.
unsafe impl Sync for Track {}
unsafe impl Send for Track {}

impl Track {
    fn new(name: &str, tid: u64, anchor: Instant, cap: usize) -> Self {
        let cap = cap.max(1);
        Track {
            name: name.to_string(),
            tid,
            anchor,
            cap,
            ring: UnsafeCell::new(vec![ZERO_SPAN; cap].into_boxed_slice()),
            total: AtomicUsize::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn tid(&self) -> u64 {
        self.tid
    }

    /// Record a span that started at `start` and ends now.
    #[inline]
    pub fn record(&self, stage: Stage, start: Instant) {
        self.record_between(stage, start, Instant::now());
    }

    /// Record a span with an explicit end (both clamped to the anchor).
    #[inline]
    pub fn record_between(&self, stage: Stage, start: Instant, end: Instant) {
        let start_ns = start.saturating_duration_since(self.anchor).as_nanos() as u64;
        let dur_ns = end.saturating_duration_since(start).as_nanos() as u64;
        self.record_raw(stage, start_ns, dur_ns);
    }

    /// Record a span from pre-computed anchor-relative nanoseconds.
    #[inline]
    pub fn record_raw(&self, stage: Stage, start_ns: u64, dur_ns: u64) {
        let total = self.total.load(Ordering::Relaxed);
        let idx = total % self.cap;
        // SAFETY: `record*` calls are serialized per track (writer
        // contract), so this slot has no concurrent accessor.
        unsafe {
            (*self.ring.get())[idx] = SpanEvent { stage, start_ns, dur_ns };
        }
        self.total.store(total + 1, Ordering::Release);
    }

    /// Copy out the retained spans, oldest first. Call only after the
    /// writer thread has been joined (or otherwise synchronized with).
    pub fn snapshot(&self) -> TrackSnapshot {
        let total = self.total.load(Ordering::Acquire);
        // SAFETY: the caller synchronized with the last writer, so all
        // `total` recorded slots are complete and no write is in flight.
        let ring = unsafe { &*self.ring.get() };
        let mut events = Vec::with_capacity(total.min(self.cap));
        if total <= self.cap {
            events.extend_from_slice(&ring[..total]);
        } else {
            let head = total % self.cap;
            events.extend_from_slice(&ring[head..]);
            events.extend_from_slice(&ring[..head]);
        }
        let dropped = total - events.len();
        TrackSnapshot { events, total, dropped }
    }
}

impl std::fmt::Debug for Track {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Track({:?}, tid {}, {} spans)",
            self.name,
            self.tid,
            self.total.load(Ordering::Relaxed)
        )
    }
}

/// The span-track registry shared by every instrumented thread.
///
/// Cloning the `Arc<Tracer>` into `ServiceConfig::telemetry` is the
/// only wiring a caller does; the service registers tracks for each of
/// its threads, and `telemetry::chrome_trace_json` reads them all back
/// after shutdown.
pub struct Tracer {
    anchor: Instant,
    tracks: Mutex<Vec<Arc<Track>>>,
}

impl Tracer {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Arc<Tracer> {
        Arc::new(Tracer { anchor: Instant::now(), tracks: Mutex::new(Vec::new()) })
    }

    /// All spans are timestamped relative to this instant.
    pub fn anchor(&self) -> Instant {
        self.anchor
    }

    /// Register a new track holding at most `cap` spans. The returned
    /// handle is handed to exactly one pipeline thread (or a
    /// lock-serialized writer set — see the module docs).
    pub fn track(&self, name: &str, cap: usize) -> Arc<Track> {
        let mut tracks = self.tracks.lock().unwrap();
        let tid = tracks.len() as u64 + 1;
        let t = Arc::new(Track::new(name, tid, self.anchor, cap));
        tracks.push(Arc::clone(&t));
        t
    }

    /// Snapshot of the registered tracks (the tracks themselves are
    /// read with `Track::snapshot` after the writers quiesced).
    pub fn tracks(&self) -> Vec<Arc<Track>> {
        self.tracks.lock().unwrap().clone()
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.tracks.lock().unwrap().len();
        write!(f, "Tracer({n} tracks)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraparound_keeps_newest_in_order() {
        let tracer = Tracer::new();
        let t = tracer.track("wrap", 8);
        for i in 0..20u64 {
            t.record_raw(Stage::Compute, i * 10, i + 1); // dur encodes index + 1
        }
        let snap = t.snapshot();
        assert_eq!(snap.total, 20);
        assert_eq!(snap.dropped, 12);
        assert_eq!(snap.events.len(), 8);
        // the retained spans are exactly 12..20, oldest first
        for (k, ev) in snap.events.iter().enumerate() {
            let i = (12 + k) as u64;
            assert_eq!(ev.dur_ns, i + 1);
            assert_eq!(ev.start_ns, i * 10);
        }
        // chronological: start_ns non-decreasing
        for w in snap.events.windows(2) {
            assert!(w[0].start_ns <= w[1].start_ns);
        }
    }

    #[test]
    fn short_ring_without_wrap_returns_everything() {
        let tracer = Tracer::new();
        let t = tracer.track("short", 64);
        let start = Instant::now();
        t.record(Stage::Merge, start);
        t.record_between(Stage::Publish, start, Instant::now());
        let snap = t.snapshot();
        assert_eq!(snap.total, 2);
        assert_eq!(snap.dropped, 0);
        assert_eq!(snap.events[0].stage, Stage::Merge);
        assert_eq!(snap.events[1].stage, Stage::Publish);
    }

    #[test]
    fn cross_thread_spans_attribute_to_their_own_track() {
        let tracer = Tracer::new();
        let a = tracer.track("worker-a", 256);
        let b = tracer.track("worker-b", 256);
        assert_ne!(a.tid(), b.tid());
        let (ta, tb) = (Arc::clone(&a), Arc::clone(&b));
        let ha = std::thread::spawn(move || {
            for i in 0..100 {
                ta.record_raw(Stage::Scatter, i, 1);
            }
        });
        let hb = std::thread::spawn(move || {
            for i in 0..50 {
                tb.record_raw(Stage::Gather, i, 2);
            }
        });
        ha.join().unwrap();
        hb.join().unwrap(); // joins give snapshot() its happens-before edge
        let sa = a.snapshot();
        let sb = b.snapshot();
        assert_eq!(sa.events.len(), 100);
        assert_eq!(sb.events.len(), 50);
        assert!(sa.events.iter().all(|e| e.stage == Stage::Scatter && e.dur_ns == 1));
        assert!(sb.events.iter().all(|e| e.stage == Stage::Gather && e.dur_ns == 2));
        for s in [&sa, &sb] {
            for w in s.events.windows(2) {
                assert!(w[0].start_ns <= w[1].start_ns);
            }
        }
    }
}
