//! Pipeline telemetry: span tracing, fixed-memory histograms, metrics.
//!
//! The streaming runtime spans ingest → batcher → engine (single or
//! sharded BSP fleet) → epoch publish; this module is its unified,
//! zero-dependency measurement substrate:
//!
//! * [`span`] — lock-free per-thread span recording into bounded ring
//!   buffers ([`Tracer`] / [`Track`]), timestamped on one monotonic
//!   anchor so every pipeline thread lands on a comparable timeline;
//! * [`trace`] — Chrome-trace-event / Perfetto JSON export of those
//!   tracks (`serve --trace-out <path>`), plus the dependency-free
//!   [`validate_json`] checker the tests and CI lean on;
//! * [`hist`] — [`LogHistogram`], a fixed-memory log2-bucketed
//!   concurrent histogram (±1.6% midpoint error, ~15 KiB) that replaces
//!   the sampled-`Vec` percentile path and makes p999 honest;
//! * [`metrics`] — a registration-ordered named registry of counters,
//!   gauges, and histograms; handles are cloned out at startup so the
//!   hot path never takes the registry lock, and `snapshot_json()`
//!   backs the `serve --stats-every <secs>` sampler line.
//!
//! Instrumentation is wall-clock-only — `Instant` reads and relaxed
//! atomic bumps — so enabling it cannot perturb any fixed point: the
//! equivalence matrix in `tests/stream_equivalence.rs` re-runs a
//! sharded leg with tracing on and asserts bitwise-identical results.

pub mod hist;
pub mod metrics;
pub mod span;
pub mod trace;

pub use hist::LogHistogram;
pub use metrics::{Counter, Gauge, MetricsRegistry};
pub use span::{SpanEvent, Stage, TrackSnapshot, Tracer, Track};
pub use trace::{chrome_trace_json, validate_json, write_chrome_trace};

use std::sync::Arc;
use std::time::Duration;

/// Telemetry knobs carried by `stream::ServiceConfig`.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Span tracing: when set, the service registers tracks for each
    /// pipeline thread and records stage spans into it. `None` (the
    /// default) skips every span call site.
    pub tracer: Option<Arc<Tracer>>,
    /// Use the fixed-memory [`LogHistogram`] for batch-latency
    /// percentiles (accurate p999). When off, the service falls back to
    /// the Algorithm-R sampling reservoir (the bench-harness fallback).
    pub histograms: bool,
    /// Emit a one-line JSON stats snapshot every interval from a
    /// sampler thread that reads only atomics (plus one final line at
    /// shutdown, so short runs still get a snapshot).
    pub stats_every: Option<Duration>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { tracer: None, histograms: true, stats_every: None }
    }
}

/// Span-track capacity for the engine/batcher/ingest tracks.
pub const TRACK_CAP: usize = 1 << 14;
/// Span-track capacity for per-shard worker tracks (scatter + steal +
/// gather + pull + barrier spans per round add up faster).
pub const SHARD_TRACK_CAP: usize = 1 << 15;
