//! Dynamic BFS — the paper's own motivating example from §1 ("a dynamic
//! BFS may maintain the underlying BFS DAG in addition to the BFS level
//! number information"). Extension beyond the paper's three evaluated
//! algorithms: static levels + parent DAG, incremental (added edges can
//! only lower levels), and decremental (invalidate the affected subtree
//! of the BFS tree, then pull-recompute) — the unit-weight instance of
//! the SSSP pipeline, maintained separately because BFS keeps *levels*
//! and can early-terminate per level.

use crate::graph::updates::Batch;
use crate::graph::{DynGraph, NodeId};

/// Unreached level marker.
pub const UNREACHED: i64 = i64::MAX / 4;

/// BFS state: level per vertex + one tree parent (the maintained DAG is
/// recoverable as all in-neighbors at level-1).
#[derive(Debug, Clone, PartialEq)]
pub struct BfsState {
    pub level: Vec<i64>,
    pub parent: Vec<i64>,
    pub source: NodeId,
}

/// Static BFS from `source`.
pub fn static_bfs(g: &DynGraph, source: NodeId) -> BfsState {
    let n = g.num_nodes();
    let mut st = BfsState { level: vec![UNREACHED; n], parent: vec![-1; n], source };
    st.level[source as usize] = 0;
    let mut frontier = vec![source];
    let mut lvl = 0i64;
    while !frontier.is_empty() {
        lvl += 1;
        let mut next = Vec::new();
        for &v in &frontier {
            for (nbr, _) in g.out_neighbors(v) {
                if st.level[nbr as usize] == UNREACHED {
                    st.level[nbr as usize] = lvl;
                    st.parent[nbr as usize] = v as i64;
                    next.push(nbr);
                }
            }
        }
        frontier = next;
    }
    st
}

/// Incremental BFS: an added edge `(u, v)` with `level[u] + 1 < level[v]`
/// seeds a relaxation wavefront (levels only decrease).
pub fn incremental(g: &DynGraph, st: &mut BfsState, adds: &[(NodeId, NodeId, i32)]) {
    let mut frontier: Vec<NodeId> = Vec::new();
    for &(u, v, _) in adds {
        if st.level[u as usize] < UNREACHED && st.level[u as usize] + 1 < st.level[v as usize]
        {
            st.level[v as usize] = st.level[u as usize] + 1;
            st.parent[v as usize] = u as i64;
            frontier.push(v);
        }
    }
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            let lv = st.level[v as usize];
            for (nbr, _) in g.out_neighbors(v) {
                if lv + 1 < st.level[nbr as usize] {
                    st.level[nbr as usize] = lv + 1;
                    st.parent[nbr as usize] = v as i64;
                    next.push(nbr);
                }
            }
        }
        frontier = next;
    }
}

/// Decremental BFS: deleted tree edges invalidate their subtree, which is
/// then pull-recomputed from intact in-neighbors.
pub fn decremental(g: &DynGraph, st: &mut BfsState, dels: &[(NodeId, NodeId)]) {
    let n = g.num_nodes();
    let mut modified = vec![false; n];
    for &(u, v) in dels {
        if st.parent[v as usize] == u as i64 {
            st.level[v as usize] = UNREACHED;
            st.parent[v as usize] = -1;
            modified[v as usize] = true;
        }
    }
    // cascade down the former tree
    loop {
        let mut changed = false;
        for v in 0..n {
            if modified[v] {
                continue;
            }
            let p = st.parent[v];
            if p > -1 && modified[p as usize] {
                st.level[v] = UNREACHED;
                st.parent[v] = -1;
                modified[v] = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    // pull recompute restricted to the invalidated set
    loop {
        let mut changed = false;
        for v in 0..n as NodeId {
            if !modified[v as usize] {
                continue;
            }
            for (u, _) in g.in_neighbors(v) {
                let lu = st.level[u as usize];
                if lu < UNREACHED && lu + 1 < st.level[v as usize] {
                    st.level[v as usize] = lu + 1;
                    st.parent[v as usize] = u as i64;
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
}

/// Full dynamic batch: OnDelete → updateCSRDel → Decremental → OnAdd →
/// updateCSRAdd → Incremental.
pub fn dynamic_batch(g: &mut DynGraph, st: &mut BfsState, batch: &Batch<'_>) {
    let dels: Vec<_> = batch.deletions().collect();
    g.apply_deletions(&dels);
    decremental(g, st, &dels);
    let adds: Vec<_> = batch.additions().collect();
    g.apply_additions(&adds);
    incremental(g, st, &adds);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, UpdateStream};
    use crate::util::propcheck::forall_checks;

    #[test]
    fn static_bfs_levels_on_path() {
        let g = DynGraph::from_edges(4, &[(0, 1, 1), (1, 2, 1), (2, 3, 1)]);
        let st = static_bfs(&g, 0);
        assert_eq!(st.level, vec![0, 1, 2, 3]);
        assert_eq!(st.parent, vec![-1, 0, 1, 2]);
    }

    #[test]
    fn incremental_shortcut_lowers_levels() {
        let mut g = DynGraph::from_edges(5, &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (3, 4, 1)]);
        let mut st = static_bfs(&g, 0);
        g.apply_additions(&[(0, 3, 1)]);
        incremental(&g, &mut st, &[(0, 3, 1)]);
        assert_eq!(st.level, vec![0, 1, 2, 1, 2]);
    }

    #[test]
    fn decremental_cuts_subtree() {
        let mut g = DynGraph::from_edges(4, &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (0, 3, 1)]);
        let mut st = static_bfs(&g, 0);
        let dels = [(1u32, 2u32)];
        g.apply_deletions(&dels);
        decremental(&g, &mut st, &dels);
        assert_eq!(st.level[2], UNREACHED, "2 unreachable after cut");
        assert_eq!(st.level[3], 1, "3 still reachable via direct edge");
    }

    #[test]
    fn prop_dynamic_bfs_equals_static_recompute() {
        forall_checks(0xBF5, 30, |gen| {
            let n = gen.usize_in(8, 60);
            let seed = gen.rng().next_u64();
            let g0 = generators::uniform_random(n, n * 4, 3, seed);
            let stream =
                UpdateStream::generate_percent(&g0, 12.0, gen.usize_in(2, 32), 3, seed ^ 9);
            let mut g = g0.clone();
            let mut st = static_bfs(&g, 0);
            for b in stream.batches() {
                dynamic_batch(&mut g, &mut st, &b);
            }
            let mut g2 = g0.clone();
            stream.apply_all_static(&mut g2);
            let want = static_bfs(&g2, 0);
            assert_eq!(st.level, want.level, "BFS levels diverged");
        });
    }
}
