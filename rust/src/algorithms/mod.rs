//! Hand-written reference implementations of the paper's three algorithms
//! (SSSP, PageRank, Triangle Counting), each with static + incremental +
//! decremental variants, plus the baseline-framework strategy engines used
//! by the Table 5/7/8 comparisons.
//!
//! These serve three roles:
//!  1. correctness oracles for the DSL/backend execution paths,
//!  2. the workload bodies the `cpu`/`dist`/`xla` engines parallelize,
//!  3. the static baselines the dynamic variants are benchmarked against.

pub mod baselines;
pub mod bfs;
pub mod pagerank;
pub mod sssp;
pub mod triangle;

pub use bfs::BfsState;
pub use pagerank::PrState;
pub use sssp::{SsspState, INF};
pub use triangle::TcState;
