//! Ligra-style engine: frontier-based *direction optimization*.
//!
//! §6.2: "Depending on the frontier size, Ligra alternates between sparse
//! and dense edge processing." Its PR is slowed by "the loop separation …
//! between the difference of successive PR values and the PR value
//! computation"; its TC is edge-iterator based.

use crate::algorithms::sssp::INF;
use crate::graph::{DynGraph, NodeId};

/// Direction-optimizing SSSP (Bellman-Ford rounds): sparse push when the
/// frontier is small, dense pull sweep when it exceeds `threshold_frac`
/// of the vertices.
pub fn sssp_direction_opt(g: &DynGraph, source: NodeId, threshold_frac: f64) -> Vec<i64> {
    let n = g.num_nodes();
    let mut dist = vec![INF; n];
    dist[source as usize] = 0;
    let mut frontier = vec![source];
    let threshold = ((n as f64) * threshold_frac) as usize;
    while !frontier.is_empty() {
        let mut changed: Vec<NodeId> = Vec::new();
        if frontier.len() <= threshold {
            // sparse push from the frontier
            let mut in_next = vec![false; n];
            for &v in &frontier {
                let dv = dist[v as usize];
                if dv >= INF {
                    continue;
                }
                for (nbr, w) in g.out_neighbors(v) {
                    let alt = dv + w as i64;
                    if alt < dist[nbr as usize] {
                        dist[nbr as usize] = alt;
                        if !in_next[nbr as usize] {
                            in_next[nbr as usize] = true;
                            changed.push(nbr);
                        }
                    }
                }
            }
        } else {
            // dense pull over all vertices
            let in_frontier: Vec<bool> = {
                let mut f = vec![false; n];
                for &v in &frontier {
                    f[v as usize] = true;
                }
                f
            };
            for v in 0..n as NodeId {
                let mut best = dist[v as usize];
                let mut moved = false;
                for (nbr, w) in g.in_neighbors(v) {
                    if in_frontier[nbr as usize] && dist[nbr as usize] < INF {
                        let alt = dist[nbr as usize] + w as i64;
                        if alt < best {
                            best = alt;
                            moved = true;
                        }
                    }
                }
                if moved {
                    dist[v as usize] = best;
                    changed.push(v);
                }
            }
        }
        frontier = changed;
    }
    dist
}

/// Loop-separated PageRank (the §6.2 Ligra slowdown): one full pass to
/// compute new values, a second full pass to compute the convergence
/// delta, a third to commit — 3 sweeps of work per iteration.
pub fn pagerank_loop_separated(
    g: &DynGraph,
    beta: f64,
    delta: f64,
    max_iter: usize,
) -> (Vec<f64>, usize) {
    let n = g.num_nodes();
    let nf = n as f64;
    let mut rank = vec![1.0 / nf; n];
    let mut next = vec![0.0; n];
    let mut iters = 0;
    loop {
        // pass 1: compute
        for v in 0..n as NodeId {
            let mut sum = 0.0;
            for (nbr, _) in g.in_neighbors(v) {
                let d = g.out_degree(nbr);
                if d > 0 {
                    sum += rank[nbr as usize] / d as f64;
                }
            }
            next[v as usize] = (1.0 - delta) / nf + delta * sum;
        }
        // pass 2 (separated): convergence delta
        let mut diff = 0.0;
        for v in 0..n {
            diff += (next[v] - rank[v]).abs();
        }
        // pass 3 (separated): commit
        rank.copy_from_slice(&next);
        iters += 1;
        if diff <= beta || iters >= max_iter {
            return (rank, iters);
        }
    }
}

/// Edge-iterator TC: iterate edges `(u, v)` with `u < v` and intersect
/// sorted adjacency lists — better load balance on skewed graphs (§6.2).
pub fn tc_edge_iterator(g: &DynGraph) -> i64 {
    let n = g.num_nodes();
    let adj: Vec<Vec<NodeId>> = (0..n as NodeId)
        .map(|v| {
            let mut a: Vec<NodeId> = g.out_neighbors(v).map(|(x, _)| x).collect();
            a.sort_unstable();
            a.dedup();
            a
        })
        .collect();
    let mut count = 0i64;
    for u in 0..n as NodeId {
        for &v in adj[u as usize].iter().filter(|&&v| v > u) {
            // count common neighbors w > v via sorted-merge intersection
            let (a, b) = (&adj[u as usize], &adj[v as usize]);
            let (mut i, mut j) = (0usize, 0usize);
            while i < a.len() && j < b.len() {
                use std::cmp::Ordering::*;
                match a[i].cmp(&b[j]) {
                    Less => i += 1,
                    Greater => j += 1,
                    Equal => {
                        if a[i] > v {
                            count += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pagerank::{static_pagerank, PrState};
    use crate::algorithms::sssp::dijkstra_oracle;
    use crate::algorithms::triangle::{static_tc, symmetrize};
    use crate::graph::generators;

    #[test]
    fn direction_opt_matches_dijkstra_both_modes() {
        let g = generators::uniform_random(150, 900, 9, 2);
        // always-sparse, always-dense, and hybrid must all be correct
        for frac in [0.0, 0.2, 1.0] {
            assert_eq!(sssp_direction_opt(&g, 0, frac), dijkstra_oracle(&g, 0), "frac={frac}");
        }
    }

    #[test]
    fn loop_separated_pr_same_fixpoint() {
        let g = generators::rmat(6, 250, 0.5, 0.2, 0.2, 3);
        let n = g.num_nodes();
        let (rank, _) = pagerank_loop_separated(&g, 1e-10, 0.85, 300);
        let mut st = PrState::new(n, 1e-10, 0.85, 300);
        static_pagerank(&g, &mut st);
        let l1: f64 = rank.iter().zip(&st.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-6, "l1={l1}");
    }

    #[test]
    fn edge_iterator_tc_matches_reference() {
        let g = symmetrize(&generators::uniform_random(70, 500, 5, 6));
        assert_eq!(tc_edge_iterator(&g), static_tc(&g).triangles);
    }
}
