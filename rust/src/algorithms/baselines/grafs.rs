//! GRAFS-style engine: declarative synthesis with cross-API fusion.
//!
//! §6.2's two observations are modeled: (1) GRAFS PR terminates on the
//! *iteration count only* ("GRAFS solely considers the number of
//! iterations for determining convergence"), which makes it the slowest
//! PR; (2) GRAFS SSSP is the fastest, which we model with the
//! work-optimal fused formulation (heap-based label-setting).

use crate::algorithms::sssp::INF;
use crate::graph::{DynGraph, NodeId};

/// PR that ignores the convergence threshold and always runs the full
/// `max_iter` sweeps (Table 7 note: "doesn't set the value of beta and
/// runs for max-iteration that is 100").
pub fn pagerank_fixed_iters(g: &DynGraph, delta: f64, iters: usize) -> (Vec<f64>, usize) {
    let n = g.num_nodes();
    let nf = n as f64;
    let mut rank = vec![1.0 / nf; n];
    let mut next = vec![0.0; n];
    for _ in 0..iters {
        for v in 0..n as NodeId {
            let mut sum = 0.0;
            for (nbr, _) in g.in_neighbors(v) {
                let d = g.out_degree(nbr);
                if d > 0 {
                    sum += rank[nbr as usize] / d as f64;
                }
            }
            next[v as usize] = (1.0 - delta) / nf + delta * sum;
        }
        std::mem::swap(&mut rank, &mut next);
    }
    (rank, iters)
}

/// Work-optimal SSSP standing in for GRAFS's fused synthesis (label-
/// setting with a binary heap — each vertex settled once).
pub fn sssp_fused(g: &DynGraph, source: NodeId) -> Vec<i64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_nodes();
    let mut dist = vec![INF; n];
    dist[source as usize] = 0;
    let mut pq = BinaryHeap::new();
    pq.push(Reverse((0i64, source)));
    while let Some(Reverse((d, v))) = pq.pop() {
        if d > dist[v as usize] {
            continue;
        }
        for (nbr, w) in g.out_neighbors(v) {
            let alt = d + w as i64;
            if alt < dist[nbr as usize] {
                dist[nbr as usize] = alt;
                pq.push(Reverse((alt, nbr)));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pagerank::{static_pagerank, PrState};
    use crate::algorithms::sssp::dijkstra_oracle;
    use crate::graph::generators;

    #[test]
    fn fixed_iters_always_runs_all_sweeps() {
        let g = generators::uniform_random(50, 200, 5, 1);
        let (_, iters) = pagerank_fixed_iters(&g, 0.85, 100);
        assert_eq!(iters, 100);
    }

    #[test]
    fn fixed_iters_reaches_same_fixpoint_when_long_enough() {
        let g = generators::rmat(6, 200, 0.5, 0.2, 0.2, 2);
        let n = g.num_nodes();
        let (rank, _) = pagerank_fixed_iters(&g, 0.85, 300);
        let mut st = PrState::new(n, 1e-12, 0.85, 300);
        static_pagerank(&g, &mut st);
        let l1: f64 = rank.iter().zip(&st.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-8, "l1={l1}");
    }

    #[test]
    fn fused_sssp_matches_oracle() {
        let g = generators::uniform_random(100, 500, 9, 3);
        assert_eq!(sssp_fused(&g, 0), dijkstra_oracle(&g, 0));
    }
}
