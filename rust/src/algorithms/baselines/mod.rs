//! Baseline "framework" engines for the Table 5 / 7 / 8 comparisons.
//!
//! The paper benchmarks StarPlat-generated static code against Galois,
//! Ligra, Green-Marl, GRAFS, Gemini, Gunrock, and LonestarGPU. Those
//! frameworks cannot be vendored here; what carries the comparison is
//! each framework's *characteristic execution strategy* (the paper's own
//! analysis in §6.2/§6.3/§6.4 attributes every gap to a strategy
//! difference). Each module implements that strategy faithfully:
//!
//! | module | stands in for | strategy reproduced |
//! |---|---|---|
//! | [`galois`] | Galois | delta-stepping prioritized worklist SSSP; in-place (Gauss-Seidel) PR; node-iterator TC with sorted adjacency |
//! | [`ligra`] | Ligra | direction-optimizing (sparse-push/dense-pull) frontier SSSP; loop-separated PR (the §6.2 slowdown); edge-iterator TC |
//! | [`greenmarl`] | Green-Marl | dense-push SSSP over all vertices per round; double-buffered PR |
//! | [`grafs`] | GRAFS | fused-iteration PR with *iteration-count-only* termination (the §6.2 quirk); work-optimal heap SSSP standing in for its fused synthesis |

pub mod galois;
pub mod grafs;
pub mod greenmarl;
pub mod ligra;
