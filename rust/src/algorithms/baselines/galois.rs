//! Galois-style engine: application-specific *prioritized scheduling*.
//!
//! §6.2: "The Galois framework uses application-specific prioritized
//! scheduling … processing tasks in the ascending distance order reduces
//! the total amount of extra work done" — that is delta-stepping. PR uses
//! in-place (Gauss-Seidel) updates, "which leads to faster convergence".

use crate::algorithms::sssp::INF;
use crate::graph::{DynGraph, NodeId};

/// Delta-stepping SSSP (bucketed priority worklist).
pub fn sssp_delta_stepping(g: &DynGraph, source: NodeId, delta: i64) -> Vec<i64> {
    let n = g.num_nodes();
    let delta = delta.max(1);
    let mut dist = vec![INF; n];
    dist[source as usize] = 0;
    let mut buckets: Vec<Vec<NodeId>> = vec![Vec::new()];
    buckets[0].push(source);
    let mut b = 0usize;
    while b < buckets.len() {
        // settle bucket b to fixpoint (light edges re-enter the bucket)
        while let Some(v) = buckets[b].pop() {
            let dv = dist[v as usize];
            if dv >= INF || (dv / delta) as usize != b {
                continue; // stale entry
            }
            for (nbr, w) in g.out_neighbors(v) {
                let alt = dv + w as i64;
                if alt < dist[nbr as usize] {
                    dist[nbr as usize] = alt;
                    let nb = (alt / delta) as usize;
                    if nb >= buckets.len() {
                        buckets.resize(nb + 1, Vec::new());
                    }
                    buckets[nb].push(nbr);
                }
            }
        }
        b += 1;
    }
    dist
}

/// In-place (Gauss-Seidel) PageRank: reads current-iteration values of
/// already-updated vertices — converges in fewer sweeps than
/// double-buffered Jacobi (the paper's explanation of Galois' 3× PR win).
/// Returns (ranks, sweeps).
pub fn pagerank_inplace(
    g: &DynGraph,
    beta: f64,
    delta: f64,
    max_iter: usize,
) -> (Vec<f64>, usize) {
    let n = g.num_nodes();
    let nf = n as f64;
    let mut rank = vec![1.0 / nf; n];
    let mut iters = 0;
    loop {
        let mut diff = 0.0;
        for v in 0..n as NodeId {
            let mut sum = 0.0;
            for (nbr, _) in g.in_neighbors(v) {
                let d = g.out_degree(nbr);
                if d > 0 {
                    sum += rank[nbr as usize] / d as f64;
                }
            }
            let val = (1.0 - delta) / nf + delta * sum;
            diff += (val - rank[v as usize]).abs();
            rank[v as usize] = val; // in-place: later vertices see it
        }
        iters += 1;
        if diff <= beta || iters >= max_iter {
            return (rank, iters);
        }
    }
}

/// Node-iterator TC with sorted adjacency + binary search (Galois' fast
/// membership test).
pub fn tc_sorted(g: &DynGraph) -> i64 {
    let n = g.num_nodes();
    // materialize sorted adjacency once
    let mut adj: Vec<Vec<NodeId>> = (0..n as NodeId)
        .map(|v| {
            let mut a: Vec<NodeId> = g.out_neighbors(v).map(|(x, _)| x).collect();
            a.sort_unstable();
            a
        })
        .collect();
    adj.iter_mut().for_each(|a| a.dedup());
    let mut count = 0i64;
    for v in 0..n {
        let nbrs = &adj[v];
        for &u in nbrs.iter().filter(|&&u| (u as usize) < v) {
            for &w in nbrs.iter().filter(|&&w| (w as usize) > v) {
                if adj[u as usize].binary_search(&w).is_ok() {
                    count += 1;
                }
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::sssp::{dijkstra_oracle, static_sssp};
    use crate::algorithms::triangle::{static_tc, symmetrize};
    use crate::graph::generators;

    #[test]
    fn delta_stepping_matches_dijkstra() {
        for seed in [1u64, 2, 3] {
            let g = generators::uniform_random(120, 700, 9, seed);
            for delta in [1i64, 2, 8] {
                assert_eq!(sssp_delta_stepping(&g, 0, delta), dijkstra_oracle(&g, 0));
            }
        }
    }

    #[test]
    fn delta_stepping_matches_bellman_ford_on_road() {
        let g = generators::road_grid(12, 12, 9, 5);
        let st = static_sssp(&g, 0);
        assert_eq!(sssp_delta_stepping(&g, 0, 4), st.dist);
    }

    #[test]
    fn inplace_pr_converges_to_same_fixpoint_faster() {
        let g = generators::rmat(7, 500, 0.57, 0.19, 0.19, 9);
        let n = g.num_nodes();
        let (rank, sweeps) = pagerank_inplace(&g, 1e-10, 0.85, 500);
        let mut st = crate::algorithms::pagerank::PrState::new(n, 1e-10, 0.85, 500);
        let jacobi_sweeps = crate::algorithms::pagerank::static_pagerank(&g, &mut st);
        let l1: f64 = rank.iter().zip(&st.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-6, "same fixpoint, l1={l1}");
        assert!(sweeps <= jacobi_sweeps, "gauss-seidel {sweeps} vs jacobi {jacobi_sweeps}");
    }

    #[test]
    fn tc_sorted_matches_reference() {
        let g = symmetrize(&generators::uniform_random(60, 400, 5, 4));
        assert_eq!(tc_sorted(&g), static_tc(&g).triangles);
    }
}
