//! Green-Marl-style engine: *dense push* vertex processing.
//!
//! §6.2: "Both [Green-Marl and StarPlat] follow a dense push configuration
//! for vertex processing which needs iterating over all the vertices to
//! determine if they are active" — expensive on large-diameter road
//! networks where only a small frontier is live each round.

use crate::algorithms::sssp::INF;
use crate::graph::{DynGraph, NodeId};

/// Dense-push SSSP: every round scans *all* vertices for the active flag
/// (no frontier compaction). Returns `(dist, rounds, vertex_scans)` so
/// benches can expose the wasted-scan cost on road networks.
pub fn sssp_dense_push(g: &DynGraph, source: NodeId) -> (Vec<i64>, usize, u64) {
    let n = g.num_nodes();
    let mut dist = vec![INF; n];
    dist[source as usize] = 0;
    let mut modified = vec![false; n];
    modified[source as usize] = true;
    let mut rounds = 0usize;
    let mut scans = 0u64;
    loop {
        let mut any = false;
        let mut nxt = vec![false; n];
        for v in 0..n as NodeId {
            scans += 1; // the dense-push cost: scan regardless of activity
            if !modified[v as usize] || dist[v as usize] >= INF {
                continue;
            }
            let dv = dist[v as usize];
            for (nbr, w) in g.out_neighbors(v) {
                let alt = dv + w as i64;
                if alt < dist[nbr as usize] {
                    dist[nbr as usize] = alt;
                    nxt[nbr as usize] = true;
                    any = true;
                }
            }
        }
        rounds += 1;
        modified = nxt;
        if !any {
            return (dist, rounds, scans);
        }
    }
}

/// Green-Marl PR is double-buffered like StarPlat's; it differs mainly in
/// lock implementation details, so we model it as the same Jacobi sweep.
pub fn pagerank_jacobi(g: &DynGraph, beta: f64, delta: f64, max_iter: usize) -> (Vec<f64>, usize) {
    let n = g.num_nodes();
    let mut st = crate::algorithms::pagerank::PrState::new(n, beta, delta, max_iter);
    let iters = crate::algorithms::pagerank::static_pagerank(g, &mut st);
    (st.rank, iters)
}

/// Node-iterator TC with *linear* membership scan (no sorted adjacency) —
/// the §6.2 explanation for Green-Marl's much slower TC.
pub fn tc_linear_scan(g: &DynGraph) -> i64 {
    let n = g.num_nodes();
    let mut count = 0i64;
    for v in 0..n as NodeId {
        let nbrs: Vec<NodeId> = g.out_neighbors(v).map(|(x, _)| x).collect();
        for &u in nbrs.iter().filter(|&&u| u < v) {
            for &w in nbrs.iter().filter(|&&w| w > v) {
                // linear scan of u's adjacency for w
                if g.out_neighbors(u).any(|(x, _)| x == w) {
                    count += 1;
                }
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::sssp::dijkstra_oracle;
    use crate::algorithms::triangle::{static_tc, symmetrize};
    use crate::graph::generators;

    #[test]
    fn dense_push_matches_dijkstra() {
        let g = generators::uniform_random(100, 600, 9, 12);
        let (dist, _, _) = sssp_dense_push(&g, 0);
        assert_eq!(dist, dijkstra_oracle(&g, 0));
    }

    #[test]
    fn dense_push_scans_scale_with_rounds() {
        // long path: rounds ≈ path length, scans = rounds * n — the road
        // pathology the paper describes.
        let edges: Vec<_> = (0..49u32).map(|i| (i, i + 1, 1)).collect();
        let g = DynGraph::from_edges(50, &edges);
        let (_, rounds, scans) = sssp_dense_push(&g, 0);
        assert!(rounds >= 49, "rounds={rounds}");
        assert_eq!(scans, rounds as u64 * 50);
    }

    #[test]
    fn linear_scan_tc_matches_reference() {
        let g = symmetrize(&generators::uniform_random(50, 300, 5, 7));
        assert_eq!(tc_linear_scan(&g), static_tc(&g).triangles);
    }
}
