//! PageRank: static (double-buffered pull iteration, Appendix Fig. 20
//! `staticPR`) and dynamic (flag affected vertices, `propagateNodeFlags`
//! BFS closure, then re-iterate only the flagged subset).

use crate::graph::updates::Batch;
use crate::graph::{DynGraph, NodeId};

/// PageRank state plus the convergence parameters the paper uses
/// (`beta` threshold, damping `delta`, iteration cap).
#[derive(Debug, Clone)]
pub struct PrState {
    pub rank: Vec<f64>,
    pub beta: f64,
    pub delta: f64,
    pub max_iter: usize,
}

impl PrState {
    pub fn new(n: usize, beta: f64, delta: f64, max_iter: usize) -> Self {
        PrState { rank: vec![1.0 / n as f64; n], beta, delta, max_iter }
    }
}

/// One pull-style PR update for vertex `v` given current ranks.
#[inline]
fn pull_value(g: &DynGraph, rank: &[f64], v: NodeId, delta: f64, n: f64) -> f64 {
    let mut sum = 0.0;
    for (nbr, _) in g.in_neighbors(v) {
        let d = g.out_degree(nbr);
        if d > 0 {
            sum += rank[nbr as usize] / d as f64;
        }
    }
    (1.0 - delta) / n + delta * sum
}

/// Static PageRank (Fig. 20 `staticPR`): double-buffered, converges when
/// the summed absolute rank movement drops below `beta` or `max_iter` is
/// reached. Returns the iteration count actually used.
pub fn static_pagerank(g: &DynGraph, st: &mut PrState) -> usize {
    let n = g.num_nodes();
    let nf = n as f64;
    st.rank = vec![1.0 / nf; n];
    let mut next = vec![0.0; n];
    let mut iters = 0;
    loop {
        let mut diff = 0.0;
        for v in 0..n as NodeId {
            let val = pull_value(g, &st.rank, v, st.delta, nf);
            diff += (val - st.rank[v as usize]).abs();
            next[v as usize] = val;
        }
        std::mem::swap(&mut st.rank, &mut next);
        iters += 1;
        if diff <= st.beta || iters >= st.max_iter {
            return iters;
        }
    }
}

/// `g.propagateNodeFlags(flags)` (§6.3 discussion): BFS closure of the
/// flagged set along out-edges — every vertex reachable from a flagged
/// vertex becomes flagged. Returns the number of BFS levels (the US-road
/// anomaly in Fig. 15 is precisely this level count scaling with
/// diameter).
pub fn propagate_node_flags(g: &DynGraph, flags: &mut [bool]) -> usize {
    propagate_flags_with(g.num_nodes(), flags, |v| g.out_neighbors(v).map(|(nbr, _)| nbr))
}

/// The BFS flag-closure body, generic over the out-neighbor accessor so
/// the single-graph and sharded-graph flavors share one implementation
/// (and stay semantically identical by construction — the sharded PR
/// equivalence tests depend on that).
pub fn propagate_flags_with<I>(
    n: usize,
    flags: &mut [bool],
    mut out_neighbors: impl FnMut(NodeId) -> I,
) -> usize
where
    I: Iterator<Item = NodeId>,
{
    let mut frontier: Vec<NodeId> =
        (0..n as NodeId).filter(|&v| flags[v as usize]).collect();
    let mut levels = 0;
    while !frontier.is_empty() {
        levels += 1;
        let mut next = Vec::new();
        for &v in &frontier {
            for nbr in out_neighbors(v) {
                if !flags[nbr as usize] {
                    flags[nbr as usize] = true;
                    next.push(nbr);
                }
            }
        }
        frontier = next;
    }
    levels
}

/// Dynamic PR propagation (Fig. 20 `Incremental`/`Decremental` share this
/// body): re-iterate the pull update restricted to flagged vertices.
pub fn recompute_flagged(g: &DynGraph, st: &mut PrState, flags: &[bool]) -> usize {
    let n = g.num_nodes();
    let nf = n as f64;
    let active: Vec<NodeId> = (0..n as NodeId).filter(|&v| flags[v as usize]).collect();
    if active.is_empty() {
        return 0;
    }
    let mut next = st.rank.clone();
    let mut iters = 0;
    loop {
        let mut diff = 0.0;
        for &v in &active {
            let val = pull_value(g, &st.rank, v, st.delta, nf);
            diff += (val - st.rank[v as usize]).abs();
            next[v as usize] = val;
        }
        for &v in &active {
            st.rank[v as usize] = next[v as usize];
        }
        iters += 1;
        if diff <= st.beta || iters >= st.max_iter {
            return iters;
        }
    }
}

/// Metrics from one dynamic PR batch (used by benches to expose the
/// propagateNodeFlags diameter anomaly).
#[derive(Debug, Clone, Default)]
pub struct PrBatchStats {
    pub flagged_del: usize,
    pub flagged_add: usize,
    pub bfs_levels_del: usize,
    pub bfs_levels_add: usize,
    pub iters_del: usize,
    pub iters_add: usize,
}

/// Process one batch through the dynamic PR pipeline (Fig. 20 `DynPR`):
/// flag deletion targets → propagateNodeFlags → updateCSRDel →
/// Decremental; then the same for additions.
pub fn dynamic_batch(g: &mut DynGraph, st: &mut PrState, batch: &Batch<'_>) -> PrBatchStats {
    let n = g.num_nodes();
    let mut stats = PrBatchStats::default();

    let dels: Vec<_> = batch.deletions().collect();
    let mut modified = vec![false; n];
    for &(_, v) in &dels {
        modified[v as usize] = true;
    }
    stats.bfs_levels_del = propagate_node_flags(g, &mut modified);
    g.apply_deletions(&dels);
    stats.flagged_del = modified.iter().filter(|&&m| m).count();
    stats.iters_del = recompute_flagged(g, st, &modified);

    let adds: Vec<_> = batch.additions().collect();
    let mut modified_add = vec![false; n];
    for &(_, v, _) in &adds {
        modified_add[v as usize] = true;
    }
    stats.bfs_levels_add = propagate_node_flags(g, &mut modified_add);
    g.apply_additions(&adds);
    stats.flagged_add = modified_add.iter().filter(|&&m| m).count();
    stats.iters_add = recompute_flagged(g, st, &modified_add);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::UpdateStream;
    use crate::util::propcheck::forall_checks;

    fn params(n: usize) -> PrState {
        PrState::new(n, 1e-9, 0.85, 200)
    }

    #[test]
    fn uniform_cycle_gives_uniform_rank() {
        // directed 4-cycle: perfectly symmetric => uniform PR
        let g = DynGraph::from_edges(4, &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (3, 0, 1)]);
        let mut st = params(4);
        static_pagerank(&g, &mut st);
        for &r in &st.rank {
            assert!((r - 0.25).abs() < 1e-6, "rank={r}");
        }
    }

    #[test]
    fn hub_gets_higher_rank() {
        // everyone points at 0
        let g = DynGraph::from_edges(5, &[(1, 0, 1), (2, 0, 1), (3, 0, 1), (4, 0, 1)]);
        let mut st = params(5);
        static_pagerank(&g, &mut st);
        for v in 1..5 {
            assert!(st.rank[0] > st.rank[v] * 3.0);
        }
    }

    #[test]
    fn propagate_flags_reaches_descendants_only() {
        // 0 -> 1 -> 2,  3 isolated
        let g = DynGraph::from_edges(4, &[(0, 1, 1), (1, 2, 1)]);
        let mut flags = vec![false, true, false, false];
        let levels = propagate_node_flags(&g, &mut flags);
        assert_eq!(flags, vec![false, true, true, false]);
        assert_eq!(levels, 2, "frontier {{1}} then {{2}}");
    }

    #[test]
    fn propagate_levels_scale_with_diameter() {
        // path graph: flag the head, levels == path length
        let edges: Vec<_> = (0..9u32).map(|i| (i, i + 1, 1)).collect();
        let g = DynGraph::from_edges(10, &edges);
        let mut flags = vec![false; 10];
        flags[0] = true;
        assert_eq!(propagate_node_flags(&g, &mut flags), 10);
        assert!(flags.iter().all(|&f| f));
    }

    #[test]
    fn dynamic_tracks_static_recompute() {
        let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 31);
        let n = g0.num_nodes();
        let stream = UpdateStream::generate_percent(&g0, 8.0, 32, 9, 77);

        let mut g = g0.clone();
        let mut st = params(n);
        static_pagerank(&g, &mut st);
        for batch in stream.batches() {
            dynamic_batch(&mut g, &mut st, &batch);
        }

        let mut g2 = g0.clone();
        stream.apply_all_static(&mut g2);
        let mut truth = params(n);
        static_pagerank(&g2, &mut truth);

        // Dynamic PR is an approximation (only flagged vertices refreshed);
        // ranks must be close in L1, and the top-vertex ordering must agree
        // loosely. Tolerance mirrors the paper's premise that flag closure
        // covers every vertex whose rank can move materially.
        let l1: f64 =
            st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 0.05, "L1 divergence too large: {l1}");
    }

    #[test]
    fn prop_ranks_sum_to_one_ish() {
        forall_checks(0x9A6E, 20, |gen| {
            let n = gen.usize_in(4, 80);
            let e = gen.usize_in(n, n * 4);
            let g = generators::uniform_random(n, e, 5, gen.rng().next_u64());
            let mut st = params(n);
            static_pagerank(&g, &mut st);
            let sum: f64 = st.rank.iter().sum();
            // with dangling vertices PR mass leaks; sum stays in (0.3, 1.001]
            assert!(sum <= 1.001 && sum > 0.3, "sum={sum}");
            assert!(st.rank.iter().all(|&r| r > 0.0));
        });
    }

    #[test]
    fn recompute_flagged_touches_only_flagged() {
        let g = generators::uniform_random(30, 120, 5, 3);
        let mut st = params(30);
        static_pagerank(&g, &mut st);
        let before = st.rank.clone();
        let mut flags = vec![false; 30];
        flags[7] = true;
        recompute_flagged(&g, &mut st, &flags);
        for v in 0..30 {
            if v != 7 {
                assert_eq!(st.rank[v], before[v], "unflagged vertex {v} moved");
            }
        }
    }
}
