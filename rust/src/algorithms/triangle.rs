//! Triangle Counting over the symmetric (undirected) view: static
//! node-iterator count (Appendix Fig. 19 `staticTC`) and the paper's
//! delta-counting dynamic variants with the 1/2, 1/4, 1/6 multiplicity
//! corrections.
//!
//! Protocol notes (matching the paper's setup): TC runs on *symmetric*
//! graphs — every undirected edge is stored as two directed arcs, and an
//! update inserts/deletes both arcs in the same batch. The delta counter
//! then sees each triangle with k new undirected edges exactly 2k times,
//! which the `count_k / (2k)` division corrects.

use crate::graph::{DynGraph, NodeId, Weight};
use std::collections::HashSet;

/// Triangle-count state: the running count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TcState {
    pub triangles: i64,
}

/// Static TC (Fig. 19 `staticTC`): for every `v`, neighbors `u < v` and
/// `w > v`, count if `u–w` is an edge. Counts each triangle once.
pub fn static_tc(g: &DynGraph) -> TcState {
    let n = g.num_nodes();
    let mut count = 0i64;
    for v in 0..n as NodeId {
        let nbrs: Vec<NodeId> = g.out_neighbors(v).map(|(x, _)| x).collect();
        for &u in nbrs.iter().filter(|&&u| u < v) {
            for &w in nbrs.iter().filter(|&&w| w > v) {
                if g.has_edge(u, w) {
                    count += 1;
                }
            }
        }
    }
    TcState { triangles: count }
}

/// Brute-force oracle: enumerate all vertex triples (tests only).
pub fn brute_force_tc(g: &DynGraph) -> i64 {
    let n = g.num_nodes();
    let mut count = 0;
    for a in 0..n as NodeId {
        for b in (a + 1)..n as NodeId {
            if !g.has_edge(a, b) {
                continue;
            }
            for c in (b + 1)..n as NodeId {
                if g.has_edge(a, c) && g.has_edge(b, c) {
                    count += 1;
                }
            }
        }
    }
    count
}

/// Delta counting shared by incremental and decremental TC (Fig. 19):
/// for each updated arc `(v1, v2)` and each neighbor `v3` of `v1`,
/// a wedge closed by `v2–v3` is a triangle; its multiplicity class is the
/// number of *updated* edges among `{v1v2, v1v3, v2v3}`.
///
/// `modified` answers "is this arc part of the update batch"; the graph
/// must already contain the arcs being counted (incremental: after
/// `updateCSRAdd`; decremental: before `updateCSRDel`).
fn delta_count(
    g: &DynGraph,
    arcs: &[(NodeId, NodeId)],
    modified: &HashSet<(NodeId, NodeId)>,
) -> i64 {
    let mut count1 = 0i64;
    let mut count2 = 0i64;
    let mut count3 = 0i64;
    let is_mod = |a: NodeId, b: NodeId| modified.contains(&(a, b)) || modified.contains(&(b, a));
    for &(v1, v2) in arcs {
        if v1 == v2 {
            continue;
        }
        for (v3, _) in g.out_neighbors(v1) {
            if v3 == v2 || v3 == v1 {
                continue;
            }
            if !g.has_edge(v2, v3) && !g.has_edge(v3, v2) {
                continue;
            }
            let mut new_edges = 1; // the (v1, v2) update itself
            if is_mod(v1, v3) {
                new_edges += 1;
            }
            if is_mod(v2, v3) {
                new_edges += 1;
            }
            match new_edges {
                1 => count1 += 1,
                2 => count2 += 1,
                _ => count3 += 1,
            }
        }
    }
    count1 / 2 + count2 / 4 + count3 / 6
}

/// Incremental TC (Fig. 19): run *after* the additions are in the graph.
/// `adds` contains both arcs of each undirected insertion.
pub fn incremental(g: &DynGraph, st: &mut TcState, adds: &[(NodeId, NodeId, Weight)]) {
    let arcs: Vec<(NodeId, NodeId)> = adds.iter().map(|&(u, v, _)| (u, v)).collect();
    let modified: HashSet<(NodeId, NodeId)> = arcs.iter().copied().collect();
    st.triangles += delta_count(g, &arcs, &modified);
}

/// Decremental TC (Fig. 19): run *before* the deletions leave the graph.
pub fn decremental(g: &DynGraph, st: &mut TcState, dels: &[(NodeId, NodeId)]) {
    let modified: HashSet<(NodeId, NodeId)> = dels.iter().copied().collect();
    st.triangles -= delta_count(g, dels, &modified);
}

/// One dynamic TC batch (Fig. 19 `DynTC` body order): Decremental (graph
/// intact) → updateCSRDel → updateCSRAdd → Incremental.
pub fn dynamic_batch(
    g: &mut DynGraph,
    st: &mut TcState,
    dels: &[(NodeId, NodeId)],
    adds: &[(NodeId, NodeId, Weight)],
) {
    decremental(g, st, dels);
    g.apply_deletions(dels);
    g.apply_additions(adds);
    incremental(g, st, adds);
}

/// Make a symmetric (undirected) version of a graph: both arcs for every
/// edge, weight copied from the first arc seen.
pub fn symmetrize(g: &DynGraph) -> DynGraph {
    let n = g.num_nodes();
    let mut seen = HashSet::new();
    let mut edges = Vec::new();
    for (u, v, w) in g.edges_sorted() {
        let key = (u.min(v), u.max(v));
        if u != v && seen.insert(key) {
            edges.push((u, v, w));
            edges.push((v, u, w));
        }
    }
    DynGraph::from_edges(n, &edges)
}

/// Generate a symmetric update stream for TC: `total` undirected updates
/// (each expanded into its two arcs, kept adjacent in the stream), half
/// deletions of existing undirected edges, half fresh insertions.
pub fn symmetric_updates(
    g: &DynGraph,
    percent: f64,
    batch_size: usize,
    seed: u64,
) -> (Vec<Vec<(NodeId, NodeId)>>, Vec<Vec<(NodeId, NodeId, Weight)>>) {
    use crate::util::Rng;
    let mut rng = Rng::new(seed);
    let n = g.num_nodes();
    // undirected edge set
    let mut und: Vec<(NodeId, NodeId)> = g
        .edges_sorted()
        .into_iter()
        .filter(|&(u, v, _)| u < v)
        .map(|(u, v, _)| (u, v))
        .collect();
    let m_und = und.len();
    let total = ((m_und as f64) * percent / 100.0).round() as usize;
    let n_del = (total / 2).min(m_und);
    let n_add = total - n_del;

    rng.shuffle(&mut und);
    let dels: Vec<(NodeId, NodeId)> = und[..n_del].to_vec();

    let mut present: HashSet<(NodeId, NodeId)> = und.iter().copied().collect();
    let mut adds = Vec::new();
    let mut attempts = 0;
    while adds.len() < n_add && attempts < n_add * 64 + 1024 {
        attempts += 1;
        let a = rng.below_usize(n) as NodeId;
        let b = rng.below_usize(n) as NodeId;
        if a == b {
            continue;
        }
        let key = (a.min(b), a.max(b));
        if present.insert(key) {
            adds.push(key);
        }
    }

    // Split into per-batch arc lists (batch_size counts undirected updates,
    // mixing deletions and additions like the paper's batches).
    let mut del_batches = Vec::new();
    let mut add_batches = Vec::new();
    let num_batches = total.div_ceil(batch_size.max(1)).max(1);
    for b in 0..num_batches {
        let dlo = (b * dels.len()) / num_batches;
        let dhi = ((b + 1) * dels.len()) / num_batches;
        let alo = (b * adds.len()) / num_batches;
        let ahi = ((b + 1) * adds.len()) / num_batches;
        let mut darcs = Vec::new();
        for &(u, v) in &dels[dlo..dhi] {
            darcs.push((u, v));
            darcs.push((v, u));
        }
        let mut aarcs = Vec::new();
        for &(u, v) in &adds[alo..ahi] {
            let w = 1 + rng.below(9) as Weight;
            aarcs.push((u, v, w));
            aarcs.push((v, u, w));
        }
        del_batches.push(darcs);
        add_batches.push(aarcs);
    }
    (del_batches, add_batches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::propcheck::forall_checks;

    fn und(n: usize, pairs: &[(NodeId, NodeId)]) -> DynGraph {
        let mut edges = Vec::new();
        for &(u, v) in pairs {
            edges.push((u, v, 1));
            edges.push((v, u, 1));
        }
        DynGraph::from_edges(n, &edges)
    }

    #[test]
    fn counts_single_triangle() {
        let g = und(3, &[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(static_tc(&g).triangles, 1);
        assert_eq!(brute_force_tc(&g), 1);
    }

    #[test]
    fn counts_k4_has_four_triangles() {
        let g = und(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        assert_eq!(static_tc(&g).triangles, 4);
    }

    #[test]
    fn no_triangles_in_star() {
        let g = und(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        assert_eq!(static_tc(&g).triangles, 0);
    }

    #[test]
    fn incremental_single_new_edge() {
        // path 0-1-2; adding 0-2 closes one triangle with exactly 1 new edge
        let mut g = und(3, &[(0, 1), (1, 2)]);
        let mut st = static_tc(&g);
        assert_eq!(st.triangles, 0);
        let adds = vec![(0, 2, 1), (2, 0, 1)];
        g.apply_additions(&adds);
        incremental(&g, &mut st, &adds);
        assert_eq!(st.triangles, 1);
        assert_eq!(st.triangles, static_tc(&g).triangles);
    }

    #[test]
    fn incremental_all_three_edges_new() {
        let mut g = und(3, &[]);
        let mut st = static_tc(&g);
        let adds =
            vec![(0, 1, 1), (1, 0, 1), (1, 2, 1), (2, 1, 1), (0, 2, 1), (2, 0, 1)];
        g.apply_additions(&adds);
        incremental(&g, &mut st, &adds);
        assert_eq!(st.triangles, 1, "3-new-edge triangle counted once via /6");
    }

    #[test]
    fn decremental_removes_triangle() {
        let mut g = und(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let mut st = static_tc(&g);
        assert_eq!(st.triangles, 1);
        let dels = vec![(0, 1), (1, 0)];
        decremental(&g, &mut st, &dels);
        g.apply_deletions(&dels);
        assert_eq!(st.triangles, 0);
        assert_eq!(st.triangles, static_tc(&g).triangles);
    }

    #[test]
    fn static_matches_brute_force_random() {
        let g = symmetrize(&generators::uniform_random(40, 250, 5, 8));
        assert_eq!(static_tc(&g).triangles, brute_force_tc(&g));
    }

    #[test]
    fn prop_dynamic_tc_equals_static_recompute() {
        forall_checks(0x7C7C, 25, |gen| {
            let n = gen.usize_in(6, 40);
            let e = gen.usize_in(n, n * 4);
            let seed = gen.rng().next_u64();
            let g0 = symmetrize(&generators::uniform_random(n, e, 5, seed));
            let pct = 1.0 + gen.f64_unit() * 19.0;
            let (dels, adds) = symmetric_updates(&g0, pct, gen.usize_in(1, 8), seed ^ 0xF00);

            let mut g = g0.clone();
            let mut st = static_tc(&g);
            for (d, a) in dels.iter().zip(&adds) {
                dynamic_batch(&mut g, &mut st, d, a);
            }
            let truth = static_tc(&g).triangles;
            assert_eq!(st.triangles, truth, "delta counting diverged");
            assert_eq!(truth, brute_force_tc(&g));
        });
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let g = symmetrize(&generators::rmat(6, 150, 0.57, 0.19, 0.19, 4));
        for (u, v, _) in g.edges_sorted() {
            assert!(g.has_edge(v, u));
        }
    }
}
