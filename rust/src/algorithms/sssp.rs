//! Single-Source Shortest Paths: static (Bellman-Ford-style fixed point,
//! Appendix Fig. 21 `staticSSSP`), incremental (push relaxation from
//! activated vertices), and decremental (parent-tree invalidation cascade
//! followed by pull recomputation) — the exact structure of the paper's
//! DSL programs.

use crate::graph::updates::Batch;
use crate::graph::{DynGraph, NodeId};

/// "Infinity" distance (safe against `+ weight` overflow; the paper's
/// generated code uses `INT_MAX/2` the same way).
pub const INF: i64 = i64::MAX / 4;

/// SSSP node state: distances and the shortest-path tree.
#[derive(Debug, Clone, PartialEq)]
pub struct SsspState {
    pub dist: Vec<i64>,
    /// Parent in the SP tree, or `-1`.
    pub parent: Vec<i64>,
    pub source: NodeId,
}

impl SsspState {
    pub fn new(n: usize, source: NodeId) -> Self {
        let mut s = SsspState { dist: vec![INF; n], parent: vec![-1; n], source };
        s.dist[source as usize] = 0;
        s
    }
}

/// Static SSSP: Bellman-Ford fixed point over `modified` frontiers
/// (Fig. 21 `staticSSSP`). Returns the converged state.
pub fn static_sssp(g: &DynGraph, source: NodeId) -> SsspState {
    let n = g.num_nodes();
    let mut st = SsspState::new(n, source);
    let mut modified = vec![false; n];
    modified[source as usize] = true;
    let mut any = true;
    while any {
        any = false;
        let mut modified_nxt = vec![false; n];
        for v in 0..n as NodeId {
            if !modified[v as usize] {
                continue;
            }
            let dv = st.dist[v as usize];
            if dv >= INF {
                continue;
            }
            for (nbr, w) in g.out_neighbors(v) {
                let alt = dv + w as i64;
                if alt < st.dist[nbr as usize] {
                    st.dist[nbr as usize] = alt;
                    st.parent[nbr as usize] = v as i64;
                    modified_nxt[nbr as usize] = true;
                    any = true;
                }
            }
        }
        modified = modified_nxt;
    }
    st
}

/// `OnDelete` preprocessing (Fig. 21): a deleted edge `u -> v` whose `v`
/// had `parent == u` invalidates `v`. Returns the modified flags.
pub fn on_delete(st: &mut SsspState, dels: &[(NodeId, NodeId)]) -> Vec<bool> {
    on_delete_iter(st, dels.iter().copied())
}

/// Iterator-driven variant of [`on_delete`] — the sharded streaming
/// engine feeds per-shard deletion buffers without flattening them.
pub fn on_delete_iter<I>(st: &mut SsspState, dels: I) -> Vec<bool>
where
    I: IntoIterator<Item = (NodeId, NodeId)>,
{
    let mut modified = vec![false; st.dist.len()];
    for (u, v) in dels {
        if st.parent[v as usize] == u as i64 {
            st.dist[v as usize] = INF;
            st.parent[v as usize] = -1;
            modified[v as usize] = true;
        }
    }
    modified
}

/// Decremental SSSP (Fig. 21 `Decremental`), run *after* the graph has
/// been updated with the deletions:
/// phase 1 — cascade invalidation down the former SP tree;
/// phase 2 — pull-based recomputation of invalidated vertices.
pub fn decremental(g: &DynGraph, st: &mut SsspState, modified: &mut [bool]) {
    let n = g.num_nodes();
    // Phase 1: any vertex whose parent is invalidated becomes invalidated.
    let mut finished = false;
    while !finished {
        finished = true;
        for v in 0..n {
            if modified[v] {
                continue;
            }
            let p = st.parent[v];
            if p > -1 && modified[p as usize] {
                st.dist[v] = INF;
                st.parent[v] = -1;
                modified[v] = true;
                finished = false;
            }
        }
    }
    // Phase 2: pull — recompute invalidated vertices from in-neighbors
    // until a fixed point (restricted Bellman-Ford; converges because all
    // paths into the invalidated set start at valid vertices).
    let mut finished = false;
    while !finished {
        finished = true;
        for v in 0..n as NodeId {
            if !modified[v as usize] {
                continue;
            }
            for (nbr, w) in g.in_neighbors(v) {
                let dn = st.dist[nbr as usize];
                if dn >= INF {
                    continue;
                }
                let alt = dn + w as i64;
                if alt < st.dist[v as usize] {
                    st.dist[v as usize] = alt;
                    st.parent[v as usize] = nbr as i64;
                    finished = false;
                }
            }
        }
    }
}

/// `OnAdd` preprocessing (Fig. 3): an added edge that can shorten the
/// destination's distance activates both endpoints.
pub fn on_add(st: &SsspState, adds: &[(NodeId, NodeId, i32)]) -> Vec<bool> {
    on_add_iter(st, adds.iter().copied())
}

/// Iterator-driven variant of [`on_add`] (see [`on_delete_iter`]).
pub fn on_add_iter<I>(st: &SsspState, adds: I) -> Vec<bool>
where
    I: IntoIterator<Item = (NodeId, NodeId, i32)>,
{
    let mut modified = vec![false; st.dist.len()];
    for (u, v, w) in adds {
        if st.dist[u as usize] < INF && st.dist[u as usize] + (w as i64) < st.dist[v as usize] {
            modified[u as usize] = true;
            modified[v as usize] = true;
        }
    }
    modified
}

/// Incremental SSSP (Fig. 21 `Incremental`): push relaxation fixed point
/// seeded by the activated vertices. Run *after* `updateCSRAdd`.
pub fn incremental(g: &DynGraph, st: &mut SsspState, modified: &mut Vec<bool>) {
    let n = g.num_nodes();
    let mut any = modified.iter().any(|&m| m);
    while any {
        any = false;
        let mut nxt = vec![false; n];
        for v in 0..n as NodeId {
            if !modified[v as usize] {
                continue;
            }
            let dv = st.dist[v as usize];
            if dv >= INF {
                continue;
            }
            for (nbr, w) in g.out_neighbors(v) {
                let alt = dv + w as i64;
                if alt < st.dist[nbr as usize] {
                    st.dist[nbr as usize] = alt;
                    st.parent[nbr as usize] = v as i64;
                    nxt[nbr as usize] = true;
                    any = true;
                }
            }
        }
        *modified = nxt;
    }
}

/// Process one update batch through the full dynamic pipeline
/// (Fig. 3 `DynSSSP` body): OnDelete → updateCSRDel → Decremental →
/// OnAdd → updateCSRAdd → Incremental.
pub fn dynamic_batch(g: &mut DynGraph, st: &mut SsspState, batch: &Batch<'_>) {
    let dels: Vec<_> = batch.deletions().collect();
    let mut mod_del = on_delete(st, &dels);
    g.apply_deletions(&dels);
    decremental(g, st, &mut mod_del);

    let adds: Vec<_> = batch.additions().collect();
    let mut mod_add = on_add(st, &adds);
    g.apply_additions(&adds);
    incremental(g, st, &mut mod_add);
}

/// Deterministic SP-tree repair shared by the backends that keep their
/// parents bitwise-comparable: `parent[v]` becomes the **smallest** `u`
/// among in-neighbors achieving `dist[u] + w(u,v) == dist[v]` (`-1` for
/// the source and unreachable vertices). The cpu engine runs a parallel
/// owner-writes variant of the same argmin rule (its tests pin the two
/// bitwise-equal); the dist and xla engines call this serial form, which
/// is what makes cross-backend SSSP end-states comparable parent-for-
/// parent in the equivalence matrices.
pub fn repair_parents_argmin(g: &DynGraph, st: &mut SsspState) {
    let n = g.num_nodes();
    for v in 0..n as NodeId {
        let vu = v as usize;
        let mut best = -1i64;
        if v != st.source && st.dist[vu] < INF {
            for (u, w) in g.in_neighbors(v) {
                if st.dist[u as usize] < INF
                    && st.dist[u as usize] + w as i64 == st.dist[vu]
                {
                    let cand = u as i64;
                    if best == -1 || cand < best {
                        best = cand;
                    }
                }
            }
        }
        st.parent[vu] = best;
    }
    st.parent[st.source as usize] = -1;
}

/// Dijkstra with a binary heap — an *independent* oracle used only by
/// tests (the main implementations are all Bellman-Ford-shaped).
pub fn dijkstra_oracle(g: &DynGraph, source: NodeId) -> Vec<i64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_nodes();
    let mut dist = vec![INF; n];
    dist[source as usize] = 0;
    let mut pq = BinaryHeap::new();
    pq.push(Reverse((0i64, source)));
    while let Some(Reverse((d, v))) = pq.pop() {
        if d > dist[v as usize] {
            continue;
        }
        for (nbr, w) in g.out_neighbors(v) {
            let alt = d + w as i64;
            if alt < dist[nbr as usize] {
                dist[nbr as usize] = alt;
                pq.push(Reverse((alt, nbr)));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::UpdateStream;
    use crate::util::propcheck::forall_checks;

    #[test]
    fn static_matches_dijkstra_small() {
        let g = generators::uniform_random(60, 300, 9, 17);
        let st = static_sssp(&g, 0);
        assert_eq!(st.dist, dijkstra_oracle(&g, 0));
    }

    #[test]
    fn unreachable_stays_inf() {
        let g = DynGraph::from_edges(4, &[(0, 1, 2), (1, 2, 3)]);
        let st = static_sssp(&g, 0);
        assert_eq!(st.dist, vec![0, 2, 5, INF]);
        assert_eq!(st.parent[2], 1);
        assert_eq!(st.parent[3], -1);
    }

    #[test]
    fn parents_form_shortest_path_tree() {
        let g = generators::uniform_random(80, 400, 9, 23);
        let st = static_sssp(&g, 3);
        for v in 0..80usize {
            if st.dist[v] < INF && v != 3 {
                let p = st.parent[v];
                assert!(p >= 0, "reachable vertex {v} must have a parent");
                let w = g.edge_weight(p as NodeId, v as NodeId).expect("parent edge exists");
                assert_eq!(st.dist[v], st.dist[p as usize] + w as i64);
            }
        }
    }

    #[test]
    fn incremental_edge_shortens_path() {
        // paper's Fig. 2 example shape: adding a shortcut reduces distances
        // downstream of the target.
        let mut g = DynGraph::from_edges(
            5,
            &[(0, 1, 10), (1, 2, 10), (2, 3, 10), (3, 4, 10), (0, 2, 50)],
        );
        let mut st = static_sssp(&g, 0);
        assert_eq!(st.dist[4], 40);
        let adds = [(0u32, 3u32, 5i32)];
        let mut m = on_add(&st, &adds);
        g.apply_additions(&[(0, 3, 5)]);
        incremental(&g, &mut st, &mut m);
        assert_eq!(st.dist[3], 5);
        assert_eq!(st.dist[4], 15);
        assert_eq!(st.dist, dijkstra_oracle(&g, 0));
    }

    #[test]
    fn decremental_edge_invalidates_subtree() {
        let mut g =
            DynGraph::from_edges(5, &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (0, 3, 10), (3, 4, 1)]);
        let mut st = static_sssp(&g, 0);
        assert_eq!(st.dist[3], 3);
        let dels = [(1u32, 2u32)];
        let mut m = on_delete(&mut st, &dels);
        g.apply_deletions(&dels);
        decremental(&g, &mut st, &mut m);
        assert_eq!(st.dist[2], INF, "2 became unreachable");
        assert_eq!(st.dist[3], 10, "3 falls back to the direct edge");
        assert_eq!(st.dist[4], 11);
        assert_eq!(st.dist, dijkstra_oracle(&g, 0));
    }

    #[test]
    fn delete_nontree_edge_changes_nothing() {
        let mut g = DynGraph::from_edges(3, &[(0, 1, 1), (1, 2, 1), (0, 2, 5)]);
        let mut st = static_sssp(&g, 0);
        let before = st.clone();
        let dels = [(0u32, 2u32)]; // not a tree edge (dist[2]=2 via 1)
        let mut m = on_delete(&mut st, &dels);
        assert!(!m.iter().any(|&x| x), "no invalidation needed");
        g.apply_deletions(&dels);
        decremental(&g, &mut st, &mut m);
        assert_eq!(st.dist, before.dist);
    }

    #[test]
    fn prop_dynamic_equals_static_recompute() {
        forall_checks(0x5550, 30, |gen| {
            let n = gen.usize_in(8, 60);
            let e = gen.usize_in(n, n * 5);
            let seed = gen.rng().next_u64();
            let g0 = generators::uniform_random(n, e, 9, seed);
            let pct = 1.0 + gen.f64_unit() * 19.0;
            let stream =
                UpdateStream::generate_percent(&g0, pct, gen.usize_in(2, 16), 9, seed ^ 0xAB);
            let src = gen.usize_in(0, n - 1) as NodeId;

            // dynamic pipeline
            let mut g = g0.clone();
            let mut st = static_sssp(&g, src);
            for batch in stream.batches() {
                dynamic_batch(&mut g, &mut st, &batch);
            }

            // static recompute on the fully-updated graph
            let mut g2 = g0.clone();
            stream.apply_all_static(&mut g2);
            let want = dijkstra_oracle(&g2, src);
            assert_eq!(st.dist, want, "dynamic != static recompute");
        });
    }

    #[test]
    fn prop_road_graph_dynamic_correct() {
        forall_checks(0x5551, 8, |gen| {
            let side = gen.usize_in(4, 10);
            let seed = gen.rng().next_u64();
            let g0 = generators::road_grid(side, side, 9, seed);
            let stream = UpdateStream::generate_percent(&g0, 10.0, 8, 9, seed ^ 1);
            let mut g = g0.clone();
            let mut st = static_sssp(&g, 0);
            for batch in stream.batches() {
                dynamic_batch(&mut g, &mut st, &batch);
            }
            let mut g2 = g0.clone();
            stream.apply_all_static(&mut g2);
            assert_eq!(st.dist, dijkstra_oracle(&g2, 0));
        });
    }
}
