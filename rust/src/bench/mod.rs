//! Local bench harness (the offline crates.io snapshot has no criterion):
//! fixed-width table printing, suite construction, and argument handling
//! shared by the `rust/benches/*.rs` binaries.

use crate::graph::generators::{table1_suite, NamedGraph};

/// Default suite scale for benches: ~1000× smaller than the paper's
/// graphs, same shapes (override with env `STARPLAT_SCALE`).
pub fn bench_suite(default_scale: f64, seed: u64) -> Vec<NamedGraph> {
    let scale = std::env::var("STARPLAT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_scale);
    table1_suite(scale, seed)
}

/// Print the Table 1 header block for a suite.
pub fn print_suite(suite: &[NamedGraph]) {
    println!("\nInput graphs (paper Table 1 analogues; δ = degree):");
    println!("{:<6} {:<16} {:>8} {:>9} {:>7} {:>7}", "short", "stands for", "|V|", "|E|", "avg δ", "max δ");
    for g in suite {
        let n = g.graph.num_nodes();
        let m = g.graph.num_edges();
        let max_d = (0..n as u32).map(|v| g.graph.out_degree(v)).max().unwrap_or(0);
        println!(
            "{:<6} {:<16} {:>8} {:>9} {:>7.1} {:>7}",
            g.short,
            g.long,
            n,
            m,
            m as f64 / n as f64,
            max_d
        );
    }
    println!();
}

/// Fixed-width row printer for static-vs-dynamic tables.
pub struct TablePrinter {
    pub cols: Vec<String>,
}

impl TablePrinter {
    pub fn new(first: &str, suite: &[NamedGraph]) -> Self {
        let mut cols = vec![first.to_string()];
        cols.extend(suite.iter().map(|g| g.short.to_string()));
        let t = TablePrinter { cols };
        t.print_header();
        t
    }

    fn print_header(&self) {
        let mut line = format!("{:<22}", self.cols[0]);
        for c in &self.cols[1..] {
            line.push_str(&format!("{c:>10}"));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
    }

    pub fn row(&self, label: &str, values: &[f64]) {
        let mut line = format!("{label:<22}");
        for v in values {
            if v.is_nan() {
                line.push_str(&format!("{:>10}", "-"));
            } else if *v >= 100.0 {
                line.push_str(&format!("{v:>10.1}"));
            } else {
                line.push_str(&format!("{v:>10.4}"));
            }
        }
        println!("{line}");
    }
}

/// `cargo bench -- <filters>`: returns true if `name` matches any filter
/// (or there are no filters).
pub fn selected(name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| name.contains(a.as_str()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_builds_at_small_scale() {
        let s = bench_suite(0.01, 3);
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn printer_formats_without_panicking() {
        let s = bench_suite(0.01, 4);
        print_suite(&s);
        let t = TablePrinter::new("updates %", &s);
        t.row("1 static", &vec![0.5; 10]);
        t.row("1 dynamic", &vec![f64::NAN; 10]);
    }
}
