//! PJRT execution: load HLO-text artifacts, compile once, execute many.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. The graph
//! matrix is uploaded once per (graph, bucket) and kept device-resident
//! (`execute_b` over `PjRtBuffer`s) — the §5.3 host↔device transfer
//! optimization: only the property vector and the convergence scalar
//! cross the boundary each fixed-point iteration.
//!
//! Compiled in two flavors:
//! * with the `pjrt` cargo feature: the real runtime backed by the
//!   `xla` (xla_extension) bindings — the feature additionally requires
//!   that dependency to be present;
//! * without it (the default, dependency-free build): a stub with the
//!   same API whose constructor reports PJRT as unavailable, so every
//!   consumer (`XlaEngine`, benches, tests) degrades gracefully.

#[cfg(feature = "pjrt")]
mod real {
    use crate::util::error::{anyhow, Context, Result};
    use std::path::Path;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// xla_extension 0.5.1 cannot tolerate a second `TfrtCpuClient` in the
    /// same process (`Check failed: pointer_size > 0` on the next execute),
    /// so the crate keeps exactly ONE client for the process lifetime and
    /// serializes all PJRT entry points behind a mutex. The underlying C++
    /// client is thread-safe; the rust wrapper just isn't marked `Sync`.
    struct SyncClient(xla::PjRtClient);
    unsafe impl Send for SyncClient {}
    unsafe impl Sync for SyncClient {}

    static GLOBAL_CLIENT: OnceLock<std::result::Result<SyncClient, String>> = OnceLock::new();
    static PJRT_LOCK: Mutex<()> = Mutex::new(());

    fn pjrt_lock() -> MutexGuard<'static, ()> {
        PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn global_client() -> Result<&'static xla::PjRtClient> {
        let entry = GLOBAL_CLIENT.get_or_init(|| {
            xla::PjRtClient::cpu().map(SyncClient).map_err(|e| format!("{e:?}"))
        });
        match entry {
            Ok(c) => Ok(&c.0),
            Err(e) => Err(anyhow!("PJRT cpu client: {e}")),
        }
    }

    /// Shared PJRT CPU client + compiled executables.
    pub struct PjrtRuntime {
        client: &'static xla::PjRtClient,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            Ok(PjrtRuntime { client: global_client()? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact into a reusable executable.
        pub fn load(&self, path: &Path) -> Result<RoundsExe> {
            let _g = pjrt_lock();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
            Ok(RoundsExe { exe, client: self.client })
        }

        /// Upload an f32 tensor to the device (once per graph — §5.3).
        pub fn upload(&self, data: &[f32], dims: &[i64]) -> Result<xla::PjRtBuffer> {
            let _g = pjrt_lock();
            upload_with(self.client, data, dims)
        }
    }

    fn upload_with(
        client: &xla::PjRtClient,
        data: &[f32],
        dims: &[i64],
    ) -> Result<xla::PjRtBuffer> {
        // buffer_from_host_buffer copies with kImmutableOnlyDuringCall
        // semantics — safe to free `data` as soon as the call returns.
        // (buffer_from_host_literal is ASYNC in xla_extension 0.5.1 and reads
        // the literal after it may have been freed — the source of
        // intermittent `pointer_size`/size-check aborts.)
        let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        client
            .buffer_from_host_buffer::<f32>(data, &udims, None)
            .map_err(|e| anyhow!("upload {dims:?}: {e:?}"))
    }

    /// A compiled fixed-point-rounds executable (sssp_rounds / pr_rounds /
    /// tc_dense). Inputs are device buffers; outputs come back as literals.
    pub struct RoundsExe {
        exe: xla::PjRtLoadedExecutable,
        client: &'static xla::PjRtClient,
    }

    impl RoundsExe {
        /// Execute with device-resident buffers; returns one literal per
        /// module output. Artifacts are lowered with `return_tuple=False`,
        /// so each output is a separate *array* buffer (tuple-shaped buffers
        /// are unreliable in xla_extension 0.5.1 — see aot.py).
        pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
            let _g = pjrt_lock();
            let outs = self
                .exe
                .execute_b::<&xla::PjRtBuffer>(args)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let mut lits = Vec::new();
            for (i, buf) in outs[0].iter().enumerate() {
                let lit =
                    buf.to_literal_sync().map_err(|e| anyhow!("fetch output {i}: {e:?}"))?;
                // single-output modules may still come back tuple-wrapped
                if lit.shape().map(|s| matches!(s, xla::Shape::Tuple(_))).unwrap_or(false) {
                    lits.extend(lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?);
                } else {
                    lits.push(lit);
                }
            }
            Ok(lits)
        }

        /// Raw execution: the unflattened PJRT output buffers (debug/tests).
        pub fn run_raw(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
            let _g = pjrt_lock();
            self.exe.execute_b::<&xla::PjRtBuffer>(args).map_err(|e| anyhow!("execute: {e:?}"))
        }

        /// Upload helper sharing this executable's client.
        pub fn upload(&self, data: &[f32], dims: &[i64]) -> Result<xla::PjRtBuffer> {
            let _g = pjrt_lock();
            upload_with(self.client, data, dims)
        }
    }

    /// Extract an f32 vector from a literal.
    pub fn literal_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))
    }
}

#[cfg(feature = "pjrt")]
pub use real::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::util::error::{anyhow, Error, Result};
    use std::path::Path;

    fn unavailable() -> Error {
        anyhow!(
            "PJRT support not compiled in (rebuild with `--features pjrt` \
             and the xla_extension bindings to enable the xla backend)"
        )
    }

    /// Stand-in for `xla::Literal` in the stub build.
    pub struct Literal;
    /// Stand-in for `xla::PjRtBuffer` in the stub build.
    pub struct PjRtBuffer;

    /// Stub runtime: construction always fails, so downstream engines
    /// (`XlaEngine`) report unavailability instead of panicking.
    pub struct PjrtRuntime;

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "pjrt-unavailable".to_string()
        }

        pub fn load(&self, _path: &Path) -> Result<RoundsExe> {
            Err(unavailable())
        }

        pub fn upload(&self, _data: &[f32], _dims: &[i64]) -> Result<PjRtBuffer> {
            Err(unavailable())
        }
    }

    /// Stub executable: unreachable in practice (no runtime can be built).
    pub struct RoundsExe;

    impl RoundsExe {
        pub fn run(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
            Err(unavailable())
        }

        pub fn run_raw(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
            Err(unavailable())
        }

        pub fn upload(&self, _data: &[f32], _dims: &[i64]) -> Result<PjRtBuffer> {
            Err(unavailable())
        }
    }

    pub fn literal_f32s(_lit: &Literal) -> Result<Vec<f32>> {
        Err(unavailable())
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::ArtifactManifest;

    #[test]
    fn loads_and_runs_sssp_rounds_artifact() {
        let m = ArtifactManifest::load(&ArtifactManifest::default_dir())
            .expect("run `make artifacts`");
        let rt = PjrtRuntime::cpu().unwrap();
        let entry = m.pick("sssp_rounds", 100).unwrap();
        let exe = rt.load(&entry.path).unwrap();
        let n = entry.n_pad;

        // path graph 0->1->2->3, INF elsewhere
        const INF_F: f32 = 1e9;
        let mut adj = vec![INF_F; n * n];
        for i in 0..3 {
            adj[i * n + i + 1] = 1.0;
        }
        let mut dist = vec![INF_F; n];
        dist[0] = 0.0;

        let adj_buf = rt.upload(&adj, &[n as i64, n as i64]).unwrap();
        let dist_buf = rt.upload(&dist, &[n as i64]).unwrap();
        let outs = exe.run(&[&dist_buf, &adj_buf]).unwrap();
        assert_eq!(outs.len(), 2, "(<new_dist>, changed)");
        let new_dist = literal_f32s(&outs[0]).unwrap();
        let changed = literal_f32s(&outs[1]).unwrap()[0];
        assert_eq!(&new_dist[..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(changed, 3.0, "three vertices moved");
    }

    /// The Pallas-kernel artifact and the jnp artifact must compute the
    /// SAME numbers — this is the L1-validation bridge for the §Perf
    /// decision to time with the jnp flavor on CPU-PJRT (see model.py).
    #[test]
    fn pallas_and_jnp_artifacts_agree() {
        let m = ArtifactManifest::load(&ArtifactManifest::default_dir()).unwrap();
        let rt = PjrtRuntime::cpu().unwrap();
        let jnp = rt.load(&m.pick("sssp_rounds", 100).unwrap().path).unwrap();
        let pal = rt.load(&m.pick("sssp_rounds_pallas", 100).unwrap().path).unwrap();
        let n = m.pick("sssp_rounds", 100).unwrap().n_pad;

        const INF_F: f32 = 1e9;
        let mut adj = vec![INF_F; n * n];
        // random-ish small graph, deterministic
        let mut x = 12345u64;
        for _ in 0..600 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let u = (x >> 33) as usize % n;
            let v = (x >> 13) as usize % n;
            if u != v {
                adj[u * n + v] = 1.0 + (x % 9) as f32;
            }
        }
        let mut dist = vec![INF_F; n];
        dist[0] = 0.0;
        let adj_buf = rt.upload(&adj, &[n as i64, n as i64]).unwrap();
        let dist_buf = rt.upload(&dist, &[n as i64]).unwrap();
        let a = jnp.run(&[&dist_buf, &adj_buf]).unwrap();
        let dist_buf2 = rt.upload(&dist, &[n as i64]).unwrap();
        let b = pal.run(&[&dist_buf2, &adj_buf]).unwrap();
        assert_eq!(
            literal_f32s(&a[0]).unwrap(),
            literal_f32s(&b[0]).unwrap(),
            "pallas vs jnp flavors diverged"
        );
        assert_eq!(literal_f32s(&a[1]).unwrap(), literal_f32s(&b[1]).unwrap());
    }

    #[test]
    fn pr_rounds_artifact_runs() {
        let m = ArtifactManifest::load(&ArtifactManifest::default_dir()).unwrap();
        let rt = PjrtRuntime::cpu().unwrap();
        let entry = m.pick("pr_rounds", 200).unwrap();
        let exe = rt.load(&entry.path).unwrap();
        let n = entry.n_pad;

        // 2-cycle between vertices 0 and 1
        let mut a_norm = vec![0f32; n * n];
        a_norm[1] = 1.0; // 0 -> 1
        a_norm[n] = 1.0; // 1 -> 0
        let rank = vec![1.0 / n as f32; n];

        let a_buf = rt.upload(&a_norm, &[n as i64, n as i64]).unwrap();
        let r_buf = rt.upload(&rank, &[n as i64]).unwrap();
        let d_buf = rt.upload(&[0.85], &[]).unwrap();
        let nr_buf = rt.upload(&[1.0 / n as f32], &[]).unwrap();
        let outs = exe.run(&[&r_buf, &a_buf, &d_buf, &nr_buf]).unwrap();
        assert_eq!(outs.len(), 2);
        let new_rank = literal_f32s(&outs[0]).unwrap();
        assert!(new_rank.iter().all(|r| r.is_finite()));
        assert!(new_rank[0] > new_rank[5], "cycle vertices outrank isolated ones");
    }

    #[test]
    fn stub_platform_name_reserved() {
        // the stub build reports "pjrt-unavailable"; the real build must not
        let rt = PjrtRuntime::cpu().unwrap();
        assert_ne!(rt.platform(), "pjrt-unavailable");
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = PjrtRuntime::cpu().err().expect("stub must refuse to build");
        assert!(err.to_string().contains("pjrt"), "actionable message: {err}");
    }
}
