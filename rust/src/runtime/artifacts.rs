//! Artifact manifest: which AOT-compiled HLO module serves which
//! (function, capacity-bucket) pair, and bucket selection/padding.

use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Capacity buckets the python side lowers (`aot.py BUCKETS`). The xla
/// backend pads any graph into the smallest bucket that fits.
pub const BUCKETS: &[usize] = &[256, 1024, 2048];
/// TC is cubic in the bucket size; capped one bucket lower.
pub const TC_BUCKETS: &[usize] = &[256, 1024];

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub n_pad: usize,
    pub rounds_per_call: usize,
    pub path: PathBuf,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    entries: HashMap<(String, usize), ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt` (written by `make artifacts`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {} — run `make artifacts` first", manifest.display()))?;
        let mut entries = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = t.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 fields, got {t:?}", lineno + 1);
            }
            let name = parts[0].to_string();
            let n_pad: usize = parts[1].parse().context("n_pad")?;
            let rounds: usize = parts[2].parse().context("rounds")?;
            let path = dir.join(parts[3]);
            if !path.exists() {
                bail!("manifest references missing artifact {}", path.display());
            }
            entries.insert(
                (name.clone(), n_pad),
                ArtifactEntry { name, n_pad, rounds_per_call: rounds, path },
            );
        }
        if entries.is_empty() {
            bail!("empty manifest {}", manifest.display());
        }
        Ok(ArtifactManifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact directory: `$STARPLAT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("STARPLAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Smallest bucket holding `n` vertices for `name`, with its entry.
    pub fn pick(&self, name: &str, n: usize) -> Result<&ArtifactEntry> {
        let buckets: Vec<usize> = {
            let mut b: Vec<usize> = self
                .entries
                .keys()
                .filter(|(k, _)| k == name)
                .map(|&(_, n)| n)
                .collect();
            b.sort_unstable();
            b
        };
        for b in &buckets {
            if *b >= n {
                return Ok(&self.entries[&(name.to_string(), *b)]);
            }
        }
        bail!("no {name} bucket fits n={n} (available: {buckets:?})")
    }

    pub fn entries(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthesize a bucket layout matching `aot.py`'s (BUCKETS / TC_BUCKETS)
    /// in a temp dir, so manifest parsing and bucket selection are tested
    /// without requiring the `make artifacts` AOT step to have run.
    fn fixture_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("starplat_artifacts_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let entries: &[(&str, usize)] = &[
            ("sssp_rounds", 256),
            ("sssp_rounds", 1024),
            ("sssp_rounds", 2048),
            ("pr_rounds", 256),
            ("pr_rounds", 1024),
            ("pr_rounds", 2048),
            ("tc_dense", 256),
            ("tc_dense", 1024),
        ];
        let mut manifest = String::from("# synthesized by artifacts.rs tests\n");
        for &(name, n) in entries {
            let file = format!("{name}_{n}.hlo.txt");
            std::fs::write(dir.join(&file), "HloModule placeholder\n").unwrap();
            manifest.push_str(&format!("{name} {n} 16 {file}\n"));
        }
        std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
        dir
    }

    #[test]
    fn loads_synthesized_manifest() {
        let m = ArtifactManifest::load(&fixture_dir("load")).unwrap();
        assert!(m.entries().count() >= 8);
    }

    #[test]
    fn picks_smallest_fitting_bucket() {
        let m = ArtifactManifest::load(&fixture_dir("pick")).unwrap();
        assert_eq!(m.pick("sssp_rounds", 100).unwrap().n_pad, 256);
        assert_eq!(m.pick("sssp_rounds", 256).unwrap().n_pad, 256);
        assert_eq!(m.pick("sssp_rounds", 257).unwrap().n_pad, 1024);
        assert_eq!(m.pick("tc_dense", 1024).unwrap().n_pad, 1024);
        assert!(m.pick("tc_dense", 2000).is_err(), "TC capped at 1024");
        assert!(m.pick("sssp_rounds", 1_000_000).is_err());
    }

    #[test]
    fn manifest_rejects_missing_artifact_file() {
        let dir = fixture_dir("missing");
        std::fs::write(dir.join("manifest.txt"), "sssp_rounds 256 16 ghost.hlo.txt\n")
            .unwrap();
        let err = ArtifactManifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("missing artifact"));
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = ArtifactManifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
