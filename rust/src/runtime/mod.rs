//! Runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the PJRT CPU client from the rust hot path.
//! Python never runs at request time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, BUCKETS, TC_BUCKETS};
pub use pjrt::{PjrtRuntime, RoundsExe};
