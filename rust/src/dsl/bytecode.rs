//! Portable graph bytecode — the register IR every backend executes.
//!
//! `lower` compiles a parsed+analyzed `.sp` program into a [`Program`]:
//! two straight-line instruction segments (`init`, ran once to seed the
//! algorithm state, and `on_batch`, ran per update batch) over
//!
//! * **scalar registers** (`regs`, typed Int/Float/Bool) for the driver
//!   control flow — loop counters, convergence deltas, batch counts;
//! * **node properties** (`props`, atomic arrays) for the per-vertex
//!   state — distances, ranks, component labels, frontier flags;
//! * a handful of **coarse graph primitives** that map 1:1 onto the
//!   parallel building blocks the engines already have: [`Instr::Par`]
//!   (a `forall` sweep with slot-deterministic reductions),
//!   [`Instr::PropagateFlags`], [`Instr::RepairParents`] (the
//!   deterministic argmin parent repair shared with the hand-written
//!   cpu/dist kernels), `ApplyDeletions`/`ApplyAdditions` (diff-CSR
//!   morphs), and the `UpdCount`/`UpdGet` batch-delta hooks behind
//!   `OnAdd`/`OnDelete`.
//!
//! Design rules that make N algorithms × all backends tractable:
//!
//! * **One executor.** [`execute`] is shared by the serial and cpu
//!   engines — the only difference is whether a thread pool is passed.
//!   There is zero per-backend, per-algorithm Rust.
//! * **Determinism.** Parallel reductions write per-item slots indexed
//!   by domain position and are folded sequentially in index order, so
//!   serial and cpu runs are bitwise identical (ints, bools, and the
//!   f64 folds alike). `Min` multi-assignments use the same CAS-min the
//!   hand-written kernels use; racy companion writes (parents) are made
//!   deterministic by the trailing `RepairParents` the lowerer inserts.
//! * **Verification before execution.** [`verify`] checks register and
//!   property indices, jump targets, and type agreement up front, so
//!   the hot loop can trust the encoding (the ironplc stack-bytecode
//!   ADR's portability/determinism/inspectability argument).
//!
//! Batching is **external**: the `Batch(...)` construct's chunking is
//! done by the caller (coordinator batcher or service sealer), and each
//! sealed batch is one `execute(.., Phase::Batch{..})` call.

use crate::dsl::ast::{BinOp, Span};
use crate::graph::{DynGraph, NodeId, Weight};
use crate::util::error::{bail, Result};
use crate::util::threadpool::{Sched, ThreadPool};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

pub type RegId = usize;
pub type PropId = usize;

/// Scalar types carried by registers, locals and properties.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ty {
    Int,
    Float,
    Bool,
}

/// A scalar value (registers, Par-body locals, program results).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalarVal {
    I(i64),
    F(f64),
    B(bool),
}

impl ScalarVal {
    pub fn zero(ty: Ty) -> ScalarVal {
        match ty {
            Ty::Int => ScalarVal::I(0),
            Ty::Float => ScalarVal::F(0.0),
            Ty::Bool => ScalarVal::B(false),
        }
    }

    pub fn ty(&self) -> Ty {
        match self {
            ScalarVal::I(_) => Ty::Int,
            ScalarVal::F(_) => Ty::Float,
            ScalarVal::B(_) => Ty::Bool,
        }
    }

    pub fn as_i(&self) -> Result<i64> {
        match self {
            ScalarVal::I(v) => Ok(*v),
            ScalarVal::B(b) => Ok(*b as i64),
            ScalarVal::F(v) => bail!("expected int, got float {v}"),
        }
    }

    pub fn as_f(&self) -> Result<f64> {
        match self {
            ScalarVal::F(v) => Ok(*v),
            ScalarVal::I(v) => Ok(*v as f64),
            ScalarVal::B(b) => bail!("expected float, got bool {b}"),
        }
    }

    pub fn as_b(&self) -> Result<bool> {
        match self {
            ScalarVal::B(v) => Ok(*v),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// A declared node property: name (for snapshots/tests) + element type.
#[derive(Debug, Clone)]
pub struct PropDecl {
    pub name: String,
    pub ty: Ty,
}

/// Which half of the current update batch an instruction addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateSel {
    Dels,
    Adds,
}

/// Top-level instructions. Straight-line with explicit jumps; the only
/// nesting is [`Instr::Par`], whose body is the tree-structured per-item
/// language below (no jumps inside a parallel region).
#[derive(Debug, Clone)]
pub enum Instr {
    ConstI { dst: RegId, v: i64 },
    ConstF { dst: RegId, v: f64 },
    ConstB { dst: RegId, v: bool },
    Mov { dst: RegId, src: RegId },
    /// int → float register promotion.
    CastF { dst: RegId, src: RegId },
    Bin { dst: RegId, op: BinOp, a: RegId, b: RegId },
    Not { dst: RegId, src: RegId },
    Neg { dst: RegId, src: RegId },
    NumNodes { dst: RegId },
    NumEdges { dst: RegId },
    LoadProp { dst: RegId, prop: PropId, idx: RegId },
    StoreProp { prop: PropId, idx: RegId, val: RegId },
    /// `attachNodeProperty(p = v)` — refill the whole array.
    Fill { prop: PropId, val: RegId },
    /// whole-property copy (`modified = modified_nxt`).
    CopyProp { dst: PropId, src: PropId },
    /// fixed-point termination probe: any flag set?
    AnyTrue { dst: RegId, prop: PropId },
    /// `propagateNodeFlags(p)` — close flags over out-neighborhoods.
    PropagateFlags { prop: PropId },
    /// `updateCSRDel` — apply the batch's deletions to the graph.
    ApplyDeletions,
    /// `updateCSRAdd` — apply the batch's additions to the graph.
    ApplyAdditions,
    /// Deterministic argmin parent repair, bitwise-identical to the
    /// hand-written cpu kernel's: `parent[v] = smallest in-neighbor u
    /// with dist[u] + w(u,v) == dist[v]` (`w = 1` when `unit_weight`),
    /// `-1` for sources/unreachable. Scheduled by the race analysis
    /// ([`crate::dsl::analyze::certify`]) at segment tails wherever a
    /// `Min` assignment carries a parent companion.
    RepairParents { dist: PropId, parent: PropId, unit_weight: bool },
    /// number of updates in the selected half of the current batch.
    UpdCount { dst: RegId, sel: UpdateSel },
    /// load update `idx` of the selected half into (src, dst, weight).
    UpdGet { sel: UpdateSel, idx: RegId, src: RegId, dst: RegId, weight: RegId },
    Jump { target: usize },
    JumpIf { cond: RegId, target: usize },
    JumpIfNot { cond: RegId, target: usize },
    Par(ParOp),
}

/// Iteration domain of a parallel region.
#[derive(Debug, Clone)]
pub enum Domain {
    /// all vertices; the item *is* the vertex id.
    Nodes,
    /// out-neighbors of the vertex held in `of`.
    OutNbrs { of: RegId },
}

/// How a scalar register is reduced across a parallel region. Every
/// item owns a private slot (indexed by domain position); slots are
/// folded into the register sequentially in index order after the
/// sweep, so the reduction is schedule-independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccumKind {
    AddI,
    AddF,
    Or,
}

#[derive(Debug, Clone)]
pub struct AccumDef {
    pub reg: RegId,
    pub kind: AccumKind,
}

/// A `forall` sweep: per-item statements over a domain, with typed
/// locals and slot-deterministic reductions.
#[derive(Debug, Clone)]
pub struct ParOp {
    pub domain: Domain,
    pub locals: Vec<Ty>,
    pub body: Vec<VStmt>,
    pub accums: Vec<AccumDef>,
    /// source span of the `forall`, for analysis diagnostics.
    pub span: Span,
}

/// Per-item expressions (pure; registers are a read-only snapshot).
#[derive(Debug, Clone, PartialEq)]
pub enum VExpr {
    ConstI(i64),
    ConstF(f64),
    ConstB(bool),
    /// the current item (vertex id) as Int.
    Subject,
    Reg(RegId),
    Local(usize),
    LoadProp(PropId, Box<VExpr>),
    OutDegree(Box<VExpr>),
    IsEdge(Box<VExpr>, Box<VExpr>),
    /// symmetric membership test against a batch half.
    Contains(UpdateSel, Box<VExpr>, Box<VExpr>),
    Bin(BinOp, Box<VExpr>, Box<VExpr>),
    Not(Box<VExpr>),
    Neg(Box<VExpr>),
}

/// Per-item statements.
#[derive(Debug, Clone)]
pub enum VStmt {
    SetLocal(usize, VExpr),
    StoreProp(PropId, VExpr, VExpr),
    /// `<p[i], c1[j1], …> = <Min(p[i], val), v1, …>` — CAS-min on an Int
    /// property; companions are stored only when the CAS lowered the
    /// value (the §5.1 atomic multi-assignment).
    MinAssign { prop: PropId, idx: VExpr, val: VExpr, comps: Vec<(PropId, VExpr, VExpr)> },
    If { cond: VExpr, then: Vec<VStmt>, els: Vec<VStmt> },
    /// sequential loop over out-neighbors; binds the neighbor id and
    /// (optionally) the edge weight into locals.
    ForOut { of: VExpr, nbr: usize, w: Option<usize>, body: Vec<VStmt>, span: Span },
    /// sequential loop over in-neighbors (`g.nodes_to(v)`).
    ForIn { of: VExpr, nbr: usize, body: Vec<VStmt>, span: Span },
    /// fold `val` into this item's slot of accumulator `acc`.
    Accum { acc: usize, val: VExpr },
}

/// A compiled program: property/register declarations plus the two
/// instruction segments. `params` names the scalar registers bound from
/// CLI/driver arguments at state creation; `result` is the register the
/// driver's `return` lowered into (re-evaluated at every segment tail).
#[derive(Debug, Clone)]
pub struct Program {
    pub props: Vec<PropDecl>,
    pub regs: Vec<Ty>,
    pub params: Vec<(String, RegId)>,
    pub init: Vec<Instr>,
    pub on_batch: Vec<Instr>,
    pub result: Option<RegId>,
    /// the race/effect analysis certificate attached by `lower`
    /// (defaulted — uncertified — on hand-built programs).
    pub facts: crate::dsl::analyze::ProgramFacts,
}

impl Program {
    pub fn prop_id(&self, name: &str) -> Option<PropId> {
        self.props.iter().position(|p| p.name == name)
    }
}

/// Property storage: atomic arrays so parallel regions can write
/// without locks (floats are stored as bit patterns).
#[derive(Debug)]
pub enum PropData {
    I(Vec<AtomicI64>),
    F(Vec<AtomicU64>),
    B(Vec<AtomicBool>),
}

impl Clone for PropData {
    fn clone(&self) -> Self {
        match self {
            PropData::I(v) => {
                PropData::I(v.iter().map(|x| AtomicI64::new(x.load(Ordering::Relaxed))).collect())
            }
            PropData::F(v) => {
                PropData::F(v.iter().map(|x| AtomicU64::new(x.load(Ordering::Relaxed))).collect())
            }
            PropData::B(v) => {
                PropData::B(v.iter().map(|x| AtomicBool::new(x.load(Ordering::Relaxed))).collect())
            }
        }
    }
}

impl PropData {
    fn len(&self) -> usize {
        match self {
            PropData::I(v) => v.len(),
            PropData::F(v) => v.len(),
            PropData::B(v) => v.len(),
        }
    }
}

/// Mutable program state: one array per property, one value per
/// register. Created once (serve seed / run init) and threaded through
/// every batch.
#[derive(Debug, Clone)]
pub struct ProgState {
    pub props: Vec<PropData>,
    pub regs: Vec<ScalarVal>,
}

impl ProgState {
    /// Allocate state for `prog` over an `n`-vertex graph, binding the
    /// program's scalar parameters by name from `args` (ints promote to
    /// float parameters; extra args are ignored).
    pub fn new(prog: &Program, n: usize, args: &[(String, ScalarVal)]) -> Result<ProgState> {
        let props = prog
            .props
            .iter()
            .map(|p| match p.ty {
                Ty::Int => PropData::I((0..n).map(|_| AtomicI64::new(0)).collect()),
                Ty::Float => PropData::F((0..n).map(|_| AtomicU64::new(0)).collect()),
                Ty::Bool => PropData::B((0..n).map(|_| AtomicBool::new(false)).collect()),
            })
            .collect();
        let mut regs: Vec<ScalarVal> = prog.regs.iter().map(|t| ScalarVal::zero(*t)).collect();
        for (name, reg) in &prog.params {
            let Some((_, v)) = args.iter().find(|(a, _)| a == name) else {
                bail!("program parameter {name:?} not bound (pass it via the driver)");
            };
            regs[*reg] = match (prog.regs[*reg], v) {
                (Ty::Int, ScalarVal::I(x)) => ScalarVal::I(*x),
                (Ty::Float, ScalarVal::F(x)) => ScalarVal::F(*x),
                (Ty::Float, ScalarVal::I(x)) => ScalarVal::F(*x as f64),
                (Ty::Bool, ScalarVal::B(x)) => ScalarVal::B(*x),
                (want, got) => bail!("program parameter {name:?}: expected {want:?}, got {got:?}"),
            };
        }
        Ok(ProgState { props, regs })
    }

    /// Snapshot an Int property by name (tests, snapshots, reports).
    pub fn prop_i64(&self, prog: &Program, name: &str) -> Option<Vec<i64>> {
        let id = prog.prop_id(name)?;
        match &self.props[id] {
            PropData::I(v) => Some(v.iter().map(|x| x.load(Ordering::Relaxed)).collect()),
            _ => None,
        }
    }

    /// Snapshot a Float property by name.
    pub fn prop_f64(&self, prog: &Program, name: &str) -> Option<Vec<f64>> {
        let id = prog.prop_id(name)?;
        match &self.props[id] {
            PropData::F(v) => {
                Some(v.iter().map(|x| f64::from_bits(x.load(Ordering::Relaxed))).collect())
            }
            _ => None,
        }
    }

    /// The driver's `return` value, if it declared one.
    pub fn result(&self, prog: &Program) -> Option<ScalarVal> {
        prog.result.map(|r| self.regs[r])
    }
}

/// Which segment to execute and the update window it sees.
#[derive(Debug, Clone, Copy)]
pub enum Phase<'a> {
    Init,
    Batch { dels: &'a [(NodeId, NodeId)], adds: &'a [(NodeId, NodeId, Weight)] },
}

// ---------------------------------------------------------------------------
// verifier
// ---------------------------------------------------------------------------

/// Static checks so [`execute`] can trust the encoding: register /
/// property / jump-target ranges and top-level type agreement. Runs
/// once per compile (and in tests against hand-built programs).
pub fn verify(prog: &Program) -> Result<()> {
    for (seg_name, code) in [("init", &prog.init), ("on_batch", &prog.on_batch)] {
        verify_segment(prog, seg_name, code)?;
    }
    if let Some(r) = prog.result {
        if r >= prog.regs.len() {
            bail!("verify: result register r{r} out of range");
        }
    }
    for (name, r) in &prog.params {
        if *r >= prog.regs.len() {
            bail!("verify: parameter {name:?} register r{r} out of range");
        }
    }
    Ok(())
}

fn verify_segment(prog: &Program, seg: &str, code: &[Instr]) -> Result<()> {
    let nregs = prog.regs.len();
    let reg = |r: RegId, want: Option<Ty>, pc: usize| -> Result<Ty> {
        if r >= nregs {
            bail!("verify: {seg}@{pc}: register r{r} out of range ({nregs} registers)");
        }
        let ty = prog.regs[r];
        if let Some(w) = want {
            if ty != w {
                bail!("verify: {seg}@{pc}: register r{r} is {ty:?}, expected {w:?}");
            }
        }
        Ok(ty)
    };
    let prop = |p: PropId, want: Option<Ty>, pc: usize| -> Result<Ty> {
        let Some(decl) = prog.props.get(p) else {
            bail!("verify: {seg}@{pc}: property p{p} out of range ({} props)", prog.props.len());
        };
        if let Some(w) = want {
            if decl.ty != w {
                bail!(
                    "verify: {seg}@{pc}: property {:?} is {:?}, expected {w:?}",
                    decl.name,
                    decl.ty
                );
            }
        }
        Ok(decl.ty)
    };
    let target = |t: usize, pc: usize| -> Result<()> {
        if t > code.len() {
            bail!("verify: {seg}@{pc}: jump target {t} out of range (len {})", code.len());
        }
        Ok(())
    };
    for (pc, ins) in code.iter().enumerate() {
        match ins {
            Instr::ConstI { dst, .. } => {
                reg(*dst, Some(Ty::Int), pc)?;
            }
            Instr::ConstF { dst, .. } => {
                reg(*dst, Some(Ty::Float), pc)?;
            }
            Instr::ConstB { dst, .. } => {
                reg(*dst, Some(Ty::Bool), pc)?;
            }
            Instr::Mov { dst, src } => {
                let t = reg(*src, None, pc)?;
                reg(*dst, Some(t), pc)?;
            }
            Instr::CastF { dst, src } => {
                reg(*src, Some(Ty::Int), pc)?;
                reg(*dst, Some(Ty::Float), pc)?;
            }
            Instr::Bin { dst, op, a, b } => {
                let ta = reg(*a, None, pc)?;
                reg(*b, Some(ta), pc)?;
                let want = match bin_result_ty(*op, ta) {
                    Some(t) => t,
                    None => bail!("verify: {seg}@{pc}: operator {op:?} not defined on {ta:?}"),
                };
                reg(*dst, Some(want), pc)?;
            }
            Instr::Not { dst, src } => {
                reg(*src, Some(Ty::Bool), pc)?;
                reg(*dst, Some(Ty::Bool), pc)?;
            }
            Instr::Neg { dst, src } => {
                let t = reg(*src, None, pc)?;
                if t == Ty::Bool {
                    bail!("verify: {seg}@{pc}: negation of a bool register");
                }
                reg(*dst, Some(t), pc)?;
            }
            Instr::NumNodes { dst } | Instr::NumEdges { dst } => {
                reg(*dst, Some(Ty::Int), pc)?;
            }
            Instr::LoadProp { dst, prop: p, idx } => {
                reg(*idx, Some(Ty::Int), pc)?;
                let t = prop(*p, None, pc)?;
                reg(*dst, Some(t), pc)?;
            }
            Instr::StoreProp { prop: p, idx, val } => {
                reg(*idx, Some(Ty::Int), pc)?;
                let t = prop(*p, None, pc)?;
                reg(*val, Some(t), pc)?;
            }
            Instr::Fill { prop: p, val } => {
                let t = prop(*p, None, pc)?;
                reg(*val, Some(t), pc)?;
            }
            Instr::CopyProp { dst, src } => {
                let t = prop(*src, None, pc)?;
                prop(*dst, Some(t), pc)?;
            }
            Instr::AnyTrue { dst, prop: p } => {
                prop(*p, Some(Ty::Bool), pc)?;
                reg(*dst, Some(Ty::Bool), pc)?;
            }
            Instr::PropagateFlags { prop: p } => {
                prop(*p, Some(Ty::Bool), pc)?;
            }
            Instr::ApplyDeletions | Instr::ApplyAdditions => {}
            Instr::RepairParents { dist, parent, .. } => {
                prop(*dist, Some(Ty::Int), pc)?;
                prop(*parent, Some(Ty::Int), pc)?;
            }
            Instr::UpdCount { dst, .. } => {
                reg(*dst, Some(Ty::Int), pc)?;
            }
            Instr::UpdGet { idx, src, dst, weight, .. } => {
                reg(*idx, Some(Ty::Int), pc)?;
                reg(*src, Some(Ty::Int), pc)?;
                reg(*dst, Some(Ty::Int), pc)?;
                reg(*weight, Some(Ty::Int), pc)?;
            }
            Instr::Jump { target: t } => target(*t, pc)?,
            Instr::JumpIf { cond, target: t } | Instr::JumpIfNot { cond, target: t } => {
                reg(*cond, Some(Ty::Bool), pc)?;
                target(*t, pc)?;
            }
            Instr::Par(op) => verify_par(prog, seg, pc, op)?,
        }
    }
    Ok(())
}

pub(crate) fn bin_result_ty(op: BinOp, operand: Ty) -> Option<Ty> {
    use BinOp::*;
    match op {
        Add | Sub | Mul | Div | Mod => (operand != Ty::Bool).then_some(operand),
        Lt | Gt | Le | Ge => (operand != Ty::Bool).then_some(Ty::Bool),
        Eq | Ne => Some(Ty::Bool),
        And | Or => (operand == Ty::Bool).then_some(Ty::Bool),
    }
}

fn verify_par(prog: &Program, seg: &str, pc: usize, op: &ParOp) -> Result<()> {
    if let Domain::OutNbrs { of } = op.domain {
        if of >= prog.regs.len() || prog.regs[of] != Ty::Int {
            bail!("verify: {seg}@{pc}: Par domain register r{of} must be an Int register");
        }
    }
    for a in &op.accums {
        if a.reg >= prog.regs.len() {
            bail!("verify: {seg}@{pc}: accumulator register r{} out of range", a.reg);
        }
        let want = match a.kind {
            AccumKind::AddI => Ty::Int,
            AccumKind::AddF => Ty::Float,
            AccumKind::Or => Ty::Bool,
        };
        if prog.regs[a.reg] != want {
            bail!(
                "verify: {seg}@{pc}: accumulator r{} is {:?}, but {:?} reduces {want:?}",
                a.reg,
                prog.regs[a.reg],
                a.kind
            );
        }
    }
    verify_vstmts(prog, seg, pc, op, &op.body)
}

fn verify_vstmts(prog: &Program, seg: &str, pc: usize, op: &ParOp, body: &[VStmt]) -> Result<()> {
    for s in body {
        match s {
            VStmt::SetLocal(l, e) => {
                if *l >= op.locals.len() {
                    bail!("verify: {seg}@{pc}: local l{l} out of range");
                }
                verify_vexpr(prog, seg, pc, op, e)?;
            }
            VStmt::StoreProp(p, idx, val) => {
                if *p >= prog.props.len() {
                    bail!("verify: {seg}@{pc}: property p{p} out of range");
                }
                verify_vexpr(prog, seg, pc, op, idx)?;
                verify_vexpr(prog, seg, pc, op, val)?;
            }
            VStmt::MinAssign { prop, idx, val, comps } => {
                match prog.props.get(*prop) {
                    Some(d) if d.ty == Ty::Int => {}
                    Some(d) => bail!(
                        "verify: {seg}@{pc}: Min target {:?} must be an Int property, is {:?}",
                        d.name,
                        d.ty
                    ),
                    None => bail!("verify: {seg}@{pc}: property p{prop} out of range"),
                }
                verify_vexpr(prog, seg, pc, op, idx)?;
                verify_vexpr(prog, seg, pc, op, val)?;
                for (p, i, v) in comps {
                    if *p >= prog.props.len() {
                        bail!("verify: {seg}@{pc}: companion property p{p} out of range");
                    }
                    verify_vexpr(prog, seg, pc, op, i)?;
                    verify_vexpr(prog, seg, pc, op, v)?;
                }
            }
            VStmt::If { cond, then, els } => {
                verify_vexpr(prog, seg, pc, op, cond)?;
                verify_vstmts(prog, seg, pc, op, then)?;
                verify_vstmts(prog, seg, pc, op, els)?;
            }
            VStmt::ForOut { of, nbr, w, body, .. } => {
                verify_vexpr(prog, seg, pc, op, of)?;
                if *nbr >= op.locals.len() || w.map(|w| w >= op.locals.len()).unwrap_or(false) {
                    bail!("verify: {seg}@{pc}: ForOut local binding out of range");
                }
                verify_vstmts(prog, seg, pc, op, body)?;
            }
            VStmt::ForIn { of, nbr, body, .. } => {
                verify_vexpr(prog, seg, pc, op, of)?;
                if *nbr >= op.locals.len() {
                    bail!("verify: {seg}@{pc}: ForIn local binding out of range");
                }
                verify_vstmts(prog, seg, pc, op, body)?;
            }
            VStmt::Accum { acc, val } => {
                if *acc >= op.accums.len() {
                    bail!("verify: {seg}@{pc}: accumulator #{acc} out of range");
                }
                verify_vexpr(prog, seg, pc, op, val)?;
            }
        }
    }
    Ok(())
}

fn verify_vexpr(prog: &Program, seg: &str, pc: usize, op: &ParOp, e: &VExpr) -> Result<()> {
    match e {
        VExpr::ConstI(_) | VExpr::ConstF(_) | VExpr::ConstB(_) | VExpr::Subject => Ok(()),
        VExpr::Reg(r) => {
            if *r >= prog.regs.len() {
                bail!("verify: {seg}@{pc}: register r{r} out of range in Par body");
            }
            Ok(())
        }
        VExpr::Local(l) => {
            if *l >= op.locals.len() {
                bail!("verify: {seg}@{pc}: local l{l} out of range in Par body");
            }
            Ok(())
        }
        VExpr::LoadProp(p, idx) => {
            if *p >= prog.props.len() {
                bail!("verify: {seg}@{pc}: property p{p} out of range in Par body");
            }
            verify_vexpr(prog, seg, pc, op, idx)
        }
        VExpr::OutDegree(x) | VExpr::Not(x) | VExpr::Neg(x) => verify_vexpr(prog, seg, pc, op, x),
        VExpr::IsEdge(a, b) | VExpr::Contains(_, a, b) | VExpr::Bin(_, a, b) => {
            verify_vexpr(prog, seg, pc, op, a)?;
            verify_vexpr(prog, seg, pc, op, b)
        }
    }
}

// ---------------------------------------------------------------------------
// executor
// ---------------------------------------------------------------------------

/// Execute one segment of `prog` against `g`/`st`. `par` selects the
/// engine flavor: `None` runs items sequentially (serial backend),
/// `Some((pool, sched))` runs parallel regions on the pool (cpu
/// backend). Both produce bitwise-identical state (see module docs).
pub fn execute(
    prog: &Program,
    phase: Phase<'_>,
    st: &mut ProgState,
    g: &mut DynGraph,
    par: Option<(&ThreadPool, Sched)>,
) -> Result<()> {
    let (code, dels, adds): (&[Instr], &[(NodeId, NodeId)], &[(NodeId, NodeId, Weight)]) =
        match phase {
            Phase::Init => (&prog.init, &[], &[]),
            Phase::Batch { dels, adds } => (&prog.on_batch, dels, adds),
        };
    if st.regs.len() != prog.regs.len() || st.props.len() != prog.props.len() {
        bail!("program state does not match program shape");
    }
    for p in &st.props {
        if p.len() != g.num_nodes() {
            bail!("program state sized for {} nodes, graph has {}", p.len(), g.num_nodes());
        }
    }
    // Runaway guard: every backward jump burns fuel. Generous bound —
    // interp's own guard is n*8+256 sweeps per fixed point.
    let mut fuel: u64 =
        64 * (g.num_nodes() as u64 + dels.len() as u64 + adds.len() as u64) + (1 << 20);
    let mut pc = 0usize;
    while pc < code.len() {
        let mut next = pc + 1;
        match &code[pc] {
            Instr::ConstI { dst, v } => st.regs[*dst] = ScalarVal::I(*v),
            Instr::ConstF { dst, v } => st.regs[*dst] = ScalarVal::F(*v),
            Instr::ConstB { dst, v } => st.regs[*dst] = ScalarVal::B(*v),
            Instr::Mov { dst, src } => st.regs[*dst] = st.regs[*src],
            Instr::CastF { dst, src } => st.regs[*dst] = ScalarVal::F(st.regs[*src].as_i()? as f64),
            Instr::Bin { dst, op, a, b } => {
                st.regs[*dst] = scalar_binop(*op, st.regs[*a], st.regs[*b])?;
            }
            Instr::Not { dst, src } => st.regs[*dst] = ScalarVal::B(!st.regs[*src].as_b()?),
            Instr::Neg { dst, src } => {
                st.regs[*dst] = match st.regs[*src] {
                    ScalarVal::I(v) => ScalarVal::I(-v),
                    ScalarVal::F(v) => ScalarVal::F(-v),
                    ScalarVal::B(_) => bail!("negation of a bool"),
                };
            }
            Instr::NumNodes { dst } => st.regs[*dst] = ScalarVal::I(g.num_nodes() as i64),
            Instr::NumEdges { dst } => st.regs[*dst] = ScalarVal::I(g.num_edges() as i64),
            Instr::LoadProp { dst, prop, idx } => {
                let i = prop_index(st.regs[*idx].as_i()?, st.props[*prop].len())?;
                st.regs[*dst] = prop_get(&st.props[*prop], i);
            }
            Instr::StoreProp { prop, idx, val } => {
                let i = prop_index(st.regs[*idx].as_i()?, st.props[*prop].len())?;
                prop_set(&st.props[*prop], i, st.regs[*val])?;
            }
            Instr::Fill { prop, val } => {
                let v = st.regs[*val];
                let arr = &st.props[*prop];
                for i in 0..arr.len() {
                    prop_set(arr, i, v)?;
                }
            }
            Instr::CopyProp { dst, src } => {
                if *dst != *src {
                    let n = st.props[*src].len();
                    for i in 0..n {
                        let v = prop_get(&st.props[*src], i);
                        prop_set(&st.props[*dst], i, v)?;
                    }
                }
            }
            Instr::AnyTrue { dst, prop } => {
                let any = match &st.props[*prop] {
                    PropData::B(v) => v.iter().any(|b| b.load(Ordering::Relaxed)),
                    _ => bail!("AnyTrue on a non-bool property"),
                };
                st.regs[*dst] = ScalarVal::B(any);
            }
            Instr::PropagateFlags { prop } => {
                let PropData::B(arr) = &st.props[*prop] else {
                    bail!("propagateNodeFlags on a non-bool property");
                };
                let mut flags: Vec<bool> = arr.iter().map(|b| b.load(Ordering::Relaxed)).collect();
                crate::algorithms::pagerank::propagate_node_flags(g, &mut flags);
                for (cell, f) in arr.iter().zip(flags) {
                    cell.store(f, Ordering::Relaxed);
                }
            }
            Instr::ApplyDeletions => {
                if matches!(phase, Phase::Init) {
                    bail!("updateCSRDel outside a Batch phase");
                }
                g.apply_deletions(dels);
            }
            Instr::ApplyAdditions => {
                if matches!(phase, Phase::Init) {
                    bail!("updateCSRAdd outside a Batch phase");
                }
                g.apply_additions(adds);
            }
            Instr::RepairParents { dist, parent, unit_weight } => {
                repair_parents(g, st, *dist, *parent, *unit_weight, par)?;
            }
            Instr::UpdCount { dst, sel } => {
                let c = match sel {
                    UpdateSel::Dels => dels.len(),
                    UpdateSel::Adds => adds.len(),
                };
                st.regs[*dst] = ScalarVal::I(c as i64);
            }
            Instr::UpdGet { sel, idx, src, dst, weight } => {
                let i = st.regs[*idx].as_i()?;
                let (s, d, w) = match sel {
                    UpdateSel::Dels => {
                        let Some(&(s, d)) = usize::try_from(i).ok().and_then(|i| dels.get(i))
                        else {
                            bail!("UpdGet: deletion index {i} out of range ({})", dels.len());
                        };
                        (s, d, 1)
                    }
                    UpdateSel::Adds => {
                        let Some(&(s, d, w)) = usize::try_from(i).ok().and_then(|i| adds.get(i))
                        else {
                            bail!("UpdGet: addition index {i} out of range ({})", adds.len());
                        };
                        (s, d, w)
                    }
                };
                st.regs[*src] = ScalarVal::I(s as i64);
                st.regs[*dst] = ScalarVal::I(d as i64);
                st.regs[*weight] = ScalarVal::I(w as i64);
            }
            Instr::Jump { target } => next = *target,
            Instr::JumpIf { cond, target } => {
                if st.regs[*cond].as_b()? {
                    next = *target;
                }
            }
            Instr::JumpIfNot { cond, target } => {
                if !st.regs[*cond].as_b()? {
                    next = *target;
                }
            }
            Instr::Par(op) => run_par(op, g, st, dels, adds, par)?,
        }
        if next <= pc {
            fuel = fuel.saturating_sub(1);
            if fuel == 0 {
                bail!("program exceeded the backward-jump fuel budget (runaway loop?)");
            }
        }
        pc = next;
    }
    Ok(())
}

fn prop_index(i: i64, len: usize) -> Result<usize> {
    match usize::try_from(i) {
        Ok(u) if u < len => Ok(u),
        _ => bail!("vertex index {i} out of range (n = {len})"),
    }
}

fn prop_get(p: &PropData, i: usize) -> ScalarVal {
    match p {
        PropData::I(v) => ScalarVal::I(v[i].load(Ordering::Relaxed)),
        PropData::F(v) => ScalarVal::F(f64::from_bits(v[i].load(Ordering::Relaxed))),
        PropData::B(v) => ScalarVal::B(v[i].load(Ordering::Relaxed)),
    }
}

fn prop_set(p: &PropData, i: usize, v: ScalarVal) -> Result<()> {
    match p {
        PropData::I(a) => a[i].store(v.as_i()?, Ordering::Relaxed),
        PropData::F(a) => a[i].store(v.as_f()?.to_bits(), Ordering::Relaxed),
        PropData::B(a) => a[i].store(v.as_b()?, Ordering::Relaxed),
    }
    Ok(())
}

/// Interp-identical scalar arithmetic: promote to float when either
/// side is float; int division by zero is an error.
fn scalar_binop(op: BinOp, a: ScalarVal, b: ScalarVal) -> Result<ScalarVal> {
    use BinOp::*;
    if matches!(op, And | Or) {
        let (x, y) = (a.as_b()?, b.as_b()?);
        return Ok(ScalarVal::B(if op == And { x && y } else { x || y }));
    }
    let float = matches!(a, ScalarVal::F(_)) || matches!(b, ScalarVal::F(_));
    if float {
        let (x, y) = (a.as_f()?, b.as_f()?);
        Ok(match op {
            Add => ScalarVal::F(x + y),
            Sub => ScalarVal::F(x - y),
            Mul => ScalarVal::F(x * y),
            Div => ScalarVal::F(x / y),
            Mod => ScalarVal::F(x % y),
            Lt => ScalarVal::B(x < y),
            Gt => ScalarVal::B(x > y),
            Le => ScalarVal::B(x <= y),
            Ge => ScalarVal::B(x >= y),
            Eq => ScalarVal::B(x == y),
            Ne => ScalarVal::B(x != y),
            And | Or => unreachable!(),
        })
    } else {
        let (x, y) = (a.as_i()?, b.as_i()?);
        Ok(match op {
            Add => ScalarVal::I(x + y),
            Sub => ScalarVal::I(x - y),
            Mul => ScalarVal::I(x * y),
            Div => {
                if y == 0 {
                    bail!("division by zero");
                }
                ScalarVal::I(x / y)
            }
            Mod => {
                if y == 0 {
                    bail!("modulo by zero");
                }
                ScalarVal::I(x % y)
            }
            Lt => ScalarVal::B(x < y),
            Gt => ScalarVal::B(x > y),
            Le => ScalarVal::B(x <= y),
            Ge => ScalarVal::B(x >= y),
            Eq => ScalarVal::B(x == y),
            Ne => ScalarVal::B(x != y),
            And | Or => unreachable!(),
        })
    }
}

/// Deterministic argmin parent repair (see [`Instr::RepairParents`]).
fn repair_parents(
    g: &DynGraph,
    st: &ProgState,
    dist: PropId,
    parent: PropId,
    unit_weight: bool,
    par: Option<(&ThreadPool, Sched)>,
) -> Result<()> {
    use crate::algorithms::sssp::INF;
    let (PropData::I(dist), PropData::I(parent)) = (&st.props[dist], &st.props[parent]) else {
        bail!("RepairParents needs Int dist/parent properties");
    };
    let n = g.num_nodes();
    let item = |v: usize| {
        let dv = dist[v].load(Ordering::Relaxed);
        let mut best = -1i64;
        if dv < INF {
            for (u, w) in g.in_neighbors(v as NodeId) {
                let du = dist[u as usize].load(Ordering::Relaxed);
                let w = if unit_weight { 1 } else { w as i64 };
                if du < INF && du + w == dv {
                    let cand = u as i64;
                    if best == -1 || cand < best {
                        best = cand;
                    }
                }
            }
        }
        parent[v].store(best, Ordering::Relaxed);
    };
    match par {
        Some((pool, sched)) => pool.parallel_for(n, sched, item),
        None => (0..n).for_each(item),
    }
    Ok(())
}

/// Shared context for one parallel region.
struct ParCtx<'a> {
    g: &'a DynGraph,
    props: &'a [PropData],
    regs: &'a [ScalarVal],
    dels: &'a [(NodeId, NodeId)],
    adds: &'a [(NodeId, NodeId, Weight)],
    op: &'a ParOp,
    /// per-accumulator slot arrays (bit-encoded per kind), indexed by
    /// domain position — the determinism mechanism.
    slots: &'a [Vec<AtomicU64>],
}

fn run_par(
    op: &ParOp,
    g: &DynGraph,
    st: &mut ProgState,
    dels: &[(NodeId, NodeId)],
    adds: &[(NodeId, NodeId, Weight)],
    par: Option<(&ThreadPool, Sched)>,
) -> Result<()> {
    // Materialize the domain as (position → subject vertex id).
    let nbrs: Option<Vec<NodeId>> = match op.domain {
        Domain::Nodes => None,
        Domain::OutNbrs { of } => {
            let v = prop_index(st.regs[of].as_i()?, g.num_nodes())?;
            Some(g.out_neighbors(v as NodeId).map(|(u, _)| u).collect())
        }
    };
    let len = nbrs.as_ref().map(|v| v.len()).unwrap_or(g.num_nodes());
    let subject_of = |i: usize| -> i64 {
        match &nbrs {
            Some(v) => v[i] as i64,
            None => i as i64,
        }
    };
    let slots: Vec<Vec<AtomicU64>> = op
        .accums
        .iter()
        .map(|_| (0..len).map(|_| AtomicU64::new(0)).collect())
        .collect();
    {
        let cx = ParCtx { g, props: &st.props, regs: &st.regs, dels, adds, op, slots: &slots };
        let err: Mutex<Option<String>> = Mutex::new(None);
        let item = |i: usize| {
            if err.lock().unwrap().is_some() {
                return;
            }
            let mut locals: Vec<ScalarVal> =
                op.locals.iter().map(|t| ScalarVal::zero(*t)).collect();
            if let Err(e) = vexec(&cx, i, subject_of(i), &mut locals, &op.body) {
                let mut slot = err.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(e.to_string());
                }
            }
        };
        match par {
            Some((pool, sched)) => pool.parallel_for(len, sched, item),
            None => (0..len).for_each(item),
        }
        if let Some(e) = err.into_inner().unwrap() {
            bail!("{e}");
        }
    }
    // Sequential index-order fold: serial ≡ parallel, bitwise.
    for (a, slots) in op.accums.iter().zip(&slots) {
        match a.kind {
            AccumKind::AddI => {
                let mut acc = st.regs[a.reg].as_i()?;
                for s in slots {
                    acc += s.load(Ordering::Relaxed) as i64;
                }
                st.regs[a.reg] = ScalarVal::I(acc);
            }
            AccumKind::AddF => {
                let mut acc = st.regs[a.reg].as_f()?;
                for s in slots {
                    acc += f64::from_bits(s.load(Ordering::Relaxed));
                }
                st.regs[a.reg] = ScalarVal::F(acc);
            }
            AccumKind::Or => {
                let mut acc = st.regs[a.reg].as_b()?;
                for s in slots {
                    acc |= s.load(Ordering::Relaxed) != 0;
                }
                st.regs[a.reg] = ScalarVal::B(acc);
            }
        }
    }
    Ok(())
}

fn vexec(
    cx: &ParCtx<'_>,
    item: usize,
    subject: i64,
    locals: &mut Vec<ScalarVal>,
    body: &[VStmt],
) -> Result<()> {
    for s in body {
        match s {
            VStmt::SetLocal(l, e) => {
                let v = veval(cx, subject, locals, e)?;
                // int → float promotion for float locals (mirrors interp
                // declarations like `float sum = 0;`)
                locals[*l] = match (locals[*l].ty(), v) {
                    (Ty::Float, ScalarVal::I(x)) => ScalarVal::F(x as f64),
                    _ => v,
                };
            }
            VStmt::StoreProp(p, idx, val) => {
                let i = prop_index(veval(cx, subject, locals, idx)?.as_i()?, cx.props[*p].len())?;
                let v = veval(cx, subject, locals, val)?;
                prop_set(&cx.props[*p], i, coerce_for(&cx.props[*p], v))?;
            }
            VStmt::MinAssign { prop, idx, val, comps } => {
                let PropData::I(arr) = &cx.props[*prop] else {
                    bail!("Min target must be an Int property");
                };
                let i = prop_index(veval(cx, subject, locals, idx)?.as_i()?, arr.len())?;
                let cand = veval(cx, subject, locals, val)?.as_i()?;
                if crate::backend::cpu::atomic_min(&arr[i], cand) {
                    for (p, ci, cv) in comps {
                        let j = prop_index(
                            veval(cx, subject, locals, ci)?.as_i()?,
                            cx.props[*p].len(),
                        )?;
                        let v = veval(cx, subject, locals, cv)?;
                        prop_set(&cx.props[*p], j, coerce_for(&cx.props[*p], v))?;
                    }
                }
            }
            VStmt::If { cond, then, els } => {
                if veval(cx, subject, locals, cond)?.as_b()? {
                    vexec(cx, item, subject, locals, then)?;
                } else {
                    vexec(cx, item, subject, locals, els)?;
                }
            }
            VStmt::ForOut { of, nbr, w, body, .. } => {
                let v = prop_index(veval(cx, subject, locals, of)?.as_i()?, cx.g.num_nodes())?;
                for (u, wt) in cx.g.out_neighbors(v as NodeId) {
                    locals[*nbr] = ScalarVal::I(u as i64);
                    if let Some(wl) = w {
                        locals[*wl] = ScalarVal::I(wt as i64);
                    }
                    vexec(cx, item, subject, locals, body)?;
                }
            }
            VStmt::ForIn { of, nbr, body, .. } => {
                let v = prop_index(veval(cx, subject, locals, of)?.as_i()?, cx.g.num_nodes())?;
                for (u, _) in cx.g.in_neighbors(v as NodeId) {
                    locals[*nbr] = ScalarVal::I(u as i64);
                    vexec(cx, item, subject, locals, body)?;
                }
            }
            VStmt::Accum { acc, val } => {
                let v = veval(cx, subject, locals, val)?;
                let slot = &cx.slots[*acc][item];
                match cx.op.accums[*acc].kind {
                    AccumKind::AddI => {
                        let cur = slot.load(Ordering::Relaxed) as i64;
                        slot.store((cur + v.as_i()?) as u64, Ordering::Relaxed);
                    }
                    AccumKind::AddF => {
                        let cur = f64::from_bits(slot.load(Ordering::Relaxed));
                        slot.store((cur + v.as_f()?).to_bits(), Ordering::Relaxed);
                    }
                    AccumKind::Or => {
                        let cur = slot.load(Ordering::Relaxed) != 0;
                        slot.store((cur || v.as_b()?) as u64, Ordering::Relaxed);
                    }
                }
            }
        }
    }
    Ok(())
}

/// Promote ints for float property stores (the only implicit coercion).
fn coerce_for(p: &PropData, v: ScalarVal) -> ScalarVal {
    match (p, v) {
        (PropData::F(_), ScalarVal::I(x)) => ScalarVal::F(x as f64),
        _ => v,
    }
}

fn veval(
    cx: &ParCtx<'_>,
    subject: i64,
    locals: &[ScalarVal],
    e: &VExpr,
) -> Result<ScalarVal> {
    Ok(match e {
        VExpr::ConstI(v) => ScalarVal::I(*v),
        VExpr::ConstF(v) => ScalarVal::F(*v),
        VExpr::ConstB(v) => ScalarVal::B(*v),
        VExpr::Subject => ScalarVal::I(subject),
        VExpr::Reg(r) => cx.regs[*r],
        VExpr::Local(l) => locals[*l],
        VExpr::LoadProp(p, idx) => {
            let i = prop_index(veval(cx, subject, locals, idx)?.as_i()?, cx.props[*p].len())?;
            prop_get(&cx.props[*p], i)
        }
        VExpr::OutDegree(x) => {
            let v = prop_index(veval(cx, subject, locals, x)?.as_i()?, cx.g.num_nodes())?;
            ScalarVal::I(cx.g.out_degree(v as NodeId) as i64)
        }
        VExpr::IsEdge(a, b) => {
            let u = veval(cx, subject, locals, a)?.as_i()?;
            let v = veval(cx, subject, locals, b)?.as_i()?;
            if u < 0 || v < 0 {
                ScalarVal::B(false)
            } else {
                ScalarVal::B(cx.g.has_edge(u as NodeId, v as NodeId))
            }
        }
        VExpr::Contains(sel, a, b) => {
            let u = veval(cx, subject, locals, a)?.as_i()?;
            let v = veval(cx, subject, locals, b)?.as_i()?;
            let hit = match sel {
                UpdateSel::Dels => cx.dels.iter().any(|&(s, d)| {
                    (s as i64 == u && d as i64 == v) || (s as i64 == v && d as i64 == u)
                }),
                UpdateSel::Adds => cx.adds.iter().any(|&(s, d, _)| {
                    (s as i64 == u && d as i64 == v) || (s as i64 == v && d as i64 == u)
                }),
            };
            ScalarVal::B(hit)
        }
        VExpr::Bin(op, a, b) => {
            let x = veval(cx, subject, locals, a)?;
            let y = veval(cx, subject, locals, b)?;
            scalar_binop(*op, x, y)?
        }
        VExpr::Not(x) => ScalarVal::B(!veval(cx, subject, locals, x)?.as_b()?),
        VExpr::Neg(x) => match veval(cx, subject, locals, x)? {
            ScalarVal::I(v) => ScalarVal::I(-v),
            ScalarVal::F(v) => ScalarVal::F(-v),
            ScalarVal::B(_) => bail!("negation of a bool"),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::uniform_random;

    fn two_reg_prog(regs: Vec<Ty>, init: Vec<Instr>) -> Program {
        Program {
            props: vec![],
            regs,
            params: vec![],
            init,
            on_batch: vec![],
            result: None,
            facts: Default::default(),
        }
    }

    #[test]
    fn verifier_rejects_type_mismatched_register() {
        // Bin Add over (Int, Bool) registers — ill-typed.
        let p = two_reg_prog(
            vec![Ty::Int, Ty::Bool],
            vec![Instr::Bin { dst: 0, op: BinOp::Add, a: 0, b: 1 }],
        );
        let err = verify(&p).unwrap_err().to_string();
        assert!(err.contains("register r1"), "unexpected message: {err}");
    }

    #[test]
    fn verifier_rejects_jump_out_of_range() {
        let p = two_reg_prog(vec![], vec![Instr::Jump { target: 7 }]);
        let err = verify(&p).unwrap_err().to_string();
        assert!(err.contains("jump target 7 out of range"), "unexpected message: {err}");
    }

    #[test]
    fn verifier_rejects_min_on_float_prop() {
        let p = Program {
            props: vec![PropDecl { name: "rank".into(), ty: Ty::Float }],
            regs: vec![],
            params: vec![],
            init: vec![Instr::Par(ParOp {
                domain: Domain::Nodes,
                locals: vec![],
                body: vec![VStmt::MinAssign {
                    prop: 0,
                    idx: VExpr::Subject,
                    val: VExpr::ConstI(0),
                    comps: vec![],
                }],
                accums: vec![],
                span: Span::default(),
            })],
            on_batch: vec![],
            result: None,
            facts: Default::default(),
        };
        assert!(verify(&p).unwrap_err().to_string().contains("Int property"));
    }

    #[test]
    fn par_reduction_is_deterministic_and_matches_serial() {
        // sum of out-degrees via an AddI accumulator, serial vs pooled.
        let g0 = uniform_random(50, 300, 5, 42);
        let prog = Program {
            props: vec![],
            regs: vec![Ty::Int],
            params: vec![],
            init: vec![Instr::Par(ParOp {
                domain: Domain::Nodes,
                locals: vec![],
                body: vec![VStmt::Accum {
                    acc: 0,
                    val: VExpr::OutDegree(Box::new(VExpr::Subject)),
                }],
                accums: vec![AccumDef { reg: 0, kind: AccumKind::AddI }],
                span: Span::default(),
            })],
            on_batch: vec![],
            result: Some(0),
        };
        verify(&prog).unwrap();
        let mut g1 = g0.clone();
        let mut st1 = ProgState::new(&prog, g1.num_nodes(), &[]).unwrap();
        execute(&prog, Phase::Init, &mut st1, &mut g1, None).unwrap();
        let pool = ThreadPool::new(4);
        let mut g2 = g0.clone();
        let mut st2 = ProgState::new(&prog, g2.num_nodes(), &[]).unwrap();
        execute(&prog, Phase::Init, &mut st2, &mut g2, Some((&pool, Sched::default()))).unwrap();
        assert_eq!(st1.regs[0], st2.regs[0]);
        assert_eq!(st1.regs[0].as_i().unwrap(), g0.num_edges() as i64);
    }

    #[test]
    fn runaway_loop_burns_fuel_not_the_process() {
        let p = two_reg_prog(vec![], vec![Instr::Jump { target: 0 }]);
        verify(&p).unwrap();
        let mut g = uniform_random(4, 6, 3, 1);
        let mut st = ProgState::new(&p, g.num_nodes(), &[]).unwrap();
        let err = execute(&p, Phase::Init, &mut st, &mut g, None).unwrap_err();
        assert!(err.to_string().contains("fuel"), "unexpected: {err}");
    }

    #[test]
    fn update_hooks_see_the_batch_window() {
        // on_batch: count dels into r0, adds into r1.
        let prog = Program {
            props: vec![],
            regs: vec![Ty::Int, Ty::Int],
            params: vec![],
            init: vec![],
            on_batch: vec![
                Instr::UpdCount { dst: 0, sel: UpdateSel::Dels },
                Instr::UpdCount { dst: 1, sel: UpdateSel::Adds },
            ],
            result: None,
            facts: Default::default(),
        };
        verify(&prog).unwrap();
        let mut g = uniform_random(10, 30, 3, 2);
        let mut st = ProgState::new(&prog, g.num_nodes(), &[]).unwrap();
        let dels = [(0u32, 1u32)];
        let adds = [(2u32, 3u32, 5i32), (4, 5, 1)];
        execute(&prog, Phase::Batch { dels: &dels, adds: &adds }, &mut st, &mut g, None).unwrap();
        assert_eq!(st.regs[0], ScalarVal::I(1));
        assert_eq!(st.regs[1], ScalarVal::I(2));
    }
}
