//! AST → bytecode lowering.
//!
//! [`lower`] compiles a parsed+analyzed `.sp` program's `Dynamic` driver
//! into a [`bytecode::Program`]: everything before the `Batch` construct
//! becomes the `init` segment, the `Batch` body becomes `on_batch` (the
//! batch chunking itself is external — the coordinator batcher or the
//! service sealer decides window boundaries), and a trailing `return`
//! lowers into a result register re-evaluated at both segment tails.
//!
//! Calls to `Static`/`Incremental`/`Decremental` functions are inlined
//! (monomorphized per call site): `propNode` arguments alias the
//! caller's property arrays, `updates<g>` arguments carry the caller's
//! batch-half selection, scalars are copied by value — matching the
//! tree-walking interpreter's call semantics exactly.
//!
//! `forall` statements lower to [`Instr::Par`] regions; assignments to
//! enclosing scalars inside them are classified as reductions
//! (`x = x + e` / `x += e` → add, `x = True` → or) and become
//! slot-deterministic accumulators. The lowerer itself emits no
//! synchronization schedule beyond that: after `lower_driver` the
//! race/effect analysis ([`crate::dsl::analyze::certify`]) scans the IR,
//! infers the SSSP/BFS-style `(dist, parent)` pairs from the `Min`
//! relax shapes, appends the deterministic [`Instr::RepairParents`] to
//! both segment tails — the same argmin repair the hand-written cpu/dist
//! kernels run, which is what makes bytecode SSSP bitwise-equal to
//! them — and attaches the [`ProgramFacts`] certificate that backend
//! admission consults.
//!
//! [`ProgramFacts`]: crate::dsl::analyze::ProgramFacts

use crate::dsl::ast::{
    self, AssignOp, BinOp, Expr, FnKind, Function, Iter, LValue, Stmt, Type, UnOp,
};
use crate::dsl::bytecode::{
    self, AccumDef, AccumKind, Domain, Instr, ParOp, PropDecl, PropId, RegId, Ty, UpdateSel,
    VExpr, VStmt,
};
use crate::dsl::sema;
use crate::util::error::{bail, Result};
use std::collections::HashMap;

/// Compile source text straight to verified bytecode: parse → sema →
/// lower → verify. `entry` selects the driver by name; `None` uses the
/// program's unique `Dynamic` function.
pub fn compile(src: &str, entry: Option<&str>) -> Result<bytecode::Program> {
    let prog = crate::dsl::parser::parse_program(src)?;
    lower(&prog, entry)
}

/// Lower a parsed program's `Dynamic` driver to verified bytecode.
pub fn lower(prog: &ast::Program, entry: Option<&str>) -> Result<bytecode::Program> {
    sema::analyze(prog)?;
    let f = match entry {
        Some(name) => prog
            .find(name)
            .ok_or_else(|| crate::util::error::anyhow!("no function named {name:?}"))?,
        None => {
            let mut dyns = prog.functions.iter().filter(|f| f.kind == FnKind::Dynamic);
            match (dyns.next(), dyns.next()) {
                (Some(f), None) => f,
                (None, _) => bail!("program has no Dynamic driver function"),
                (Some(_), Some(_)) => {
                    bail!("program has multiple Dynamic drivers; pass an entry name")
                }
            }
        }
    };
    if f.kind != FnKind::Dynamic {
        bail!("entry function {:?} is not a Dynamic driver", f.name);
    }
    let lo = Lowerer {
        ast: prog,
        props: Vec::new(),
        regs: Vec::new(),
        params: Vec::new(),
        scopes: vec![HashMap::new()],
        code: Vec::new(),
        in_batch: false,
        depth: 0,
    };
    let mut out = lo.lower_driver(f)?;
    // Race/effect analysis: infers the RepairParents schedule from the
    // relax shapes, rejects racy programs, and attaches the certificate.
    out.facts = crate::dsl::analyze::certify(&mut out)?;
    bytecode::verify(&out)?;
    Ok(out)
}

/// What a DSL name refers to during lowering.
#[derive(Debug, Clone, PartialEq)]
enum Binding {
    /// scalar (or node-id) register.
    Reg(RegId),
    /// a node property array.
    Prop(PropId),
    /// the graph parameter.
    Graph,
    /// an update batch: `None` = the driver's whole-stream parameter,
    /// `Some(sel)` = a `currentBatch(0|1)` half.
    Updates(Option<UpdateSel>),
    /// the loop variable of a sequential update loop: (src, dst, weight)
    /// registers refreshed by `UpdGet` each iteration.
    UpdateVar { src: RegId, dst: RegId, w: RegId },
}

fn scalar_ty(t: &Type) -> Result<Ty> {
    Ok(match t {
        Type::Int | Type::Long | Type::Node => Ty::Int,
        Type::Float | Type::Double => Ty::Float,
        Type::Bool => Ty::Bool,
        other => bail!("type {other:?} has no scalar register representation"),
    })
}

struct Lowerer<'a> {
    ast: &'a ast::Program,
    props: Vec<PropDecl>,
    regs: Vec<Ty>,
    params: Vec<(String, RegId)>,
    scopes: Vec<HashMap<String, Binding>>,
    code: Vec<Instr>,
    in_batch: bool,
    depth: usize,
}

const MAX_INLINE_DEPTH: usize = 16;

impl<'a> Lowerer<'a> {
    // ---------------------------------------------------- infrastructure

    fn new_reg(&mut self, ty: Ty) -> RegId {
        self.regs.push(ty);
        self.regs.len() - 1
    }

    fn new_prop(&mut self, name: &str, ty: Ty) -> PropId {
        // distinct inline sites may each declare e.g. `modified_nxt`;
        // suffix duplicates so by-name snapshot lookups stay unambiguous
        // (driver params are declared first and keep their bare names).
        let mut unique = name.to_string();
        let mut k = 2;
        while self.props.iter().any(|p| p.name == unique) {
            unique = format!("{name}#{k}");
            k += 1;
        }
        self.props.push(PropDecl { name: unique, ty });
        self.props.len() - 1
    }

    fn emit(&mut self, i: Instr) -> usize {
        self.code.push(i);
        self.code.len() - 1
    }

    fn patch(&mut self, at: usize, target: usize) {
        match &mut self.code[at] {
            Instr::Jump { target: t }
            | Instr::JumpIf { target: t, .. }
            | Instr::JumpIfNot { target: t, .. } => *t = target,
            other => unreachable!("patched a non-jump instruction {other:?}"),
        }
    }

    fn push_scope(&mut self) {
        self.scopes.push(HashMap::new());
    }

    fn pop_scope(&mut self) {
        self.scopes.pop();
    }

    fn bind(&mut self, name: &str, b: Binding) {
        if let Some(scope) = self.scopes.last_mut() {
            scope.insert(name.to_string(), b);
        }
    }

    fn lookup(&self, name: &str) -> Option<Binding> {
        self.scopes.iter().rev().find_map(|s| s.get(name).cloned())
    }

    fn prop_named(&self, name: &str) -> Result<(PropId, Ty)> {
        match self.lookup(name) {
            Some(Binding::Prop(p)) => Ok((p, self.props[p].ty)),
            Some(other) => bail!("{name:?} is {other:?}, not a node property"),
            None => bail!("unknown property {name:?}"),
        }
    }

    /// Emit a fresh register holding a typed zero.
    fn zero_reg(&mut self, ty: Ty) -> RegId {
        let r = self.new_reg(ty);
        match ty {
            Ty::Int => self.emit(Instr::ConstI { dst: r, v: 0 }),
            Ty::Float => self.emit(Instr::ConstF { dst: r, v: 0.0 }),
            Ty::Bool => self.emit(Instr::ConstB { dst: r, v: false }),
        };
        r
    }

    /// int → float promotion; anything else must match exactly.
    fn coerce(&mut self, r: RegId, want: Ty) -> Result<RegId> {
        let have = self.regs[r];
        if have == want {
            Ok(r)
        } else if have == Ty::Int && want == Ty::Float {
            let d = self.new_reg(Ty::Float);
            self.emit(Instr::CastF { dst: d, src: r });
            Ok(d)
        } else {
            bail!("type mismatch: expected {want:?}, found {have:?}")
        }
    }

    // ---------------------------------------------------- driver

    fn lower_driver(mut self, f: &Function) -> Result<bytecode::Program> {
        for p in &f.params {
            match &p.ty {
                Type::Graph => self.bind(&p.name, Binding::Graph),
                Type::Updates => self.bind(&p.name, Binding::Updates(None)),
                Type::PropNode(inner) => {
                    let t = scalar_ty(inner)?;
                    let id = self.new_prop(&p.name, t);
                    self.bind(&p.name, Binding::Prop(id));
                }
                Type::PropEdge(_) => {
                    bail!("propEdge parameters are not supported by the bytecode backend")
                }
                other => {
                    let t = scalar_ty(other)?;
                    let r = self.new_reg(t);
                    self.params.push((p.name.clone(), r));
                    self.bind(&p.name, Binding::Reg(r));
                }
            }
        }
        // Split the driver body: pre-Batch stmts → init, the Batch body →
        // on_batch, and at most a trailing `return` after it.
        let mut pre: Vec<&Stmt> = Vec::new();
        let mut batch_body: Option<&[Stmt]> = None;
        let mut ret: Option<&Expr> = None;
        for (i, s) in f.body.iter().enumerate() {
            match s {
                Stmt::Batch { updates, body, .. } => {
                    if batch_body.is_some() {
                        bail!("{}: driver has more than one Batch construct", s.span());
                    }
                    match self.lookup(updates) {
                        Some(Binding::Updates(None)) => {}
                        _ => bail!(
                            "{}: Batch({updates}: …) does not name the updates parameter",
                            s.span()
                        ),
                    }
                    batch_body = Some(body);
                }
                Stmt::Return(e) => {
                    if i + 1 != f.body.len() {
                        bail!("return must be the driver's final statement");
                    }
                    ret = Some(e);
                }
                other => {
                    if batch_body.is_some() {
                        bail!(
                            "{}: only `return` may follow the Batch construct",
                            other.span()
                        );
                    }
                    pre.push(other);
                }
            }
        }
        let Some(batch_body) = batch_body else {
            bail!("Dynamic driver {:?} has no Batch construct", f.name);
        };
        for s in pre {
            self.lower_stmt(s)?;
        }
        let result = match ret {
            Some(e) => {
                let r = self.eval(e)?;
                let out = self.new_reg(self.regs[r]);
                self.emit(Instr::Mov { dst: out, src: r });
                Some(out)
            }
            None => None,
        };
        let init = std::mem::take(&mut self.code);
        self.in_batch = true;
        self.push_scope();
        for s in batch_body {
            self.lower_stmt(s)?;
        }
        self.pop_scope();
        if let (Some(out), Some(e)) = (result, ret) {
            let r = self.eval(e)?;
            let r = self.coerce(r, self.regs[out])?;
            self.emit(Instr::Mov { dst: out, src: r });
        }
        let on_batch = std::mem::take(&mut self.code);
        Ok(bytecode::Program {
            props: self.props,
            regs: self.regs,
            params: self.params,
            init,
            on_batch,
            result,
            // the analysis pass fills this in (and appends the
            // RepairParents schedule it infers from the relax shapes).
            facts: Default::default(),
        })
    }

    // ---------------------------------------------------- statements

    fn lower_stmts(&mut self, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            self.lower_stmt(s)?;
        }
        Ok(())
    }

    fn lower_stmt(&mut self, s: &Stmt) -> Result<()> {
        let span = s.span();
        match s {
            Stmt::Decl { ty, name, init, .. } => match ty {
                Type::PropNode(inner) => {
                    let t = scalar_ty(inner)?;
                    let p = self.new_prop(name, t);
                    let z = self.zero_reg(t);
                    self.emit(Instr::Fill { prop: p, val: z });
                    self.bind(name, Binding::Prop(p));
                    Ok(())
                }
                Type::Updates => {
                    let Some(Expr::MethodCall { base, method, args }) = init else {
                        bail!("{span}: updates<> declaration needs a currentBatch(0|1) initializer");
                    };
                    if method != "currentBatch" {
                        bail!("{span}: updates<> declaration needs currentBatch(0|1), found .{method}()");
                    }
                    let Expr::Var(b) = &**base else {
                        bail!("{span}: currentBatch receiver must be the updates parameter");
                    };
                    if !matches!(self.lookup(b), Some(Binding::Updates(_))) {
                        bail!("{span}: {b:?} is not an update batch");
                    }
                    let sel = match args.first() {
                        Some(Expr::IntLit(0)) => UpdateSel::Dels,
                        Some(Expr::IntLit(1)) => UpdateSel::Adds,
                        other => bail!("{span}: currentBatch selector must be 0 or 1, found {other:?}"),
                    };
                    self.bind(name, Binding::Updates(Some(sel)));
                    Ok(())
                }
                Type::Edge => {
                    bail!("{span}: edge declarations are only supported inside forall bodies")
                }
                Type::PropEdge(_) | Type::Graph => {
                    bail!("{span}: cannot declare a local of type {ty:?}")
                }
                other => {
                    let t = scalar_ty(other)?;
                    let r = self.new_reg(t);
                    match init {
                        Some(e) => {
                            let v = self.eval(e)?;
                            let v = self.coerce(v, t)?;
                            self.emit(Instr::Mov { dst: r, src: v });
                        }
                        None => {
                            match t {
                                Ty::Int => self.emit(Instr::ConstI { dst: r, v: 0 }),
                                Ty::Float => self.emit(Instr::ConstF { dst: r, v: 0.0 }),
                                Ty::Bool => self.emit(Instr::ConstB { dst: r, v: false }),
                            };
                        }
                    }
                    self.bind(name, Binding::Reg(r));
                    Ok(())
                }
            },
            Stmt::Assign { lhs, op, rhs, .. } => self.lower_assign(lhs, *op, rhs, span),
            Stmt::MinAssign { lhs, min_args, rest, .. } => {
                self.lower_min_top(lhs, min_args, rest, span)
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                let c = self.eval(cond)?;
                if self.regs[c] != Ty::Bool {
                    bail!("{span}: if condition must be boolean");
                }
                let jskip = self.emit(Instr::JumpIfNot { cond: c, target: 0 });
                self.push_scope();
                self.lower_stmts(then_branch)?;
                self.pop_scope();
                if else_branch.is_empty() {
                    let end = self.code.len();
                    self.patch(jskip, end);
                } else {
                    let jend = self.emit(Instr::Jump { target: 0 });
                    let els = self.code.len();
                    self.patch(jskip, els);
                    self.push_scope();
                    self.lower_stmts(else_branch)?;
                    self.pop_scope();
                    let end = self.code.len();
                    self.patch(jend, end);
                }
                Ok(())
            }
            Stmt::While { cond, body, .. } => {
                let start = self.code.len();
                let c = self.eval(cond)?;
                if self.regs[c] != Ty::Bool {
                    bail!("{span}: while condition must be boolean");
                }
                let jout = self.emit(Instr::JumpIfNot { cond: c, target: 0 });
                self.push_scope();
                self.lower_stmts(body)?;
                self.pop_scope();
                self.emit(Instr::Jump { target: start });
                let end = self.code.len();
                self.patch(jout, end);
                Ok(())
            }
            Stmt::DoWhile { body, cond, .. } => {
                let start = self.code.len();
                self.push_scope();
                self.lower_stmts(body)?;
                self.pop_scope();
                let c = self.eval(cond)?;
                if self.regs[c] != Ty::Bool {
                    bail!("{span}: do-while condition must be boolean");
                }
                self.emit(Instr::JumpIf { cond: c, target: start });
                Ok(())
            }
            Stmt::FixedPoint { prop, body, .. } => {
                let (p, t) = self.prop_named(prop)?;
                if t != Ty::Bool {
                    bail!("{span}: fixedPoint convergence property {prop:?} must be propNode<bool>");
                }
                let start = self.code.len();
                self.push_scope();
                self.lower_stmts(body)?;
                self.pop_scope();
                let r = self.new_reg(Ty::Bool);
                self.emit(Instr::AnyTrue { dst: r, prop: p });
                self.emit(Instr::JumpIf { cond: r, target: start });
                Ok(())
            }
            Stmt::Forall { var, iter, body, .. } => self.lower_par(var, iter, body, span),
            Stmt::For { var, iter, body, .. } => match iter {
                Iter::UpdateList(name) => {
                    let sel = match self.lookup(name) {
                        Some(Binding::Updates(Some(sel))) => sel,
                        Some(Binding::Updates(None)) => bail!(
                            "{span}: iterate a currentBatch(0|1) half, not the whole stream"
                        ),
                        _ => bail!("{span}: {name:?} is not an update batch"),
                    };
                    self.lower_update_loop(var, sel, body)
                }
                _ => bail!(
                    "{span}: sequential `for` at driver level is only supported over update batches"
                ),
            },
            Stmt::OnAdd { var, updates, body, .. } => {
                self.check_hook(updates, span)?;
                self.lower_update_loop(var, UpdateSel::Adds, body)
            }
            Stmt::OnDelete { var, updates, body, .. } => {
                self.check_hook(updates, span)?;
                self.lower_update_loop(var, UpdateSel::Dels, body)
            }
            Stmt::Batch { .. } => bail!("{span}: nested Batch constructs are not supported"),
            Stmt::Return(_) => bail!("return is only allowed as a function's final statement"),
            Stmt::Expr(e) => self.lower_expr_stmt(e, span),
        }
    }

    fn check_hook(&self, updates: &str, span: ast::Span) -> Result<()> {
        if !self.in_batch {
            bail!("{span}: OnAdd/OnDelete must appear inside a Batch construct");
        }
        match self.lookup(updates) {
            Some(Binding::Updates(_)) => Ok(()),
            _ => bail!("{span}: {updates:?} is not an update batch"),
        }
    }

    fn lower_assign(
        &mut self,
        lhs: &LValue,
        op: AssignOp,
        rhs: &Expr,
        span: ast::Span,
    ) -> Result<()> {
        match lhs {
            LValue::Var(name) => match self.lookup(name) {
                Some(Binding::Reg(r)) => {
                    let v = self.eval(rhs)?;
                    let v = self.coerce(v, self.regs[r])?;
                    match op {
                        AssignOp::Set => {
                            self.emit(Instr::Mov { dst: r, src: v });
                        }
                        AssignOp::Add => {
                            self.emit(Instr::Bin { dst: r, op: BinOp::Add, a: r, b: v });
                        }
                        AssignOp::Sub => {
                            self.emit(Instr::Bin { dst: r, op: BinOp::Sub, a: r, b: v });
                        }
                    }
                    Ok(())
                }
                Some(Binding::Prop(dst)) => {
                    // whole-property assignment: `modified = modified_nxt;`
                    if op != AssignOp::Set {
                        bail!("{span}: only plain `=` is supported between properties");
                    }
                    let Expr::Var(srcname) = rhs else {
                        bail!("{span}: property assignment requires a property on the right");
                    };
                    let (src, st) = self.prop_named(srcname)?;
                    if st != self.props[dst].ty {
                        bail!("{span}: property copy between different types");
                    }
                    self.emit(Instr::CopyProp { dst, src });
                    Ok(())
                }
                Some(other) => bail!("{span}: cannot assign to {name:?} ({other:?})"),
                None => bail!("{span}: assignment to undeclared variable {name:?}"),
            },
            LValue::Member { base, prop } => {
                let (p, pt) = self.prop_named(prop)?;
                let idx = self.eval(base)?;
                let idx = self.coerce(idx, Ty::Int)?;
                let v = self.eval(rhs)?;
                let v = self.coerce(v, pt)?;
                match op {
                    AssignOp::Set => {
                        self.emit(Instr::StoreProp { prop: p, idx, val: v });
                    }
                    AssignOp::Add | AssignOp::Sub => {
                        let tmp = self.new_reg(pt);
                        self.emit(Instr::LoadProp { dst: tmp, prop: p, idx });
                        let bop = if op == AssignOp::Add { BinOp::Add } else { BinOp::Sub };
                        self.emit(Instr::Bin { dst: tmp, op: bop, a: tmp, b: v });
                        self.emit(Instr::StoreProp { prop: p, idx, val: tmp });
                    }
                }
                Ok(())
            }
        }
    }

    /// Sequential `Min` multi-assignment (OnAdd seeding): fire iff the
    /// candidate is strictly smaller, companions stored only on fire —
    /// the interpreter's exact rule.
    fn lower_min_top(
        &mut self,
        lhs: &[LValue],
        min_args: &(Expr, Expr),
        rest: &[Expr],
        span: ast::Span,
    ) -> Result<()> {
        let Some(LValue::Member { base, prop }) = lhs.first() else {
            bail!("{span}: Min assignment target must be a property member");
        };
        let (p, pt) = self.prop_named(prop)?;
        if pt != Ty::Int {
            bail!("{span}: Min target {prop:?} must be an int property");
        }
        let idx = self.eval(base)?;
        let idx = self.coerce(idx, Ty::Int)?;
        let cur = self.new_reg(Ty::Int);
        self.emit(Instr::LoadProp { dst: cur, prop: p, idx });
        let cand = self.eval(&min_args.1)?;
        let cand = self.coerce(cand, Ty::Int)?;
        let fire = self.new_reg(Ty::Bool);
        self.emit(Instr::Bin { dst: fire, op: BinOp::Lt, a: cand, b: cur });
        let jskip = self.emit(Instr::JumpIfNot { cond: fire, target: 0 });
        self.emit(Instr::StoreProp { prop: p, idx, val: cand });
        for (lv, re) in lhs[1..].iter().zip(rest) {
            let LValue::Member { base, prop } = lv else {
                bail!("{span}: Min companion targets must be property members");
            };
            let (cp, cpt) = self.prop_named(prop)?;
            let cidx = self.eval(base)?;
            let cidx = self.coerce(cidx, Ty::Int)?;
            let cv = self.eval(re)?;
            let cv = self.coerce(cv, cpt)?;
            self.emit(Instr::StoreProp { prop: cp, idx: cidx, val: cv });
        }
        let end = self.code.len();
        self.patch(jskip, end);
        Ok(())
    }

    /// `OnAdd`/`OnDelete`/`for (u in half)` — a sequential loop over one
    /// half of the current batch, matching the interpreter's in-order
    /// iteration exactly.
    fn lower_update_loop(&mut self, var: &str, sel: UpdateSel, body: &[Stmt]) -> Result<()> {
        let cnt = self.new_reg(Ty::Int);
        self.emit(Instr::UpdCount { dst: cnt, sel });
        let i = self.new_reg(Ty::Int);
        self.emit(Instr::ConstI { dst: i, v: 0 });
        let one = self.new_reg(Ty::Int);
        self.emit(Instr::ConstI { dst: one, v: 1 });
        let (src, dst, w) =
            (self.new_reg(Ty::Int), self.new_reg(Ty::Int), self.new_reg(Ty::Int));
        let start = self.code.len();
        let more = self.new_reg(Ty::Bool);
        self.emit(Instr::Bin { dst: more, op: BinOp::Lt, a: i, b: cnt });
        let jout = self.emit(Instr::JumpIfNot { cond: more, target: 0 });
        self.emit(Instr::UpdGet { sel, idx: i, src, dst, weight: w });
        self.push_scope();
        self.bind(var, Binding::UpdateVar { src, dst, w });
        self.lower_stmts(body)?;
        self.pop_scope();
        self.emit(Instr::Bin { dst: i, op: BinOp::Add, a: i, b: one });
        self.emit(Instr::Jump { target: start });
        let end = self.code.len();
        self.patch(jout, end);
        Ok(())
    }

    fn lower_expr_stmt(&mut self, e: &Expr, span: ast::Span) -> Result<()> {
        match e {
            Expr::MethodCall { base, method, args } => {
                let Expr::Var(b) = &**base else {
                    bail!("{span}: unsupported method receiver");
                };
                match (self.lookup(b), method.as_str()) {
                    (Some(Binding::Graph), "attachNodeProperty") => {
                        for a in args {
                            let Expr::KwArg { name, value } = a else {
                                bail!("{span}: attachNodeProperty takes prop = value arguments");
                            };
                            let (p, pt) = self.prop_named(name)?;
                            let v = self.eval(value)?;
                            let v = self.coerce(v, pt)?;
                            self.emit(Instr::Fill { prop: p, val: v });
                        }
                        Ok(())
                    }
                    (Some(Binding::Graph), "attachEdgeProperty") => Ok(()),
                    (Some(Binding::Graph), "updateCSRDel") => {
                        if !self.in_batch {
                            bail!("{span}: updateCSRDel outside a Batch construct");
                        }
                        self.emit(Instr::ApplyDeletions);
                        Ok(())
                    }
                    (Some(Binding::Graph), "updateCSRAdd") => {
                        if !self.in_batch {
                            bail!("{span}: updateCSRAdd outside a Batch construct");
                        }
                        self.emit(Instr::ApplyAdditions);
                        Ok(())
                    }
                    (Some(Binding::Graph), "propagateNodeFlags") => {
                        let Some(Expr::Var(pn)) = args.first() else {
                            bail!("{span}: propagateNodeFlags takes a property name");
                        };
                        let (p, pt) = self.prop_named(pn)?;
                        if pt != Ty::Bool {
                            bail!("{span}: propagateNodeFlags needs a propNode<bool>");
                        }
                        self.emit(Instr::PropagateFlags { prop: p });
                        Ok(())
                    }
                    (_, m) => bail!("{span}: unsupported method call .{m}() as a statement"),
                }
            }
            Expr::Call { name, args } => {
                self.inline_call(name, args, span)?;
                Ok(())
            }
            other => bail!("{span}: unsupported expression statement {other:?}"),
        }
    }

    // ---------------------------------------------------- expressions

    fn eval(&mut self, e: &Expr) -> Result<RegId> {
        match e {
            Expr::IntLit(v) => {
                let r = self.new_reg(Ty::Int);
                self.emit(Instr::ConstI { dst: r, v: *v });
                Ok(r)
            }
            Expr::FloatLit(v) => {
                let r = self.new_reg(Ty::Float);
                self.emit(Instr::ConstF { dst: r, v: *v });
                Ok(r)
            }
            Expr::BoolLit(v) => {
                let r = self.new_reg(Ty::Bool);
                self.emit(Instr::ConstB { dst: r, v: *v });
                Ok(r)
            }
            Expr::Inf => {
                let r = self.new_reg(Ty::Int);
                self.emit(Instr::ConstI { dst: r, v: crate::algorithms::sssp::INF });
                Ok(r)
            }
            Expr::Var(name) => match self.lookup(name) {
                Some(Binding::Reg(r)) => Ok(r),
                Some(other) => bail!("{name:?} ({other:?}) cannot be used as a scalar value"),
                None => bail!("unknown variable {name:?}"),
            },
            Expr::Member { base, prop } => {
                if let Expr::Var(b) = &**base {
                    if let Some(Binding::UpdateVar { src, dst, w }) = self.lookup(b) {
                        return match prop.as_str() {
                            "source" => Ok(src),
                            "destination" => Ok(dst),
                            "weight" => Ok(w),
                            other => bail!("update tuples have no property {other:?}"),
                        };
                    }
                }
                let (p, pt) = self.prop_named(prop)?;
                let idx = self.eval(base)?;
                let idx = self.coerce(idx, Ty::Int)?;
                let r = self.new_reg(pt);
                self.emit(Instr::LoadProp { dst: r, prop: p, idx });
                Ok(r)
            }
            Expr::MethodCall { base, method, .. } => {
                let is_graph =
                    matches!(&**base, Expr::Var(b) if self.lookup(b) == Some(Binding::Graph));
                match method.as_str() {
                    "num_nodes" if is_graph => {
                        let r = self.new_reg(Ty::Int);
                        self.emit(Instr::NumNodes { dst: r });
                        Ok(r)
                    }
                    "num_edges" if is_graph => {
                        let r = self.new_reg(Ty::Int);
                        self.emit(Instr::NumEdges { dst: r });
                        Ok(r)
                    }
                    "currentBatch" => {
                        bail!("currentBatch(…) may only initialize an updates<> declaration")
                    }
                    other => bail!("unsupported method .{other}() in sequential driver code"),
                }
            }
            Expr::Call { name, args } => {
                match self.inline_call(name, args, ast::Span::default())? {
                    Some(r) => Ok(r),
                    None => bail!("function {name:?} returns no value"),
                }
            }
            Expr::Unary { op: UnOp::Not, expr } => {
                let v = self.eval(expr)?;
                if self.regs[v] != Ty::Bool {
                    bail!("`!` applied to a non-boolean");
                }
                let r = self.new_reg(Ty::Bool);
                self.emit(Instr::Not { dst: r, src: v });
                Ok(r)
            }
            Expr::Unary { op: UnOp::Neg, expr } => {
                let v = self.eval(expr)?;
                let t = self.regs[v];
                if t == Ty::Bool {
                    bail!("unary minus applied to a boolean");
                }
                let r = self.new_reg(t);
                self.emit(Instr::Neg { dst: r, src: v });
                Ok(r)
            }
            Expr::Binary { op, lhs, rhs } => {
                let mut a = self.eval(lhs)?;
                let mut b = self.eval(rhs)?;
                match (self.regs[a], self.regs[b]) {
                    (Ty::Float, Ty::Int) => b = self.coerce(b, Ty::Float)?,
                    (Ty::Int, Ty::Float) => a = self.coerce(a, Ty::Float)?,
                    _ => {}
                }
                let ta = self.regs[a];
                let Some(rt) = bytecode::bin_result_ty(*op, ta) else {
                    bail!("operator {op:?} is not defined on {ta:?} operands");
                };
                let r = self.new_reg(rt);
                self.emit(Instr::Bin { dst: r, op: *op, a, b });
                Ok(r)
            }
            Expr::KwArg { .. } => bail!("keyword argument outside attachNodeProperty"),
        }
    }

    // ---------------------------------------------------- call inlining

    /// Monomorphize a `Static`/`Incremental`/`Decremental` call at its
    /// call site. Returns the register holding the callee's `return`
    /// value, if it has one (which must be its final statement).
    fn inline_call(
        &mut self,
        name: &str,
        args: &[Expr],
        span: ast::Span,
    ) -> Result<Option<RegId>> {
        let Some(f) = self.ast.find(name) else {
            bail!("{span}: call to unknown function {name:?}");
        };
        if f.kind == FnKind::Dynamic {
            bail!("{span}: cannot call the Dynamic driver {name:?}");
        }
        if self.depth >= MAX_INLINE_DEPTH {
            bail!("{span}: call inlining depth exceeded ({MAX_INLINE_DEPTH}) — recursive calls?");
        }
        if f.params.len() != args.len() {
            bail!(
                "{span}: {name:?} takes {} arguments, {} supplied",
                f.params.len(),
                args.len()
            );
        }
        let mut frame = HashMap::new();
        for (p, a) in f.params.iter().zip(args) {
            let binding = match &p.ty {
                Type::Graph => match a {
                    Expr::Var(v) if self.lookup(v) == Some(Binding::Graph) => Binding::Graph,
                    _ => bail!("{span}: argument for Graph parameter {:?} must be the graph", p.name),
                },
                Type::Updates => match a {
                    Expr::Var(v) => match self.lookup(v) {
                        Some(b @ Binding::Updates(_)) => b,
                        _ => bail!("{span}: {v:?} is not an update batch"),
                    },
                    _ => bail!("{span}: argument for updates parameter must be a batch name"),
                },
                Type::PropNode(inner) => match a {
                    Expr::Var(v) => match self.lookup(v) {
                        Some(Binding::Prop(id)) => {
                            if self.props[id].ty != scalar_ty(inner)? {
                                bail!("{span}: property {v:?} type mismatch for {:?}", p.name);
                            }
                            Binding::Prop(id)
                        }
                        _ => bail!("{span}: {v:?} is not a node property"),
                    },
                    _ => bail!("{span}: argument for propNode parameter must be a property name"),
                },
                Type::PropEdge(_) | Type::Edge => {
                    bail!("{span}: {:?} parameters are not supported", p.ty)
                }
                other => {
                    // scalars are passed by value: copy into a fresh register
                    // so callee-side assignment can't alias the caller's.
                    let t = scalar_ty(other)?;
                    let v = self.eval(a)?;
                    let v = self.coerce(v, t)?;
                    let fresh = self.new_reg(t);
                    self.emit(Instr::Mov { dst: fresh, src: v });
                    Binding::Reg(fresh)
                }
            };
            frame.insert(p.name.clone(), binding);
        }
        let saved = std::mem::replace(&mut self.scopes, vec![frame]);
        self.depth += 1;
        let (body, ret) = match f.body.split_last() {
            Some((Stmt::Return(e), rest)) => (rest, Some(e)),
            _ => (&f.body[..], None),
        };
        self.lower_stmts(body)?;
        let out = match ret {
            Some(e) => Some(self.eval(e)?),
            None => None,
        };
        self.depth -= 1;
        self.scopes = saved;
        Ok(out)
    }

    // ---------------------------------------------------- parallel regions

    /// `forall` → [`Instr::Par`]. The domain is materialized up front
    /// (nodes, or the out-neighbors of an evaluated vertex); filters
    /// become guards at execution time — equivalent to the interpreter's
    /// pre-collected item lists because loop bodies only ever write the
    /// subject's own flags or disjoint properties.
    fn lower_par(&mut self, var: &str, iter: &Iter, body: &[Stmt], span: ast::Span) -> Result<()> {
        let (domain, filter) = match iter {
            Iter::Nodes { filter, .. } => (Domain::Nodes, filter.as_ref()),
            Iter::Neighbors { of, filter, .. } => {
                let r = self.eval(of)?;
                let r = self.coerce(r, Ty::Int)?;
                (Domain::OutNbrs { of: r }, filter.as_ref())
            }
            Iter::NodesTo { .. } => {
                bail!("{span}: parallel iteration over in-neighbors is not supported")
            }
            Iter::UpdateList(_) => {
                bail!("{span}: update batches are iterated sequentially (for/OnAdd/OnDelete)")
            }
        };
        let mut pl = ParLower {
            lo: self,
            subject: var.to_string(),
            locals: Vec::new(),
            scopes: vec![HashMap::new()],
            forouts: Vec::new(),
            accums: Vec::new(),
            accum_map: HashMap::new(),
        };
        let guard = match filter {
            Some(f) => Some(pl.vexpr(f)?),
            None => None,
        };
        let mut vbody = pl.vlower_stmts(body)?;
        if let Some(cond) = guard {
            vbody = vec![VStmt::If { cond, then: vbody, els: Vec::new() }];
        }
        let (locals, accums) = (pl.locals, pl.accums);
        self.emit(Instr::Par(ParOp { domain, locals, body: vbody, accums, span }));
        Ok(())
    }
}

/// What a name means inside a parallel region, on top of the outer
/// [`Binding`] table.
#[derive(Debug, Clone)]
enum VBind {
    Local(usize),
    /// `edge e = g.get_edge(a, b)` — symbolic: source/destination are
    /// the lowered argument expressions; `w` is the enclosing neighbor
    /// loop's weight local when `b` is its loop variable.
    Edge { src: VExpr, dst: VExpr, w: Option<usize> },
}

struct ParLower<'a, 'b> {
    lo: &'b mut Lowerer<'a>,
    subject: String,
    locals: Vec<Ty>,
    scopes: Vec<HashMap<String, VBind>>,
    /// enclosing neighbor loops: (nbr local, weight local).
    forouts: Vec<(usize, usize)>,
    accums: Vec<AccumDef>,
    accum_map: HashMap<RegId, usize>,
}

impl ParLower<'_, '_> {
    fn new_local(&mut self, ty: Ty) -> usize {
        self.locals.push(ty);
        self.locals.len() - 1
    }

    fn vbind(&mut self, name: &str, b: VBind) {
        if let Some(scope) = self.scopes.last_mut() {
            scope.insert(name.to_string(), b);
        }
    }

    fn vlookup(&self, name: &str) -> Option<VBind> {
        self.scopes.iter().rev().find_map(|s| s.get(name).cloned())
    }

    /// Find or create the accumulator for an enclosing scalar register.
    fn accum_for(&mut self, reg: RegId, kind: AccumKind) -> Result<usize> {
        if let Some(&i) = self.accum_map.get(&reg) {
            if self.accums[i].kind != kind {
                bail!("conflicting reduction kinds on the same variable inside forall");
            }
            return Ok(i);
        }
        self.accums.push(AccumDef { reg, kind });
        let i = self.accums.len() - 1;
        self.accum_map.insert(reg, i);
        Ok(i)
    }

    fn vlower_stmts(&mut self, stmts: &[Stmt]) -> Result<Vec<VStmt>> {
        let mut out = Vec::new();
        for s in stmts {
            self.vlower_stmt(s, &mut out)?;
        }
        Ok(out)
    }

    fn vlower_stmt(&mut self, s: &Stmt, out: &mut Vec<VStmt>) -> Result<()> {
        let span = s.span();
        match s {
            Stmt::Decl { ty: Type::Edge, name, init, .. } => {
                let Some(Expr::MethodCall { method, args, .. }) = init else {
                    bail!("{span}: edge locals must be initialized with g.get_edge(u, v)");
                };
                if method != "get_edge" || args.len() != 2 {
                    bail!("{span}: edge locals must be initialized with g.get_edge(u, v)");
                }
                let src = self.vexpr(&args[0])?;
                let dst = self.vexpr(&args[1])?;
                let w = match &dst {
                    VExpr::Local(l) => self
                        .forouts
                        .iter()
                        .rev()
                        .find(|(nbr, _)| nbr == l)
                        .map(|&(_, w)| w),
                    _ => None,
                };
                self.vbind(name, VBind::Edge { src, dst, w });
                Ok(())
            }
            Stmt::Decl { ty, name, init, .. } => {
                let t = scalar_ty(ty)
                    .map_err(|e| crate::util::error::anyhow!("{span}: {e}"))?;
                let l = self.new_local(t);
                let v = match init {
                    Some(e) => self.vexpr(e)?,
                    None => match t {
                        Ty::Int => VExpr::ConstI(0),
                        Ty::Float => VExpr::ConstF(0.0),
                        Ty::Bool => VExpr::ConstB(false),
                    },
                };
                self.vbind(name, VBind::Local(l));
                out.push(VStmt::SetLocal(l, v));
                Ok(())
            }
            Stmt::Assign { lhs, op, rhs, .. } => self.vlower_assign(lhs, *op, rhs, span, out),
            Stmt::MinAssign { lhs, min_args, rest, .. } => {
                let Some(LValue::Member { base, prop }) = lhs.first() else {
                    bail!("{span}: Min assignment target must be a property member");
                };
                let (p, pt) = self.lo.prop_named(prop)?;
                if pt != Ty::Int {
                    bail!("{span}: Min target {prop:?} must be an int property");
                }
                let idx = self.vexpr(base)?;
                let val = self.vexpr(&min_args.1)?;
                let mut comps = Vec::new();
                for (lv, re) in lhs[1..].iter().zip(rest) {
                    let LValue::Member { base, prop } = lv else {
                        bail!("{span}: Min companion targets must be property members");
                    };
                    let (cp, _) = self.lo.prop_named(prop)?;
                    comps.push((cp, self.vexpr(base)?, self.vexpr(re)?));
                }
                out.push(VStmt::MinAssign { prop: p, idx, val, comps });
                Ok(())
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                let cond = self.vexpr(cond)?;
                self.scopes.push(HashMap::new());
                let then = self.vlower_stmts(then_branch)?;
                self.scopes.pop();
                self.scopes.push(HashMap::new());
                let els = self.vlower_stmts(else_branch)?;
                self.scopes.pop();
                out.push(VStmt::If { cond, then, els });
                Ok(())
            }
            Stmt::Forall { var, iter, body, .. } | Stmt::For { var, iter, body, .. } => {
                match iter {
                    Iter::Neighbors { of, filter, .. } => {
                        let of = self.vexpr(of)?;
                        let nbr = self.new_local(Ty::Int);
                        let w = self.new_local(Ty::Int);
                        self.scopes.push(HashMap::new());
                        self.vbind(var, VBind::Local(nbr));
                        self.forouts.push((nbr, w));
                        let guard = match filter {
                            Some(f) => Some(self.vexpr(f)?),
                            None => None,
                        };
                        let mut body = self.vlower_stmts(body)?;
                        self.forouts.pop();
                        self.scopes.pop();
                        if let Some(cond) = guard {
                            body = vec![VStmt::If { cond, then: body, els: Vec::new() }];
                        }
                        out.push(VStmt::ForOut { of, nbr, w: Some(w), body, span });
                        Ok(())
                    }
                    Iter::NodesTo { of, .. } => {
                        let of = self.vexpr(of)?;
                        let nbr = self.new_local(Ty::Int);
                        self.scopes.push(HashMap::new());
                        self.vbind(var, VBind::Local(nbr));
                        let body = self.vlower_stmts(body)?;
                        self.scopes.pop();
                        out.push(VStmt::ForIn { of, nbr, body, span });
                        Ok(())
                    }
                    Iter::Nodes { .. } => {
                        bail!("{span}: nested all-nodes loops inside forall are not supported")
                    }
                    Iter::UpdateList(_) => {
                        bail!("{span}: update batches cannot be iterated inside forall")
                    }
                }
            }
            other => bail!(
                "{span}: statement {other:?} is not supported inside a parallel region"
            ),
        }
    }

    fn vlower_assign(
        &mut self,
        lhs: &LValue,
        op: AssignOp,
        rhs: &Expr,
        span: ast::Span,
        out: &mut Vec<VStmt>,
    ) -> Result<()> {
        match lhs {
            LValue::Var(name) => {
                if let Some(VBind::Local(l)) = self.vlookup(name) {
                    let v = self.vexpr(rhs)?;
                    let v = match op {
                        AssignOp::Set => v,
                        AssignOp::Add => {
                            VExpr::Bin(BinOp::Add, Box::new(VExpr::Local(l)), Box::new(v))
                        }
                        AssignOp::Sub => {
                            VExpr::Bin(BinOp::Sub, Box::new(VExpr::Local(l)), Box::new(v))
                        }
                    };
                    out.push(VStmt::SetLocal(l, v));
                    return Ok(());
                }
                if matches!(self.vlookup(name), Some(VBind::Edge { .. })) || *name == self.subject {
                    bail!("{span}: cannot assign to {name:?} inside forall");
                }
                match self.lo.lookup(name) {
                    Some(Binding::Reg(r)) => {
                        // reductions into enclosing scalars:
                        //   x += e / x -= e / x = x ± e  → add accumulator
                        //   x = True                     → or accumulator
                        let delta: Option<VExpr> = match (op, rhs) {
                            (AssignOp::Add, e) => Some(self.vexpr(e)?),
                            (AssignOp::Sub, e) => {
                                Some(VExpr::Neg(Box::new(self.vexpr(e)?)))
                            }
                            (AssignOp::Set, Expr::Binary { op: BinOp::Add, lhs: a, rhs: b })
                                if matches!(&**a, Expr::Var(v) if v == name) =>
                            {
                                Some(self.vexpr(b)?)
                            }
                            (AssignOp::Set, Expr::Binary { op: BinOp::Add, lhs: a, rhs: b })
                                if matches!(&**b, Expr::Var(v) if v == name) =>
                            {
                                Some(self.vexpr(a)?)
                            }
                            (AssignOp::Set, Expr::Binary { op: BinOp::Sub, lhs: a, rhs: b })
                                if matches!(&**a, Expr::Var(v) if v == name) =>
                            {
                                Some(VExpr::Neg(Box::new(self.vexpr(b)?)))
                            }
                            (AssignOp::Set, Expr::BoolLit(true)) => {
                                let acc = self.accum_for(r, AccumKind::Or)?;
                                out.push(VStmt::Accum { acc, val: VExpr::ConstB(true) });
                                return Ok(());
                            }
                            _ => None,
                        };
                        let Some(delta) = delta else {
                            bail!(
                                "{span}: only reduction-shaped assignments (x = x + e, x += e, \
                                 x = True) to enclosing scalars are allowed inside forall"
                            );
                        };
                        let kind = match self.lo.regs[r] {
                            Ty::Int => AccumKind::AddI,
                            Ty::Float => AccumKind::AddF,
                            Ty::Bool => bail!(
                                "{span}: boolean reductions inside forall support only `= True`"
                            ),
                        };
                        let acc = self.accum_for(r, kind)?;
                        out.push(VStmt::Accum { acc, val: delta });
                        Ok(())
                    }
                    Some(other) => {
                        bail!("{span}: cannot assign to {name:?} ({other:?}) inside forall")
                    }
                    None => bail!("{span}: assignment to undeclared variable {name:?}"),
                }
            }
            LValue::Member { base, prop } => {
                if op != AssignOp::Set {
                    bail!("{span}: compound property updates inside forall are not supported");
                }
                let (p, _) = self.lo.prop_named(prop)?;
                let idx = self.vexpr(base)?;
                let val = self.vexpr(rhs)?;
                out.push(VStmt::StoreProp(p, idx, val));
                Ok(())
            }
        }
    }

    fn vexpr(&mut self, e: &Expr) -> Result<VExpr> {
        Ok(match e {
            Expr::IntLit(v) => VExpr::ConstI(*v),
            Expr::FloatLit(v) => VExpr::ConstF(*v),
            Expr::BoolLit(v) => VExpr::ConstB(*v),
            Expr::Inf => VExpr::ConstI(crate::algorithms::sssp::INF),
            Expr::Var(name) => {
                if let Some(b) = self.vlookup(name) {
                    match b {
                        VBind::Local(l) => VExpr::Local(l),
                        VBind::Edge { .. } => bail!("edge {name:?} used as a scalar value"),
                    }
                } else if name == &self.subject {
                    VExpr::Subject
                } else {
                    match self.lo.lookup(name) {
                        Some(Binding::Reg(r)) => VExpr::Reg(r),
                        // a bare property name in a filter refers to the
                        // subject's value: `.filter(modified == True)`
                        Some(Binding::Prop(p)) => {
                            VExpr::LoadProp(p, Box::new(VExpr::Subject))
                        }
                        Some(other) => bail!("{name:?} ({other:?}) used as a scalar value"),
                        None => bail!("unknown identifier {name:?} inside forall"),
                    }
                }
            }
            Expr::Member { base, prop } => {
                if let Expr::Var(b) = &**base {
                    if let Some(VBind::Edge { src, dst, w }) = self.vlookup(b) {
                        return Ok(match prop.as_str() {
                            "weight" => match w {
                                Some(l) => VExpr::Local(l),
                                None => bail!(
                                    "edge weight is only available for neighbor-loop edges"
                                ),
                            },
                            "source" => src,
                            "destination" => dst,
                            other => bail!("edges have no property {other:?}"),
                        });
                    }
                    if let Some(Binding::UpdateVar { src, dst, w }) = self.lo.lookup(b) {
                        return Ok(match prop.as_str() {
                            "source" => VExpr::Reg(src),
                            "destination" => VExpr::Reg(dst),
                            "weight" => VExpr::Reg(w),
                            other => bail!("update tuples have no property {other:?}"),
                        });
                    }
                }
                let (p, _) = self.lo.prop_named(prop)?;
                let idx = self.vexpr(base)?;
                VExpr::LoadProp(p, Box::new(idx))
            }
            Expr::MethodCall { base, method, args } => match method.as_str() {
                "count_outNbrs" => {
                    let Some(a) = args.first() else {
                        bail!("count_outNbrs needs a vertex argument");
                    };
                    VExpr::OutDegree(Box::new(self.vexpr(a)?))
                }
                "is_an_edge" => {
                    if args.len() != 2 {
                        bail!("is_an_edge needs two vertex arguments");
                    }
                    VExpr::IsEdge(
                        Box::new(self.vexpr(&args[0])?),
                        Box::new(self.vexpr(&args[1])?),
                    )
                }
                "contains" => {
                    let Expr::Var(b) = &**base else {
                        bail!("contains receiver must be an update batch");
                    };
                    let sel = match self.lo.lookup(b) {
                        Some(Binding::Updates(Some(sel))) => sel,
                        _ => bail!("{b:?} is not a currentBatch(0|1) half"),
                    };
                    if args.len() != 2 {
                        bail!("contains needs two vertex arguments");
                    }
                    VExpr::Contains(
                        sel,
                        Box::new(self.vexpr(&args[0])?),
                        Box::new(self.vexpr(&args[1])?),
                    )
                }
                other => bail!("unsupported method .{other}() inside forall"),
            },
            Expr::Call { name, .. } => {
                bail!("call to {name:?} inside forall — function calls are sequential-only")
            }
            Expr::Unary { op: UnOp::Not, expr } => VExpr::Not(Box::new(self.vexpr(expr)?)),
            Expr::Unary { op: UnOp::Neg, expr } => VExpr::Neg(Box::new(self.vexpr(expr)?)),
            Expr::Binary { op, lhs, rhs } => VExpr::Bin(
                *op,
                Box::new(self.vexpr(lhs)?),
                Box::new(self.vexpr(rhs)?),
            ),
            Expr::KwArg { .. } => bail!("keyword argument outside attachNodeProperty"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowers_all_shipped_programs() {
        for (name, src) in [
            ("sssp", include_str!("../../dsl/sssp_dynamic.sp")),
            ("bfs", include_str!("../../dsl/bfs_dynamic.sp")),
            ("pagerank", include_str!("../../dsl/pagerank_dynamic.sp")),
            ("tc", include_str!("../../dsl/tc_dynamic.sp")),
            ("cc", include_str!("../../dsl/cc_dynamic.sp")),
        ] {
            let prog = compile(src, None).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!prog.init.is_empty(), "{name}: empty init segment");
            assert!(!prog.on_batch.is_empty(), "{name}: empty batch segment");
        }
    }

    #[test]
    fn sssp_records_weighted_parent_repair() {
        let prog = compile(include_str!("../../dsl/sssp_dynamic.sp"), None).unwrap();
        let repairs: Vec<_> = prog
            .init
            .iter()
            .filter_map(|i| match i {
                Instr::RepairParents { dist, parent, unit_weight } => {
                    Some((*dist, *parent, *unit_weight))
                }
                _ => None,
            })
            .collect();
        assert_eq!(repairs.len(), 1, "one (dist, parent) repair pair");
        let (d, p, unit) = repairs[0];
        assert_eq!(prog.props[d].name, "dist");
        assert_eq!(prog.props[p].name, "parent");
        assert!(!unit, "sssp relaxes with edge weights");
        // the batch segment repairs the same pair
        assert!(prog.on_batch.iter().any(|i| matches!(
            i,
            Instr::RepairParents { dist, parent, .. } if *dist == d && *parent == p
        )));
    }

    #[test]
    fn bfs_repair_is_unit_weight() {
        let prog = compile(include_str!("../../dsl/bfs_dynamic.sp"), None).unwrap();
        assert!(prog.init.iter().any(|i| matches!(
            i,
            Instr::RepairParents { unit_weight: true, .. }
        )));
    }

    #[test]
    fn tc_has_result_register_and_no_props() {
        let prog = compile(include_str!("../../dsl/tc_dynamic.sp"), None).unwrap();
        assert!(prog.result.is_some(), "DynTC returns the triangle count");
        assert!(prog.props.is_empty(), "TC declares no node properties");
    }

    #[test]
    fn unknown_entry_is_an_error() {
        let err = compile(include_str!("../../dsl/tc_dynamic.sp"), Some("NoSuchFn"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("NoSuchFn"), "unexpected: {err}");
    }
}
