//! AST — the compiler's intermediate representation (§3.4).

/// A parsed DSL translation unit: a set of functions.
#[derive(Debug, Clone)]
pub struct Program {
    pub functions: Vec<Function>,
}

impl Program {
    pub fn find(&self, name: &str) -> Option<&Function> {
        self.functions.iter().find(|f| f.name == name)
    }
}

/// A source position (1-based line and column) carried on statements so
/// sema/lowering diagnostics can point into the `.sp` file.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Span {
    pub line: u32,
    pub col: u32,
}

impl std::fmt::Display for Span {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}:{}", self.line, self.col)
    }
}

/// Function kinds (§3.3): `Static`, `Dynamic` (the driver with the Batch
/// construct), and the special `Incremental`/`Decremental` handlers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FnKind {
    Static,
    Dynamic,
    Incremental,
    Decremental,
}

#[derive(Debug, Clone)]
pub struct Function {
    pub kind: FnKind,
    pub name: String,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
}

#[derive(Debug, Clone)]
pub struct Param {
    pub ty: Type,
    pub name: String,
}

/// DSL types (§2: primitives + Graph/node/edge first-class types +
/// attachable property types).
#[derive(Debug, Clone, PartialEq)]
pub enum Type {
    Int,
    Long,
    Bool,
    Float,
    Double,
    Graph,
    Node,
    Edge,
    PropNode(Box<Type>),
    PropEdge(Box<Type>),
    /// `updates<g>`
    Updates,
}

/// Statements.
#[derive(Debug, Clone)]
pub enum Stmt {
    /// `int x = e;` / `propNode<bool> m;` / `node v = e;`
    Decl { ty: Type, name: String, init: Option<Expr>, span: Span },
    /// `lhs = e;`, `lhs += e;`, `lhs -= e;`
    Assign { lhs: LValue, op: AssignOp, rhs: Expr, span: Span },
    /// `<l1, l2, l3> = <Min(a, b), e2, e3>;` — atomic multi-assign (§2)
    MinAssign { lhs: Vec<LValue>, min_args: (Expr, Expr), rest: Vec<Expr>, span: Span },
    If { cond: Expr, then_branch: Vec<Stmt>, else_branch: Vec<Stmt>, span: Span },
    While { cond: Expr, body: Vec<Stmt>, span: Span },
    DoWhile { body: Vec<Stmt>, cond: Expr, span: Span },
    /// `forall (v in <iter>) { … }` — parallel aggregate (§2)
    Forall { var: String, iter: Iter, body: Vec<Stmt>, span: Span },
    /// `for (v in <iter>) { … }` — sequential
    For { var: String, iter: Iter, body: Vec<Stmt>, span: Span },
    /// `fixedPoint until (flag: !prop) { … }` (§2)
    FixedPoint { flag: String, prop: String, body: Vec<Stmt>, span: Span },
    /// `Batch(updates:size) { … }` (§3.3.1)
    Batch { updates: String, size: Expr, body: Vec<Stmt>, span: Span },
    /// `OnAdd (u in updates.currentBatch()) { … }` (§3.3.2)
    OnAdd { var: String, updates: String, body: Vec<Stmt>, span: Span },
    /// `OnDelete (u in updates.currentBatch()) { … }`
    OnDelete { var: String, updates: String, body: Vec<Stmt>, span: Span },
    Return(Expr),
    /// expression statement (method calls: `g.updateCSRDel(b);`,
    /// function calls: `staticSSSP(g, …);`)
    Expr(Expr),
}

impl Stmt {
    /// The statement's source position. `Return`/`Expr` statements carry
    /// no span of their own (they are tuple variants kept stable for
    /// pattern-matching callers) and report the default position.
    pub fn span(&self) -> Span {
        match self {
            Stmt::Decl { span, .. }
            | Stmt::Assign { span, .. }
            | Stmt::MinAssign { span, .. }
            | Stmt::If { span, .. }
            | Stmt::While { span, .. }
            | Stmt::DoWhile { span, .. }
            | Stmt::Forall { span, .. }
            | Stmt::For { span, .. }
            | Stmt::FixedPoint { span, .. }
            | Stmt::Batch { span, .. }
            | Stmt::OnAdd { span, .. }
            | Stmt::OnDelete { span, .. } => *span,
            Stmt::Return(_) | Stmt::Expr(_) => Span::default(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignOp {
    Set,
    Add,
    Sub,
}

/// Assignable places.
#[derive(Debug, Clone, PartialEq)]
pub enum LValue {
    Var(String),
    /// `v.dist` — property of a node/edge expression
    Member { base: Expr, prop: String },
}

/// Iteration domains for for/forall.
#[derive(Debug, Clone)]
pub enum Iter {
    /// `g.nodes()` (+ optional `.filter(cond)`)
    Nodes { graph: String, filter: Option<Expr> },
    /// `g.neighbors(v)` (+ optional `.filter(cond)`)
    Neighbors { graph: String, of: Expr, filter: Option<Expr> },
    /// `g.nodes_to(v)` — in-neighbors
    NodesTo { graph: String, of: Expr },
    /// a named updates batch (`forall (u in addBatch)`)
    UpdateList(String),
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    IntLit(i64),
    FloatLit(f64),
    BoolLit(bool),
    /// `INF` / `INT_MAX` (the parser folds `INT_MAX/2` into Inf too)
    Inf,
    Var(String),
    /// `v.dist`, `e.source`, `u.weight`
    Member { base: Box<Expr>, prop: String },
    /// `g.num_nodes()`, `g.get_edge(u, v)`, `b.currentBatch(0)` …
    MethodCall { base: Box<Expr>, method: String, args: Vec<Expr> },
    /// free function call: `staticSSSP(g, …)`
    Call { name: String, args: Vec<Expr> },
    Unary { op: UnOp, expr: Box<Expr> },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
    /// keyword argument `name = value` inside
    /// `g.attachNodeProperty(dist = INF, …)`
    KwArg { name: String, value: Box<Expr> },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Not,
    Neg,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
    And,
    Or,
}

impl Expr {
    /// Convenience: does this expression mention identifier `name`?
    pub fn mentions(&self, name: &str) -> bool {
        match self {
            Expr::Var(v) => v == name,
            Expr::Member { base, .. } => base.mentions(name),
            Expr::MethodCall { base, args, .. } => {
                base.mentions(name) || args.iter().any(|a| a.mentions(name))
            }
            Expr::Call { args, .. } => args.iter().any(|a| a.mentions(name)),
            Expr::Unary { expr, .. } => expr.mentions(name),
            Expr::Binary { lhs, rhs, .. } => lhs.mentions(name) || rhs.mentions(name),
            _ => false,
        }
    }
}
