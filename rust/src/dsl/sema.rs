//! Semantic analysis (§2, §4): symbol table construction, read/write-set
//! computation for every `forall`, and data-race detection that decides
//! the synchronization the generated code needs:
//!
//! * `Min` multi-assignments → atomic min (`atomicMin` in CUDA, gcc
//!   `__atomic` builtins in OpenMP, `MPI_Accumulate(MIN)` in MPI);
//! * `+=`/`-=` on a scalar inside a `forall` → reduction clause;
//! * a property written through a vertex other than the loop variable
//!   (e.g. `nbr.dist` inside `forall (v …) forall (nbr …)`) → atomic /
//!   critical section;
//! * a property written only through the loop variable → owner-computes,
//!   no synchronization (the common fast path).

use super::ast::*;
use crate::util::error::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// What kind of synchronization a write site needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sync {
    /// owner-computes, race-free
    None,
    /// atomic compare-exchange minimum
    AtomicMin,
    /// parallel reduction (`reduction(+: x)` in OpenMP)
    Reduction,
    /// generic atomic/critical update
    Critical,
}

/// Analysis result for one `forall` site.
#[derive(Debug, Clone)]
pub struct ForallInfo {
    /// properties read in the body
    pub reads: BTreeSet<String>,
    /// properties written in the body → required sync
    pub writes: BTreeMap<String, Sync>,
    /// scalar reduction variables (name → sync)
    pub reductions: BTreeSet<String>,
    /// source location of the `forall`, for reports and diagnostics
    pub span: Span,
    /// nesting depth (outermost = 0); backends parallelize depth 0 only
    pub depth: usize,
}

/// Per-function analysis.
#[derive(Debug, Clone, Default)]
pub struct FnAnalysis {
    pub foralls: Vec<ForallInfo>,
    /// node properties declared or attached in this function
    pub node_props: BTreeSet<String>,
    /// properties the xla backend must copy device→host after the kernel
    /// (§5.3 transfer analysis: written properties only)
    pub dirty_props: BTreeSet<String>,
}

/// Whole-program analysis keyed by function name.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    pub functions: BTreeMap<String, FnAnalysis>,
}

/// Run semantic analysis. Errors on malformed programs (e.g. `Batch`
/// outside a `Dynamic` function, unknown function calls).
pub fn analyze(p: &Program) -> Result<Analysis> {
    let known: BTreeSet<&str> = p.functions.iter().map(|f| f.name.as_str()).collect();
    let mut out = Analysis::default();
    for f in &p.functions {
        let mut fa = FnAnalysis::default();
        // update-tuple/edge members are always available
        let mut props: BTreeSet<String> =
            ["source", "destination", "weight"].iter().map(|s| s.to_string()).collect();
        for param in &f.params {
            if let Type::PropNode(_) = param.ty {
                fa.node_props.insert(param.name.clone());
            }
            if matches!(param.ty, Type::PropNode(_) | Type::PropEdge(_)) {
                props.insert(param.name.clone());
            }
        }
        let mut ctx =
            Ctx { fa: &mut fa, known: &known, fn_kind: f.kind, props, in_batch: false };
        ctx.stmts(&f.body, 0)?;
        out.functions.insert(f.name.clone(), fa);
    }
    Ok(out)
}

struct Ctx<'a> {
    fa: &'a mut FnAnalysis,
    known: &'a BTreeSet<&'a str>,
    fn_kind: FnKind,
    /// property names visible so far (params + earlier declarations);
    /// member accesses against anything else are an error.
    props: BTreeSet<String>,
    in_batch: bool,
}

impl Ctx<'_> {
    fn stmts(&mut self, body: &[Stmt], forall_depth: usize) -> Result<()> {
        for s in body {
            self.stmt(s, forall_depth)?;
        }
        Ok(())
    }

    /// Error if `e` mentions a property (member access or
    /// `attachNodeProperty` keyword) that is not in scope.
    fn check_expr(&self, e: &Expr, span: Span) -> Result<()> {
        let mut mentioned = BTreeSet::new();
        collect_prop_mentions(e, &mut mentioned);
        for p in mentioned {
            if !self.props.contains(&p) {
                bail!("{span}: undefined property {p:?}");
            }
        }
        Ok(())
    }

    fn check_iter(&self, iter: &Iter, span: Span) -> Result<()> {
        match iter {
            Iter::Nodes { filter, .. } => {
                if let Some(f) = filter {
                    self.check_expr(f, span)?;
                }
            }
            Iter::Neighbors { of, filter, .. } => {
                self.check_expr(of, span)?;
                if let Some(f) = filter {
                    self.check_expr(f, span)?;
                }
            }
            Iter::NodesTo { of, .. } => self.check_expr(of, span)?,
            Iter::UpdateList(_) => {}
        }
        Ok(())
    }

    fn check_lvalue(&self, lv: &LValue, span: Span) -> Result<()> {
        if let LValue::Member { base, prop } = lv {
            if !self.props.contains(prop) {
                bail!("{span}: undefined property {prop:?}");
            }
            self.check_expr(base, span)?;
        }
        Ok(())
    }

    fn stmt(&mut self, s: &Stmt, forall_depth: usize) -> Result<()> {
        let span = s.span();
        match s {
            Stmt::Decl { ty, name, init, .. } => {
                if matches!(ty, Type::PropNode(_) | Type::PropEdge(_)) {
                    self.fa.node_props.insert(name.clone());
                    self.props.insert(name.clone());
                }
                if let Some(e) = init {
                    self.check_expr(e, span)?;
                }
            }
            Stmt::Batch { body, size, .. } => {
                if self.fn_kind != FnKind::Dynamic {
                    bail!(
                        "{span}: Batch construct is only allowed in Dynamic functions (§3.3.1)"
                    );
                }
                self.check_expr(size, span)?;
                let saved = self.in_batch;
                self.in_batch = true;
                self.stmts(body, forall_depth)?;
                self.in_batch = saved;
            }
            Stmt::OnAdd { body, .. } | Stmt::OnDelete { body, .. } => {
                if !self.in_batch {
                    bail!(
                        "{span}: OnAdd/OnDelete hooks are only allowed inside a Batch \
                         construct (§3.3.2)"
                    );
                }
                self.stmts(body, forall_depth)?;
            }
            Stmt::Forall { var, iter, body, .. } => {
                let mut info = ForallInfo {
                    reads: BTreeSet::new(),
                    writes: BTreeMap::new(),
                    reductions: BTreeSet::new(),
                    span,
                    depth: forall_depth,
                };
                Self::scan_forall(var, body, &mut info);
                if let Some(f) = iter_filter(iter) {
                    collect_props(f, &mut info.reads);
                }
                for p in info.writes.keys() {
                    self.fa.dirty_props.insert(p.clone());
                }
                self.fa.foralls.push(info);
                self.check_iter(iter, span)?;
                self.stmts(body, forall_depth + 1)?;
            }
            Stmt::For { iter, body, .. } => {
                self.check_iter(iter, span)?;
                self.stmts(body, forall_depth)?;
            }
            Stmt::FixedPoint { prop, body, .. } => {
                if !self.props.contains(prop) {
                    bail!("{span}: undefined property {prop:?} in fixedPoint condition");
                }
                self.stmts(body, forall_depth)?;
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                self.check_expr(cond, span)?;
                self.stmts(then_branch, forall_depth)?;
                self.stmts(else_branch, forall_depth)?;
            }
            Stmt::While { cond, body, .. } | Stmt::DoWhile { body, cond, .. } => {
                self.check_expr(cond, span)?;
                self.stmts(body, forall_depth)?
            }
            Stmt::Assign { lhs, rhs, .. } => {
                self.check_lvalue(lhs, span)?;
                self.check_expr(rhs, span)?;
            }
            Stmt::MinAssign { lhs, min_args, rest, .. } => {
                for lv in lhs {
                    self.check_lvalue(lv, span)?;
                }
                self.check_expr(&min_args.0, span)?;
                self.check_expr(&min_args.1, span)?;
                for e in rest {
                    self.check_expr(e, span)?;
                }
            }
            Stmt::Expr(e) => {
                if let Expr::Call { name, .. } = e {
                    if !self.known.contains(name.as_str()) {
                        bail!("call to unknown function {name:?}");
                    }
                }
                self.check_expr(e, span)?;
            }
            Stmt::Return(e) => self.check_expr(e, span)?,
        }
        Ok(())
    }

    /// Scan one forall body for read/write sets and sync requirements.
    fn scan_forall(loop_var: &str, body: &[Stmt], info: &mut ForallInfo) {
        for s in body {
            match s {
                Stmt::Assign { lhs, op, rhs, .. } => {
                    collect_props(rhs, &mut info.reads);
                    match lhs {
                        LValue::Member { base, prop } => {
                            let owner_writes = matches!(base, Expr::Var(v) if v == loop_var);
                            let sync = if owner_writes { Sync::None } else { Sync::Critical };
                            upgrade(&mut info.writes, prop, sync);
                        }
                        LValue::Var(v) => {
                            if *op != AssignOp::Set {
                                // scalar accumulated across iterations
                                info.reductions.insert(v.clone());
                            }
                        }
                    }
                }
                Stmt::MinAssign { lhs, min_args, rest, .. } => {
                    collect_props(&min_args.0, &mut info.reads);
                    collect_props(&min_args.1, &mut info.reads);
                    for e in rest {
                        collect_props(e, &mut info.reads);
                    }
                    for lv in lhs {
                        if let LValue::Member { prop, .. } = lv {
                            upgrade(&mut info.writes, prop, Sync::AtomicMin);
                        }
                    }
                }
                Stmt::If { cond, then_branch, else_branch, .. } => {
                    collect_props(cond, &mut info.reads);
                    Self::scan_forall(loop_var, then_branch, info);
                    Self::scan_forall(loop_var, else_branch, info);
                }
                Stmt::While { cond, body, .. } | Stmt::DoWhile { body, cond, .. } => {
                    collect_props(cond, &mut info.reads);
                    Self::scan_forall(loop_var, body, info);
                }
                // nested forall/for: writes through *their* loop vars are
                // races for the outer loop; keep scanning with the outer
                // loop var so `nbr.dist = …` is flagged.
                Stmt::Forall { body, iter, .. } | Stmt::For { body, iter, .. } => {
                    if let Some(f) = iter_filter(iter) {
                        collect_props(f, &mut info.reads);
                    }
                    Self::scan_forall(loop_var, body, info);
                }
                Stmt::Decl { init: Some(e), .. } => collect_props(e, &mut info.reads),
                Stmt::Expr(e) | Stmt::Return(e) => collect_props(e, &mut info.reads),
                Stmt::FixedPoint { body, .. } => Self::scan_forall(loop_var, body, info),
                _ => {}
            }
        }
    }
}

fn iter_filter(iter: &Iter) -> Option<&Expr> {
    match iter {
        Iter::Nodes { filter, .. } | Iter::Neighbors { filter, .. } => filter.as_ref(),
        _ => None,
    }
}

fn upgrade(map: &mut BTreeMap<String, Sync>, prop: &str, sync: Sync) {
    let cur = map.get(prop).copied().unwrap_or(Sync::None);
    let rank = |s: Sync| match s {
        Sync::None => 0,
        Sync::Reduction => 1,
        Sync::AtomicMin => 2,
        Sync::Critical => 3,
    };
    if rank(sync) >= rank(cur) {
        map.insert(prop.to_string(), sync);
    }
}

/// Like [`collect_props`], but also counts `attachNodeProperty(p = …)`
/// keyword names as property mentions — used for definedness checking.
fn collect_prop_mentions(e: &Expr, out: &mut BTreeSet<String>) {
    if let Expr::KwArg { name, value } = e {
        out.insert(name.clone());
        collect_prop_mentions(value, out);
        return;
    }
    collect_props(e, out);
    // descend for KwArgs nested under method calls
    if let Expr::MethodCall { args, .. } = e {
        for a in args {
            if let Expr::KwArg { name, .. } = a {
                out.insert(name.clone());
            }
        }
    }
}

/// Collect property names mentioned in an expression (member accesses).
fn collect_props(e: &Expr, out: &mut BTreeSet<String>) {
    match e {
        Expr::Member { base, prop } => {
            out.insert(prop.clone());
            collect_props(base, out);
        }
        Expr::MethodCall { base, args, .. } => {
            collect_props(base, out);
            for a in args {
                collect_props(a, out);
            }
        }
        Expr::Call { args, .. } => {
            for a in args {
                collect_props(a, out);
            }
        }
        Expr::Unary { expr, .. } => collect_props(expr, out),
        Expr::Binary { lhs, rhs, .. } => {
            collect_props(lhs, out);
            collect_props(rhs, out);
        }
        Expr::KwArg { value, .. } => collect_props(value, out),
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parse_program;

    fn sssp() -> Program {
        parse_program(&std::fs::read_to_string("dsl/sssp_dynamic.sp").unwrap()).unwrap()
    }

    #[test]
    fn min_assign_requires_atomic_min() {
        let p = sssp();
        let a = analyze(&p).unwrap();
        let f = &a.functions["staticSSSP"];
        // the outer forall over modified vertices writes dist via nbr →
        // AtomicMin
        let outer = f.foralls.iter().find(|fa| fa.depth == 0).unwrap();
        assert_eq!(outer.writes.get("dist"), Some(&Sync::AtomicMin));
        assert_eq!(outer.writes.get("modified_nxt"), Some(&Sync::AtomicMin));
        assert!(outer.reads.contains("weight"));
    }

    #[test]
    fn owner_writes_need_no_sync() {
        let p = sssp();
        let a = analyze(&p).unwrap();
        let dec = &a.functions["Decremental"];
        // phase-1 cascade writes v.dist with v the loop var → Sync::None
        let first = &dec.foralls[0];
        assert_eq!(first.writes.get("dist"), Some(&Sync::None));
        assert!(first.reads.contains("modified"), "parent flag is read");
    }

    #[test]
    fn tc_reduction_detected() {
        let p = parse_program(&std::fs::read_to_string("dsl/tc_dynamic.sp").unwrap()).unwrap();
        let a = analyze(&p).unwrap();
        let tc = &a.functions["staticTC"];
        let outer = tc.foralls.iter().find(|f| f.depth == 0).unwrap();
        assert!(outer.reductions.contains("triangle_count"), "scalar += is a reduction");
    }

    #[test]
    fn batch_outside_dynamic_rejected() {
        let src = "Static f(Graph g, updates<g> u) { Batch(u : 10) { int x = 0; } }";
        let p = parse_program(src).unwrap();
        assert!(analyze(&p).is_err());
    }

    #[test]
    fn unknown_call_rejected() {
        let src = "Static f(Graph g) { mystery(g); }";
        let p = parse_program(src).unwrap();
        assert!(analyze(&p).is_err());
    }

    #[test]
    fn undefined_property_has_positioned_message() {
        let src = "Static f(Graph g, propNode<int> dist) {\n  forall (v in g.nodes()) {\n    v.distt = 0;\n  }\n}";
        let p = parse_program(src).unwrap();
        let err = analyze(&p).unwrap_err().to_string();
        assert!(err.contains("undefined property \"distt\""), "names the property: {err}");
        assert!(err.contains("line 3:"), "points at the statement: {err}");
    }

    #[test]
    fn on_add_outside_batch_rejected() {
        let src = "Dynamic D(Graph g, updates<g> u, int batchSize) {\n  OnAdd (e in u.currentBatch()) {\n    int x = 0;\n  }\n  Batch(u : batchSize) { int y = 0; }\n}";
        let p = parse_program(src).unwrap();
        let err = analyze(&p).unwrap_err().to_string();
        assert!(err.contains("inside a Batch"), "explains the constraint: {err}");
        assert!(err.contains("line 2:"), "points at the hook: {err}");
    }

    #[test]
    fn dirty_props_feed_transfer_plan() {
        let p = sssp();
        let a = analyze(&p).unwrap();
        let inc = &a.functions["Incremental"];
        assert!(inc.dirty_props.contains("dist"));
        assert!(inc.dirty_props.contains("parent"));
    }
}
