//! Reference interpreter: *executes* StarPlat Dynamic programs over the
//! diff-CSR substrate.
//!
//! This is the semantic ground truth for the code generators: the
//! `dsl/*.sp` programs run here and their results are asserted equal to
//! the hand-written reference algorithms (tests below) and to the
//! parallel backends. It plays the role of StarPlat's "generated serial
//! code" — same AST, no parallel scheduling.

use super::ast::*;
use crate::algorithms::sssp::INF;
use crate::graph::updates::{Batch as GBatch, UpdateKind, UpdateStream};
use crate::graph::{DynGraph, NodeId};
use crate::util::error::{anyhow, bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Runtime values.
#[derive(Debug, Clone)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    /// an edge handle `(src, dst)`
    Edge(i64, i64),
    /// one update record (bound by OnAdd/OnDelete/forall-over-updates)
    Update { src: i64, dst: i64, weight: i64 },
    /// a shared node-property array
    NodeProp(Rc<RefCell<Vec<Value>>>),
    /// an updates list (subset view of the stream)
    Updates(Rc<Vec<(i64, i64, i64)>>),
    Unit,
}

impl Value {
    fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::Float(v) => Ok(*v as i64),
            Value::Bool(b) => Ok(*b as i64),
            other => bail!("expected int, got {other:?}"),
        }
    }

    fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(v) => Ok(*v as f64),
            Value::Float(v) => Ok(*v),
            other => bail!("expected number, got {other:?}"),
        }
    }

    fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            Value::Int(v) => Ok(*v != 0),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    fn truthy_default(ty: &Type) -> Value {
        match ty {
            Type::Bool => Value::Bool(false),
            Type::Float | Type::Double => Value::Float(0.0),
            _ => Value::Int(0),
        }
    }
}

enum Flow {
    Normal,
    Return(Value),
}

/// The interpreter: owns the graph and the update stream context.
pub struct Interp<'p> {
    program: &'p Program,
    pub graph: DynGraph,
    stream: Option<UpdateStream>,
    /// current batch bounds during `Batch` execution
    cur_batch: Option<(usize, usize)>,
    /// iteration guard for fixedPoint/while loops
    max_sweeps: usize,
}

struct Env {
    scopes: Vec<HashMap<String, Value>>,
    /// current filter subject (bare property names resolve against it)
    subject: Option<i64>,
}

impl Env {
    fn new() -> Self {
        Env { scopes: vec![HashMap::new()], subject: None }
    }

    fn push(&mut self) {
        self.scopes.push(HashMap::new());
    }

    fn pop(&mut self) {
        self.scopes.pop();
    }

    fn get(&self, name: &str) -> Option<&Value> {
        self.scopes.iter().rev().find_map(|s| s.get(name))
    }

    fn set_new(&mut self, name: &str, v: Value) {
        // a popped-to-empty scope stack is a bug elsewhere, but it must
        // not abort the process — recover with a fresh scope
        if self.scopes.is_empty() {
            self.scopes.push(HashMap::new());
        }
        if let Some(scope) = self.scopes.last_mut() {
            scope.insert(name.to_string(), v);
        }
    }

    fn assign(&mut self, name: &str, v: Value) -> Result<()> {
        for s in self.scopes.iter_mut().rev() {
            if let Some(slot) = s.get_mut(name) {
                *slot = v;
                return Ok(());
            }
        }
        bail!("assignment to undeclared variable {name:?}")
    }
}

impl<'p> Interp<'p> {
    pub fn new(program: &'p Program, graph: DynGraph) -> Self {
        let n = graph.num_nodes();
        Interp {
            program,
            graph,
            stream: None,
            cur_batch: None,
            max_sweeps: n * 8 + 256,
        }
    }

    /// Run a `Dynamic` driver: binds the graph, the update stream, and
    /// scalar arguments positionally (Graph/updates/prop params are
    /// created automatically). Returns (return value, node props).
    pub fn run_dynamic(
        &mut self,
        name: &str,
        stream: UpdateStream,
        scalars: &[(&str, Value)],
    ) -> Result<(Value, HashMap<String, Vec<Value>>)> {
        self.stream = Some(stream);
        self.run_inner(name, scalars)
    }

    /// Run a driver with NO update stream attached. `Batch` blocks (and
    /// the hooks inside them) report a typed error instead of executing
    /// — useful for validating a program against a graph without
    /// fabricating updates.
    pub fn run_static(
        &mut self,
        name: &str,
        scalars: &[(&str, Value)],
    ) -> Result<(Value, HashMap<String, Vec<Value>>)> {
        self.stream = None;
        self.run_inner(name, scalars)
    }

    fn run_inner(
        &mut self,
        name: &str,
        scalars: &[(&str, Value)],
    ) -> Result<(Value, HashMap<String, Vec<Value>>)> {
        let f = self
            .program
            .find(name)
            .ok_or_else(|| anyhow!("no function {name:?}"))?
            .clone();
        let n = self.graph.num_nodes();
        let mut env = Env::new();
        let mut props: Vec<(String, Rc<RefCell<Vec<Value>>>)> = Vec::new();
        for p in &f.params {
            match &p.ty {
                Type::Graph | Type::Updates | Type::PropEdge(_) => {
                    env.set_new(&p.name, Value::Unit) // resolved natively
                }
                Type::PropNode(inner) => {
                    let arr = Rc::new(RefCell::new(vec![
                        Value::truthy_default(inner);
                        n
                    ]));
                    props.push((p.name.clone(), Rc::clone(&arr)));
                    env.set_new(&p.name, Value::NodeProp(arr));
                }
                _ => {
                    let v = scalars
                        .iter()
                        .find(|(k, _)| k == &p.name)
                        .map(|(_, v)| v.clone())
                        .ok_or_else(|| anyhow!("missing scalar argument {:?}", p.name))?;
                    env.set_new(&p.name, v);
                }
            }
        }
        let flow = self.exec_block(&f.body, &mut env)?;
        let ret = match flow {
            Flow::Return(v) => v,
            Flow::Normal => Value::Unit,
        };
        let out = props
            .into_iter()
            .map(|(k, v)| (k, v.borrow().clone()))
            .collect();
        Ok((ret, out))
    }

    // ------------------------------------------------------ statements

    fn exec_block(&mut self, body: &[Stmt], env: &mut Env) -> Result<Flow> {
        for s in body {
            if let Flow::Return(v) = self.exec(s, env)? {
                return Ok(Flow::Return(v));
            }
        }
        Ok(Flow::Normal)
    }

    fn exec(&mut self, s: &Stmt, env: &mut Env) -> Result<Flow> {
        match s {
            Stmt::Decl { ty, name, init, .. } => {
                let v = match (ty, init) {
                    (Type::PropNode(inner), _) => {
                        let n = self.graph.num_nodes();
                        Value::NodeProp(Rc::new(RefCell::new(vec![
                            Value::truthy_default(inner);
                            n
                        ])))
                    }
                    (_, Some(e)) => self.eval(e, env)?,
                    (t, None) => Value::truthy_default(t),
                };
                env.set_new(name, v);
            }
            Stmt::Assign { lhs, op, rhs, .. } => {
                let rv = self.eval(rhs, env)?;
                self.assign(lhs, *op, rv, env)?;
            }
            Stmt::MinAssign { lhs, min_args, rest, .. } => {
                let cur = self.eval(&min_args.0, env)?;
                let cand = self.eval(&min_args.1, env)?;
                let fire = match (&cur, &cand) {
                    (Value::Float(a), _) | (_, Value::Float(a)) => {
                        let _ = a;
                        cand.as_f64()? < cur.as_f64()?
                    }
                    _ => cand.as_int()? < cur.as_int()?,
                };
                if fire {
                    self.assign(&lhs[0], AssignOp::Set, cand, env)?;
                    for (lv, e) in lhs[1..].iter().zip(rest) {
                        let v = self.eval(e, env)?;
                        self.assign(lv, AssignOp::Set, v, env)?;
                    }
                }
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                if self.eval(cond, env)?.as_bool()? {
                    env.push();
                    let f = self.exec_block(then_branch, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                } else {
                    env.push();
                    let f = self.exec_block(else_branch, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                }
            }
            Stmt::While { cond, body, .. } => {
                let mut sweeps = 0;
                while self.eval(cond, env)?.as_bool()? {
                    env.push();
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                    sweeps += 1;
                    if sweeps > self.max_sweeps {
                        bail!("while loop exceeded {} sweeps (diverging?)", self.max_sweeps);
                    }
                }
            }
            Stmt::DoWhile { body, cond, .. } => {
                let mut sweeps = 0;
                loop {
                    env.push();
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                    if !self.eval(cond, env)?.as_bool()? {
                        break;
                    }
                    sweeps += 1;
                    if sweeps > self.max_sweeps {
                        bail!("do-while exceeded {} sweeps", self.max_sweeps);
                    }
                }
            }
            Stmt::Forall { var, iter, body, .. } | Stmt::For { var, iter, body, .. } => {
                let items = self.iter_items(iter, env)?;
                for item in items {
                    env.push();
                    env.set_new(var, item);
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                }
            }
            Stmt::FixedPoint { prop, body, .. } => {
                let mut sweeps = 0;
                loop {
                    env.push();
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                    // converged when no vertex has `prop` set
                    let any = match env.get(prop) {
                        Some(Value::NodeProp(arr)) => {
                            arr.borrow().iter().any(|v| matches!(v, Value::Bool(true)))
                        }
                        _ => bail!("fixedPoint condition property {prop:?} not found"),
                    };
                    if !any {
                        break;
                    }
                    sweeps += 1;
                    if sweeps > self.max_sweeps {
                        bail!("fixedPoint exceeded {} sweeps", self.max_sweeps);
                    }
                }
            }
            Stmt::Batch { size, body, .. } => {
                let Some(stream) = self.stream.as_ref() else {
                    bail!(
                        "{}: Batch block requires an update stream (run via run_dynamic, \
                         not run_static)",
                        s.span()
                    );
                };
                let size = self.eval(size, env)?.as_int()?.max(1) as usize;
                let total = stream.len();
                let mut start = 0;
                while start < total {
                    let end = (start + size).min(total);
                    self.cur_batch = Some((start, end));
                    env.push();
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    self.cur_batch = None;
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                    start = end;
                }
            }
            Stmt::OnAdd { var, body, .. } => {
                for u in self.batch_updates(UpdateKind::Add)? {
                    env.push();
                    env.set_new(var, u);
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                }
            }
            Stmt::OnDelete { var, body, .. } => {
                for u in self.batch_updates(UpdateKind::Delete)? {
                    env.push();
                    env.set_new(var, u);
                    let f = self.exec_block(body, env)?;
                    env.pop();
                    if let Flow::Return(v) = f {
                        return Ok(Flow::Return(v));
                    }
                }
            }
            Stmt::Return(e) => {
                let v = self.eval(e, env)?;
                return Ok(Flow::Return(v));
            }
            Stmt::Expr(e) => {
                self.eval(e, env)?;
            }
        }
        Ok(Flow::Normal)
    }

    fn batch_updates(&self, kind: UpdateKind) -> Result<Vec<Value>> {
        let (lo, hi) = self.cur_batch.ok_or_else(|| anyhow!("OnAdd/OnDelete outside Batch"))?;
        let stream = self
            .stream
            .as_ref()
            .ok_or_else(|| anyhow!("OnAdd/OnDelete requires an update stream"))?;
        Ok(stream.updates[lo..hi]
            .iter()
            .filter(|u| u.kind == kind)
            .map(|u| Value::Update {
                src: u.src as i64,
                dst: u.dst as i64,
                weight: u.weight as i64,
            })
            .collect())
    }

    fn current_gbatch(&self) -> Result<GBatch<'_>> {
        let (lo, hi) = self.cur_batch.ok_or_else(|| anyhow!("no current batch"))?;
        let stream = self
            .stream
            .as_ref()
            .ok_or_else(|| anyhow!("batch access requires an update stream"))?;
        Ok(GBatch { updates: &stream.updates[lo..hi] })
    }

    // ------------------------------------------------------ iteration

    fn iter_items(&mut self, iter: &Iter, env: &mut Env) -> Result<Vec<Value>> {
        match iter {
            Iter::Nodes { filter, .. } => {
                let n = self.graph.num_nodes();
                let mut out = Vec::new();
                for v in 0..n as i64 {
                    if let Some(f) = filter {
                        if !self.eval_filter(f, v, env)? {
                            continue;
                        }
                    }
                    out.push(Value::Int(v));
                }
                Ok(out)
            }
            Iter::Neighbors { of, filter, .. } => {
                let v = self.eval(of, env)?.as_int()?;
                let nbrs: Vec<i64> = self
                    .graph
                    .out_neighbors(v as NodeId)
                    .map(|(nbr, _)| nbr as i64)
                    .collect();
                let mut out = Vec::new();
                for nbr in nbrs {
                    if let Some(f) = filter {
                        if !self.eval_filter(f, nbr, env)? {
                            continue;
                        }
                    }
                    out.push(Value::Int(nbr));
                }
                Ok(out)
            }
            Iter::NodesTo { of, .. } => {
                let v = self.eval(of, env)?.as_int()?;
                Ok(self
                    .graph
                    .in_neighbors(v as NodeId)
                    .map(|(nbr, _)| Value::Int(nbr as i64))
                    .collect())
            }
            Iter::UpdateList(name) => match env.get(name) {
                Some(Value::Updates(list)) => Ok(list
                    .iter()
                    .map(|&(s, d, w)| Value::Update { src: s, dst: d, weight: w })
                    .collect()),
                other => bail!("{name:?} is not an updates list (got {other:?})"),
            },
        }
    }

    /// Evaluate a filter with `subject` as the candidate: bare property
    /// names resolve against the subject (`filter(modified == True)`),
    /// and the loop variable itself is bound via `subject` too
    /// (`filter(u < v)` binds `u`).
    fn eval_filter(&mut self, f: &Expr, subject: i64, env: &mut Env) -> Result<bool> {
        let saved = env.subject;
        env.subject = Some(subject);
        let r = self.eval(f, env).and_then(|v| v.as_bool());
        env.subject = saved;
        r
    }

    // ------------------------------------------------------ assignment

    fn assign(&mut self, lhs: &LValue, op: AssignOp, rv: Value, env: &mut Env) -> Result<()> {
        match lhs {
            LValue::Var(name) => {
                // whole-property copy: `modified = modified_nxt`
                if let (Some(Value::NodeProp(dst)), Value::NodeProp(src)) =
                    (env.get(name), &rv)
                {
                    let src = src.borrow().clone();
                    *dst.borrow_mut() = src;
                    return Ok(());
                }
                let new = match op {
                    AssignOp::Set => rv,
                    AssignOp::Add | AssignOp::Sub => {
                        let cur = env
                            .get(name)
                            .ok_or_else(|| anyhow!("undeclared {name:?}"))?
                            .clone();
                        numeric_binop(
                            if op == AssignOp::Add { BinOp::Add } else { BinOp::Sub },
                            &cur,
                            &rv,
                        )?
                    }
                };
                env.assign(name, new)
            }
            LValue::Member { base, prop } => {
                let id = self.eval(base, env)?.as_int()?;
                if id < 0 {
                    bail!("property write through negative node id");
                }
                let arr = match env.get(prop) {
                    Some(Value::NodeProp(a)) => Rc::clone(a),
                    other => bail!("unknown node property {prop:?} (got {other:?})"),
                };
                let mut arr = arr.borrow_mut();
                let slot = arr
                    .get_mut(id as usize)
                    .ok_or_else(|| anyhow!("node id {id} out of range"))?;
                let new = match op {
                    AssignOp::Set => rv,
                    AssignOp::Add => numeric_binop(BinOp::Add, slot, &rv)?,
                    AssignOp::Sub => numeric_binop(BinOp::Sub, slot, &rv)?,
                };
                *slot = new;
                Ok(())
            }
        }
    }

    // ------------------------------------------------------ expressions

    fn eval(&mut self, e: &Expr, env: &mut Env) -> Result<Value> {
        match e {
            Expr::IntLit(v) => Ok(Value::Int(*v)),
            Expr::FloatLit(v) => Ok(Value::Float(*v)),
            Expr::BoolLit(b) => Ok(Value::Bool(*b)),
            Expr::Inf => Ok(Value::Int(INF)),
            Expr::Var(name) => {
                if let Some(v) = env.get(name) {
                    // bare property name inside a filter → subject.prop
                    if let (Value::NodeProp(arr), Some(subj)) = (v, env.subject) {
                        return Ok(arr
                            .borrow()
                            .get(subj as usize)
                            .cloned()
                            .ok_or_else(|| anyhow!("filter subject {subj} out of range"))?);
                    }
                    return Ok(v.clone());
                }
                // the loop candidate itself inside a filter (`filter(u < v)`
                // evaluates before `u` is bound — `u` is the subject)
                if let Some(subj) = env.subject {
                    return Ok(Value::Int(subj));
                }
                bail!("unknown identifier {name:?}")
            }
            Expr::Member { base, prop } => self.eval_member(base, prop, env),
            Expr::MethodCall { base, method, args } => {
                self.eval_method(base, method, args, env)
            }
            Expr::Call { name, args } => self.eval_call(name, args, env),
            Expr::Unary { op, expr } => {
                let v = self.eval(expr, env)?;
                Ok(match op {
                    UnOp::Not => Value::Bool(!v.as_bool()?),
                    UnOp::Neg => match v {
                        Value::Float(f) => Value::Float(-f),
                        other => Value::Int(-other.as_int()?),
                    },
                })
            }
            Expr::Binary { op, lhs, rhs } => {
                // short-circuit logicals
                match op {
                    BinOp::And => {
                        return Ok(Value::Bool(
                            self.eval(lhs, env)?.as_bool()?
                                && self.eval(rhs, env)?.as_bool()?,
                        ))
                    }
                    BinOp::Or => {
                        return Ok(Value::Bool(
                            self.eval(lhs, env)?.as_bool()?
                                || self.eval(rhs, env)?.as_bool()?,
                        ))
                    }
                    _ => {}
                }
                let a = self.eval(lhs, env)?;
                let b = self.eval(rhs, env)?;
                numeric_binop(*op, &a, &b)
            }
            Expr::KwArg { .. } => bail!("keyword argument outside attachNodeProperty"),
        }
    }

    fn eval_member(&mut self, base: &Expr, prop: &str, env: &mut Env) -> Result<Value> {
        let bv = self.eval(base, env)?;
        match (&bv, prop) {
            (Value::Update { src, .. }, "source") => Ok(Value::Int(*src)),
            (Value::Update { dst, .. }, "destination") => Ok(Value::Int(*dst)),
            (Value::Update { weight, .. }, "weight") => Ok(Value::Int(*weight)),
            (Value::Edge(u, v), "weight") => {
                let w = self
                    .graph
                    .edge_weight(*u as NodeId, *v as NodeId)
                    .ok_or_else(|| anyhow!("edge {u}->{v} not in graph"))?;
                Ok(Value::Int(w as i64))
            }
            (_, prop) => {
                let id = bv.as_int()?;
                if id < 0 {
                    bail!("property read through negative node id {id}");
                }
                match env.get(prop) {
                    Some(Value::NodeProp(arr)) => Ok(arr
                        .borrow()
                        .get(id as usize)
                        .cloned()
                        .ok_or_else(|| anyhow!("node {id} out of range"))?),
                    other => bail!("unknown property {prop:?} (got {other:?})"),
                }
            }
        }
    }

    fn eval_method(
        &mut self,
        base: &Expr,
        method: &str,
        args: &[Expr],
        env: &mut Env,
    ) -> Result<Value> {
        // updates-list methods
        if let Expr::Var(name) = base {
            if let Some(Value::Updates(list)) = env.get(name) {
                let list = Rc::clone(list);
                match method {
                    "contains" => {
                        let u = self.eval(&args[0], env)?.as_int()?;
                        let v = self.eval(&args[1], env)?.as_int()?;
                        return Ok(Value::Bool(
                            list.iter().any(|&(s, d, _)| {
                                (s == u && d == v) || (s == v && d == u)
                            }),
                        ));
                    }
                    other => bail!("unknown updates method {other:?}"),
                }
            }
        }
        // stream-level: updateBatch.currentBatch(k)
        if method == "currentBatch" {
            let k = if args.is_empty() {
                -1
            } else {
                self.eval(&args[0], env)?.as_int()?
            };
            let b = self.current_gbatch()?;
            let list: Vec<(i64, i64, i64)> = b
                .updates
                .iter()
                .filter(|u| match k {
                    0 => u.kind == UpdateKind::Delete,
                    1 => u.kind == UpdateKind::Add,
                    _ => true,
                })
                .map(|u| (u.src as i64, u.dst as i64, u.weight as i64))
                .collect();
            return Ok(Value::Updates(Rc::new(list)));
        }
        // graph methods (base must be the Graph param)
        match method {
            "num_nodes" => Ok(Value::Int(self.graph.num_nodes() as i64)),
            "num_edges" => Ok(Value::Int(self.graph.num_edges() as i64)),
            "count_outNbrs" => {
                let v = self.eval(&args[0], env)?.as_int()?;
                Ok(Value::Int(self.graph.out_degree(v as NodeId) as i64))
            }
            "is_an_edge" => {
                let u = self.eval(&args[0], env)?.as_int()?;
                let v = self.eval(&args[1], env)?.as_int()?;
                Ok(Value::Bool(self.graph.has_edge(u as NodeId, v as NodeId)))
            }
            "get_edge" => {
                let u = self.eval(&args[0], env)?.as_int()?;
                let v = self.eval(&args[1], env)?.as_int()?;
                Ok(Value::Edge(u, v))
            }
            "attachNodeProperty" => {
                for a in args {
                    let Expr::KwArg { name, value } = a else {
                        bail!("attachNodeProperty takes prop = value arguments");
                    };
                    let fill = self.eval(value, env)?;
                    let arr = match env.get(name) {
                        Some(Value::NodeProp(arr)) => Rc::clone(arr),
                        other => bail!("attach of unknown property {name:?} ({other:?})"),
                    };
                    let n = self.graph.num_nodes();
                    *arr.borrow_mut() = vec![fill; n];
                }
                Ok(Value::Unit)
            }
            "attachEdgeProperty" => Ok(Value::Unit), // edge flags handled via contains()
            "updateCSRDel" => {
                let b = self.current_gbatch()?;
                let dels: Vec<_> = b.deletions().collect();
                self.graph.apply_deletions(&dels);
                Ok(Value::Unit)
            }
            "updateCSRAdd" => {
                let b = self.current_gbatch()?;
                let adds: Vec<_> = b.additions().collect();
                self.graph.apply_additions(&adds);
                Ok(Value::Unit)
            }
            "propagateNodeFlags" => {
                let Expr::Var(pname) = &args[0] else {
                    bail!("propagateNodeFlags takes a property name");
                };
                let arr = match env.get(pname) {
                    Some(Value::NodeProp(arr)) => Rc::clone(arr),
                    other => bail!("unknown property {pname:?} ({other:?})"),
                };
                let mut flags: Vec<bool> = arr
                    .borrow()
                    .iter()
                    .map(|v| matches!(v, Value::Bool(true)))
                    .collect();
                crate::algorithms::pagerank::propagate_node_flags(&self.graph, &mut flags);
                *arr.borrow_mut() = flags.into_iter().map(Value::Bool).collect();
                Ok(Value::Unit)
            }
            other => bail!("unknown graph method {other:?}"),
        }
    }

    fn eval_call(&mut self, name: &str, args: &[Expr], env: &mut Env) -> Result<Value> {
        let f = self
            .program
            .find(name)
            .ok_or_else(|| anyhow!("call to unknown function {name:?}"))?
            .clone();
        if f.params.len() != args.len() {
            bail!("{name}: expected {} args, got {}", f.params.len(), args.len());
        }
        let mut callee_env = Env::new();
        for (p, a) in f.params.iter().zip(args) {
            let v = match p.ty {
                // Graph and propEdge resolve natively inside the callee
                Type::Graph | Type::PropEdge(_) => Value::Unit,
                _ => self.eval(a, env)?,
            };
            callee_env.set_new(&p.name, v);
        }
        match self.exec_block(&f.body, &mut callee_env)? {
            Flow::Return(v) => Ok(v),
            Flow::Normal => Ok(Value::Unit),
        }
    }
}

fn numeric_binop(op: BinOp, a: &Value, b: &Value) -> Result<Value> {
    use BinOp::*;
    let float = matches!(a, Value::Float(_)) || matches!(b, Value::Float(_));
    if float {
        let (x, y) = (a.as_f64()?, b.as_f64()?);
        Ok(match op {
            Add => Value::Float(x + y),
            Sub => Value::Float(x - y),
            Mul => Value::Float(x * y),
            Div => Value::Float(x / y),
            Mod => Value::Float(x % y),
            Lt => Value::Bool(x < y),
            Gt => Value::Bool(x > y),
            Le => Value::Bool(x <= y),
            Ge => Value::Bool(x >= y),
            Eq => Value::Bool(x == y),
            Ne => Value::Bool(x != y),
            And | Or => bail!("logical op on floats"),
        })
    } else {
        let (x, y) = (a.as_int()?, b.as_int()?);
        Ok(match op {
            Add => Value::Int(x + y),
            Sub => Value::Int(x - y),
            Mul => Value::Int(x * y),
            Div => {
                if y == 0 {
                    bail!("division by zero");
                }
                Value::Int(x / y)
            }
            Mod => Value::Int(x % y),
            Lt => Value::Bool(x < y),
            Gt => Value::Bool(x > y),
            Le => Value::Bool(x <= y),
            Ge => Value::Bool(x >= y),
            Eq => Value::Bool(x == y),
            Ne => Value::Bool(x != y),
            And => Value::Bool(x != 0 && y != 0),
            Or => Value::Bool(x != 0 || y != 0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{pagerank, sssp, triangle};
    use crate::graph::generators;
    use crate::graph::updates::Update;

    fn load(name: &str) -> Program {
        crate::dsl::parse_program(&std::fs::read_to_string(name).unwrap()).unwrap()
    }

    fn prop_ints(props: &HashMap<String, Vec<Value>>, name: &str) -> Vec<i64> {
        props[name].iter().map(|v| v.as_int().unwrap()).collect()
    }

    #[test]
    fn dsl_dynamic_sssp_matches_hand_written_oracle() {
        let program = load("dsl/sssp_dynamic.sp");
        let g0 = generators::uniform_random(60, 260, 9, 91);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 8, 9, 92);

        let mut interp = Interp::new(&program, g0.clone());
        let (_, props) = interp
            .run_dynamic(
                "DynSSSP",
                stream.clone(),
                &[("batchSize", Value::Int(8)), ("src", Value::Int(0))],
            )
            .unwrap();
        let dist = prop_ints(&props, "dist");

        // ground truth: dijkstra on fully-updated graph
        let mut g2 = g0.clone();
        stream.apply_all_static(&mut g2);
        let want = sssp::dijkstra_oracle(&g2, 0);
        assert_eq!(dist, want, "DSL-interpreted DynSSSP != oracle");
        // and the interpreter's graph must equal the statically-updated one
        assert_eq!(interp.graph.edges_sorted(), g2.edges_sorted());
    }

    #[test]
    fn dsl_static_sssp_alone_matches() {
        let program = load("dsl/sssp_dynamic.sp");
        let g0 = generators::road_grid(7, 7, 9, 93);
        let stream = UpdateStream::new(vec![], 8); // no updates
        let mut interp = Interp::new(&program, g0.clone());
        let (_, props) = interp
            .run_dynamic(
                "DynSSSP",
                stream,
                &[("batchSize", Value::Int(8)), ("src", Value::Int(3))],
            )
            .unwrap();
        assert_eq!(prop_ints(&props, "dist"), sssp::dijkstra_oracle(&g0, 3));
    }

    #[test]
    fn dsl_dynamic_pagerank_tracks_reference_pipeline() {
        let program = load("dsl/pagerank_dynamic.sp");
        let g0 = generators::rmat(6, 220, 0.5, 0.2, 0.2, 94);
        let n = g0.num_nodes();
        let stream = UpdateStream::generate_percent(&g0, 6.0, 16, 9, 95);

        let mut interp = Interp::new(&program, g0.clone());
        let (_, props) = interp
            .run_dynamic(
                "DynPR",
                stream.clone(),
                &[
                    ("beta", Value::Float(1e-9)),
                    ("delta", Value::Float(0.85)),
                    ("maxIter", Value::Int(100)),
                    ("batchSize", Value::Int(16)),
                ],
            )
            .unwrap();
        let got: Vec<f64> = props["pageRank"].iter().map(|v| v.as_f64().unwrap()).collect();

        // reference: same pipeline, hand-written
        let mut g = g0.clone();
        let mut st = pagerank::PrState::new(n, 1e-9, 0.85, 100);
        pagerank::static_pagerank(&g, &mut st);
        for b in stream.batches() {
            pagerank::dynamic_batch(&mut g, &mut st, &b);
        }
        let l1: f64 = got.iter().zip(&st.rank).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-6, "DSL PR drifted from reference pipeline: l1={l1}");
    }

    #[test]
    fn dsl_dynamic_tc_matches_recount() {
        let program = load("dsl/tc_dynamic.sp");
        let g0 = triangle::symmetrize(&generators::uniform_random(30, 160, 5, 96));
        // symmetric updates: both arcs adjacent in the stream
        let (dels, adds) = triangle::symmetric_updates(&g0, 14.0, 4, 97);
        let mut upd = Vec::new();
        for (db, ab) in dels.iter().zip(&adds) {
            for &(u, v) in db {
                upd.push(Update { kind: UpdateKind::Delete, src: u, dst: v, weight: 1 });
            }
            for &(u, v, w) in ab {
                upd.push(Update { kind: UpdateKind::Add, src: u, dst: v, weight: w });
            }
        }
        let total = upd.len().max(1);
        let stream = UpdateStream::new(upd, total); // one batch per everything
        let mut interp = Interp::new(&program, g0.clone());
        let (ret, _) = interp
            .run_dynamic("DynTC", stream, &[("batchSize", Value::Int(total as i64))])
            .unwrap();
        let got = ret.as_int().unwrap();
        let want = triangle::static_tc(&interp.graph).triangles;
        assert_eq!(got, want, "DSL delta TC != recount on updated graph");
    }

    #[test]
    fn dsl_static_tc_counts_correctly() {
        let program = load("dsl/tc_dynamic.sp");
        let g = triangle::symmetrize(&generators::uniform_random(25, 120, 5, 98));
        let stream = UpdateStream::new(vec![], 4);
        let mut interp = Interp::new(&program, g.clone());
        let (ret, _) =
            interp.run_dynamic("DynTC", stream, &[("batchSize", Value::Int(4))]).unwrap();
        assert_eq!(ret.as_int().unwrap(), triangle::static_tc(&g).triangles);
    }

    #[test]
    fn dsl_dynamic_bfs_matches_hand_written() {
        let program = load("dsl/bfs_dynamic.sp");
        let g0 = generators::uniform_random(50, 180, 3, 99);
        let stream = UpdateStream::generate_percent(&g0, 10.0, 8, 3, 100);
        let mut interp = Interp::new(&program, g0.clone());
        let (_, props) = interp
            .run_dynamic(
                "DynBFS",
                stream.clone(),
                &[("batchSize", Value::Int(8)), ("src", Value::Int(0))],
            )
            .unwrap();
        let levels = prop_ints(&props, "level");
        let mut g2 = g0.clone();
        stream.apply_all_static(&mut g2);
        let want = crate::algorithms::bfs::static_bfs(&g2, 0);
        // DSL INF vs algorithms UNREACHED are the same constant (i64::MAX/4)
        assert_eq!(levels, want.level, "DSL DynBFS != hand-written BFS");
    }

    #[test]
    fn interp_rejects_unknown_property() {
        let src = "Dynamic f(Graph g, updates<g> u, int batchSize) { forall (v in g.nodes()) { v.ghost = 1; } }";
        let program = crate::dsl::parse_program(src).unwrap();
        let g = generators::uniform_random(5, 8, 3, 1);
        let mut interp = Interp::new(&program, g);
        let err = interp
            .run_dynamic("f", UpdateStream::new(vec![], 1), &[("batchSize", Value::Int(1))])
            .unwrap_err();
        assert!(err.to_string().contains("ghost"));
    }

    #[test]
    fn batch_without_stream_is_a_typed_error() {
        // run_static attaches no stream; reaching the Batch block must be
        // a typed error, not a panic on `stream.unwrap()`.
        let program = load("dsl/sssp_dynamic.sp");
        let g = generators::uniform_random(10, 30, 5, 7);
        let mut interp = Interp::new(&program, g);
        let err = interp
            .run_static(
                "DynSSSP",
                &[("batchSize", Value::Int(4)), ("src", Value::Int(0))],
            )
            .unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("update stream"),
            "expected typed stream error, got: {msg}"
        );
    }
}
