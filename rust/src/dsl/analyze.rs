//! Static race & effect analysis over the bytecode IR.
//!
//! [`certify`] runs between `lower` and `verify`: it walks every
//! [`Instr::Par`] region of a lowered [`Program`] and
//!
//! 1. computes **effect summaries** — per property, the read/write sets
//!    classified by access shape (owner-local `v.prop`, neighbor
//!    `nbr.prop`, edge-endpoint src/dst registers, loop-uniform
//!    registers, indirect pointer chains like `v.parent.modified`);
//! 2. runs **race detection** over cross-iteration write-write and
//!    read-write conflicts, admitting exactly the shapes the executor
//!    makes deterministic — owner-disjoint stores, slot-folded
//!    accumulator reductions, monotone CAS-min relaxations with
//!    idempotent-constant or repair-covered companions — and rejecting
//!    everything else with a `line:col`-spanned, coded diagnostic;
//! 3. infers the **synchronization** the lowerer used to hand-pattern
//!    match: the `(dist, parent)` pairs needing a deterministic
//!    [`Instr::RepairParents`] at the segment tails are derived here
//!    ([`infer_repairs`]) from the relax shape in the IR, not from AST
//!    pattern matching in the lowerer;
//! 4. emits a [`ProgramFacts`] **certificate** (per-loop sync
//!    annotations, determinism verdict incl. f64 fold-order safety,
//!    batch-segment monotonicity, dead-property and unreachable-code
//!    reports, lint diagnostics) that travels with the compiled program
//!    and drives per-program backend admission — `run_program` on a
//!    backend without a bytecode executor explains *which* construct
//!    blocks it instead of a blanket capability bit.
//!
//! Diagnostic codes (errors reject the program; lints are warnings):
//!
//! * `R001` — plain store through a non-owner index in a parallel loop
//!   (cross-iteration write-write race).
//! * `R002` — CAS-min companion write that is neither an idempotent
//!   constant nor the relax source covered by a parent repair
//!   (non-monotone companion; its final value would be schedule-dependent).
//! * `R003` — cross-iteration read of a property whose writes in the
//!   same loop are neither all monotone CAS-min nor all identical
//!   constants (read-after-racy-write).
//! * `R004` — plain stores and CAS-min mixed on one property in one
//!   loop (the store races the relax).
//! * `L001` (lint) — property read in the batch segment but never
//!   written by `Init` or a prior batch statement (it silently reads
//!   the zero-fill from state creation).

use crate::dsl::ast::Span;
use crate::dsl::bytecode::{
    AccumKind, Domain, Instr, ParOp, Program, PropId, RegId, VExpr, VStmt,
};
use crate::util::error::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};

// ---------------------------------------------------------------------------
// taxonomy
// ---------------------------------------------------------------------------

/// How a property element is addressed from inside a parallel loop,
/// relative to the loop's subject vertex.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AccessShape {
    /// `v.prop` — indexed by the subject; disjoint across iterations.
    Owner,
    /// `nbr.prop` — indexed by a neighbor-loop binding; cross-vertex.
    Neighbor,
    /// indexed by an update-tuple src/dst register (edge endpoint).
    EdgeEndpoint,
    /// indexed by a loop-invariant register: every iteration addresses
    /// the same element.
    Uniform,
    /// indexed through a pointer chain (`v.parent.modified`) or any
    /// other computed index.
    Indirect,
}

impl AccessShape {
    pub fn as_str(&self) -> &'static str {
        match self {
            AccessShape::Owner => "owner",
            AccessShape::Neighbor => "neighbor",
            AccessShape::EdgeEndpoint => "edge-endpoint",
            AccessShape::Uniform => "uniform",
            AccessShape::Indirect => "indirect",
        }
    }
}

impl std::fmt::Display for AccessShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What kind of write a site is, after classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteClass {
    /// plain store; safe only owner-shaped (disjoint cells).
    Plain,
    /// monotone CAS-min — commutative, idempotent, schedule-independent
    /// at the fixed point.
    CasMin,
    /// companion storing a constant: every racing writer stores the
    /// same value, so the outcome is schedule-independent.
    FlagConst,
    /// companion storing the relax source (a parent pointer); racy on
    /// its own, made deterministic by the trailing argmin
    /// `RepairParents` this analysis schedules.
    Repaired,
}

impl WriteClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            WriteClass::Plain => "store",
            WriteClass::CasMin => "cas-min",
            WriteClass::FlagConst => "flag-const",
            WriteClass::Repaired => "parent-repaired",
        }
    }
}

/// A `(dist, parent)` pair whose companion writes need the
/// deterministic argmin repair at both segment tails.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RepairSpec {
    pub dist: PropId,
    pub parent: PropId,
    pub unit_weight: bool,
}

/// One write site inside a parallel loop.
#[derive(Debug, Clone, PartialEq)]
pub struct WriteFacts {
    pub shape: AccessShape,
    pub class: WriteClass,
}

/// Effect summary for one property inside one parallel loop.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EffectFacts {
    pub prop: String,
    /// distinct read shapes (deduplicated, sorted).
    pub reads: Vec<AccessShape>,
    /// every write site, in body order.
    pub writes: Vec<WriteFacts>,
}

/// Per-loop certificate entry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LoopFacts {
    /// `"init"` or `"on_batch"`.
    pub seg: &'static str,
    pub pc: usize,
    pub span: Span,
    /// `"nodes"` or `"out-neighbors"`.
    pub domain: &'static str,
    /// inferred synchronization tags: `owner-writes`, `cas-relax`,
    /// `slot-fold`, `monotone-flag`, `relaxed-read`, `pure`.
    pub sync: Vec<&'static str>,
    pub effects: Vec<EffectFacts>,
    /// slot-folded reductions: (register, kind).
    pub accums: Vec<(RegId, AccumKind)>,
}

/// A warning-level diagnostic (does not reject the program).
#[derive(Debug, Clone, PartialEq)]
pub struct Lint {
    pub code: &'static str,
    pub seg: &'static str,
    pub pc: usize,
    /// the enclosing loop's span when the read sits in one,
    /// `Span::default()` for straight-line driver code.
    pub span: Span,
    pub message: String,
}

impl std::fmt::Display for Lint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.span == Span::default() {
            write!(f, "{}@{}: {}: {}", self.seg, self.pc, self.code, self.message)
        } else {
            write!(f, "{}: {}: {}", self.span, self.code, self.message)
        }
    }
}

/// The analysis certificate attached to every compiled [`Program`].
///
/// Hand-built programs (tests) carry `Default::default()` — no loops,
/// `certified = false` — and are rejected by program-less backends with
/// the generic explanation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProgramFacts {
    /// true iff [`certify`] ran (distinguishes a real certificate from
    /// a defaulted one on hand-built programs).
    pub certified: bool,
    pub loops: Vec<LoopFacts>,
    pub repairs: Vec<RepairSpec>,
    /// property names for the repair pairs (JSON/report convenience).
    pub repair_names: Vec<(String, String)>,
    /// every cross-vertex (non-owner) write in the program is a
    /// monotone CAS-min relax or one of its admissible companions —
    /// the precondition a dist superstep lowering needs.
    pub relax_only_cross_vertex_writes: bool,
    /// every cross-vertex write in the *batch* segment is monotone
    /// (CAS-min or idempotent flag) — Incremental hooks only move
    /// labels toward the fixed point.
    pub batch_monotone: bool,
    /// no race diagnostics: serial and parallel execution are bitwise
    /// identical (per-item slots, index-order folds, CAS-min + repair).
    pub deterministic: bool,
    /// float reductions are slot-folded in index order, so f64
    /// non-associativity cannot leak schedule dependence.
    pub f64_fold_order_safe: bool,
    /// number of `AddF` accumulators the fold-order guarantee covers.
    pub float_accums: usize,
    /// properties never read by any instruction in either segment.
    pub dead_props: Vec<String>,
    /// instructions unreachable from either segment's entry.
    pub unreachable_instrs: usize,
    pub lints: Vec<Lint>,
}

// ---------------------------------------------------------------------------
// entry points
// ---------------------------------------------------------------------------

/// Analyze a freshly-lowered program: infer the repair schedule, append
/// the [`Instr::RepairParents`] tails, run race detection, and return
/// the certificate. Called by `lower` between lowering and `verify`.
pub fn certify(prog: &mut Program) -> Result<ProgramFacts> {
    let repairs = infer_repairs(prog);
    for r in &repairs {
        let ins = Instr::RepairParents {
            dist: r.dist,
            parent: r.parent,
            unit_weight: r.unit_weight,
        };
        prog.init.push(ins.clone());
        prog.on_batch.push(ins);
    }
    analyze_program(prog, &repairs)
}

/// Derive the repair schedule from the IR: a parallel
/// `MinAssign { prop: d, val: d[src] + w, comps }` whose companion
/// stores `src` into an Int property `p` is an SSSP/BFS-style relax
/// recording a parent pointer — racy under CAS-min, so `(d, p)` gets a
/// deterministic argmin [`Instr::RepairParents`] at both segment tails
/// (`w == 1` marks the unit-weight BFS variant). This replaces the
/// lowerer's old AST pattern match; sequential relaxes (OnAdd seeding)
/// need no repair of their own — they are deterministic, and the pairs
/// they touch are exactly the ones the parallel relaxes already
/// register.
pub fn infer_repairs(prog: &Program) -> Vec<RepairSpec> {
    let mut out: Vec<RepairSpec> = Vec::new();
    for code in [&prog.init, &prog.on_batch] {
        for ins in code {
            if let Instr::Par(op) = ins {
                repairs_in_body(&op.body, &mut out);
            }
        }
    }
    out
}

fn repairs_in_body(body: &[VStmt], out: &mut Vec<RepairSpec>) {
    for s in body {
        match s {
            VStmt::MinAssign { prop, val, comps, .. } => {
                if let Some((src, unit_weight)) = relax_source(*prop, val) {
                    for (p, _ci, cv) in comps {
                        if cv == src && !out.iter().any(|r| r.dist == *prop && r.parent == *p) {
                            out.push(RepairSpec { dist: *prop, parent: *p, unit_weight });
                        }
                    }
                }
            }
            VStmt::If { then, els, .. } => {
                repairs_in_body(then, out);
                repairs_in_body(els, out);
            }
            VStmt::ForOut { body, .. } | VStmt::ForIn { body, .. } => repairs_in_body(body, out),
            VStmt::SetLocal(..) | VStmt::StoreProp(..) | VStmt::Accum { .. } => {}
        }
    }
}

/// `val == d[src] + w` for the relax on property `d`: returns the
/// source index expression and whether `w` is the literal 1.
fn relax_source(d: PropId, val: &VExpr) -> Option<(&VExpr, bool)> {
    let VExpr::Bin(crate::dsl::ast::BinOp::Add, lhs, rhs) = val else {
        return None;
    };
    let VExpr::LoadProp(p, src) = &**lhs else {
        return None;
    };
    if *p != d {
        return None;
    }
    Some((&**src, matches!(&**rhs, VExpr::ConstI(1))))
}

/// The full pass over an already-repair-scheduled program. Errors are
/// race diagnostics; `Ok` carries the certificate.
pub fn analyze_program(prog: &Program, repairs: &[RepairSpec]) -> Result<ProgramFacts> {
    let mut facts = ProgramFacts {
        certified: true,
        repairs: repairs.to_vec(),
        repair_names: repairs
            .iter()
            .map(|r| (prog.props[r.dist].name.clone(), prog.props[r.parent].name.clone()))
            .collect(),
        deterministic: true,
        f64_fold_order_safe: true,
        relax_only_cross_vertex_writes: true,
        batch_monotone: true,
        ..Default::default()
    };

    for (seg, code) in [("init", &prog.init), ("on_batch", &prog.on_batch)] {
        let upd_regs = endpoint_regs(prog, code);
        for (pc, ins) in code.iter().enumerate() {
            if let Instr::Par(op) = ins {
                let lf = analyze_par(prog, seg, pc, op, &upd_regs, repairs)?;
                for e in &lf.effects {
                    for w in &e.writes {
                        if w.shape != AccessShape::Owner && w.class == WriteClass::Plain {
                            facts.relax_only_cross_vertex_writes = false;
                        }
                        if seg == "on_batch"
                            && w.shape != AccessShape::Owner
                            && !matches!(w.class, WriteClass::CasMin | WriteClass::FlagConst)
                            && w.class != WriteClass::Repaired
                        {
                            facts.batch_monotone = false;
                        }
                    }
                }
                facts.float_accums +=
                    lf.accums.iter().filter(|(_, k)| *k == AccumKind::AddF).count();
                facts.loops.push(lf);
            }
        }
    }

    facts.dead_props = dead_props(prog);
    facts.unreachable_instrs = unreachable_instrs(&prog.init) + unreachable_instrs(&prog.on_batch);
    facts.lints = uninit_read_lints(prog);
    Ok(facts)
}

// ---------------------------------------------------------------------------
// per-loop effect summary + race detection
// ---------------------------------------------------------------------------

/// Internal per-property accumulation while walking one Par body.
#[derive(Default)]
struct PropEffect {
    reads: BTreeSet<AccessShape>,
    writes: Vec<WriteSite>,
}

struct WriteSite {
    shape: AccessShape,
    class: WriteClass,
    /// the stored constant, when the value is a literal (idempotence
    /// check for racy reads of flag properties).
    cval: Option<ConstVal>,
    span: Span,
}

#[derive(Clone, Copy, PartialEq)]
enum ConstVal {
    I(i64),
    F(f64),
    B(bool),
}

fn const_of(e: &VExpr) -> Option<ConstVal> {
    match e {
        VExpr::ConstI(v) => Some(ConstVal::I(*v)),
        VExpr::ConstF(v) => Some(ConstVal::F(*v)),
        VExpr::ConstB(v) => Some(ConstVal::B(*v)),
        _ => None,
    }
}

struct LoopWalk<'a> {
    prog: &'a Program,
    upd_regs: &'a [bool],
    repairs: &'a [RepairSpec],
    /// locals currently bound as neighbor-loop variables.
    nbr_locals: Vec<bool>,
    /// innermost enclosing loop span (the Par's own span at top level).
    spans: Vec<Span>,
    effects: BTreeMap<PropId, PropEffect>,
}

impl LoopWalk<'_> {
    fn span(&self) -> Span {
        *self.spans.last().expect("span stack never empty")
    }

    fn shape(&self, idx: &VExpr) -> AccessShape {
        match idx {
            VExpr::Subject => AccessShape::Owner,
            VExpr::Local(l) if self.nbr_locals[*l] => AccessShape::Neighbor,
            VExpr::Reg(r) if self.upd_regs[*r] => AccessShape::EdgeEndpoint,
            VExpr::Reg(_) => AccessShape::Uniform,
            _ => AccessShape::Indirect,
        }
    }

    fn read(&mut self, p: PropId, shape: AccessShape) {
        self.effects.entry(p).or_default().reads.insert(shape);
    }

    fn write(&mut self, p: PropId, site: WriteSite) {
        self.effects.entry(p).or_default().writes.push(site);
    }

    /// Record every property read inside an expression.
    fn reads_in(&mut self, e: &VExpr) {
        match e {
            VExpr::LoadProp(p, idx) => {
                let s = self.shape(idx);
                self.read(*p, s);
                self.reads_in(idx);
            }
            VExpr::OutDegree(x) | VExpr::Not(x) | VExpr::Neg(x) => self.reads_in(x),
            VExpr::IsEdge(a, b) | VExpr::Contains(_, a, b) | VExpr::Bin(_, a, b) => {
                self.reads_in(a);
                self.reads_in(b);
            }
            VExpr::ConstI(_)
            | VExpr::ConstF(_)
            | VExpr::ConstB(_)
            | VExpr::Subject
            | VExpr::Reg(_)
            | VExpr::Local(_) => {}
        }
    }

    fn walk(&mut self, body: &[VStmt]) -> Result<()> {
        for s in body {
            match s {
                VStmt::SetLocal(_, e) => self.reads_in(e),
                VStmt::StoreProp(p, idx, val) => {
                    self.reads_in(idx);
                    self.reads_in(val);
                    let site = WriteSite {
                        shape: self.shape(idx),
                        class: WriteClass::Plain,
                        cval: const_of(val),
                        span: self.span(),
                    };
                    self.write(*p, site);
                }
                VStmt::MinAssign { prop, idx, val, comps } => {
                    self.reads_in(idx);
                    self.reads_in(val);
                    // the CAS reads its target before comparing.
                    let tshape = self.shape(idx);
                    self.read(*prop, tshape);
                    self.write(
                        *prop,
                        WriteSite {
                            shape: tshape,
                            class: WriteClass::CasMin,
                            cval: None,
                            span: self.span(),
                        },
                    );
                    let src = relax_source(*prop, val).map(|(s, _)| s);
                    for (cp, ci, cv) in comps {
                        self.reads_in(ci);
                        self.reads_in(cv);
                        let cshape = self.shape(ci);
                        let class = if const_of(cv).is_some() {
                            WriteClass::FlagConst
                        } else if src.is_some_and(|s| cv == s)
                            && self.repairs.iter().any(|r| r.dist == *prop && r.parent == *cp)
                        {
                            WriteClass::Repaired
                        } else {
                            bail!(
                                "{}: R002: companion write to property {:?} ({} index) under \
                                 the CAS-min on {:?} is neither an idempotent constant nor the \
                                 relax source — its final value depends on the winning schedule",
                                self.span(),
                                self.prog.props[*cp].name,
                                cshape,
                                self.prog.props[*prop].name,
                            );
                        };
                        let site = WriteSite {
                            shape: cshape,
                            class,
                            cval: const_of(cv),
                            span: self.span(),
                        };
                        self.write(*cp, site);
                    }
                }
                VStmt::If { cond, then, els } => {
                    self.reads_in(cond);
                    self.walk(then)?;
                    self.walk(els)?;
                }
                VStmt::ForOut { of, nbr, body, span, .. } => {
                    self.reads_in(of);
                    self.nbr_locals[*nbr] = true;
                    self.spans.push(*span);
                    self.walk(body)?;
                    self.spans.pop();
                    self.nbr_locals[*nbr] = false;
                }
                VStmt::ForIn { of, nbr, body, span } => {
                    self.reads_in(of);
                    self.nbr_locals[*nbr] = true;
                    self.spans.push(*span);
                    self.walk(body)?;
                    self.spans.pop();
                    self.nbr_locals[*nbr] = false;
                }
                VStmt::Accum { val, .. } => self.reads_in(val),
            }
        }
        Ok(())
    }
}

fn analyze_par(
    prog: &Program,
    seg: &'static str,
    pc: usize,
    op: &ParOp,
    upd_regs: &[bool],
    repairs: &[RepairSpec],
) -> Result<LoopFacts> {
    let mut w = LoopWalk {
        prog,
        upd_regs,
        repairs,
        nbr_locals: vec![false; op.locals.len()],
        spans: vec![op.span],
        effects: BTreeMap::new(),
    };
    w.walk(&op.body)?;
    let effects = std::mem::take(&mut w.effects);

    // race detection per property.
    let mut tags: BTreeSet<&'static str> = BTreeSet::new();
    for (pid, eff) in &effects {
        let pname = &prog.props[*pid].name;
        if let Some(site) = eff
            .writes
            .iter()
            .find(|s| s.class == WriteClass::Plain && s.shape != AccessShape::Owner)
        {
            bail!(
                "{}: R001: parallel loop writes property {:?} through a {} index — a plain \
                 store in a parallel region is a cross-iteration write-write race (reduce into \
                 a scalar, or relax with <Min(...)>)",
                site.span,
                pname,
                site.shape,
            );
        }
        let has_plain = eff.writes.iter().any(|s| s.class == WriteClass::Plain);
        let has_min = eff.writes.iter().any(|s| s.class == WriteClass::CasMin);
        if has_plain && has_min {
            let site = eff.writes.iter().find(|s| s.class == WriteClass::Plain).unwrap();
            bail!(
                "{}: R004: property {:?} is both plainly stored and CAS-min relaxed in one \
                 parallel loop — the store races the relax",
                site.span,
                pname,
            );
        }
        if !eff.reads.is_empty() && !eff.writes.is_empty() {
            let crosses = eff.reads.iter().any(|s| *s != AccessShape::Owner)
                || eff.writes.iter().any(|s| s.shape != AccessShape::Owner);
            if crosses {
                let all_min = eff.writes.iter().all(|s| s.class == WriteClass::CasMin);
                let all_same_const = eff.writes.first().is_some_and(|first| {
                    first.cval.is_some() && eff.writes.iter().all(|s| s.cval == first.cval)
                });
                if all_min {
                    tags.insert("relaxed-read");
                } else if all_same_const {
                    tags.insert("monotone-flag");
                } else {
                    let shape = eff
                        .reads
                        .iter()
                        .find(|s| **s != AccessShape::Owner)
                        .copied()
                        .unwrap_or(AccessShape::Owner);
                    let site = eff
                        .writes
                        .iter()
                        .find(|s| s.shape != AccessShape::Owner)
                        .unwrap_or(&eff.writes[0]);
                    bail!(
                        "{}: R003: property {:?} is read through a {} index while another \
                         iteration may be storing it — the read observes a racy in-flight \
                         value (double-buffer the property, or make every write a CAS-min or \
                         an identical constant)",
                        site.span,
                        pname,
                        shape,
                    );
                }
            }
        }
        for s in &eff.writes {
            match s.class {
                WriteClass::CasMin => {
                    tags.insert("cas-relax");
                }
                WriteClass::Plain if s.shape == AccessShape::Owner => {
                    tags.insert("owner-writes");
                }
                _ => {}
            }
        }
    }
    if !op.accums.is_empty() {
        tags.insert("slot-fold");
    }
    if tags.is_empty() {
        tags.insert("pure");
    }

    Ok(LoopFacts {
        seg,
        pc,
        span: op.span,
        domain: match op.domain {
            Domain::Nodes => "nodes",
            Domain::OutNbrs { .. } => "out-neighbors",
        },
        sync: tags.into_iter().collect(),
        effects: effects
            .into_iter()
            .map(|(pid, eff)| EffectFacts {
                prop: prog.props[pid].name.clone(),
                reads: eff.reads.into_iter().collect(),
                writes: eff
                    .writes
                    .into_iter()
                    .map(|s| WriteFacts { shape: s.shape, class: s.class })
                    .collect(),
            })
            .collect(),
        accums: op.accums.iter().map(|a| (a.reg, a.kind)).collect(),
    })
}

/// Registers holding update-tuple endpoints in this segment: tainted by
/// `UpdGet` src/dst and propagated through `Mov` to a fixed point.
fn endpoint_regs(prog: &Program, code: &[Instr]) -> Vec<bool> {
    let mut t = vec![false; prog.regs.len()];
    loop {
        let mut changed = false;
        for ins in code {
            match ins {
                Instr::UpdGet { src, dst, .. } => {
                    for r in [*src, *dst] {
                        if !t[r] {
                            t[r] = true;
                            changed = true;
                        }
                    }
                }
                Instr::Mov { dst, src } if t[*src] && !t[*dst] => {
                    t[*dst] = true;
                    changed = true;
                }
                _ => {}
            }
        }
        if !changed {
            return t;
        }
    }
}

// ---------------------------------------------------------------------------
// whole-program reports: dead properties, unreachable code, uninit reads
// ---------------------------------------------------------------------------

/// (reads, writes) of one instruction at the property level, Par bodies
/// included.
fn instr_prop_effects(ins: &Instr, reads: &mut BTreeSet<PropId>, writes: &mut BTreeSet<PropId>) {
    match ins {
        Instr::LoadProp { prop, .. } | Instr::AnyTrue { prop, .. } => {
            reads.insert(*prop);
        }
        Instr::StoreProp { prop, .. } | Instr::Fill { prop, .. } => {
            writes.insert(*prop);
        }
        Instr::CopyProp { dst, src } => {
            reads.insert(*src);
            writes.insert(*dst);
        }
        Instr::PropagateFlags { prop } => {
            reads.insert(*prop);
            writes.insert(*prop);
        }
        Instr::RepairParents { dist, parent, .. } => {
            reads.insert(*dist);
            writes.insert(*parent);
        }
        Instr::Par(op) => vstmt_prop_effects(&op.body, reads, writes),
        _ => {}
    }
}

fn vexpr_prop_reads(e: &VExpr, reads: &mut BTreeSet<PropId>) {
    match e {
        VExpr::LoadProp(p, idx) => {
            reads.insert(*p);
            vexpr_prop_reads(idx, reads);
        }
        VExpr::OutDegree(x) | VExpr::Not(x) | VExpr::Neg(x) => vexpr_prop_reads(x, reads),
        VExpr::IsEdge(a, b) | VExpr::Contains(_, a, b) | VExpr::Bin(_, a, b) => {
            vexpr_prop_reads(a, reads);
            vexpr_prop_reads(b, reads);
        }
        _ => {}
    }
}

fn vstmt_prop_effects(
    body: &[VStmt],
    reads: &mut BTreeSet<PropId>,
    writes: &mut BTreeSet<PropId>,
) {
    for s in body {
        match s {
            VStmt::SetLocal(_, e) | VStmt::Accum { val: e, .. } => vexpr_prop_reads(e, reads),
            VStmt::StoreProp(p, idx, val) => {
                writes.insert(*p);
                vexpr_prop_reads(idx, reads);
                vexpr_prop_reads(val, reads);
            }
            VStmt::MinAssign { prop, idx, val, comps } => {
                reads.insert(*prop);
                writes.insert(*prop);
                vexpr_prop_reads(idx, reads);
                vexpr_prop_reads(val, reads);
                for (p, ci, cv) in comps {
                    writes.insert(*p);
                    vexpr_prop_reads(ci, reads);
                    vexpr_prop_reads(cv, reads);
                }
            }
            VStmt::If { cond, then, els } => {
                vexpr_prop_reads(cond, reads);
                vstmt_prop_effects(then, reads, writes);
                vstmt_prop_effects(els, reads, writes);
            }
            VStmt::ForOut { of, body, .. } | VStmt::ForIn { of, body, .. } => {
                vexpr_prop_reads(of, reads);
                vstmt_prop_effects(body, reads, writes);
            }
        }
    }
}

fn dead_props(prog: &Program) -> Vec<String> {
    let mut reads = BTreeSet::new();
    let mut writes = BTreeSet::new();
    for ins in prog.init.iter().chain(&prog.on_batch) {
        instr_prop_effects(ins, &mut reads, &mut writes);
    }
    prog.props
        .iter()
        .enumerate()
        .filter(|(i, _)| !reads.contains(i))
        .map(|(_, d)| d.name.clone())
        .collect()
}

fn unreachable_instrs(code: &[Instr]) -> usize {
    if code.is_empty() {
        return 0;
    }
    let mut seen = vec![false; code.len()];
    let mut stack = vec![0usize];
    while let Some(pc) = stack.pop() {
        if pc >= code.len() || seen[pc] {
            continue;
        }
        seen[pc] = true;
        match &code[pc] {
            Instr::Jump { target } => stack.push(*target),
            Instr::JumpIf { target, .. } | Instr::JumpIfNot { target, .. } => {
                stack.push(*target);
                stack.push(pc + 1);
            }
            _ => stack.push(pc + 1),
        }
    }
    seen.iter().filter(|s| !**s).count()
}

/// L001: properties read in the batch segment before any write in Init
/// or earlier in the segment (execution still sees the zero-fill from
/// state creation, so this is a warning, not an error).
fn uninit_read_lints(prog: &Program) -> Vec<Lint> {
    let mut written: BTreeSet<PropId> = BTreeSet::new();
    for ins in &prog.init {
        let mut r = BTreeSet::new();
        instr_prop_effects(ins, &mut r, &mut written);
    }
    let mut lints = Vec::new();
    let mut flagged: BTreeSet<PropId> = BTreeSet::new();
    for (pc, ins) in prog.on_batch.iter().enumerate() {
        let (mut reads, mut writes) = (BTreeSet::new(), BTreeSet::new());
        instr_prop_effects(ins, &mut reads, &mut writes);
        for p in reads {
            if !written.contains(&p) && flagged.insert(p) {
                let span = match ins {
                    Instr::Par(op) => op.span,
                    _ => Span::default(),
                };
                lints.push(Lint {
                    code: "L001",
                    seg: "on_batch",
                    pc,
                    span,
                    message: format!(
                        "property {:?} is read in the batch segment but never written by Init \
                         or a prior batch statement — it reads the zero-fill from state creation",
                        prog.props[p].name
                    ),
                });
            }
        }
        written.extend(writes);
    }
    lints
}

// ---------------------------------------------------------------------------
// certificate: admission, summary, JSON
// ---------------------------------------------------------------------------

impl ProgramFacts {
    /// Name the construct that blocks a backend without a bytecode
    /// executor — the most demanding feature first (cross-vertex relax,
    /// then float folds, then anything at all).
    pub fn blocking_construct(&self) -> String {
        if !self.certified {
            return "the program carries no analysis certificate (hand-built bytecode)".into();
        }
        for lf in &self.loops {
            for e in &lf.effects {
                if let Some(w) = e.writes.iter().find(|w| w.shape != AccessShape::Owner) {
                    return format!(
                        "the parallel loop at {} ({}@{}) {} property {:?} through a {} index \
                         (cross-vertex {})",
                        lf.span,
                        lf.seg,
                        lf.pc,
                        if w.class == WriteClass::CasMin { "min-writes" } else { "writes" },
                        e.prop,
                        w.shape,
                        w.class.as_str(),
                    );
                }
            }
        }
        for lf in &self.loops {
            if lf.accums.iter().any(|(_, k)| *k == AccumKind::AddF) {
                return format!(
                    "the parallel loop at {} ({}@{}) folds a float reduction \
                     (slot-ordered f64 fold)",
                    lf.span, lf.seg, lf.pc,
                );
            }
        }
        if let Some(lf) = self.loops.first() {
            return format!(
                "the parallel loop at {} ({}@{}) needs a bytecode executor",
                lf.span, lf.seg, lf.pc,
            );
        }
        "the program's driver segments need a bytecode executor".into()
    }

    /// Typed admission check: `Ok` iff `supports_programs`; the error
    /// names the offending construct from the certificate.
    pub fn admit(&self, backend: &str, supports_programs: bool) -> Result<()> {
        if supports_programs {
            return Ok(());
        }
        bail!(
            "backend `{backend}` does not support DSL bytecode programs: {}; run it on \
             --backend serial or --backend cpu",
            self.blocking_construct(),
        )
    }

    /// One-line human verdict for `run --program` / `serve --program`.
    pub fn summary(&self) -> String {
        let relaxes = self
            .loops
            .iter()
            .filter(|l| l.sync.contains(&"cas-relax"))
            .count();
        format!(
            "{} parallel loops ({} cas-relax), {} repair pairs, {} reductions ({} f64 \
             slot-folded), cross-vertex writes {}, batch {}, {}{}",
            self.loops.len(),
            relaxes,
            self.repairs.len(),
            self.loops.iter().map(|l| l.accums.len()).sum::<usize>(),
            self.float_accums,
            if self.relax_only_cross_vertex_writes { "relax-only" } else { "unconstrained" },
            if self.batch_monotone { "monotone" } else { "non-monotone" },
            if self.deterministic { "deterministic" } else { "racy" },
            if self.lints.is_empty() {
                String::new()
            } else {
                format!(", {} lint(s)", self.lints.len())
            },
        )
    }

    /// Serialize the certificate as JSON (hand-rolled: the crate is
    /// zero-dependency; `telemetry::trace::validate_json` checks it).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push('{');
        push_kv(&mut s, "certified", &self.certified.to_string());
        s.push_str("\"loops\":[");
        for (i, lf) in self.loops.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('{');
            push_kv(&mut s, "seg", &quote(lf.seg));
            push_kv(&mut s, "pc", &lf.pc.to_string());
            push_kv(&mut s, "line", &lf.span.line.to_string());
            push_kv(&mut s, "col", &lf.span.col.to_string());
            push_kv(&mut s, "domain", &quote(lf.domain));
            s.push_str("\"sync\":[");
            for (j, t) in lf.sync.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&quote(t));
            }
            s.push_str("],\"effects\":[");
            for (j, e) in lf.effects.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push('{');
                push_kv(&mut s, "prop", &quote(&e.prop));
                s.push_str("\"reads\":[");
                for (k, r) in e.reads.iter().enumerate() {
                    if k > 0 {
                        s.push(',');
                    }
                    s.push_str(&quote(r.as_str()));
                }
                s.push_str("],\"writes\":[");
                for (k, w) in e.writes.iter().enumerate() {
                    if k > 0 {
                        s.push(',');
                    }
                    s.push_str(&format!(
                        "{{\"shape\":{},\"class\":{}}}",
                        quote(w.shape.as_str()),
                        quote(w.class.as_str())
                    ));
                }
                s.push_str("]}");
            }
            s.push_str("],\"accums\":[");
            for (j, (reg, kind)) in lf.accums.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                let k = match kind {
                    AccumKind::AddI => "add-int",
                    AccumKind::AddF => "add-float",
                    AccumKind::Or => "or",
                };
                s.push_str(&format!("{{\"reg\":{reg},\"kind\":{}}}", quote(k)));
            }
            s.push_str("]}");
        }
        s.push_str("],\"repairs\":[");
        for (i, (r, (dn, pn))) in self.repairs.iter().zip(&self.repair_names).enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"dist\":{},\"parent\":{},\"unit_weight\":{}}}",
                quote(dn),
                quote(pn),
                r.unit_weight
            ));
        }
        s.push_str("],");
        s.push_str("\"determinism\":{");
        push_kv(&mut s, "deterministic", &self.deterministic.to_string());
        push_kv(&mut s, "f64_fold_order_safe", &self.f64_fold_order_safe.to_string());
        s.push_str(&format!("\"float_accums\":{}}},", self.float_accums));
        push_kv(
            &mut s,
            "relax_only_cross_vertex_writes",
            &self.relax_only_cross_vertex_writes.to_string(),
        );
        push_kv(&mut s, "batch_monotone", &self.batch_monotone.to_string());
        s.push_str("\"dead_props\":[");
        for (i, p) in self.dead_props.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&quote(p));
        }
        s.push_str("],");
        s.push_str(&format!("\"unreachable_instrs\":{},", self.unreachable_instrs));
        s.push_str("\"lints\":[");
        for (i, l) in self.lints.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"code\":{},\"seg\":{},\"pc\":{},\"line\":{},\"col\":{},\"message\":{}}}",
                quote(l.code),
                quote(l.seg),
                l.pc,
                l.span.line,
                l.span.col,
                quote(&l.message)
            ));
        }
        s.push_str("]}");
        s
    }
}

fn push_kv(s: &mut String, key: &str, raw_val: &str) {
    s.push_str(&format!("{}:{raw_val},", quote(key)));
}

fn quote(v: &str) -> String {
    let mut out = String::with_capacity(v.len() + 2);
    out.push('"');
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::lower;

    fn facts_of(src: &str) -> ProgramFacts {
        lower::compile(src, None).unwrap().facts
    }

    #[test]
    fn sssp_certificate_is_relax_only_with_one_repair() {
        let f = facts_of(include_str!("../../dsl/sssp_dynamic.sp"));
        assert!(f.certified && f.deterministic && f.f64_fold_order_safe);
        assert!(f.relax_only_cross_vertex_writes);
        assert!(f.batch_monotone);
        assert_eq!(f.repair_names, vec![("dist".to_string(), "parent".to_string())]);
        assert!(!f.repairs[0].unit_weight);
        assert!(f.lints.is_empty(), "unexpected lints: {:?}", f.lints);
        assert_eq!(f.unreachable_instrs, 0);
        // the relax loops carry cas-relax sync; reads of dist are relaxed
        assert!(f
            .loops
            .iter()
            .any(|l| l.sync.contains(&"cas-relax") && l.sync.contains(&"relaxed-read")));
        // the decremental cascade is a monotone flag sweep
        assert!(f.loops.iter().any(|l| l.sync.contains(&"monotone-flag")));
    }

    #[test]
    fn cc_certificate_has_no_repairs_but_relaxes_both_directions() {
        let f = facts_of(include_str!("../../dsl/cc_dynamic.sp"));
        assert!(f.repairs.is_empty(), "cc has no parent companion");
        assert!(f.relax_only_cross_vertex_writes);
        let relax = f
            .loops
            .iter()
            .find(|l| l.sync.contains(&"cas-relax"))
            .expect("cc has relax loops");
        let comp = relax.effects.iter().find(|e| e.prop == "comp").unwrap();
        assert!(comp.reads.contains(&AccessShape::Owner));
        assert!(comp.reads.contains(&AccessShape::Neighbor));
        assert!(comp.writes.iter().all(|w| w.class == WriteClass::CasMin));
    }

    #[test]
    fn pagerank_certificate_covers_float_folds() {
        let f = facts_of(include_str!("../../dsl/pagerank_dynamic.sp"));
        assert!(f.float_accums > 0, "pagerank folds f64 diffs");
        assert!(f.f64_fold_order_safe);
        assert!(f.relax_only_cross_vertex_writes, "all pagerank writes are owner-local");
        // the pull sweep reads neighbor ranks but double-buffers writes
        assert!(f.loops.iter().any(|l| l
            .effects
            .iter()
            .any(|e| e.prop == "pageRank" && e.reads.contains(&AccessShape::Neighbor))));
    }

    #[test]
    fn facts_json_is_valid_for_all_shipped_programs() {
        for src in [
            include_str!("../../dsl/sssp_dynamic.sp"),
            include_str!("../../dsl/bfs_dynamic.sp"),
            include_str!("../../dsl/pagerank_dynamic.sp"),
            include_str!("../../dsl/tc_dynamic.sp"),
            include_str!("../../dsl/cc_dynamic.sp"),
        ] {
            let f = facts_of(src);
            let json = f.to_json();
            crate::telemetry::trace::validate_json(&json)
                .unwrap_or_else(|e| panic!("invalid facts JSON: {e}\n{json}"));
        }
    }

    #[test]
    fn default_facts_admit_program_backends_and_explain_others() {
        let f = ProgramFacts::default();
        f.admit("cpu", true).unwrap();
        let err = f.admit("dist", false).unwrap_err().to_string();
        assert!(err.contains("does not support DSL bytecode programs"), "{err}");
        assert!(err.contains("no analysis certificate"), "{err}");
    }
}
