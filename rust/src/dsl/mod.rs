//! The StarPlat Dynamic DSL front-end (§3): lexer, recursive-descent
//! parser, AST (the compiler's IR, §3.4), semantic analysis (symbol
//! table, read/write sets, data-race detection → synchronization
//! insertion), a reference interpreter that *executes* DSL programs over
//! the diff-CSR substrate, and the per-backend C++ code emitters (§4).
//!
//! Beyond the interpreter and the C++ emitters, `lower` compiles a
//! checked AST to the register-based bytecode in `bytecode`, which the
//! serial and cpu engines execute natively — the path behind
//! `run --program` / `serve --program`.
//!
//! The shipped programs in `dsl/*.sp` are the paper's Appendix A
//! listings (Figs. 19–21), plus `cc_dynamic.sp` (connected components,
//! bytecode-only — no hand-written kernel).

pub mod analyze;
pub mod ast;
pub mod bytecode;
pub mod emit;
pub mod interp;
pub mod lexer;
pub mod lower;
pub mod parser;
pub mod sema;

pub use ast::Program;
pub use parser::parse_program;
pub use sema::analyze;
