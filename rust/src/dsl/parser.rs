//! Recursive-descent parser: tokens → AST.
//!
//! Grammar is the StarPlat Dynamic surface syntax used by the Appendix A
//! programs shipped in `dsl/*.sp` (Figs. 19–21), including the dynamic
//! constructs `Batch`, `OnAdd`, `OnDelete`, `fixedPoint until`, and the
//! atomic `Min` multi-assignment.

use super::ast::*;
use super::lexer::{lex, Tok, Token};
use crate::util::error::{anyhow, bail, Result};

pub fn parse_program(src: &str) -> Result<Program> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    let mut functions = Vec::new();
    while !p.at(&Tok::Eof) {
        functions.push(p.function()?);
    }
    Ok(Program { functions })
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos.min(self.toks.len() - 1)].kind
    }

    fn peek2(&self) -> &Tok {
        &self.toks[(self.pos + 1).min(self.toks.len() - 1)].kind
    }

    /// Source position of the current token, for diagnostics and for
    /// stamping statements as they are built.
    fn span(&self) -> Span {
        let t = &self.toks[self.pos.min(self.toks.len() - 1)];
        Span { line: t.line as u32, col: t.col as u32 }
    }

    fn at(&self, t: &Tok) -> bool {
        self.peek() == t
    }

    fn bump(&mut self) -> Tok {
        // clamped at Eof: an unterminated construct yields a parse error
        // instead of running off the token vector
        let t = self.toks[self.pos.min(self.toks.len() - 1)].kind.clone();
        self.pos += 1;
        t
    }

    fn expect(&mut self, t: Tok) -> Result<()> {
        if self.peek() == &t {
            self.pos += 1;
            Ok(())
        } else {
            bail!("{}: expected {:?}, found {:?}", self.span(), t, self.peek())
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => bail!("{}: expected identifier, found {other:?}", self.span()),
        }
    }

    fn at_ident(&self, s: &str) -> bool {
        matches!(self.peek(), Tok::Ident(w) if w == s)
    }

    fn eat_ident(&mut self, s: &str) -> bool {
        if self.at_ident(s) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    // ---------------------------------------------------------- types

    fn is_type_start(&self) -> bool {
        matches!(self.peek(), Tok::Ident(w) if matches!(
            w.as_str(),
            "int" | "long" | "bool" | "float" | "double" | "Graph" | "node" | "edge"
                | "propNode" | "propEdge" | "updates"
        ))
    }

    fn ty(&mut self) -> Result<Type> {
        let name = self.ident()?;
        Ok(match name.as_str() {
            "int" => Type::Int,
            "long" => Type::Long,
            "bool" => Type::Bool,
            "float" => Type::Float,
            "double" => Type::Double,
            "Graph" => Type::Graph,
            "node" => Type::Node,
            "edge" => Type::Edge,
            "propNode" => {
                self.expect(Tok::Lt)?;
                let inner = self.ty()?;
                self.expect(Tok::Gt)?;
                Type::PropNode(Box::new(inner))
            }
            "propEdge" => {
                self.expect(Tok::Lt)?;
                let inner = self.ty()?;
                self.expect(Tok::Gt)?;
                Type::PropEdge(Box::new(inner))
            }
            "updates" => {
                self.expect(Tok::Lt)?;
                let _g = self.ident()?;
                self.expect(Tok::Gt)?;
                Type::Updates
            }
            other => bail!("{}: unknown type {other:?}", self.span()),
        })
    }

    // ------------------------------------------------------ functions

    fn function(&mut self) -> Result<Function> {
        let kw = self.ident()?;
        let (kind, name) = match kw.as_str() {
            "Static" => (FnKind::Static, self.ident()?),
            "Dynamic" => (FnKind::Dynamic, self.ident()?),
            "Incremental" => (FnKind::Incremental, "Incremental".to_string()),
            "Decremental" => (FnKind::Decremental, "Decremental".to_string()),
            other => bail!(
                "{}: expected Static/Dynamic/Incremental/Decremental, found {other:?}",
                self.span()
            ),
        };
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        if !self.at(&Tok::RParen) {
            loop {
                let ty = self.ty()?;
                let name = self.ident()?;
                params.push(Param { ty, name });
                if !self.at(&Tok::Comma) {
                    break;
                }
                self.bump();
            }
        }
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(Function { kind, name, params, body })
    }

    fn block(&mut self) -> Result<Vec<Stmt>> {
        self.expect(Tok::LBrace)?;
        let mut out = Vec::new();
        while !self.at(&Tok::RBrace) {
            out.push(self.stmt()?);
        }
        self.expect(Tok::RBrace)?;
        Ok(out)
    }

    // ------------------------------------------------------ statements

    fn stmt(&mut self) -> Result<Stmt> {
        let span = self.span();
        // Min multi-assign: `<lv, lv, lv> = <Min(a,b), e, e>;`
        if self.at(&Tok::Lt) {
            return self.min_assign(span);
        }
        if let Tok::Ident(w) = self.peek() {
            match w.as_str() {
                "if" => return self.if_stmt(span),
                "while" => return self.while_stmt(span),
                "do" => return self.do_while(span),
                "forall" => return self.loop_stmt(true, span),
                "for" => return self.loop_stmt(false, span),
                "fixedPoint" => return self.fixed_point(span),
                "Batch" => return self.batch(span),
                "OnAdd" => return self.on_update(true, span),
                "OnDelete" => return self.on_update(false, span),
                "return" => {
                    self.bump();
                    let e = self.expr()?;
                    self.expect(Tok::Semi)?;
                    return Ok(Stmt::Return(e));
                }
                _ => {}
            }
        }
        // Declaration? (type keyword followed by identifier)
        if self.is_type_start() && !matches!(self.peek2(), Tok::Dot | Tok::Assign) {
            let ty = self.ty()?;
            let name = self.ident()?;
            let init = if self.at(&Tok::Assign) {
                self.bump();
                Some(self.expr()?)
            } else {
                None
            };
            self.expect(Tok::Semi)?;
            return Ok(Stmt::Decl { ty, name, init, span });
        }
        // Expression-led: assignment or expression statement.
        let e = self.expr()?;
        let op = match self.peek() {
            Tok::Assign => Some(AssignOp::Set),
            Tok::PlusEq => Some(AssignOp::Add),
            Tok::MinusEq => Some(AssignOp::Sub),
            _ => None,
        };
        if let Some(op) = op {
            self.bump();
            let rhs = self.expr()?;
            self.expect(Tok::Semi)?;
            let lhs = Self::lvalue(e, span)?;
            return Ok(Stmt::Assign { lhs, op, rhs, span });
        }
        self.expect(Tok::Semi)?;
        Ok(Stmt::Expr(e))
    }

    fn lvalue(e: Expr, span: Span) -> Result<LValue> {
        match e {
            Expr::Var(v) => Ok(LValue::Var(v)),
            Expr::Member { base, prop } => Ok(LValue::Member { base: *base, prop }),
            other => Err(anyhow!("{span}: not assignable: {other:?}")),
        }
    }

    fn min_assign(&mut self, span: Span) -> Result<Stmt> {
        self.expect(Tok::Lt)?;
        let mut lhs = Vec::new();
        loop {
            let e = self.expr_primary_chain()?;
            lhs.push(Self::lvalue(e, span)?);
            if !self.at(&Tok::Comma) {
                break;
            }
            self.bump();
        }
        self.expect(Tok::Gt)?;
        self.expect(Tok::Assign)?;
        self.expect(Tok::Lt)?;
        // first element must be Min(a, b)
        if !self.eat_ident("Min") {
            bail!("{}: Min(...) expected as first tuple element", self.span());
        }
        self.expect(Tok::LParen)?;
        let a = self.expr()?;
        self.expect(Tok::Comma)?;
        let b = self.expr()?;
        self.expect(Tok::RParen)?;
        let mut rest = Vec::new();
        while self.at(&Tok::Comma) {
            self.bump();
            // additive level only: a comparison would swallow the closing `>`
            rest.push(self.add_expr()?);
        }
        self.expect(Tok::Gt)?;
        self.expect(Tok::Semi)?;
        if lhs.len() != rest.len() + 1 {
            bail!(
                "{span}: Min multi-assign arity mismatch: {} lhs vs {} rhs",
                lhs.len(),
                rest.len() + 1
            );
        }
        Ok(Stmt::MinAssign { lhs, min_args: (a, b), rest, span })
    }

    fn if_stmt(&mut self, span: Span) -> Result<Stmt> {
        self.bump(); // if
        self.expect(Tok::LParen)?;
        let cond = self.expr()?;
        self.expect(Tok::RParen)?;
        let then_branch = self.block()?;
        let else_branch = if self.eat_ident("else") {
            if self.at_ident("if") {
                let inner = self.span();
                vec![self.if_stmt(inner)?]
            } else {
                self.block()?
            }
        } else {
            Vec::new()
        };
        Ok(Stmt::If { cond, then_branch, else_branch, span })
    }

    fn while_stmt(&mut self, span: Span) -> Result<Stmt> {
        self.bump();
        self.expect(Tok::LParen)?;
        let cond = self.expr()?;
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(Stmt::While { cond, body, span })
    }

    fn do_while(&mut self, span: Span) -> Result<Stmt> {
        self.bump(); // do
        let body = self.block()?;
        if !self.eat_ident("while") {
            bail!("{}: expected while after do-block", self.span());
        }
        self.expect(Tok::LParen)?;
        let cond = self.expr()?;
        self.expect(Tok::RParen)?;
        self.expect(Tok::Semi)?;
        Ok(Stmt::DoWhile { body, cond, span })
    }

    /// `forall (v in <domain>) { … }` / `for (...)`.
    fn loop_stmt(&mut self, parallel: bool, span: Span) -> Result<Stmt> {
        self.bump(); // forall | for
        self.expect(Tok::LParen)?;
        let var = self.ident()?;
        if !self.eat_ident("in") {
            bail!("{}: expected `in`", self.span());
        }
        let iter = self.iter_domain()?;
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(if parallel {
            Stmt::Forall { var, iter, body, span }
        } else {
            Stmt::For { var, iter, body, span }
        })
    }

    fn iter_domain(&mut self) -> Result<Iter> {
        let base = self.ident()?;
        if !self.at(&Tok::Dot) {
            return Ok(Iter::UpdateList(base));
        }
        self.bump(); // .
        let method = self.ident()?;
        self.expect(Tok::LParen)?;
        let mut args = Vec::new();
        if !self.at(&Tok::RParen) {
            loop {
                args.push(self.expr()?);
                if !self.at(&Tok::Comma) {
                    break;
                }
                self.bump();
            }
        }
        self.expect(Tok::RParen)?;
        let filter = if self.at(&Tok::Dot) {
            self.bump();
            if !self.eat_ident("filter") {
                bail!("{}: only .filter() may follow an iteration domain", self.span());
            }
            self.expect(Tok::LParen)?;
            let f = self.expr()?;
            self.expect(Tok::RParen)?;
            Some(f)
        } else {
            None
        };
        match method.as_str() {
            "nodes" => Ok(Iter::Nodes { graph: base, filter }),
            "neighbors" => Ok(Iter::Neighbors {
                graph: base,
                of: args.into_iter().next().ok_or_else(|| anyhow!("neighbors() needs arg"))?,
                filter,
            }),
            "nodes_to" => Ok(Iter::NodesTo {
                graph: base,
                of: args.into_iter().next().ok_or_else(|| anyhow!("nodes_to() needs arg"))?,
            }),
            other => bail!("{}: unknown iteration domain .{other}()", self.span()),
        }
    }

    fn fixed_point(&mut self, span: Span) -> Result<Stmt> {
        self.bump(); // fixedPoint
        if !self.eat_ident("until") {
            bail!("{}: expected `until`", self.span());
        }
        self.expect(Tok::LParen)?;
        let flag = self.ident()?;
        self.expect(Tok::Colon)?;
        self.expect(Tok::Not)?;
        let prop = self.ident()?;
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(Stmt::FixedPoint { flag, prop, body, span })
    }

    fn batch(&mut self, span: Span) -> Result<Stmt> {
        self.bump(); // Batch
        self.expect(Tok::LParen)?;
        let updates = self.ident()?;
        self.expect(Tok::Colon)?;
        let size = self.expr()?;
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(Stmt::Batch { updates, size, body, span })
    }

    fn on_update(&mut self, add: bool, span: Span) -> Result<Stmt> {
        self.bump(); // OnAdd | OnDelete
        self.expect(Tok::LParen)?;
        let var = self.ident()?;
        if !self.eat_ident("in") {
            bail!("{}: expected `in`", self.span());
        }
        let updates = self.ident()?;
        self.expect(Tok::Dot)?;
        if !self.eat_ident("currentBatch") {
            bail!("{}: expected currentBatch()", self.span());
        }
        self.expect(Tok::LParen)?;
        // optional selector arg (0 = deletes, 1 = adds) — ignored here,
        // the construct itself selects the subset.
        if !self.at(&Tok::RParen) {
            let _ = self.expr()?;
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(if add {
            Stmt::OnAdd { var, updates, body, span }
        } else {
            Stmt::OnDelete { var, updates, body, span }
        })
    }

    // ------------------------------------------------------ expressions

    /// An argument: either `name = expr` (kwarg) or a plain expression.
    fn arg_expr(&mut self) -> Result<Expr> {
        if let (Tok::Ident(name), Tok::Assign) = (self.peek(), self.peek2()) {
            let name = name.clone();
            self.bump();
            self.bump();
            let value = self.expr()?;
            return Ok(Expr::KwArg { name, value: Box::new(value) });
        }
        self.expr()
    }

    fn expr(&mut self) -> Result<Expr> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.and_expr()?;
        while self.at(&Tok::OrOr) {
            self.bump();
            let rhs = self.and_expr()?;
            lhs = Expr::Binary { op: BinOp::Or, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.cmp_expr()?;
        while self.at(&Tok::AndAnd) {
            self.bump();
            let rhs = self.cmp_expr()?;
            lhs = Expr::Binary { op: BinOp::And, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Lt => Some(BinOp::Lt),
            Tok::Gt => Some(BinOp::Gt),
            Tok::Le => Some(BinOp::Le),
            Tok::Ge => Some(BinOp::Ge),
            Tok::EqEq => Some(BinOp::Eq),
            Tok::Ne => Some(BinOp::Ne),
            _ => None,
        };
        if let Some(op) = op {
            self.bump();
            let rhs = self.add_expr()?;
            Ok(Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) })
        } else {
            Ok(lhs)
        }
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::Percent => BinOp::Mod,
                _ => break,
            };
            self.bump();
            let rhs = self.unary_expr()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr> {
        match self.peek() {
            Tok::Not => {
                self.bump();
                Ok(Expr::Unary { op: UnOp::Not, expr: Box::new(self.unary_expr()?) })
            }
            Tok::Minus => {
                self.bump();
                Ok(Expr::Unary { op: UnOp::Neg, expr: Box::new(self.unary_expr()?) })
            }
            _ => self.expr_primary_chain(),
        }
    }

    /// primary with member/method chains: `g.get_edge(u,v).weight` etc.
    fn expr_primary_chain(&mut self) -> Result<Expr> {
        let mut e = self.primary()?;
        while self.at(&Tok::Dot) {
            self.bump();
            let name = self.ident()?;
            if self.at(&Tok::LParen) {
                self.bump();
                let mut args = Vec::new();
                if !self.at(&Tok::RParen) {
                    loop {
                        args.push(self.arg_expr()?);
                        if !self.at(&Tok::Comma) {
                            break;
                        }
                        self.bump();
                    }
                }
                self.expect(Tok::RParen)?;
                e = Expr::MethodCall { base: Box::new(e), method: name, args };
            } else {
                e = Expr::Member { base: Box::new(e), prop: name };
            }
        }
        Ok(e)
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.bump() {
            Tok::Int(v) => Ok(Expr::IntLit(v)),
            Tok::Float(v) => Ok(Expr::FloatLit(v)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(w) => match w.as_str() {
                "True" => Ok(Expr::BoolLit(true)),
                "False" => Ok(Expr::BoolLit(false)),
                "INF" | "INT_MAX" => Ok(Expr::Inf),
                _ => {
                    if self.at(&Tok::LParen) {
                        self.bump();
                        let mut args = Vec::new();
                        if !self.at(&Tok::RParen) {
                            loop {
                                args.push(self.expr()?);
                                if !self.at(&Tok::Comma) {
                                    break;
                                }
                                self.bump();
                            }
                        }
                        self.expect(Tok::RParen)?;
                        Ok(Expr::Call { name: w, args })
                    } else {
                        Ok(Expr::Var(w))
                    }
                }
            },
            other => bail!("{}: unexpected token {other:?} in expression", self.span()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(path: &str) -> String {
        std::fs::read_to_string(path).unwrap()
    }

    #[test]
    fn parses_sssp_program() {
        let p = parse_program(&sp("dsl/sssp_dynamic.sp")).unwrap();
        assert_eq!(p.functions.len(), 4);
        let names: Vec<_> = p.functions.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["staticSSSP", "Incremental", "Decremental", "DynSSSP"]);
        let dyn_fn = p.find("DynSSSP").unwrap();
        assert_eq!(dyn_fn.kind, FnKind::Dynamic);
        assert_eq!(dyn_fn.params.len(), 7);
        // driver: static call then a Batch construct
        assert!(matches!(dyn_fn.body[0], Stmt::Expr(Expr::Call { .. })));
        assert!(matches!(dyn_fn.body[1], Stmt::Batch { .. }));
    }

    #[test]
    fn parses_pagerank_program() {
        let p = parse_program(&sp("dsl/pagerank_dynamic.sp")).unwrap();
        assert_eq!(p.functions.len(), 4);
        let st = p.find("staticPR").unwrap();
        // body ends with a do-while
        assert!(st.body.iter().any(|s| matches!(s, Stmt::DoWhile { .. })));
    }

    #[test]
    fn parses_tc_program() {
        let p = parse_program(&sp("dsl/tc_dynamic.sp")).unwrap();
        assert_eq!(p.functions.len(), 4);
        let st = p.find("staticTC").unwrap();
        assert!(matches!(st.body.last(), Some(Stmt::Return(_))));
    }

    #[test]
    fn parses_min_multiassign() {
        let src = r#"
        Static f(Graph g, propNode<int> dist) {
          forall (v in g.nodes()) {
            forall (nbr in g.neighbors(v)) {
              edge e = g.get_edge(v, nbr);
              <nbr.dist, nbr.m, nbr.parent> = <Min(nbr.dist, v.dist + e.weight), True, v>;
            }
          }
        }"#;
        let p = parse_program(src).unwrap();
        let f = &p.functions[0];
        let Stmt::Forall { body, .. } = &f.body[0] else { panic!() };
        let Stmt::Forall { body: inner, .. } = &body[0] else { panic!() };
        assert!(matches!(inner[1], Stmt::MinAssign { ref lhs, .. } if lhs.len() == 3));
    }

    #[test]
    fn parses_fixed_point_header() {
        let src = "Static f(Graph g) { bool fin = False; fixedPoint until (fin : !modified) { fin = True; } }";
        let p = parse_program(src).unwrap();
        assert!(p.functions[0]
            .body
            .iter()
            .any(|s| matches!(s, Stmt::FixedPoint { flag, prop, .. } if flag == "fin" && prop == "modified")));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_program("Static f(Graph g) { 5 = x; }").is_err());
        assert!(parse_program("NotAKind f() {}").is_err());
    }

    #[test]
    fn statements_carry_spans() {
        let src = "Static f(Graph g) {\n  int x = 0;\n  x = 1;\n}";
        let p = parse_program(src).unwrap();
        assert_eq!(p.functions[0].body[0].span(), Span { line: 2, col: 3 });
        assert_eq!(p.functions[0].body[1].span(), Span { line: 3, col: 3 });
    }

    #[test]
    fn parse_errors_carry_line_and_col() {
        let err = parse_program("Static f(Graph g) {\n  forall (v on g.nodes()) { }\n}")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2:"), "line:col in message: {err}");
    }

    #[test]
    fn parses_filter_with_compound_condition() {
        let src = "Static f(Graph g) { forall (v3 in g.neighbors(v1).filter(v3 != v2 && v3 != v1)) { int x = 0; } }";
        let p = parse_program(src).unwrap();
        let Stmt::Forall { iter: Iter::Neighbors { filter, .. }, .. } = &p.functions[0].body[0]
        else {
            panic!()
        };
        assert!(filter.is_some());
    }
}
