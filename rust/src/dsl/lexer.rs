//! Tokenizer for the StarPlat Dynamic DSL.

use crate::util::error::{bail, Result};

/// A lexical token with its source position (for diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: Tok,
    pub line: usize,
    /// 1-based column of the token's first character.
    pub col: usize,
}

/// Token kinds. Keywords are recognized in the parser from `Ident` where
/// that keeps the grammar simpler; structural keywords get their own
/// variants.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Int(i64),
    Float(f64),
    // punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    Lt,
    Gt,
    Le,
    Ge,
    EqEq,
    Ne,
    Assign,
    PlusEq,
    MinusEq,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Not,
    AndAnd,
    OrOr,
    Comma,
    Semi,
    Colon,
    Dot,
    Eof,
}

/// Tokenize DSL source. `//` line comments and `/* */` block comments
/// are skipped.
pub fn lex(src: &str) -> Result<Vec<Token>> {
    let b: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    let mut line = 1;
    // index of the current line's first character; col = i - line_start + 1
    let mut line_start = 0;
    let n = b.len();
    while i < n {
        let c = b[i];
        let col = i - line_start + 1;
        match c {
            '\n' => {
                line += 1;
                i += 1;
                line_start = i;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < n && b[i + 1] == '/' => {
                while i < n && b[i] != '\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                i += 2;
                while i + 1 < n && !(b[i] == '*' && b[i + 1] == '/') {
                    if b[i] == '\n' {
                        line += 1;
                        line_start = i + 1;
                    }
                    i += 1;
                }
                i = (i + 2).min(n);
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                let word: String = b[start..i].iter().collect();
                out.push(Token { kind: Tok::Ident(word), line, col });
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < n && (b[i].is_ascii_digit() || b[i] == '.') {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                let kind = if text.contains('.') {
                    Tok::Float(text.parse()?)
                } else {
                    Tok::Int(text.parse()?)
                };
                out.push(Token { kind, line, col });
            }
            _ => {
                let two: String = b[i..(i + 2).min(n)].iter().collect();
                let (kind, adv) = match two.as_str() {
                    "==" => (Tok::EqEq, 2),
                    "!=" => (Tok::Ne, 2),
                    "<=" => (Tok::Le, 2),
                    ">=" => (Tok::Ge, 2),
                    "+=" => (Tok::PlusEq, 2),
                    "-=" => (Tok::MinusEq, 2),
                    "&&" => (Tok::AndAnd, 2),
                    "||" => (Tok::OrOr, 2),
                    _ => match c {
                        '(' => (Tok::LParen, 1),
                        ')' => (Tok::RParen, 1),
                        '{' => (Tok::LBrace, 1),
                        '}' => (Tok::RBrace, 1),
                        '<' => (Tok::Lt, 1),
                        '>' => (Tok::Gt, 1),
                        '=' => (Tok::Assign, 1),
                        '+' => (Tok::Plus, 1),
                        '-' => (Tok::Minus, 1),
                        '*' => (Tok::Star, 1),
                        '/' => (Tok::Slash, 1),
                        '%' => (Tok::Percent, 1),
                        '!' => (Tok::Not, 1),
                        ',' => (Tok::Comma, 1),
                        ';' => (Tok::Semi, 1),
                        ':' => (Tok::Colon, 1),
                        '.' => (Tok::Dot, 1),
                        other => bail!("line {line}:{col}: unexpected character {other:?}"),
                    },
                };
                out.push(Token { kind, line, col });
                i += adv;
            }
        }
    }
    out.push(Token {
        kind: Tok::Eof,
        line,
        col: n.saturating_sub(line_start) + 1,
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_declaration() {
        let toks = lex("propNode<int> dist; // comment\n").unwrap();
        let kinds: Vec<_> = toks.into_iter().map(|t| t.kind).collect();
        assert_eq!(
            kinds,
            vec![
                Tok::Ident("propNode".into()),
                Tok::Lt,
                Tok::Ident("int".into()),
                Tok::Gt,
                Tok::Ident("dist".into()),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn lexes_operators_and_numbers() {
        let toks = lex("a += 1.5 <= 2 != x && !y").unwrap();
        let kinds: Vec<_> = toks.into_iter().map(|t| t.kind).collect();
        assert!(kinds.contains(&Tok::PlusEq));
        assert!(kinds.contains(&Tok::Float(1.5)));
        assert!(kinds.contains(&Tok::Le));
        assert!(kinds.contains(&Tok::Ne));
        assert!(kinds.contains(&Tok::AndAnd));
        assert!(kinds.contains(&Tok::Not));
    }

    #[test]
    fn block_comments_and_lines() {
        let toks = lex("a /* multi\nline */ b").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2, "line counting through block comment");
    }

    #[test]
    fn rejects_stray_chars() {
        let err = lex("a # b").unwrap_err().to_string();
        assert!(err.contains("line 1:3"), "position in message: {err}");
    }

    #[test]
    fn tracks_columns() {
        let toks = lex("ab cd\n  ef").unwrap();
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (1, 4));
        assert_eq!((toks[2].line, toks[2].col), (2, 3), "col resets per line");
    }
}
