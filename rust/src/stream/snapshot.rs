//! Epoch-versioned property publication: double-buffered snapshots behind
//! an atomic epoch.
//!
//! The consistency problem: while the engine is mid-propagate, its working
//! `dist`/`rank` arrays are torn — some entries reflect the new batch,
//! some the old graph. Chatterjee et al. solve the general multi-writer
//! case with non-blocking snapshots (PAPERS.md, "Dynamic Graph Operations:
//! A Consistent Non-blocking Approach"); here the writers are already
//! batch-serialized behind the batcher, so cheap **epoch double-buffering**
//! suffices:
//!
//! * two [`PropTable`] slots; slot `epoch & 1` is the published one;
//! * the engine fills the *unpublished* slot after each batch, then
//!   flips the epoch with a release store — readers never observe a
//!   partially-filled table;
//! * readers acquire-load the epoch and take a shared read lock on the
//!   published slot. The engine never writes that slot (it writes the
//!   other one), so readers are **never blocked by propagation** — the
//!   only possible wait is the bounded moment where a publish that is two
//!   epochs ahead recycles the slot a straggling reader still holds, and
//!   that blocks the *writer*, not the readers.
//!
//! Every table carries `(epoch, graph_epoch, |V|, |E|)` alongside the
//! property arrays, so a reader always sees a mutually-consistent
//! (graph-version, property) pair even if a newer epoch lands mid-query.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// One published property view. Only the arrays relevant to the running
/// algorithm are non-empty.
#[derive(Debug, Clone, Default)]
pub struct PropTable {
    /// Publication epoch (monotonic; 0 = never published).
    pub epoch: u64,
    /// `DynGraph::epoch()` at publish time — which graph version these
    /// properties were computed against.
    pub graph_epoch: u64,
    /// Per-shard graph epochs for sharded services (empty for the
    /// single-engine service). The stitch invariant — every shard at the
    /// same epoch in every published view — is what the sharded service's
    /// all-or-nothing publication guarantees; the epoch-stitch test
    /// hammers snapshots during propagation and asserts these stamps
    /// never diverge.
    pub shard_epochs: Vec<u64>,
    pub num_nodes: usize,
    pub num_edges: usize,
    /// SSSP distances (empty unless the service runs SSSP).
    pub dist: Vec<i64>,
    /// SSSP shortest-path-tree parents.
    pub parent: Vec<i64>,
    /// PageRank ranks (empty unless the service runs PR).
    pub rank: Vec<f64>,
    /// Triangle count (meaningful only when the service runs TC).
    pub triangles: i64,
    /// DSL program int-typed node properties by name (`serve --program`;
    /// empty otherwise).
    pub prog_ints: Vec<(String, Vec<i64>)>,
    /// DSL program float-typed node properties by name.
    pub prog_floats: Vec<(String, Vec<f64>)>,
    /// DSL program scalar return value, if the driver returns one.
    pub prog_result: Option<crate::dsl::bytecode::ScalarVal>,
}

/// The double-buffered publication cell.
#[derive(Debug, Default)]
pub struct SnapshotCell {
    slots: [RwLock<PropTable>; 2],
    epoch: AtomicU64,
}

impl SnapshotCell {
    pub fn new() -> Self {
        Self::default()
    }

    /// Latest published epoch (0 = nothing published yet).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Recovery epoch continuity: seed the counter at `e` so the next
    /// publish lands at `e + 1` — a recovered service resumes the epoch
    /// line where the crashed process left it (its recovered batch
    /// sequence number is a floor on the epochs the old process ever
    /// published) instead of restarting at 1. Only effective on a cell
    /// that has never published; after the first publish the slot parity
    /// is tied to the epoch and jumping it would re-point readers at the
    /// stale slot.
    pub fn resume_from(&self, e: u64) {
        let _ = self.epoch.compare_exchange(0, e, Ordering::AcqRel, Ordering::Acquire);
    }

    /// Engine side: fill the unpublished slot via `fill`, then flip the
    /// epoch. The slot's buffers are reused across publishes (capacity is
    /// retained), so steady-state publication allocates nothing.
    pub fn publish(&self, fill: impl FnOnce(&mut PropTable)) {
        let e = self.epoch.load(Ordering::Acquire);
        let next = e + 1;
        {
            let mut w = self.slots[(next & 1) as usize].write().unwrap();
            fill(&mut w);
            w.epoch = next;
        }
        self.epoch.store(next, Ordering::Release);
    }

    /// Reader side: run `f` against the currently-published table. The
    /// table is immutable while `f` runs; its `epoch`/`graph_epoch` fields
    /// say exactly which version was observed (a concurrent publish can
    /// promote the slot to a *newer complete* table between the epoch load
    /// and the lock, never to a torn one).
    pub fn read<R>(&self, f: impl FnOnce(&PropTable) -> R) -> R {
        let e = self.epoch.load(Ordering::Acquire);
        let guard = self.slots[(e & 1) as usize].read().unwrap();
        f(&guard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn publish_flips_epochs_and_reuses_slots() {
        let cell = SnapshotCell::new();
        assert_eq!(cell.epoch(), 0);
        cell.publish(|t| {
            t.num_nodes = 4;
            t.dist = vec![0, 1, 2, 3];
            t.graph_epoch = 0;
        });
        assert_eq!(cell.epoch(), 1);
        cell.read(|t| {
            assert_eq!(t.epoch, 1);
            assert_eq!(t.dist, vec![0, 1, 2, 3]);
        });
        cell.publish(|t| {
            t.num_nodes = 4;
            t.dist.clear();
            t.dist.extend_from_slice(&[9, 9, 9, 9]);
            t.graph_epoch = 1;
        });
        cell.read(|t| {
            assert_eq!(t.epoch, 2);
            assert_eq!(t.graph_epoch, 1);
            assert_eq!(t.dist, vec![9, 9, 9, 9]);
        });
    }

    /// Readers hammering the cell during continuous publishes must always
    /// see an internally-consistent table: the sentinel invariant is that
    /// every entry of `dist` equals the table's `graph_epoch` — a torn
    /// read would mix values from two publishes.
    #[test]
    fn concurrent_readers_always_see_consistent_tables() {
        let cell = Arc::new(SnapshotCell::new());
        let stop = Arc::new(AtomicBool::new(false));
        cell.publish(|t| {
            t.graph_epoch = 0;
            t.dist = vec![0; 256];
        });
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let cell = Arc::clone(&cell);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut reads = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        cell.read(|t| {
                            for &d in &t.dist {
                                assert_eq!(
                                    d as u64, t.graph_epoch,
                                    "torn snapshot: dist from a different epoch"
                                );
                            }
                        });
                        reads += 1;
                    }
                    reads
                })
            })
            .collect();
        for ge in 1..200u64 {
            cell.publish(|t| {
                t.graph_epoch = ge;
                t.dist.clear();
                t.dist.resize(256, ge as i64);
            });
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            assert!(r.join().unwrap() > 0, "readers made progress");
        }
    }
}
