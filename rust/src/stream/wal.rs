//! Segmented write-ahead log for the streaming service.
//!
//! Durability rides the batch-seal boundary: when the batcher seals a
//! coalesced batch, the engine loop appends one WAL record — the split
//! deletion/addition slices plus a monotonically increasing batch
//! sequence number — *before* computing on it. After a crash, recovery
//! loads the latest checkpoint (`stream::checkpoint`) and replays every
//! WAL record with a higher sequence number through the normal batch
//! pipeline, so a crash at any batch boundary reconverges bitwise with an
//! uninterrupted run. Updates accepted into the ingest queues but not yet
//! sealed are the acknowledged-but-volatile window; the WAL's unit of
//! durability is the sealed batch.
//!
//! On-disk layout (`<dir>/wal-<start_seq>.log`, zero-dep, little-endian):
//!
//! ```text
//! segment := "SPWL" 0x01 record*
//! record  := u32 payload_len | u64 fnv1a64(payload) | payload
//! payload := u64 seq | u32 n_dels | u32 n_adds
//!            | (u32 src, u32 dst)           * n_dels
//!            | (u32 src, u32 dst, i32 w)    * n_adds
//! ```
//!
//! A crash mid-append leaves a **torn tail**: a record whose length
//! prefix, payload, or checksum is incomplete. The reader stops at the
//! first invalid record and physically truncates the segment there —
//! torn tails are expected damage, never fatal. Fsync policy is a knob
//! ([`FsyncPolicy`]): `seal-fsync` fsyncs every appended record (a
//! machine crash loses nothing sealed), `os-buffered` leaves flushing to
//! the page cache (cheaper; a *process* crash still loses nothing
//! because the kernel holds the written bytes).

use crate::graph::{NodeId, Weight};
use crate::util::error::{bail, Context, Result};
use crate::util::failpoint;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const SEGMENT_MAGIC: &[u8; 5] = b"SPWL\x01";
/// Rotate to a fresh segment once the current one exceeds this.
const DEFAULT_SEGMENT_BYTES: u64 = 8 << 20;
/// Upper bound on a single record payload (corruption guard: a torn
/// length prefix must not make the reader attempt a huge allocation).
const MAX_PAYLOAD: u32 = 1 << 28;

/// When the WAL flushes appended records to stable storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// `fsync` after every sealed-batch append (survives machine crash).
    #[default]
    SealFsync,
    /// Write without fsync (survives process crash via the page cache).
    OsBuffered,
}

impl std::str::FromStr for FsyncPolicy {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "seal-fsync" | "fsync" => Ok(FsyncPolicy::SealFsync),
            "os-buffered" | "buffered" => Ok(FsyncPolicy::OsBuffered),
            other => Err(format!("unknown fsync policy {other:?} (seal-fsync|os-buffered)")),
        }
    }
}

impl FsyncPolicy {
    pub const fn name(self) -> &'static str {
        match self {
            FsyncPolicy::SealFsync => "seal-fsync",
            FsyncPolicy::OsBuffered => "os-buffered",
        }
    }
}

/// One replayed sealed batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    pub seq: u64,
    pub dels: Vec<(NodeId, NodeId)>,
    pub adds: Vec<(NodeId, NodeId, Weight)>,
}

#[inline]
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn segment_path(dir: &Path, start_seq: u64) -> PathBuf {
    dir.join(format!("wal-{start_seq:020}.log"))
}

/// Sorted `(start_seq, path)` list of the segments in `dir`.
fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    let entries = std::fs::read_dir(dir).with_context(|| format!("read WAL dir {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if let Some(seq) = name
            .strip_prefix("wal-")
            .and_then(|r| r.strip_suffix(".log"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            segs.push((seq, path));
        }
    }
    segs.sort_unstable_by_key(|&(seq, _)| seq);
    Ok(segs)
}

/// Appender half: owns the current tail segment, rotates on size.
pub struct WalWriter {
    dir: PathBuf,
    policy: FsyncPolicy,
    segment_bytes: u64,
    file: File,
    seg_bytes_written: u64,
    scratch: Vec<u8>,
}

impl WalWriter {
    /// Open the WAL in `dir` (created if absent) for appending batches
    /// starting at `next_seq`. Always begins a fresh segment — recovery
    /// has already truncated any torn tail, and old segments stay on disk
    /// until [`prune_below`](Self::prune_below) retires them.
    pub fn open(dir: &Path, policy: FsyncPolicy, next_seq: u64) -> Result<WalWriter> {
        std::fs::create_dir_all(dir).with_context(|| format!("create WAL dir {dir:?}"))?;
        let path = segment_path(dir, next_seq);
        let mut file = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("open WAL segment {path:?}"))?;
        file.write_all(SEGMENT_MAGIC)?;
        if policy == FsyncPolicy::SealFsync {
            file.sync_data()?;
        }
        Ok(WalWriter {
            dir: dir.to_path_buf(),
            policy,
            segment_bytes: DEFAULT_SEGMENT_BYTES,
            file,
            seg_bytes_written: SEGMENT_MAGIC.len() as u64,
            scratch: Vec::new(),
        })
    }

    /// Override the rotation threshold (tests use tiny segments).
    pub fn set_segment_bytes(&mut self, bytes: u64) {
        self.segment_bytes = bytes.max(64);
    }

    /// Append one sealed batch. With `FsyncPolicy::SealFsync` the record
    /// is on stable storage when this returns.
    pub fn append(
        &mut self,
        seq: u64,
        dels: &[(NodeId, NodeId)],
        adds: &[(NodeId, NodeId, Weight)],
    ) -> Result<()> {
        failpoint::hit("wal_append")?;
        let buf = &mut self.scratch;
        buf.clear();
        buf.extend_from_slice(&seq.to_le_bytes());
        buf.extend_from_slice(&(dels.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(adds.len() as u32).to_le_bytes());
        for &(u, v) in dels {
            buf.extend_from_slice(&u.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for &(u, v, w) in adds {
            buf.extend_from_slice(&u.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
            buf.extend_from_slice(&w.to_le_bytes());
        }
        let mut rec = Vec::with_capacity(12 + buf.len());
        rec.extend_from_slice(&(buf.len() as u32).to_le_bytes());
        rec.extend_from_slice(&fnv1a64(buf).to_le_bytes());
        rec.extend_from_slice(buf);
        self.file.write_all(&rec).context("append WAL record")?;
        if self.policy == FsyncPolicy::SealFsync {
            self.file.sync_data().context("fsync WAL segment")?;
        }
        self.seg_bytes_written += rec.len() as u64;
        if self.seg_bytes_written >= self.segment_bytes {
            self.rotate(seq + 1)?;
        }
        Ok(())
    }

    fn rotate(&mut self, next_seq: u64) -> Result<()> {
        self.file.sync_data().ok();
        let path = segment_path(&self.dir, next_seq);
        let mut file = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("rotate WAL segment {path:?}"))?;
        file.write_all(SEGMENT_MAGIC)?;
        if self.policy == FsyncPolicy::SealFsync {
            file.sync_data()?;
        }
        self.file = file;
        self.seg_bytes_written = SEGMENT_MAGIC.len() as u64;
        Ok(())
    }

    /// Delete segments made fully redundant by a checkpoint at `seq`
    /// (every record in them has sequence ≤ `seq`). A segment is provably
    /// covered when its *successor* segment starts at or below `seq + 1`.
    /// Returns the number of segments removed.
    pub fn prune_below(&self, seq: u64) -> Result<usize> {
        let segs = list_segments(&self.dir)?;
        let mut removed = 0;
        for pair in segs.windows(2) {
            let (_, ref path) = pair[0];
            let (next_start, _) = pair[1];
            if next_start <= seq + 1 {
                std::fs::remove_file(path)
                    .with_context(|| format!("prune WAL segment {path:?}"))?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

/// Everything recovery learned from the log.
#[derive(Debug, Default)]
pub struct ReplayInfo {
    /// Segments scanned.
    pub segments: usize,
    /// Bytes physically truncated off a torn tail (0 on a clean log).
    pub truncated_bytes: u64,
    /// Trailing segments discarded past a torn record.
    pub dropped_segments: usize,
}

/// Replay every record with `seq > from_seq`, in order. Stops at the
/// first torn/corrupt record, truncates that segment to its last valid
/// byte, and removes any later segments (nothing past a tear can be
/// applied without a sequence gap). Missing directory = empty log.
pub fn replay(dir: &Path, from_seq: u64) -> Result<(Vec<WalRecord>, ReplayInfo)> {
    let mut info = ReplayInfo::default();
    if !dir.exists() {
        return Ok((Vec::new(), info));
    }
    let segs = list_segments(dir)?;
    let mut records = Vec::new();
    let mut last_seq = from_seq;
    let mut torn = false;
    for (_, path) in &segs {
        if torn {
            std::fs::remove_file(path)
                .with_context(|| format!("drop post-tear WAL segment {path:?}"))?;
            info.dropped_segments += 1;
            continue;
        }
        info.segments += 1;
        let mut bytes = Vec::new();
        File::open(path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .with_context(|| format!("read WAL segment {path:?}"))?;
        let valid_end = scan_segment(&bytes, &mut last_seq, &mut records);
        if valid_end < bytes.len() {
            // Torn or corrupt tail: truncate the file to the last valid
            // record boundary and stop replaying.
            info.truncated_bytes += (bytes.len() - valid_end) as u64;
            let f = OpenOptions::new()
                .write(true)
                .open(path)
                .with_context(|| format!("truncate WAL segment {path:?}"))?;
            f.set_len(valid_end as u64)?;
            f.sync_data().ok();
            torn = true;
        }
    }
    Ok((records, info))
}

/// Decode records from one segment's bytes, pushing those past
/// `last_seq` into `out`. Returns the byte offset of the first invalid
/// record (== `bytes.len()` on a clean segment).
fn scan_segment(bytes: &[u8], last_seq: &mut u64, out: &mut Vec<WalRecord>) -> usize {
    if bytes.len() < SEGMENT_MAGIC.len() || &bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
        return 0;
    }
    let mut off = SEGMENT_MAGIC.len();
    loop {
        let rec_start = off;
        if bytes.len() - off < 12 {
            return rec_start; // torn length/checksum prefix (or clean EOF)
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let sum = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
        off += 12;
        if len > MAX_PAYLOAD || bytes.len() - off < len as usize {
            return rec_start; // torn payload
        }
        let payload = &bytes[off..off + len as usize];
        off += len as usize;
        if fnv1a64(payload) != sum {
            return rec_start; // bit rot / partial overwrite
        }
        match decode_payload(payload) {
            Some(rec) if rec.seq > *last_seq => {
                *last_seq = rec.seq;
                out.push(rec);
            }
            // Below/at the checkpoint horizon: already applied, skip.
            Some(_) => {}
            None => return rec_start,
        }
    }
}

fn decode_payload(payload: &[u8]) -> Option<WalRecord> {
    if payload.len() < 16 {
        return None;
    }
    let seq = u64::from_le_bytes(payload[0..8].try_into().unwrap());
    let n_dels = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
    let n_adds = u32::from_le_bytes(payload[12..16].try_into().unwrap()) as usize;
    let want = 16usize
        .checked_add(n_dels.checked_mul(8)?)?
        .checked_add(n_adds.checked_mul(12)?)?;
    if payload.len() != want {
        return None;
    }
    let mut off = 16;
    let mut dels = Vec::with_capacity(n_dels);
    for _ in 0..n_dels {
        let u = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
        let v = u32::from_le_bytes(payload[off + 4..off + 8].try_into().unwrap());
        off += 8;
        dels.push((u, v));
    }
    let mut adds = Vec::with_capacity(n_adds);
    for _ in 0..n_adds {
        let u = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
        let v = u32::from_le_bytes(payload[off + 4..off + 8].try_into().unwrap());
        let w = i32::from_le_bytes(payload[off + 8..off + 12].try_into().unwrap());
        off += 12;
        adds.push((u, v, w));
    }
    Some(WalRecord { seq, dels, adds })
}

/// The last sequence number present in the log (0 if empty) — used by
/// the kill-9 smoke to compare pre/post-crash progress.
pub fn last_seq(dir: &Path) -> Result<u64> {
    let (records, _) = replay(dir, 0)?;
    Ok(records.last().map(|r| r.seq).unwrap_or(0))
}

/// Truncate the final segment by `n` bytes — a deterministic "torn tail"
/// for tests and the chaos harness.
pub fn tear_tail(dir: &Path, n: u64) -> Result<()> {
    let segs = list_segments(dir)?;
    let Some((_, path)) = segs.last() else { bail!("no WAL segments in {dir:?}") };
    let f = OpenOptions::new().write(true).open(path)?;
    let len = f.metadata()?.len();
    f.set_len(len.saturating_sub(n))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("starplat-wal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample(seq: u64) -> WalRecord {
        WalRecord {
            seq,
            dels: vec![(seq as u32, seq as u32 + 1)],
            adds: vec![(seq as u32 + 2, seq as u32 + 3, -(seq as i32))],
        }
    }

    fn append_all(w: &mut WalWriter, recs: &[WalRecord]) {
        for r in recs {
            w.append(r.seq, &r.dels, &r.adds).unwrap();
        }
    }

    #[test]
    fn roundtrip_preserves_records_and_order() {
        let dir = tmpdir("roundtrip");
        let recs: Vec<_> = (1..=20).map(sample).collect();
        let mut w = WalWriter::open(&dir, FsyncPolicy::OsBuffered, 1).unwrap();
        append_all(&mut w, &recs);
        drop(w);
        let (got, info) = replay(&dir, 0).unwrap();
        assert_eq!(got, recs);
        assert_eq!(info.truncated_bytes, 0);
        // Replay from a checkpoint horizon skips the prefix.
        let (tail, _) = replay(&dir, 15).unwrap();
        assert_eq!(tail, recs[15..]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rotation_splits_segments_and_prune_respects_horizon() {
        let dir = tmpdir("rotate");
        let recs: Vec<_> = (1..=50).map(sample).collect();
        let mut w = WalWriter::open(&dir, FsyncPolicy::OsBuffered, 1).unwrap();
        w.set_segment_bytes(64); // force a rotation every record or two
        append_all(&mut w, &recs);
        let segs = list_segments(&dir).unwrap();
        assert!(segs.len() > 3, "expected rotation, got {} segments", segs.len());
        let (got, _) = replay(&dir, 0).unwrap();
        assert_eq!(got, recs);
        // Prune everything covered by a checkpoint at seq 30; replay of
        // the tail must be unaffected.
        let removed = w.prune_below(30).unwrap();
        assert!(removed > 0);
        let (tail, _) = replay(&dir, 30).unwrap();
        assert_eq!(tail, recs[30..]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let dir = tmpdir("torn");
        let recs: Vec<_> = (1..=10).map(sample).collect();
        let mut w = WalWriter::open(&dir, FsyncPolicy::SealFsync, 1).unwrap();
        append_all(&mut w, &recs);
        drop(w);
        tear_tail(&dir, 5).unwrap(); // rip bytes off the last record
        let (got, info) = replay(&dir, 0).unwrap();
        assert_eq!(got, recs[..9], "last record lost, prefix intact");
        assert!(info.truncated_bytes > 0);
        // After truncation the log is clean again and appendable.
        let (again, info2) = replay(&dir, 0).unwrap();
        assert_eq!(again, recs[..9]);
        assert_eq!(info2.truncated_bytes, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_checksum_stops_replay_at_the_bad_record() {
        let dir = tmpdir("corrupt");
        let recs: Vec<_> = (1..=5).map(sample).collect();
        let mut w = WalWriter::open(&dir, FsyncPolicy::OsBuffered, 1).unwrap();
        append_all(&mut w, &recs);
        drop(w);
        // Flip a byte in the middle of the last record's payload.
        let (_, path) = list_segments(&dir).unwrap().pop().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (got, info) = replay(&dir, 0).unwrap();
        assert_eq!(got, recs[..4]);
        assert!(info.truncated_bytes > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_is_an_empty_log() {
        let dir = tmpdir("missing");
        let (got, info) = replay(&dir, 0).unwrap();
        assert!(got.is_empty());
        assert_eq!(info.segments, 0);
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!("seal-fsync".parse::<FsyncPolicy>().unwrap(), FsyncPolicy::SealFsync);
        assert_eq!("os-buffered".parse::<FsyncPolicy>().unwrap(), FsyncPolicy::OsBuffered);
        assert!("sometimes".parse::<FsyncPolicy>().is_err());
    }
}
