//! Adaptive batch formation for the streaming service.
//!
//! The batcher pulls from the sharded [`Ingest`] queues and closes a batch
//! when either bound trips:
//! * **size** — `capacity` updates are buffered (throughput bound), or
//! * **latency** — the *oldest* buffered update has waited `deadline`
//!   (tail-latency bound; the deadline clock is enqueue time, so the bound
//!   covers queueing, not just batching).
//!
//! At close the batcher cancels every insert that precedes a delete of the
//! same edge inside the batch (the tail of the ingest coalescing window:
//! the pair straddled a drain, so the queues couldn't cancel it); the
//! delete itself flows through, exactly as in the ingest coalescer.
//! Without this, the engine's deletions-before-additions application order
//! would resurrect an edge the producer had already retracted.
//!
//! The batcher also owns the **merge policy** decision (ROADMAP "merge
//! policy tuning"): instead of `DynGraph`'s fixed every-k-batches period,
//! [`MergePolicy::Adaptive`] triggers `DynGraph::merge` from the
//! overflow-bitmap heat signal — merge only once enough sources pay the
//! diff-chain traversal tax, stay lazy while the chain is cold.
//!
//! The adaptive policy keys on two signals. The instantaneous
//! *touched-vertex fraction* (how many sources have any overflow edge)
//! catches broad, shallow churn. The **traversal-cost EWMA** tracked by
//! [`MergeGovernor`] catches the opposite shape — narrow-but-deep chains:
//! the expected extra diff-block probes *per neighbor read* is
//! `overflow_fraction × chain_len` (a flagged source walks every block),
//! and the governor exponentially averages that per-read chain *depth*
//! across batches so a sustained deep chain merges even when few vertices
//! are touched, while a one-batch spike does not.

use super::ingest::{Ingest, Stamped};
use crate::graph::updates::{Update, UpdateKind};
use crate::graph::{DynGraph, NodeId, Weight};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// When should the service compact the diff-CSR chain?
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MergePolicy {
    /// Merge every `batches` applied batches (the paper's §3.5 fixed
    /// period, service-side).
    Periodic { batches: usize },
    /// Merge when the chain is hot by either signal: at least
    /// `hot_fraction` of vertices carry overflow edges (every read on them
    /// walks the chain), the [`MergeGovernor`]'s per-read chain-depth EWMA
    /// reaches `depth_hot` expected extra block probes, or the chain
    /// reaches `max_chain` blocks (memory/latency backstop). While both
    /// signals say cold, merges are skipped entirely — point-update
    /// workloads keep their chain.
    Adaptive { hot_fraction: f64, max_chain: usize, depth_hot: f64 },
    /// Never merge (ablation / tests).
    Never,
}

/// Default depth threshold: merge once reads pay (in expectation, EWMA'd)
/// one extra diff-block probe per neighbor access.
pub const DEFAULT_DEPTH_HOT: f64 = 1.0;

impl Default for MergePolicy {
    fn default() -> Self {
        MergePolicy::Adaptive { hot_fraction: 0.05, max_chain: 32, depth_hot: DEFAULT_DEPTH_HOT }
    }
}

impl MergePolicy {
    /// Decide right after a batch was applied. `batches_since` counts
    /// applied batches since the last merge. Stateless form — the depth
    /// EWMA is unavailable here, so only the instantaneous signals fire;
    /// continuous callers should go through [`MergeGovernor`].
    pub fn should_merge(&self, g: &DynGraph, batches_since: usize) -> bool {
        self.should_merge_signal(
            g.diff_chain_len(),
            Self::overflow_fraction(g),
            batches_since,
        )
    }

    /// Signal-level variant: callers that already computed the chain
    /// length and overflow fraction (the engine loop reports both in its
    /// stats) pass them in so the bitmap is scanned once per batch.
    pub fn should_merge_signal(
        &self,
        chain_len: usize,
        overflow_fraction: f64,
        batches_since: usize,
    ) -> bool {
        self.should_merge_depth(chain_len, overflow_fraction, batches_since, 0.0)
    }

    /// Full-signal variant, including the per-read chain-depth EWMA a
    /// [`MergeGovernor`] maintains.
    pub fn should_merge_depth(
        &self,
        chain_len: usize,
        overflow_fraction: f64,
        batches_since: usize,
        ewma_depth: f64,
    ) -> bool {
        match *self {
            MergePolicy::Periodic { batches } => batches > 0 && batches_since >= batches,
            MergePolicy::Never => false,
            MergePolicy::Adaptive { hot_fraction, max_chain, depth_hot } => {
                chain_len > 0
                    && (chain_len >= max_chain.max(1)
                        || overflow_fraction >= hot_fraction
                        || ewma_depth >= depth_hot)
            }
        }
    }

    /// Current overflow heat in `[0, 1]` (exposed via service stats).
    pub fn overflow_fraction(g: &DynGraph) -> f64 {
        g.overflow_touched() as f64 / g.num_nodes().max(1) as f64
    }

    /// Expected extra diff-block probes per neighbor read, right now: a
    /// source with its overflow bit set walks every sealed block, so the
    /// per-read chain *depth* is `overflow_fraction × chain_len`.
    pub fn read_depth(g: &DynGraph) -> f64 {
        Self::overflow_fraction(g) * g.diff_chain_len() as f64
    }

    pub fn describe(&self) -> String {
        match *self {
            MergePolicy::Periodic { batches } => format!("periodic:{batches}"),
            MergePolicy::Adaptive { hot_fraction, max_chain, depth_hot } => {
                format!("adaptive:hot={hot_fraction},depth={depth_hot},max_chain={max_chain}")
            }
            MergePolicy::Never => "never".to_string(),
        }
    }
}

impl std::str::FromStr for MergePolicy {
    type Err = String;

    /// `periodic:<k>` | `adaptive[:<hot_fraction>[,<depth_hot>]]` | `never`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "periodic" => {
                let k = arg
                    .unwrap_or("8")
                    .parse::<usize>()
                    .map_err(|e| format!("bad periodic merge count: {e}"))?;
                Ok(MergePolicy::Periodic { batches: k })
            }
            "adaptive" => {
                let (hot, depth) = match arg {
                    None => ("0.05", None),
                    Some(a) => match a.split_once(',') {
                        None => (a, None),
                        Some((h, d)) => (h, Some(d)),
                    },
                };
                let f = hot
                    .parse::<f64>()
                    .map_err(|e| format!("bad adaptive hot fraction: {e}"))?;
                let d = depth
                    .map(|d| d.parse::<f64>().map_err(|e| format!("bad depth threshold: {e}")))
                    .transpose()?
                    .unwrap_or(DEFAULT_DEPTH_HOT);
                Ok(MergePolicy::Adaptive { hot_fraction: f, max_chain: 32, depth_hot: d })
            }
            "never" => Ok(MergePolicy::Never),
            other => Err(format!(
                "unknown merge policy {other:?} (periodic:<k>|adaptive[:<f>[,<d>]]|never)"
            )),
        }
    }
}

/// Exponential-smoothing weight for the per-read depth signal: ~4 batches
/// of memory, enough to ride out a single spiky batch.
const DEPTH_EWMA_LAMBDA: f64 = 0.25;

/// What the governor saw (and decided) at one batch boundary.
#[derive(Debug, Clone, Copy)]
pub struct MergeSignal {
    pub merge: bool,
    pub overflow_fraction: f64,
    /// Smoothed per-read chain depth at decision time.
    pub ewma_depth: f64,
}

/// Stateful merge decision-maker: owns the batches-since counter and the
/// traversal-cost (per-read chain depth) EWMA that the stateless
/// [`MergePolicy`] methods cannot track. One per engine loop.
#[derive(Debug, Clone)]
pub struct MergeGovernor {
    pub policy: MergePolicy,
    ewma_depth: f64,
    batches_since: usize,
}

impl MergeGovernor {
    pub fn new(policy: MergePolicy) -> Self {
        MergeGovernor { policy, ewma_depth: 0.0, batches_since: 0 }
    }

    /// Observe the post-batch graph, fold the instantaneous per-read depth
    /// into the EWMA, and decide. On a merge decision the internal state
    /// resets (the chain is about to vanish); the caller performs the
    /// actual [`DynGraph::merge`].
    pub fn after_batch(&mut self, g: &DynGraph) -> MergeSignal {
        self.observe(g.diff_chain_len(), MergePolicy::overflow_fraction(g))
    }

    /// Signal-level variant of [`after_batch`](Self::after_batch): the
    /// sharded service runs one governor *per shard*, feeding each its own
    /// shard's chain depth and owned-range overflow fraction, so a
    /// deep-chained shard compacts alone instead of triggering a global
    /// `merge_all` — while both service flavors share one EWMA/decision
    /// path.
    pub fn observe(&mut self, chain_len: usize, overflow_fraction: f64) -> MergeSignal {
        self.batches_since += 1;
        let depth_now = overflow_fraction * chain_len as f64;
        self.ewma_depth =
            DEPTH_EWMA_LAMBDA * depth_now + (1.0 - DEPTH_EWMA_LAMBDA) * self.ewma_depth;
        let merge = self.policy.should_merge_depth(
            chain_len,
            overflow_fraction,
            self.batches_since,
            self.ewma_depth,
        );
        let signal = MergeSignal { merge, overflow_fraction, ewma_depth: self.ewma_depth };
        if merge {
            self.batches_since = 0;
            self.ewma_depth = 0.0;
        }
        signal
    }

    /// Smoothed per-read chain depth (exposed via service stats).
    pub fn ewma_depth(&self) -> f64 {
        self.ewma_depth
    }
}

/// Why a batch was closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    /// Hit the size capacity.
    Size,
    /// Oldest buffered update hit the latency deadline.
    Deadline,
    /// Final flush during shutdown.
    Drain,
}

/// Metadata of one closed batch.
#[derive(Debug, Clone, Copy)]
pub struct BatchMeta {
    /// Updates drained into the batch, including pairs cancelled at close
    /// (completion accounting uses this).
    pub raw_len: usize,
    /// Updates that survive close-time coalescing.
    pub live_len: usize,
    /// Inserts cancelled at close (their deletes flow through the batch).
    pub coalesced: usize,
    /// Enqueue time of the oldest update in the batch.
    pub oldest: Option<Instant>,
    pub reason: CloseReason,
}

/// Pulls from [`Ingest`], forms batches, hands them to the engine loop as
/// reusable deletion/addition buffers. All buffers are retained across
/// batches: the steady-state loop is allocation-free.
pub struct Batcher {
    capacity: usize,
    deadline: Duration,
    symmetric: bool,
    buf: Vec<Stamped>,
    cancel: Vec<bool>,
    oldest: Option<Instant>,
    cursor: usize,
    gen_seen: u64,
    /// Edge key → indices of all not-yet-cancelled adds in `buf` (a delete
    /// cancels the whole set — see the ingest coalescer for the duplicate-
    /// insert rationale).
    scratch_adds: HashMap<(NodeId, NodeId), Vec<usize>>,
}

impl Batcher {
    pub fn new(capacity: usize, deadline: Duration, symmetric: bool) -> Self {
        Batcher {
            capacity: capacity.max(1),
            deadline,
            symmetric,
            buf: Vec::new(),
            cancel: Vec::new(),
            oldest: None,
            cursor: 0,
            gen_seen: 0,
            scratch_adds: HashMap::new(),
        }
    }

    /// Pull whatever is currently available, round-robin across shards,
    /// capped at remaining capacity.
    fn pull(&mut self, ingest: &Ingest) -> usize {
        let shards = ingest.num_shards();
        let mut pulled = 0;
        for k in 0..shards {
            let room = self.capacity - self.buf.len();
            if room == 0 {
                break;
            }
            let i = (self.cursor + k) % shards;
            pulled += ingest.drain_shard(i, &mut self.buf, room);
        }
        self.cursor = (self.cursor + 1) % shards.max(1);
        if pulled > 0 {
            // entries arrive in enqueue order per shard; track the global min
            for s in &self.buf[self.buf.len() - pulled..] {
                let older = match self.oldest {
                    None => true,
                    Some(o) => s.at < o,
                };
                if older {
                    self.oldest = Some(s.at);
                }
            }
        }
        pulled
    }

    /// Block until a batch closes (size, deadline, or shutdown flush).
    /// Returns `None` when the service is stopping and everything has been
    /// flushed. After `Some(meta)`, call [`take_into`](Self::take_into) to
    /// consume the batch.
    pub fn next_batch(&mut self, ingest: &Ingest, stop: &AtomicBool) -> Option<BatchMeta> {
        loop {
            self.pull(ingest);
            if self.buf.len() >= self.capacity {
                return Some(self.close(CloseReason::Size));
            }
            let now = Instant::now();
            if let Some(oldest) = self.oldest {
                if now.duration_since(oldest) >= self.deadline {
                    return Some(self.close(CloseReason::Deadline));
                }
            }
            if stop.load(Ordering::Acquire) {
                if ingest.queued() > 0 {
                    continue; // keep pulling the final backlog without waiting
                }
                if self.buf.is_empty() {
                    return None;
                }
                return Some(self.close(CloseReason::Drain));
            }
            let timeout = match self.oldest {
                Some(o) => self.deadline.saturating_sub(now.duration_since(o)),
                None => self.deadline, // idle tick
            };
            ingest.wait_for_data(&mut self.gen_seen, timeout.max(Duration::from_micros(100)));
        }
    }

    /// Close the open batch: cancel same-edge insert→delete pairs that
    /// landed in this batch, compute metadata.
    fn close(&mut self, reason: CloseReason) -> BatchMeta {
        let raw_len = self.buf.len();
        self.cancel.clear();
        self.cancel.resize(raw_len, false);
        self.scratch_adds.clear();
        let mut coalesced = 0;
        for i in 0..raw_len {
            let u = self.buf[i].upd;
            let key = if self.symmetric {
                (u.src.min(u.dst), u.src.max(u.dst))
            } else {
                (u.src, u.dst)
            };
            match u.kind {
                UpdateKind::Add => {
                    self.scratch_adds.entry(key).or_default().push(i);
                }
                UpdateKind::Delete => {
                    // Cancel the batch's earlier inserts of this edge; the
                    // delete itself stays (the edge may have been applied
                    // by an earlier batch or pre-exist in the graph, and a
                    // delete of an absent edge is a no-op at apply time).
                    if let Some(js) = self.scratch_adds.remove(&key) {
                        for j in &js {
                            self.cancel[*j] = true;
                        }
                        coalesced += js.len();
                    }
                }
            }
        }
        BatchMeta {
            raw_len,
            live_len: raw_len - coalesced,
            coalesced,
            oldest: self.oldest,
            reason,
        }
    }

    /// Decompose the closed batch into the caller's reusable buffers
    /// (cleared first) and reset the batcher for the next batch. In
    /// symmetric mode every update expands into both arcs.
    pub fn take_into(
        &mut self,
        dels: &mut Vec<(NodeId, NodeId)>,
        adds: &mut Vec<(NodeId, NodeId, Weight)>,
    ) {
        dels.clear();
        adds.clear();
        for (i, s) in self.buf.iter().enumerate() {
            if self.cancel.get(i).copied().unwrap_or(false) {
                continue;
            }
            let Update { kind, src, dst, weight } = s.upd;
            match kind {
                UpdateKind::Delete => {
                    dels.push((src, dst));
                    if self.symmetric {
                        dels.push((dst, src));
                    }
                }
                UpdateKind::Add => {
                    adds.push((src, dst, weight));
                    if self.symmetric {
                        adds.push((dst, src, weight));
                    }
                }
            }
        }
        self.buf.clear();
        self.cancel.clear();
        self.oldest = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn add(u: NodeId, v: NodeId) -> Update {
        Update { kind: UpdateKind::Add, src: u, dst: v, weight: 1 }
    }

    fn del(u: NodeId, v: NodeId) -> Update {
        Update { kind: UpdateKind::Delete, src: u, dst: v, weight: 0 }
    }

    #[test]
    fn closes_on_size() {
        let ing = Ingest::new(2, 64, false);
        let stop = AtomicBool::new(false);
        let mut b = Batcher::new(3, Duration::from_secs(60), false);
        for i in 0..5 {
            ing.submit(add(i, i + 10));
        }
        let meta = b.next_batch(&ing, &stop).unwrap();
        assert_eq!(meta.reason, CloseReason::Size);
        assert_eq!(meta.raw_len, 3);
        let (mut dels, mut adds) = (Vec::new(), Vec::new());
        b.take_into(&mut dels, &mut adds);
        assert_eq!(adds.len(), 3);
        assert!(dels.is_empty());
        // remaining two still queued
        assert_eq!(ing.queued(), 2);
    }

    #[test]
    fn closes_on_deadline() {
        let ing = Ingest::new(1, 64, false);
        let stop = AtomicBool::new(false);
        let mut b = Batcher::new(1000, Duration::from_millis(30), false);
        ing.submit(add(1, 2));
        let t0 = Instant::now();
        let meta = b.next_batch(&ing, &stop).unwrap();
        assert_eq!(meta.reason, CloseReason::Deadline);
        assert_eq!(meta.raw_len, 1);
        assert!(t0.elapsed() >= Duration::from_millis(25), "waited out the deadline");
    }

    #[test]
    fn drain_flush_on_stop() {
        let ing = Ingest::new(2, 64, false);
        let stop = AtomicBool::new(true);
        let mut b = Batcher::new(1000, Duration::from_secs(60), false);
        ing.submit(add(1, 2));
        ing.submit(del(9, 9));
        let meta = b.next_batch(&ing, &stop).unwrap();
        assert_eq!(meta.reason, CloseReason::Drain);
        assert_eq!(meta.raw_len, 2);
        assert!(b.next_batch(&ing, &stop).is_none(), "flushed service yields None");
    }

    #[test]
    fn close_time_coalescing_cancels_in_batch_inserts() {
        let ing = Ingest::new(1, 64, false);
        let stop = AtomicBool::new(true);
        let mut b = Batcher::new(100, Duration::from_secs(60), false);
        // drain the add out of the shard before submitting the delete, so
        // ingest-level coalescing cannot catch the pair
        ing.submit(add(4, 5));
        b.pull(&ing);
        ing.submit(del(4, 5));
        ing.submit(add(6, 7));
        let meta = b.next_batch(&ing, &stop).unwrap();
        assert_eq!(meta.raw_len, 3);
        assert_eq!(meta.coalesced, 1, "only the insert cancels");
        assert_eq!(meta.live_len, 2);
        let (mut dels, mut adds) = (Vec::new(), Vec::new());
        b.take_into(&mut dels, &mut adds);
        assert_eq!(dels, vec![(4, 5)], "the delete flows through");
        assert_eq!(adds, vec![(6, 7, 1)]);
    }

    #[test]
    fn close_time_coalescing_cancels_duplicate_adds_too() {
        let ing = Ingest::new(1, 64, false);
        let stop = AtomicBool::new(true);
        let mut b = Batcher::new(100, Duration::from_secs(60), false);
        ing.submit(add(4, 5));
        b.pull(&ing); // defeat the ingest-level coalescer
        ing.submit(add(4, 5));
        b.pull(&ing);
        ing.submit(del(4, 5));
        let meta = b.next_batch(&ing, &stop).unwrap();
        assert_eq!(meta.raw_len, 3);
        assert_eq!(meta.coalesced, 2, "both inserts cancel, the delete stays");
        let (mut dels, mut adds) = (Vec::new(), Vec::new());
        b.take_into(&mut dels, &mut adds);
        assert_eq!(dels, vec![(4, 5)]);
        assert!(adds.is_empty());
    }

    #[test]
    fn delete_then_add_same_edge_in_batch_is_preserved() {
        // replace semantics: D before A must survive close-time coalescing
        let ing = Ingest::new(1, 64, false);
        let stop = AtomicBool::new(true);
        let mut b = Batcher::new(100, Duration::from_secs(60), false);
        ing.submit(del(4, 5));
        b.pull(&ing); // split across pulls like a real drain
        ing.submit(add(4, 5));
        let meta = b.next_batch(&ing, &stop).unwrap();
        assert_eq!(meta.coalesced, 0);
        let (mut dels, mut adds) = (Vec::new(), Vec::new());
        b.take_into(&mut dels, &mut adds);
        assert_eq!(dels, vec![(4, 5)]);
        assert_eq!(adds, vec![(4, 5, 1)]);
    }

    #[test]
    fn symmetric_take_expands_arcs() {
        let ing = Ingest::new(1, 64, true);
        let stop = AtomicBool::new(true);
        let mut b = Batcher::new(100, Duration::from_secs(60), true);
        ing.submit(add(2, 7));
        ing.submit(del(8, 3));
        b.next_batch(&ing, &stop).unwrap();
        let (mut dels, mut adds) = (Vec::new(), Vec::new());
        b.take_into(&mut dels, &mut adds);
        assert_eq!(adds, vec![(2, 7, 1), (7, 2, 1)]);
        assert_eq!(dels, vec![(8, 3), (3, 8)]);
    }

    #[test]
    fn adaptive_policy_fires_on_hot_chain_only() {
        // paper_example-ish graph with full base ranges: overflow quickly
        let mut g = generators::uniform_random(64, 256, 5, 3);
        g.merge_period = 0;
        let cold =
            MergePolicy::Adaptive { hot_fraction: 0.5, max_chain: 1000, depth_hot: f64::MAX };
        let hot =
            MergePolicy::Adaptive { hot_fraction: 0.0, max_chain: 1000, depth_hot: f64::MAX };
        assert!(!cold.should_merge(&g, 100), "clean chain never merges");
        assert!(!hot.should_merge(&g, 100), "hot_fraction 0 still needs a chain");
        // force overflow inserts: fresh out-edges from every vertex
        let adds: Vec<_> = (0..64u32).map(|u| (u, (u + 32) % 64, 1)).collect();
        g.apply_additions(&adds);
        if g.diff_chain_len() > 0 {
            assert!(hot.should_merge(&g, 1));
            assert_eq!(
                cold.should_merge(&g, 1),
                MergePolicy::overflow_fraction(&g) >= 0.5
            );
        }
        assert!(!MergePolicy::Never.should_merge(&g, 1000));
        assert!(MergePolicy::Periodic { batches: 2 }.should_merge(&g, 2));
        assert!(!MergePolicy::Periodic { batches: 2 }.should_merge(&g, 1));
    }

    #[test]
    fn merge_policy_parses() {
        assert_eq!("never".parse::<MergePolicy>().unwrap(), MergePolicy::Never);
        assert_eq!(
            "periodic:4".parse::<MergePolicy>().unwrap(),
            MergePolicy::Periodic { batches: 4 }
        );
        match "adaptive:0.1".parse::<MergePolicy>().unwrap() {
            MergePolicy::Adaptive { hot_fraction, depth_hot, .. } => {
                assert!((hot_fraction - 0.1).abs() < 1e-12);
                assert!((depth_hot - DEFAULT_DEPTH_HOT).abs() < 1e-12);
            }
            other => panic!("{other:?}"),
        }
        match "adaptive:0.1,2.5".parse::<MergePolicy>().unwrap() {
            MergePolicy::Adaptive { hot_fraction, depth_hot, .. } => {
                assert!((hot_fraction - 0.1).abs() < 1e-12);
                assert!((depth_hot - 2.5).abs() < 1e-12);
            }
            other => panic!("{other:?}"),
        }
        assert!("bogus".parse::<MergePolicy>().is_err());
        assert!("adaptive:0.1,x".parse::<MergePolicy>().is_err());
    }

    /// A deep-but-narrow chain must trip the depth EWMA even though the
    /// touched-vertex fraction stays below `hot_fraction`: one overflowing
    /// source accumulating sealed blocks batch after batch.
    #[test]
    fn governor_depth_ewma_fires_on_deep_narrow_chain() {
        let mut g = generators::uniform_random(256, 1024, 5, 9);
        g.merge_period = 0;
        // hot_fraction impossible to reach with one touched vertex
        // (1/256 ≈ 0.004); depth threshold reachable once the chain of
        // that vertex is deep enough for sustained rounds.
        let policy = MergePolicy::Adaptive {
            hot_fraction: 0.5,
            max_chain: usize::MAX,
            depth_hot: 0.05,
        };
        let mut gov = MergeGovernor::new(policy);
        // pick one source with a full base range so every insert overflows
        let src = (0..256u32)
            .find(|&u| {
                let b = g.fwd_base();
                b.live_degree(u) > 0 && b.live_degree(u) == b.slot_range(u).len()
            })
            .expect("some full range exists");
        let mut fired = false;
        for i in 0..400u32 {
            let dst = (src + 1 + i) % 256;
            g.apply_additions(&[(src, dst, 1)]);
            let sig = gov.after_batch(&g);
            assert!(
                MergePolicy::overflow_fraction(&g) < 0.5,
                "the narrow workload must stay below hot_fraction"
            );
            if sig.merge {
                fired = true;
                g.merge();
                assert_eq!(gov.ewma_depth(), 0.0, "state resets on merge");
                break;
            }
        }
        assert!(fired, "depth EWMA never fired on a deep narrow chain");
    }

    /// A single spiky batch must *not* fire the smoothed depth signal.
    #[test]
    fn governor_depth_ewma_rides_out_single_spike() {
        let mut g = generators::uniform_random(64, 256, 5, 3);
        g.merge_period = 0;
        let policy = MergePolicy::Adaptive {
            hot_fraction: 2.0, // unreachable
            max_chain: usize::MAX,
            depth_hot: 1.0,
        };
        let mut gov = MergeGovernor::new(policy);
        // one hot batch: fresh out-edges from every vertex
        let adds: Vec<_> = (0..64u32).map(|u| (u, (u + 32) % 64, 1)).collect();
        g.apply_additions(&adds);
        let instantaneous = MergePolicy::read_depth(&g);
        let sig = gov.after_batch(&g);
        assert!(sig.ewma_depth < instantaneous, "EWMA smooths the spike");
        assert!(!sig.merge, "one spike must not trigger a merge");
        // …but the same heat sustained for several batches does.
        let mut fired = false;
        for i in 0..40u32 {
            let adds: Vec<_> = (0..64u32).map(|u| (u, (u + 2 + i) % 64, 1)).collect();
            g.apply_additions(&adds);
            if gov.after_batch(&g).merge {
                fired = true;
                break;
            }
        }
        assert!(fired, "sustained depth must eventually merge");
    }
}
