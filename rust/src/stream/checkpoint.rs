//! Periodic checkpoints of the streaming service's sealed state.
//!
//! A checkpoint is the durable complement of the WAL (`stream::wal`): it
//! serializes the engine's graph (live edge set), the evolved algorithm
//! state, and the batch sequence number it covers, so recovery is
//! `load_latest()` + replay of the WAL records past `seq` instead of a
//! full-log replay from genesis. The algorithm state **must** be part of
//! the checkpoint: dynamic PageRank is path-dependent (restricted sweeps
//! from the previous ranks), so recomputing a static solve on the
//! recovered graph would diverge from the uninterrupted run — restoring
//! the serialized arrays is what makes crash/recover bitwise-equal.
//!
//! On-disk layout (`<dir>/checkpoint-<seq>.ckpt`, little-endian):
//!
//! ```text
//! file := "SPCK" 0x01 body u64 fnv1a64(body)
//! body := u8 algo | u64 seq | u64 graph_epoch | u64 n | u64 m
//!         | (u32 src, u32 dst, i32 w) * m
//!         | state                    (per-algo arrays, see below)
//! ```
//!
//! Writes are atomic: the file is assembled as `.tmp`, fsynced, then
//! renamed over the final name (a crash mid-checkpoint leaves either the
//! previous checkpoint or a stray `.tmp`, never a torn `.ckpt`).
//! [`load_latest`] tries newest-first and skips damaged files, so a
//! corrupt checkpoint degrades recovery to the previous one plus a longer
//! WAL replay — never to a failure.

use super::service::AlgoState;
use crate::algorithms::{PrState, SsspState, TcState};
use crate::graph::{DynGraph, NodeId, Weight};
use crate::util::error::{bail, Context, Result};
use crate::util::failpoint;
use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 5] = b"SPCK\x01";

#[inline]
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A recoverable point-in-time image of the engine's sealed state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Last batch sequence number applied before this image was taken.
    pub seq: u64,
    /// `DynGraph::epoch()` at capture (informational; the restored graph
    /// restarts its own epoch counter).
    pub graph_epoch: u64,
    pub num_nodes: usize,
    /// The live edge set, sorted (`DynGraph::edges_sorted`).
    pub edges: Vec<(NodeId, NodeId, Weight)>,
    pub state: AlgoState,
}

impl Checkpoint {
    /// Capture the engine's state after batch `seq` was applied.
    pub fn capture(seq: u64, g: &DynGraph, state: &AlgoState) -> Checkpoint {
        Self::capture_parts(seq, g.epoch(), g.num_nodes(), g.edges_sorted(), state)
    }

    /// [`capture`](Self::capture) from pre-extracted parts — the sharded
    /// service images its `ShardedGraph` through this (same sorted edge
    /// set, no intermediate `DynGraph`).
    pub fn capture_parts(
        seq: u64,
        graph_epoch: u64,
        num_nodes: usize,
        edges: Vec<(NodeId, NodeId, Weight)>,
        state: &AlgoState,
    ) -> Checkpoint {
        Checkpoint { seq, graph_epoch, num_nodes, edges, state: state.clone() }
    }

    /// Rebuild the graph image (a fresh diff-CSR over the checkpointed
    /// edge set; tombstone/diff layout is not preserved — the edge set
    /// and every property are, which is what result equivalence needs).
    pub fn restore_graph(&self) -> DynGraph {
        DynGraph::from_edges(self.num_nodes, &self.edges)
    }

    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(64 + self.edges.len() * 12);
        let tag: u8 = match &self.state {
            AlgoState::Sssp(_) => 0,
            AlgoState::Pr(_) => 1,
            AlgoState::Tc(_) => 2,
            AlgoState::Program { .. } => {
                unreachable!("program state is never checkpointed (serve --program rejects --wal)")
            }
        };
        b.push(tag);
        b.extend_from_slice(&self.seq.to_le_bytes());
        b.extend_from_slice(&self.graph_epoch.to_le_bytes());
        b.extend_from_slice(&(self.num_nodes as u64).to_le_bytes());
        b.extend_from_slice(&(self.edges.len() as u64).to_le_bytes());
        for &(u, v, w) in &self.edges {
            b.extend_from_slice(&u.to_le_bytes());
            b.extend_from_slice(&v.to_le_bytes());
            b.extend_from_slice(&w.to_le_bytes());
        }
        match &self.state {
            AlgoState::Sssp(st) => {
                b.extend_from_slice(&st.source.to_le_bytes());
                for &d in &st.dist {
                    b.extend_from_slice(&d.to_le_bytes());
                }
                for &p in &st.parent {
                    b.extend_from_slice(&p.to_le_bytes());
                }
            }
            AlgoState::Pr(st) => {
                b.extend_from_slice(&st.beta.to_le_bytes());
                b.extend_from_slice(&st.delta.to_le_bytes());
                b.extend_from_slice(&(st.max_iter as u64).to_le_bytes());
                for &r in &st.rank {
                    b.extend_from_slice(&r.to_le_bytes());
                }
            }
            AlgoState::Tc(st) => {
                b.extend_from_slice(&st.triangles.to_le_bytes());
            }
            AlgoState::Program { .. } => {
                unreachable!("program state is never checkpointed (serve --program rejects --wal)")
            }
        }
        b
    }

    fn decode(body: &[u8]) -> Result<Checkpoint> {
        let mut c = Cursor { buf: body, off: 0 };
        let tag = c.u8()?;
        let seq = c.u64()?;
        let graph_epoch = c.u64()?;
        let n = c.u64()? as usize;
        let m = c.u64()? as usize;
        // corruption guard before the big allocations
        if body.len() < 33 + m.saturating_mul(12) {
            bail!("checkpoint body shorter than its edge count claims");
        }
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            let u = c.u32()?;
            let v = c.u32()?;
            let w = c.i32()?;
            edges.push((u, v, w));
        }
        let state = match tag {
            0 => {
                let source = c.u32()?;
                if body.len() - c.off != n.saturating_mul(16) {
                    bail!("checkpoint SSSP arrays do not match node count {n}");
                }
                let mut dist = Vec::with_capacity(n);
                for _ in 0..n {
                    dist.push(c.i64()?);
                }
                let mut parent = Vec::with_capacity(n);
                for _ in 0..n {
                    parent.push(c.i64()?);
                }
                AlgoState::Sssp(SsspState { dist, parent, source })
            }
            1 => {
                let beta = c.f64()?;
                let delta = c.f64()?;
                let max_iter = c.u64()? as usize;
                if body.len() - c.off != n.saturating_mul(8) {
                    bail!("checkpoint PR rank array does not match node count {n}");
                }
                let mut rank = Vec::with_capacity(n);
                for _ in 0..n {
                    rank.push(c.f64()?);
                }
                AlgoState::Pr(PrState { rank, beta, delta, max_iter })
            }
            2 => AlgoState::Tc(TcState { triangles: c.i64()? }),
            t => bail!("checkpoint has unknown algo tag {t}"),
        };
        if c.off != body.len() {
            bail!("checkpoint has {} trailing bytes", body.len() - c.off);
        }
        Ok(Checkpoint { seq, graph_epoch, num_nodes: n, edges, state })
    }

    /// Write atomically into `dir` (created if absent): assemble as
    /// `.tmp`, fsync, rename. Returns the final path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        failpoint::hit("checkpoint")?;
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {dir:?}"))?;
        let body = self.encode();
        let final_path = dir.join(format!("checkpoint-{:020}.ckpt", self.seq));
        let tmp_path = final_path.with_extension("ckpt.tmp");
        {
            let mut f = File::create(&tmp_path)
                .with_context(|| format!("create {tmp_path:?}"))?;
            f.write_all(MAGIC)?;
            f.write_all(&body)?;
            f.write_all(&fnv1a64(&body).to_le_bytes())?;
            f.sync_data().context("fsync checkpoint")?;
        }
        std::fs::rename(&tmp_path, &final_path)
            .with_context(|| format!("publish checkpoint {final_path:?}"))?;
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all(); // persist the rename itself
        }
        Ok(final_path)
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.buf.len() - self.off < n {
            bail!("checkpoint truncated at offset {}", self.off);
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn list_checkpoints(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if let Some(seq) = name
            .strip_prefix("checkpoint-")
            .and_then(|r| r.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            out.push((seq, path));
        }
    }
    out.sort_unstable_by_key(|&(seq, _)| seq);
    Ok(out)
}

/// Load one checkpoint file, validating magic + checksum.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut bytes = Vec::new();
    File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .with_context(|| format!("read checkpoint {path:?}"))?;
    if bytes.len() < MAGIC.len() + 8 || &bytes[..MAGIC.len()] != MAGIC {
        bail!("checkpoint {path:?}: bad magic or truncated header");
    }
    let body = &bytes[MAGIC.len()..bytes.len() - 8];
    let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv1a64(body) != sum {
        bail!("checkpoint {path:?}: checksum mismatch");
    }
    Checkpoint::decode(body)
}

/// Load the newest valid checkpoint in `dir`, skipping damaged files
/// (newest-first). `Ok(None)` when the directory holds none.
pub fn load_latest(dir: &Path) -> Result<Option<Checkpoint>> {
    let mut cks = list_checkpoints(dir)?;
    cks.reverse();
    for (_, path) in cks {
        match load(&path) {
            Ok(ck) => return Ok(Some(ck)),
            Err(_) => continue, // damaged: fall back to the previous one
        }
    }
    Ok(None)
}

/// Retire all but the newest `keep` checkpoints. Returns how many files
/// were removed.
pub fn prune(dir: &Path, keep: usize) -> Result<usize> {
    let cks = list_checkpoints(dir)?;
    let mut removed = 0;
    if cks.len() > keep {
        for (_, path) in &cks[..cks.len() - keep] {
            std::fs::remove_file(path)
                .with_context(|| format!("prune checkpoint {path:?}"))?;
            removed += 1;
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("starplat-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sssp_ck(seq: u64) -> (DynGraph, Checkpoint) {
        let g = generators::uniform_random(50, 250, 9, seq);
        let st = crate::algorithms::sssp::static_sssp(&g, 0);
        let ck = Checkpoint::capture(seq, &g, &AlgoState::Sssp(st));
        (g, ck)
    }

    #[test]
    fn roundtrip_restores_graph_and_state() {
        let dir = tmpdir("roundtrip");
        let (g, ck) = sssp_ck(7);
        let path = ck.write(&dir).unwrap();
        let got = load(&path).unwrap();
        assert_eq!(got.seq, 7);
        assert_eq!(got.num_nodes, g.num_nodes());
        assert_eq!(got.edges, g.edges_sorted());
        assert_eq!(got.restore_graph().edges_sorted(), g.edges_sorted());
        match (&got.state, &ck.state) {
            (AlgoState::Sssp(a), AlgoState::Sssp(b)) => {
                assert_eq!(a.dist, b.dist);
                assert_eq!(a.parent, b.parent);
                assert_eq!(a.source, b.source);
            }
            _ => panic!("algo tag changed in flight"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pr_and_tc_states_roundtrip() {
        let dir = tmpdir("algos");
        let g = generators::uniform_random(40, 160, 9, 3);
        let pr = PrState { rank: vec![0.25; 40], beta: 1e-3, delta: 0.85, max_iter: 50 };
        let ck = Checkpoint::capture(1, &g, &AlgoState::Pr(pr.clone()));
        ck.write(&dir).unwrap();
        let got = load_latest(&dir).unwrap().unwrap();
        match got.state {
            AlgoState::Pr(st) => {
                assert_eq!(st.rank, pr.rank);
                assert_eq!(st.max_iter, 50);
            }
            _ => panic!("expected PR state"),
        }
        let tc = Checkpoint::capture(2, &g, &AlgoState::Tc(TcState { triangles: -7 }));
        tc.write(&dir).unwrap();
        let got = load_latest(&dir).unwrap().unwrap();
        assert_eq!(got.seq, 2, "latest wins");
        match got.state {
            AlgoState::Tc(st) => assert_eq!(st.triangles, -7),
            _ => panic!("expected TC state"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_latest_falls_back_to_previous() {
        let dir = tmpdir("fallback");
        let (_, ck1) = sssp_ck(1);
        let (_, ck2) = sssp_ck(2);
        ck1.write(&dir).unwrap();
        let p2 = ck2.write(&dir).unwrap();
        // Damage the newest file.
        let mut bytes = std::fs::read(&p2).unwrap();
        let n = bytes.len();
        bytes[n / 2] ^= 0xff;
        std::fs::write(&p2, &bytes).unwrap();
        let got = load_latest(&dir).unwrap().unwrap();
        assert_eq!(got.seq, 1, "recovery degrades to the previous checkpoint");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_keeps_newest() {
        let dir = tmpdir("prune");
        for seq in 1..=5 {
            sssp_ck(seq).1.write(&dir).unwrap();
        }
        assert_eq!(prune(&dir, 2).unwrap(), 3);
        let left = list_checkpoints(&dir).unwrap();
        assert_eq!(left.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![4, 5]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_dir_loads_none() {
        let dir = tmpdir("empty");
        assert!(load_latest(&dir).unwrap().is_none());
    }
}
