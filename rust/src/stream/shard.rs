//! Graph sharding across engine threads: the scale-out substrate of the
//! sharded streaming service (ROADMAP "streaming layer scale-out").
//!
//! [`ShardedGraph`] splits one logical dynamic graph over N shards by
//! **vertex ownership**: shard `r` owns the contiguous vertex block of an
//! edge-mass-balanced [`PartitionMap`] (degree-weighted boundaries — the
//! degree-balanced follow-up to the PR 3 partition contract) and stores
//! exactly the edges whose *source* it owns, as a full-vertex-space
//! [`DynGraph`] — the same owner-computes convention as the `dist`
//! backend's MPI partitioning (§3.6: "a process stores only those edges
//! for which the source node is owned by that process"). Because every
//! shard keeps its own diff-CSR, batch application — including
//! `seal_batch` — is **shard-local**: shards mutate their structures
//! concurrently with no sharing at all.
//!
//! [`ShardedEngine`] runs the dynamic pipelines over the sharded graph in
//! bulk-synchronous rounds. Phases execute on a **persistent shard
//! fleet** when one is attached ([`ShardedEngine::attach_fleet`]): one
//! long-lived pinned worker per shard receives the phase closure over its
//! channel and meets the coordinator at a reusable sense-reversing
//! barrier ([`crate::util::barrier`]) — no thread spawn/join on the hot
//! path. Without a fleet, phases fall back to the original
//! spawn-per-phase `std::thread::scope` model (the bench baseline; also
//! what plain `ShardedEngine::new()` tests exercise):
//!
//! * **push phases** (incremental SSSP) walk owned frontier out-edges and
//!   emit `(dst, candidate)` relax messages bucketed by the destination's
//!   owner — the in-process mirror of the `dist` backend's halo exchange.
//!   Messages are exchanged *between* rounds; each shard then drains its
//!   inbox with exclusive ownership of its distance block, so no phase
//!   ever takes a lock or issues an atomic on the property arrays;
//! * **pull phases** (decremental SSSP, PR sweeps, parent repair) are
//!   owner-writes: shard `r` writes only its contiguous block
//!   (`split_at_mut`-partitioned, safe Rust) while reading the previous
//!   round's values and any shard's adjacency immutably. A vertex's
//!   in-edges live with their *source* owners, so a pull over `v` chains
//!   `in_neighbors(v)` across every shard's transpose;
//! * **reductions** (TC wedge counts, PR convergence deltas) fold
//!   per-shard partials in shard order, so results are deterministic for
//!   a fixed shard count.
//!
//! Equivalence is pinned by `tests/stream_equivalence.rs`: SSSP and TC
//! end-states are *bitwise* equal to the single-engine service and the
//! offline batch pipeline across shards ∈ {1, 2, 4, 8} (SSSP's fixed point
//! is unique and the parent repair is a deterministic argmin; TC counts
//! are order-independent integers), and PR is oracle-equal within the
//! convergence tolerance (float sums reassociate across shard
//! boundaries).
//!
//! The shard fleet is deliberately *not* a `backend::DynamicEngine`
//! instance: its entry points take per-shard routed buffers, not whole
//! batches, and its parallelism is the partition itself. The
//! single-engine [`GraphService`](super::GraphService) is the
//! trait-backed flavor (`serve --backend {serial,cpu,dist,xla}`);
//! running *this* fleet over non-cpu engines — or heterogeneous shards —
//! is the ROADMAP "streaming backends" follow-up.

use crate::algorithms::{pagerank, sssp, PrState, SsspState, TcState, INF};
use crate::graph::partition::PartitionMap;
use crate::graph::{DynGraph, NodeId, Weight};
use crate::telemetry::{Stage, Track};
use crate::util::{ShardFleet, SyncSlice};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Frontier-chunk granularity of the scatter phase — the unit of in-phase
/// work stealing. Small enough that a hub shard's frontier splits into
/// many stealable pieces, large enough that the claim (one `fetch_add`)
/// amortizes.
const STEAL_CHUNK: usize = 64;

/// Run one phase: worker `r` executes `job(r)` for every shard, and the
/// call returns only when all shards finished (the superstep barrier).
///
/// With a matching fleet the closures are delivered to the resident
/// workers; otherwise (or for a single shard, which runs inline) this is
/// the original spawn-per-phase scoped fallback.
pub(crate) fn exec_shards(
    fleet: Option<&ShardFleet>,
    nshards: usize,
    job: &(dyn Fn(usize) + Sync),
) {
    if nshards <= 1 {
        job(0);
        return;
    }
    match fleet {
        Some(f) if f.workers() == nshards => f.run(job),
        _ => std::thread::scope(|sc| {
            for r in 0..nshards {
                sc.spawn(move || job(r));
            }
        }),
    }
}

/// Borrow rank `r`'s owned block out of a shared slice — the owner-writes
/// idiom for fleet phases, where one `Fn(usize)` closure is shared by all
/// workers and per-worker `&mut` blocks cannot be moved in.
///
/// # Safety
/// Caller must guarantee worker `r` is the only one touching `r`'s owned
/// range during the current phase (the partition ranges are disjoint, so
/// calling this with distinct `r` per worker satisfies it).
unsafe fn owned_block<'s, T>(sl: &'s SyncSlice<'_, T>, pm: &PartitionMap, r: usize) -> &'s mut [T] {
    let range = pm.owned_range(r);
    if range.is_empty() {
        &mut []
    } else {
        sl.slice_mut(range.start, range.end - range.start)
    }
}

/// One logical dynamic graph stored as N owner-computes shards.
#[derive(Debug, Clone)]
pub struct ShardedGraph {
    pm: PartitionMap,
    /// Shard `r` holds exactly the edges whose source `r` owns, over the
    /// full vertex-id space (so per-shard diff-CSRs never translate ids).
    shards: Vec<DynGraph>,
    n: usize,
}

impl ShardedGraph {
    /// Partition `g` into `shards` owner-computes shards with edge-mass
    /// balanced block boundaries (out-degree prefix sums of the seed
    /// graph).
    pub fn partition(g: &DynGraph, shards: usize) -> Self {
        let n = g.num_nodes();
        let nshards = shards.max(1);
        let degrees: Vec<u32> = (0..n as NodeId).map(|v| g.out_degree(v)).collect();
        let pm = PartitionMap::edge_balanced(n, nshards, &degrees);
        let mut buckets: Vec<Vec<(NodeId, NodeId, Weight)>> = vec![Vec::new(); nshards];
        for (u, v, w) in g.edges_sorted() {
            buckets[pm.owner(u)].push((u, v, w));
        }
        let shards = buckets
            .into_iter()
            .map(|edges| {
                let mut sg = DynGraph::from_edges(n, &edges);
                // the service owns the merge schedule; shard merges run
                // inside their own thread (already parallel across shards)
                sg.merge_period = 0;
                sg
            })
            .collect();
        ShardedGraph { pm, shards, n }
    }

    #[inline]
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Live edge count across all shards.
    pub fn num_edges(&self) -> usize {
        self.shards.iter().map(|s| s.num_edges()).sum()
    }

    #[inline]
    pub fn owner(&self, v: NodeId) -> usize {
        self.pm.owner(v)
    }

    pub fn partition_map(&self) -> &PartitionMap {
        &self.pm
    }

    /// Borrow one shard's graph (tests / stats).
    pub fn shard(&self, r: usize) -> &DynGraph {
        &self.shards[r]
    }

    /// Out-neighbors of `v` — complete, served by the owner's shard.
    #[inline]
    pub fn out_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.shards[self.owner(v)].out_neighbors(v)
    }

    /// In-neighbors of `v` — the union over every shard's transpose (a
    /// vertex's in-edges live with their source owners).
    #[inline]
    pub fn in_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.shards.iter().flat_map(move |s| s.in_neighbors(v))
    }

    /// Live out-degree of `v` (owner-exact).
    #[inline]
    pub fn out_degree(&self, v: NodeId) -> u32 {
        self.shards[self.owner(v)].out_degree(v)
    }

    /// `is_an_edge(u, v)` — one probe in the owner's shard.
    #[inline]
    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.shards[self.owner(u)].has_edge(u, v)
    }

    /// Graph epoch. Every shard applies (and seals) every batch — empty
    /// addition sets included — so shard epochs advance in lockstep; this
    /// is the invariant the epoch-stitched snapshot publishes.
    pub fn epoch(&self) -> u64 {
        let e = self.shards[0].epoch();
        debug_assert!(
            self.shards.iter().all(|s| s.epoch() == e),
            "shard epochs diverged"
        );
        e
    }

    /// Per-shard graph epochs (the stamps the stitched snapshot carries).
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch()).collect()
    }

    /// Route flat deletion/addition buffers into per-shard buffers by the
    /// *source* owner (the shard that stores the edge). The per-shard
    /// buffers are caller-owned and reused across batches.
    pub fn route(
        &self,
        dels: &[(NodeId, NodeId)],
        adds: &[(NodeId, NodeId, Weight)],
        dels_by: &mut [Vec<(NodeId, NodeId)>],
        adds_by: &mut [Vec<(NodeId, NodeId, Weight)>],
    ) {
        debug_assert_eq!(dels_by.len(), self.num_shards());
        debug_assert_eq!(adds_by.len(), self.num_shards());
        for b in dels_by.iter_mut() {
            b.clear();
        }
        for b in adds_by.iter_mut() {
            b.clear();
        }
        for &(u, v) in dels {
            dels_by[self.owner(u)].push((u, v));
        }
        for &(u, v, w) in adds {
            adds_by[self.owner(u)].push((u, v, w));
        }
    }

    /// `updateCSRDel`, owner-routed: every shard applies its own deletion
    /// buffer concurrently (shard-local structures, no sharing).
    pub fn apply_deletions_routed(&mut self, dels_by: &[Vec<(NodeId, NodeId)>]) {
        self.apply_deletions_routed_with(None, dels_by);
    }

    /// [`Self::apply_deletions_routed`] on an explicit execution substrate
    /// (the engine passes its resident fleet here).
    pub fn apply_deletions_routed_with(
        &mut self,
        fleet: Option<&ShardFleet>,
        dels_by: &[Vec<(NodeId, NodeId)>],
    ) {
        debug_assert_eq!(dels_by.len(), self.shards.len());
        let nshards = self.shards.len();
        let sl = SyncSlice::new(&mut self.shards);
        exec_shards(fleet, nshards, &|r| {
            // SAFETY: worker r touches only shard r.
            let sg = &mut unsafe { sl.slice_mut(r, 1) }[0];
            sg.apply_deletions(&dels_by[r]);
        });
    }

    /// `updateCSRAdd`, owner-routed. Every shard calls `apply_additions`
    /// even with an empty buffer: the seal is shard-local and the epoch
    /// bump keeps all shard epochs in lockstep (the stitch invariant).
    pub fn apply_additions_routed(&mut self, adds_by: &[Vec<(NodeId, NodeId, Weight)>]) {
        self.apply_additions_routed_with(None, adds_by);
    }

    /// [`Self::apply_additions_routed`] on an explicit execution substrate.
    pub fn apply_additions_routed_with(
        &mut self,
        fleet: Option<&ShardFleet>,
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        debug_assert_eq!(adds_by.len(), self.shards.len());
        let nshards = self.shards.len();
        let sl = SyncSlice::new(&mut self.shards);
        exec_shards(fleet, nshards, &|r| {
            // SAFETY: worker r touches only shard r.
            let sg = &mut unsafe { sl.slice_mut(r, 1) }[0];
            sg.apply_additions(&adds_by[r]);
        });
    }

    /// Aggregate overflow heat: flagged sources / n. Shard bitmaps flag
    /// only owned sources, so the per-shard counts are disjoint and sum
    /// to the global count.
    pub fn overflow_fraction(&self) -> f64 {
        let touched: usize = self.shards.iter().map(|s| s.overflow_touched()).sum();
        touched as f64 / self.n.max(1) as f64
    }

    /// Deepest per-shard diff chain — the read-cost signal a merge
    /// decision keys on (a reader pays the chain of the owner it hits).
    pub fn diff_chain_len(&self) -> usize {
        self.shards.iter().map(|s| s.diff_chain_len()).max().unwrap_or(0)
    }

    /// Live edges outside the base CSRs, across all shards.
    pub fn diff_live_edges(&self) -> usize {
        self.shards.iter().map(|s| s.diff_live_edges()).sum()
    }

    /// Compact every shard's diff chain, shards in parallel (each merge is
    /// serial *within* its shard thread — shard-local by construction).
    pub fn merge_all(&mut self) {
        let all = vec![true; self.shards.len()];
        self.merge_shards_with(None, &all);
    }

    /// Compact only the flagged shards' diff chains — the per-shard
    /// `MergeGovernor` path: a deep-chained shard merges alone instead of
    /// dragging every shard through a global `merge_all`. Returns how many
    /// shards merged.
    pub fn merge_shards_with(&mut self, fleet: Option<&ShardFleet>, hot: &[bool]) -> usize {
        debug_assert_eq!(hot.len(), self.shards.len());
        let nshards = self.shards.len();
        let sl = SyncSlice::new(&mut self.shards);
        exec_shards(fleet, nshards, &|r| {
            if hot[r] {
                // SAFETY: worker r touches only shard r.
                let sg = &mut unsafe { sl.slice_mut(r, 1) }[0];
                sg.merge();
            }
        });
        hot.iter().filter(|&&h| h).count()
    }

    /// One shard's overflow heat: flagged sources over its owned vertex
    /// count — the local analogue of [`Self::overflow_fraction`], which a
    /// per-shard merge governor keys on. (After a migration a shard may
    /// still carry flags for vertices it no longer owns until its next
    /// merge clears the bitmap; the signal is a heat heuristic, so the
    /// transient overcount is harmless.)
    pub fn shard_overflow_fraction(&self, r: usize) -> f64 {
        let owned = self.pm.owned_range(r).len();
        self.shards[r].overflow_touched() as f64 / owned.max(1) as f64
    }

    /// Per-shard live edge mass — the skew signal rebalancing and the
    /// per-shard load stats key on.
    pub fn shard_edge_masses(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.num_edges()).collect()
    }

    /// Max shard edge mass over the ideal (total / shards); `1.0` means
    /// perfectly balanced. Single-shard and empty graphs report `1.0`.
    pub fn imbalance(&self) -> f64 {
        let masses = self.shard_edge_masses();
        let total: usize = masses.iter().sum();
        if total == 0 || masses.len() <= 1 {
            return 1.0;
        }
        let ideal = total as f64 / masses.len() as f64;
        masses.into_iter().max().unwrap_or(0) as f64 / ideal
    }

    /// Churn-driven rebalance: recompute `edge_balanced` boundaries from
    /// the *current live* out-degrees and migrate only the moved vertices'
    /// diff-CSR rows ([`DynGraph::extract_row`] / [`DynGraph::ingest_row`])
    /// to their new owners. Row migration never seals, so shard epochs are
    /// untouched and the stitch invariant holds — run it at a batch
    /// boundary before the snapshot publish and readers cannot observe the
    /// move. Returns `(moved_vertices, moved_edges)`.
    pub fn rebalance(&mut self) -> (usize, usize) {
        let n = self.n;
        let nshards = self.shards.len();
        if nshards <= 1 {
            return (0, 0);
        }
        let degrees: Vec<u32> = (0..n as NodeId).map(|v| self.out_degree(v)).collect();
        let new_pm = PartitionMap::edge_balanced(n, nshards, &degrees);
        let mut moved_v = 0usize;
        let mut moved_e = 0usize;
        for v in 0..n as NodeId {
            let old = self.pm.owner(v);
            let new = new_pm.owner(v);
            if old == new {
                continue;
            }
            moved_v += 1;
            let row = self.shards[old].extract_row(v);
            if !row.is_empty() {
                moved_e += row.len();
                self.shards[new].ingest_row(v, &row);
            }
        }
        self.pm = new_pm;
        (moved_v, moved_e)
    }

    /// All live edges, sorted (tests / oracles / report conversion).
    pub fn edges_sorted(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.edges_sorted());
        }
        out.sort_unstable();
        out
    }

    /// Collapse the shards back into one `DynGraph` (report conversion —
    /// the diff/tombstone structure is not preserved, the edge set is).
    pub fn into_dyn_graph(self) -> DynGraph {
        let n = self.n;
        let edges = self.edges_sorted();
        DynGraph::from_edges(n, &edges)
    }
}

/// Relay traffic counters (cumulative per engine): messages that stayed on
/// the emitting shard vs messages that crossed a shard boundary, and BSP
/// rounds executed. Benches and tests read this to confirm the frontier
/// actually spills across shards.
#[derive(Debug, Default, Clone, Copy)]
pub struct RelayStats {
    pub rounds: u64,
    pub local_msgs: u64,
    pub cross_msgs: u64,
    /// Frontier chunks executed by a non-owner worker during scatter
    /// (in-phase work stealing). The stolen buckets are still *applied*
    /// by their destination owner in gather, so owner-writes — and the
    /// bitwise fixed point — are unaffected.
    pub steals: u64,
    /// Cumulative worker idle time at the fleet's phase barrier, in
    /// seconds (0 under the spawn-per-phase fallback, which has no
    /// reusable barrier to measure).
    pub barrier_wait_secs: f64,
}

/// Persistent per-engine work buffers, grown once and reused across
/// batches — the sharded mirror of the single engine's `EngineScratch`
/// contract, so the steady-state batch loop doesn't re-allocate O(n)
/// buffers per batch. Contents are garbage between uses; every consumer
/// fully writes what it later reads.
#[derive(Debug, Default)]
struct ShardScratch {
    /// SP-tree child index (head pointer per vertex).
    child_head: Vec<i64>,
    /// SP-tree child index (next-sibling list).
    child_next: Vec<i64>,
    /// Decremental pull-phase Jacobi buffer.
    next_dist: Vec<i64>,
    /// Restricted PR-sweep Jacobi buffer.
    next_rank: Vec<f64>,
}

/// Bulk-synchronous multi-shard engine: resident fleet workers (or
/// scoped threads as fallback) per phase, message relay between push
/// rounds, owner-writes pulls. See the module docs for the execution
/// model and the determinism argument.
#[derive(Debug, Default)]
pub struct ShardedEngine {
    stats: RelayStats,
    scratch: ShardScratch,
    /// Resident workers; phases fall back to spawn-per-phase when absent
    /// or when the worker count doesn't match the graph's shard count.
    fleet: Option<ShardFleet>,
    /// In-phase scatter work stealing (off by default: the stolen work
    /// changes nothing semantically, but keeping the baseline exact makes
    /// the bench comparison honest).
    steal: bool,
    /// Per-shard steal counters: chunks of shard `r`'s frontier run by
    /// another worker / chunks worker `r` stole from others.
    steals_donated: Vec<u64>,
    steals_received: Vec<u64>,
    /// Per-shard span tracks (`tracks[r]` belongs to shard `r`); empty
    /// disables span recording. During any phase, worker `r` is the only
    /// writer of `tracks[r]` (single-writer contract).
    tracks: Vec<Arc<Track>>,
    /// Cumulative wall time of gather (relay-apply) phases, in seconds.
    relay_secs: f64,
}

impl ShardedEngine {
    pub fn new() -> Self {
        ShardedEngine::default()
    }

    /// Adopt a persistent worker fleet: every subsequent phase is
    /// delivered to these resident workers instead of spawning scoped
    /// threads. The fleet lives until the engine is dropped.
    pub fn attach_fleet(&mut self, fleet: ShardFleet) {
        self.fleet = Some(fleet);
    }

    pub fn fleet(&self) -> Option<&ShardFleet> {
        self.fleet.as_ref()
    }

    /// Enable/disable in-phase scatter stealing.
    pub fn set_steal(&mut self, on: bool) {
        self.steal = on;
    }

    pub fn steal_enabled(&self) -> bool {
        self.steal
    }

    /// Attach per-shard span tracks (`tracks[r]` belongs to shard `r`).
    /// Phase closures record scatter/steal/gather/pull spans from worker
    /// `r` into its track; hand the same vec to
    /// [`ShardFleet::with_tracks`] and the fleet's barrier-wait spans
    /// land on the same timeline (same thread, so the single-writer
    /// contract holds).
    pub fn set_tracks(&mut self, tracks: Vec<Arc<Track>>) {
        self.tracks = tracks;
    }

    /// Cumulative wall-clock seconds spent in gather (relay-apply)
    /// phases — the "relay" slice of the service's batch decomposition.
    pub fn relay_secs(&self) -> f64 {
        self.relay_secs
    }

    /// Cumulative worker idle at the fleet phase barrier, in seconds
    /// (0 under the spawn-per-phase fallback, which has no reusable
    /// barrier to measure).
    pub fn barrier_wait_secs(&self) -> f64 {
        self.fleet.as_ref().map(|f| f.wait_nanos() as f64 / 1e9).unwrap_or(0.0)
    }

    /// Per-shard steal counters as `(donated, received)` slices — the
    /// per-shard load surface the service stats report. Empty until the
    /// first relax phase sizes them.
    pub fn shard_steals(&self) -> (&[u64], &[u64]) {
        (&self.steals_donated, &self.steals_received)
    }

    /// Cumulative relay counters since engine creation (barrier idle time
    /// is read live from the fleet).
    pub fn relay_stats(&self) -> RelayStats {
        let mut s = self.stats;
        if let Some(f) = &self.fleet {
            s.barrier_wait_secs = f.wait_nanos() as f64 / 1e9;
        }
        s
    }

    // ------------------------------------------------------------ SSSP

    /// Static SSSP: relay push fixed point from the source, then the
    /// deterministic owner-writes parent repair.
    pub fn sssp_static(&mut self, g: &ShardedGraph, source: NodeId) -> SsspState {
        let n = g.num_nodes();
        let mut st = SsspState::new(n, source);
        let mut seed = vec![false; n];
        seed[source as usize] = true;
        self.relax_relay(g, &mut st.dist, &seed);
        self.repair_parents(g, &mut st);
        st
    }

    /// One dynamic batch through the sharded pipeline: OnDelete →
    /// updateCSRDel (shard-parallel) → decremental cascade + BSP pull →
    /// OnAdd → updateCSRAdd (shard-parallel, shard-local seals) →
    /// incremental relay push → parent repair. Deletion/addition buffers
    /// arrive pre-routed by source owner (see [`ShardedGraph::route`]).
    pub fn sssp_dynamic_batch(
        &mut self,
        g: &mut ShardedGraph,
        st: &mut SsspState,
        dels_by: &[Vec<(NodeId, NodeId)>],
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        let n = g.num_nodes();

        // OnDelete preprocessing (serial: batch-sized, not graph-sized).
        let mut modified = sssp::on_delete_iter(st, dels_by.iter().flatten().copied());
        g.apply_deletions_routed_with(self.fleet.as_ref(), dels_by);

        // Decremental phase 1: cascade invalidation down the former SP
        // tree via a child index (serial — the single-engine path is
        // serial here too; the tree lives in global state, not the graph).
        let mut affected: Vec<NodeId> =
            (0..n).filter(|&v| modified[v]).map(|v| v as NodeId).collect();
        if !affected.is_empty() {
            let ShardScratch { child_head, child_next, .. } = &mut self.scratch;
            child_head.resize(n, -1);
            child_next.resize(n, -1);
            child_head[..n].fill(-1);
            child_next[..n].fill(-1);
            for v in 0..n {
                let p = st.parent[v];
                if p > -1 {
                    child_next[v] = child_head[p as usize];
                    child_head[p as usize] = v as i64;
                }
            }
            let mut queue = affected.clone();
            while let Some(v) = queue.pop() {
                let mut c = child_head[v as usize];
                while c > -1 {
                    let cv = c as usize;
                    if !modified[cv] {
                        modified[cv] = true;
                        st.dist[cv] = INF;
                        st.parent[cv] = -1;
                        affected.push(cv as NodeId);
                        queue.push(cv as NodeId);
                    }
                    c = child_next[cv];
                }
            }
        }

        // Decremental phase 2: BSP Jacobi pull over the affected set.
        // Owner-writes into the next-distance blocks; reads of the stable
        // previous round cross shards freely (shared-memory "window
        // reads"). Identical arithmetic to the single-engine pull — mins
        // only, no float sums — so per-round values are bitwise equal.
        if !affected.is_empty() {
            let pm = g.partition_map();
            let nshards = g.num_shards();
            let mut affected_by: Vec<Vec<NodeId>> = vec![Vec::new(); nshards];
            for &v in &affected {
                affected_by[g.owner(v)].push(v);
            }
            // Jacobi buffer from scratch: only affected slots are written
            // (every round) and read (the copy), so stale content is fine.
            let next_dist = &mut self.scratch.next_dist;
            next_dist.resize(n, 0);
            let fleet = self.fleet.as_ref();
            let tracks = &self.tracks;
            loop {
                let mut changed_by = vec![false; nshards];
                {
                    let dist_ro: &[i64] = &st.dist;
                    let gr: &ShardedGraph = g;
                    let nd = SyncSlice::new(&mut next_dist[..n]);
                    let cb = SyncSlice::new(&mut changed_by);
                    exec_shards(fleet, nshards, &|r| {
                        let phase_start = Instant::now();
                        // SAFETY: owner-exclusive block / per-shard slot.
                        let block = unsafe { owned_block(&nd, pm, r) };
                        let lo = pm.owned_range(r).start;
                        let mut ch = false;
                        for &v in &affected_by[r] {
                            let mut best = dist_ro[v as usize];
                            for (u, w) in gr.in_neighbors(v) {
                                let du = dist_ro[u as usize];
                                if du < INF && du + (w as i64) < best {
                                    best = du + w as i64;
                                }
                            }
                            block[v as usize - lo] = best;
                            if best < dist_ro[v as usize] {
                                ch = true;
                            }
                        }
                        unsafe { cb.set(r, ch) };
                        if let Some(t) = tracks.get(r) {
                            t.record(Stage::Pull, phase_start);
                        }
                    });
                }
                if !changed_by.iter().any(|&c| c) {
                    break;
                }
                for &v in &affected {
                    st.dist[v as usize] = next_dist[v as usize];
                }
            }
        }

        // OnAdd + shard-local updateCSRAdd + incremental relay push.
        let seed = sssp::on_add_iter(st, adds_by.iter().flatten().copied());
        g.apply_additions_routed_with(self.fleet.as_ref(), adds_by);
        self.relax_relay(g, &mut st.dist, &seed);
        self.repair_parents(g, st);
    }

    /// BSP push relaxation with the cross-shard relay — the halo
    /// exchange. Each round has two barrier-separated phases:
    ///
    /// * **scatter**: shard `r` walks its owned frontier's out-edges
    ///   (read-only on `dist`) and emits `(dst, candidate)` messages into
    ///   per-destination-owner outboxes;
    /// * **gather**: shard `r` — now exclusive owner of its distance
    ///   block — drains every sender's messages addressed to it, applies
    ///   the min, and collects the vertices it lowered as its next
    ///   frontier (sorted + dedup'd, so rounds are fully deterministic).
    ///
    /// `min` is commutative, so message order never matters; the fixed
    /// point is the unique shortest-distance solution, which is why the
    /// sharded end-state is bitwise equal to the single-engine one.
    fn relax_relay(&mut self, g: &ShardedGraph, dist: &mut [i64], seed: &[bool]) {
        let nshards = g.num_shards();
        let pm = g.partition_map();
        let steal_on = self.steal && nshards > 1;
        if self.steals_donated.len() < nshards {
            self.steals_donated.resize(nshards, 0);
            self.steals_received.resize(nshards, 0);
        }
        let fleet = self.fleet.as_ref();
        let tracks = &self.tracks;
        let mut frontiers: Vec<Vec<NodeId>> = (0..nshards)
            .map(|r| pm.owned_range(r).filter(|&v| seed[v]).map(|v| v as NodeId).collect())
            .collect();
        while frontiers.iter().any(|f| !f.is_empty()) {
            self.stats.rounds += 1;
            // scatter: worker r drains its own frontier in STEAL_CHUNK
            // units, then (with stealing on) claims chunks from the most
            // loaded shard. A thief emits into its *own* outbox row, so
            // the message multiset — and hence the min fixed point — is
            // identical under any steal schedule; gather stays
            // owner-exclusive.
            let mut outboxes: Vec<Vec<Vec<(NodeId, i64)>>> =
                (0..nshards).map(|_| vec![Vec::new(); nshards]).collect();
            let local_msgs = AtomicU64::new(0);
            let cross_msgs = AtomicU64::new(0);
            let stolen = AtomicU64::new(0);
            let donated: Vec<AtomicU64> = (0..nshards).map(|_| AtomicU64::new(0)).collect();
            let received: Vec<AtomicU64> = (0..nshards).map(|_| AtomicU64::new(0)).collect();
            {
                let dist_ro: &[i64] = dist;
                let frontiers_ro: &[Vec<NodeId>] = &frontiers;
                let cursors: Vec<AtomicUsize> =
                    (0..nshards).map(|_| AtomicUsize::new(0)).collect();
                let nchunks =
                    |s: usize| frontiers_ro[s].len().div_ceil(STEAL_CHUNK);
                let ob = SyncSlice::new(&mut outboxes);
                exec_shards(fleet, nshards, &|r| {
                    let phase_start = Instant::now();
                    let trk = tracks.get(r);
                    // SAFETY: each worker writes only its own outbox row.
                    let my = &mut unsafe { ob.slice_mut(r, 1) }[0];
                    let (mut loc, mut cro) = (0u64, 0u64);
                    let mut process = |sender: usize,
                                       chunk: usize,
                                       my: &mut Vec<Vec<(NodeId, i64)>>| {
                        let f = &frontiers_ro[sender];
                        let lo = chunk * STEAL_CHUNK;
                        let hi = (lo + STEAL_CHUNK).min(f.len());
                        for &v in &f[lo..hi] {
                            let dv = dist_ro[v as usize];
                            if dv >= INF {
                                continue;
                            }
                            for (nbr, w) in g.out_neighbors(v) {
                                let alt = dv + w as i64;
                                // read-only prune; the owner re-checks
                                // against its authoritative block
                                if alt < dist_ro[nbr as usize] {
                                    let dest = g.owner(nbr);
                                    if dest == sender {
                                        loc += 1;
                                    } else {
                                        cro += 1;
                                    }
                                    my[dest].push((nbr, alt));
                                }
                            }
                        }
                    };
                    loop {
                        let c = cursors[r].fetch_add(1, Ordering::Relaxed);
                        if c >= nchunks(r) {
                            break;
                        }
                        process(r, c, &mut *my);
                    }
                    if steal_on {
                        loop {
                            // victim = shard with the most unclaimed chunks
                            let mut victim = None;
                            let mut most = 0usize;
                            for s in 0..nshards {
                                if s == r {
                                    continue;
                                }
                                let rem = nchunks(s)
                                    .saturating_sub(cursors[s].load(Ordering::Relaxed));
                                if rem > most {
                                    most = rem;
                                    victim = Some(s);
                                }
                            }
                            let Some(s) = victim else { break };
                            let c = cursors[s].fetch_add(1, Ordering::Relaxed);
                            if c >= nchunks(s) {
                                continue;
                            }
                            if let Some(t) = trk {
                                let steal_start = Instant::now();
                                process(s, c, &mut *my);
                                t.record(Stage::Steal, steal_start);
                            } else {
                                process(s, c, &mut *my);
                            }
                            stolen.fetch_add(1, Ordering::Relaxed);
                            donated[s].fetch_add(1, Ordering::Relaxed);
                            received[r].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    local_msgs.fetch_add(loc, Ordering::Relaxed);
                    cross_msgs.fetch_add(cro, Ordering::Relaxed);
                    if let Some(t) = trk {
                        t.record(Stage::Scatter, phase_start);
                    }
                });
            }
            self.stats.local_msgs += local_msgs.load(Ordering::Relaxed);
            self.stats.cross_msgs += cross_msgs.load(Ordering::Relaxed);
            self.stats.steals += stolen.load(Ordering::Relaxed);
            for s in 0..nshards {
                self.steals_donated[s] += donated[s].load(Ordering::Relaxed);
                self.steals_received[s] += received[s].load(Ordering::Relaxed);
            }
            // gather: owner-exclusive min-apply over every row's bucket
            // addressed to it (thief rows included — stolen buckets are
            // still applied by their owner).
            let mut next_frontiers: Vec<Vec<NodeId>> = vec![Vec::new(); nshards];
            let gather_start = Instant::now();
            {
                let ds = SyncSlice::new(&mut *dist);
                let nf = SyncSlice::new(&mut next_frontiers);
                let ob_ro: &[Vec<Vec<(NodeId, i64)>>] = &outboxes;
                exec_shards(fleet, nshards, &|r| {
                    let phase_start = Instant::now();
                    // SAFETY: owner-exclusive block / per-shard slot.
                    let block = unsafe { owned_block(&ds, pm, r) };
                    let lo = pm.owned_range(r).start;
                    let mut lowered = Vec::new();
                    for row in ob_ro {
                        for &(v, alt) in &row[r] {
                            let slot = &mut block[v as usize - lo];
                            if alt < *slot {
                                *slot = alt;
                                lowered.push(v);
                            }
                        }
                    }
                    lowered.sort_unstable();
                    lowered.dedup();
                    unsafe { nf.set(r, lowered) };
                    if let Some(t) = tracks.get(r) {
                        t.record(Stage::Gather, phase_start);
                    }
                });
            }
            self.relay_secs += gather_start.elapsed().as_secs_f64();
            frontiers = next_frontiers;
        }
    }

    /// Deterministic parent repair, owner-writes: shard `r` recomputes
    /// `parent[v] = argmin_u (dist[u] + w(u,v) == dist[v], smallest u)`
    /// for its owned block, pulling in-edges from every shard. Bitwise
    /// identical to the single-engine repair (min over a set).
    fn repair_parents(&mut self, g: &ShardedGraph, st: &mut SsspState) {
        let pm = g.partition_map();
        let nshards = g.num_shards();
        let fleet = self.fleet.as_ref();
        let tracks = &self.tracks;
        let source = st.source;
        let dist_ro: &[i64] = &st.dist;
        let ps = SyncSlice::new(&mut st.parent);
        exec_shards(fleet, nshards, &|r| {
            let phase_start = Instant::now();
            // SAFETY: owner-exclusive block.
            let block = unsafe { owned_block(&ps, pm, r) };
            let lo = pm.owned_range(r).start;
            for (i, slot) in block.iter_mut().enumerate() {
                let v = (lo + i) as NodeId;
                let mut best = -1i64;
                if v != source && dist_ro[v as usize] < INF {
                    for (u, w) in g.in_neighbors(v) {
                        let du = dist_ro[u as usize];
                        if du < INF && du + w as i64 == dist_ro[v as usize] {
                            let cand = u as i64;
                            if best == -1 || cand < best {
                                best = cand;
                            }
                        }
                    }
                }
                *slot = best;
            }
            if let Some(t) = tracks.get(r) {
                t.record(Stage::Pull, phase_start);
            }
        });
    }

    // ------------------------------------------------------------ PR

    /// Static PageRank: BSP Jacobi — each round, shard `r` pulls its
    /// owned block from the stable previous ranks and accumulates its
    /// convergence delta; deltas fold in shard order (deterministic for a
    /// fixed shard count; float reassociation keeps cross-shard-count
    /// equality at tolerance, not bitwise).
    pub fn pr_static(&mut self, g: &ShardedGraph, st: &mut PrState) -> usize {
        let n = g.num_nodes();
        let nf = n as f64;
        st.rank.clear();
        st.rank.resize(n, 1.0 / nf);
        let mut next = vec![0.0f64; n];
        let pm = g.partition_map();
        let nshards = g.num_shards();
        let fleet = self.fleet.as_ref();
        let tracks = &self.tracks;
        let mut iters = 0;
        loop {
            let mut diffs = vec![0.0f64; nshards];
            {
                let rank_ro: &[f64] = &st.rank;
                let delta = st.delta;
                let nx = SyncSlice::new(&mut next);
                let df = SyncSlice::new(&mut diffs);
                exec_shards(fleet, nshards, &|r| {
                    let phase_start = Instant::now();
                    // SAFETY: owner-exclusive block / per-shard slot.
                    let block = unsafe { owned_block(&nx, pm, r) };
                    let lo = pm.owned_range(r).start;
                    let mut dacc = 0.0;
                    for (i, slot) in block.iter_mut().enumerate() {
                        let v = (lo + i) as NodeId;
                        let mut sum = 0.0;
                        for (nbr, _) in g.in_neighbors(v) {
                            let d = g.out_degree(nbr);
                            if d > 0 {
                                sum += rank_ro[nbr as usize] / d as f64;
                            }
                        }
                        let val = (1.0 - delta) / nf + delta * sum;
                        dacc += (val - rank_ro[v as usize]).abs();
                        *slot = val;
                    }
                    unsafe { df.set(r, dacc) };
                    if let Some(t) = tracks.get(r) {
                        t.record(Stage::Pull, phase_start);
                    }
                });
            }
            let diff: f64 = diffs.iter().sum();
            std::mem::swap(&mut st.rank, &mut next);
            iters += 1;
            if diff <= st.beta || iters >= st.max_iter {
                return iters;
            }
        }
    }

    /// One dynamic PR batch: flag → BFS closure → updateCSRDel →
    /// restricted sweeps, then the same for additions (Fig. 20 order, the
    /// closure computed on the pre-update graph exactly like the
    /// single-engine path).
    pub fn pr_dynamic_batch(
        &mut self,
        g: &mut ShardedGraph,
        st: &mut PrState,
        dels_by: &[Vec<(NodeId, NodeId)>],
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        let n = g.num_nodes();

        let mut modified = vec![false; n];
        for &(_, v) in dels_by.iter().flatten() {
            modified[v as usize] = true;
        }
        propagate_flags(g, &mut modified);
        g.apply_deletions_routed_with(self.fleet.as_ref(), dels_by);
        self.recompute_flagged(g, st, &modified);

        let mut modified_add = vec![false; n];
        for &(_, v, _) in adds_by.iter().flatten() {
            modified_add[v as usize] = true;
        }
        propagate_flags(g, &mut modified_add);
        g.apply_additions_routed_with(self.fleet.as_ref(), adds_by);
        self.recompute_flagged(g, st, &modified_add);
    }

    /// Restricted Jacobi sweeps over the flagged set (the dynamic-PR
    /// propagate body), owner-writes like [`Self::pr_static`].
    fn recompute_flagged(&mut self, g: &ShardedGraph, st: &mut PrState, flags: &[bool]) -> usize {
        let n = g.num_nodes();
        let nf = n as f64;
        let pm = g.partition_map();
        let mut active_by: Vec<Vec<NodeId>> = vec![Vec::new(); g.num_shards()];
        let mut active: Vec<NodeId> = Vec::new();
        for v in 0..n as NodeId {
            if flags[v as usize] {
                active_by[g.owner(v)].push(v);
                active.push(v);
            }
        }
        if active.is_empty() {
            return 0;
        }
        // Jacobi buffer from scratch: only active slots are written (every
        // round) and read (the copy), so stale content is fine.
        let next = &mut self.scratch.next_rank;
        next.resize(n, 0.0);
        let nshards = g.num_shards();
        let fleet = self.fleet.as_ref();
        let tracks = &self.tracks;
        let mut iters = 0;
        loop {
            let mut diffs = vec![0.0f64; nshards];
            {
                let rank_ro: &[f64] = &st.rank;
                let delta = st.delta;
                let nx = SyncSlice::new(&mut next[..n]);
                let df = SyncSlice::new(&mut diffs);
                exec_shards(fleet, nshards, &|r| {
                    let phase_start = Instant::now();
                    // SAFETY: owner-exclusive block / per-shard slot.
                    let block = unsafe { owned_block(&nx, pm, r) };
                    let lo = pm.owned_range(r).start;
                    let mut dacc = 0.0;
                    for &v in &active_by[r] {
                        let mut sum = 0.0;
                        for (nbr, _) in g.in_neighbors(v) {
                            let d = g.out_degree(nbr);
                            if d > 0 {
                                sum += rank_ro[nbr as usize] / d as f64;
                            }
                        }
                        let val = (1.0 - delta) / nf + delta * sum;
                        dacc += (val - rank_ro[v as usize]).abs();
                        block[v as usize - lo] = val;
                    }
                    unsafe { df.set(r, dacc) };
                    if let Some(t) = tracks.get(r) {
                        t.record(Stage::Pull, phase_start);
                    }
                });
            }
            let diff: f64 = diffs.iter().sum();
            for &v in &active {
                st.rank[v as usize] = next[v as usize];
            }
            iters += 1;
            if diff <= st.beta || iters >= st.max_iter {
                return iters;
            }
        }
    }

    // ------------------------------------------------------------ TC

    /// Static TC: each shard counts the wedges of its owned vertices
    /// (membership probes cross shards through the owner), partials sum
    /// in shard order — integer counts, bitwise equal to single-engine.
    pub fn tc_static(&mut self, g: &ShardedGraph) -> TcState {
        let pm = g.partition_map();
        let nshards = g.num_shards();
        let fleet = self.fleet.as_ref();
        let mut counts = vec![0i64; nshards];
        {
            let cs = SyncSlice::new(&mut counts);
            exec_shards(fleet, nshards, &|r| {
                let mut local = 0i64;
                for v in pm.owned_range(r) {
                    let v = v as NodeId;
                    for (u, _) in g.out_neighbors(v) {
                        if u >= v {
                            continue;
                        }
                        for (w, _) in g.out_neighbors(v) {
                            if w <= v {
                                continue;
                            }
                            if g.has_edge(u, w) {
                                local += 1;
                            }
                        }
                    }
                }
                // SAFETY: per-shard slot.
                unsafe { cs.set(r, local) };
            });
        }
        TcState { triangles: counts.iter().sum() }
    }

    /// Dynamic TC batch (Fig. 19 order): delta-count deletions while the
    /// graph still holds them, apply both update kinds, delta-count the
    /// additions. Arc lists arrive pre-routed by `v1`'s owner, which is
    /// exactly the shard that can enumerate `v1`'s adjacency locally.
    pub fn tc_dynamic_batch(
        &mut self,
        g: &mut ShardedGraph,
        st: &mut TcState,
        dels_by: &[Vec<(NodeId, NodeId)>],
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        let del_set: HashSet<(NodeId, NodeId)> =
            dels_by.iter().flatten().copied().collect();
        st.triangles -= self.delta_count(g, dels_by, &del_set);
        g.apply_deletions_routed_with(self.fleet.as_ref(), dels_by);
        g.apply_additions_routed_with(self.fleet.as_ref(), adds_by);
        let add_arcs_by: Vec<Vec<(NodeId, NodeId)>> = adds_by
            .iter()
            .map(|adds| adds.iter().map(|&(u, v, _)| (u, v)).collect())
            .collect();
        let add_set: HashSet<(NodeId, NodeId)> =
            add_arcs_by.iter().flatten().copied().collect();
        st.triangles += self.delta_count(g, &add_arcs_by, &add_set);
    }

    /// Sharded delta counting: per-shard (c1, c2, c3) partials over the
    /// shard's own arcs, folded globally *before* the 1/2, 1/4, 1/6
    /// multiplicity division (the division only distributes over the
    /// global sums).
    fn delta_count(
        &self,
        g: &ShardedGraph,
        arcs_by: &[Vec<(NodeId, NodeId)>],
        modified: &HashSet<(NodeId, NodeId)>,
    ) -> i64 {
        let is_mod =
            |a: NodeId, b: NodeId| modified.contains(&(a, b)) || modified.contains(&(b, a));
        let nshards = arcs_by.len();
        let fleet = self.fleet.as_ref();
        let tracks = &self.tracks;
        let mut partials = vec![(0i64, 0i64, 0i64); nshards];
        {
            let ps = SyncSlice::new(&mut partials);
            let is_mod = &is_mod;
            exec_shards(fleet, nshards, &|r| {
                let phase_start = Instant::now();
                let (mut c1, mut c2, mut c3) = (0i64, 0i64, 0i64);
                for &(v1, v2) in &arcs_by[r] {
                    if v1 == v2 {
                        continue;
                    }
                    for (v3, _) in g.out_neighbors(v1) {
                        if v3 == v1 || v3 == v2 {
                            continue;
                        }
                        if !g.has_edge(v2, v3) && !g.has_edge(v3, v2) {
                            continue;
                        }
                        let mut k = 1;
                        if is_mod(v1, v3) {
                            k += 1;
                        }
                        if is_mod(v2, v3) {
                            k += 1;
                        }
                        match k {
                            1 => c1 += 1,
                            2 => c2 += 1,
                            _ => c3 += 1,
                        }
                    }
                }
                // SAFETY: per-shard slot.
                unsafe { ps.set(r, (c1, c2, c3)) };
                if let Some(t) = tracks.get(r) {
                    t.record(Stage::Pull, phase_start);
                }
            });
        }
        let (c1, c2, c3) = partials
            .iter()
            .fold((0i64, 0i64, 0i64), |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2));
        c1 / 2 + c2 / 4 + c3 / 6
    }
}

/// BFS closure of the flagged set along out-edges over the sharded graph
/// (`propagateNodeFlags`). Serial like the reference — the flag array is
/// global state; adjacency reads go through the owners. One shared body
/// with the single-graph flavor ([`pagerank::propagate_flags_with`]), so
/// the two can never drift apart semantically.
pub fn propagate_flags(g: &ShardedGraph, flags: &mut [bool]) -> usize {
    pagerank::propagate_flags_with(g.num_nodes(), flags, |v| {
        g.out_neighbors(v).map(|(nbr, _)| nbr)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{pagerank, triangle};
    use crate::backend::cpu::CpuEngine;
    use crate::graph::{generators, UpdateStream};
    use crate::util::threadpool::Sched;

    fn route_stream(
        g: &ShardedGraph,
        stream: &UpdateStream,
    ) -> Vec<(Vec<Vec<(NodeId, NodeId)>>, Vec<Vec<(NodeId, NodeId, Weight)>>)> {
        let s = g.num_shards();
        let mut out = Vec::new();
        for b in stream.batches() {
            let dels: Vec<_> = b.deletions().collect();
            let adds: Vec<_> = b.additions().collect();
            let mut dels_by = vec![Vec::new(); s];
            let mut adds_by = vec![Vec::new(); s];
            g.route(&dels, &adds, &mut dels_by, &mut adds_by);
            out.push((dels_by, adds_by));
        }
        out
    }

    #[test]
    fn partition_covers_edges_and_owner_serves_adjacency() {
        let g = generators::rmat(8, 1500, 0.57, 0.19, 0.19, 5);
        for shards in [1usize, 2, 4] {
            let sg = ShardedGraph::partition(&g, shards);
            assert_eq!(sg.num_shards(), shards);
            assert_eq!(sg.num_edges(), g.num_edges());
            assert_eq!(sg.edges_sorted(), g.edges_sorted());
            for v in 0..g.num_nodes() as NodeId {
                assert_eq!(sg.out_degree(v), g.out_degree(v), "out_degree({v})");
                let mut got: Vec<_> = sg.out_neighbors(v).collect();
                let mut want: Vec<_> = g.out_neighbors(v).collect();
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want, "out_neighbors({v})");
                let mut gin: Vec<_> = sg.in_neighbors(v).collect();
                let mut win: Vec<_> = g.in_neighbors(v).collect();
                gin.sort_unstable();
                win.sort_unstable();
                assert_eq!(gin, win, "in_neighbors({v})");
            }
        }
    }

    #[test]
    fn route_sends_every_update_to_the_source_owner() {
        let g0 = generators::uniform_random(120, 700, 9, 31);
        let sg = ShardedGraph::partition(&g0, 4);
        let stream = UpdateStream::generate_percent(&g0, 15.0, 32, 9, 33);
        let dels: Vec<_> = stream.batches().next().unwrap().deletions().collect();
        let adds: Vec<_> = stream.batches().next().unwrap().additions().collect();
        let mut dels_by = vec![Vec::new(); 4];
        let mut adds_by = vec![Vec::new(); 4];
        sg.route(&dels, &adds, &mut dels_by, &mut adds_by);
        assert_eq!(dels_by.iter().map(|b| b.len()).sum::<usize>(), dels.len());
        assert_eq!(adds_by.iter().map(|b| b.len()).sum::<usize>(), adds.len());
        for (r, b) in dels_by.iter().enumerate() {
            for &(u, _) in b {
                assert_eq!(sg.owner(u), r, "deletion routed off-owner");
            }
        }
        for (r, b) in adds_by.iter().enumerate() {
            for &(u, _, _) in b {
                assert_eq!(sg.owner(u), r, "addition routed off-owner");
            }
        }
    }

    #[test]
    fn sharded_static_sssp_bitwise_matches_cpu_engine() {
        let g = generators::rmat(8, 1200, 0.57, 0.19, 0.19, 3);
        let cpu = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
        let want = cpu.sssp_static(&g, 0);
        for shards in [1usize, 2, 4] {
            let sg = ShardedGraph::partition(&g, shards);
            let mut e = ShardedEngine::new();
            let st = e.sssp_static(&sg, 0);
            assert_eq!(st.dist, want.dist, "shards={shards}");
            assert_eq!(st.parent, want.parent, "shards={shards} parents");
        }
    }

    #[test]
    fn sharded_dynamic_sssp_bitwise_matches_single_engine() {
        let g0 = generators::uniform_random(200, 1000, 9, 11);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 32, 9, 13);
        // single-engine reference
        let cpu = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
        let mut gref = g0.clone();
        let mut want = cpu.sssp_static(&gref, 0);
        for b in stream.batches() {
            cpu.sssp_dynamic_batch(&mut gref, &mut want, &b);
        }
        for shards in [1usize, 2, 4] {
            let mut sg = ShardedGraph::partition(&g0, shards);
            let mut e = ShardedEngine::new();
            let mut st = e.sssp_static(&sg, 0);
            for (dels_by, adds_by) in route_stream(&sg, &stream) {
                e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            }
            assert_eq!(sg.edges_sorted(), gref.edges_sorted(), "shards={shards}");
            assert_eq!(st.dist, want.dist, "shards={shards} dist");
            assert_eq!(st.parent, want.parent, "shards={shards} parent");
            assert_eq!(st.dist, sssp::dijkstra_oracle(&gref, 0), "oracle");
            if shards > 1 {
                assert!(
                    e.relay_stats().cross_msgs > 0,
                    "frontier never spilled across shards"
                );
            }
        }
    }

    #[test]
    fn sharded_pr_tracks_reference_fixed_point() {
        let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 7);
        let n = g0.num_nodes();
        let stream = UpdateStream::generate_percent(&g0, 10.0, 16, 9, 9);
        let mut gref = g0.clone();
        let mut truth = PrState::new(n, 1e-10, 0.85, 300);
        pagerank::static_pagerank(&gref, &mut truth);
        for b in stream.batches() {
            pagerank::dynamic_batch(&mut gref, &mut truth, &b);
        }
        for shards in [1usize, 2, 4] {
            let mut sg = ShardedGraph::partition(&g0, shards);
            let mut e = ShardedEngine::new();
            let mut st = PrState::new(n, 1e-10, 0.85, 300);
            e.pr_static(&sg, &mut st);
            for (dels_by, adds_by) in route_stream(&sg, &stream) {
                e.pr_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            }
            let l1: f64 =
                st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
            assert!(l1 < 1e-7, "shards={shards} diverged from reference: l1={l1}");
        }
    }

    #[test]
    fn sharded_tc_counts_bitwise() {
        let g0 = triangle::symmetrize(&generators::uniform_random(60, 360, 5, 17));
        let (dels, adds) = triangle::symmetric_updates(&g0, 12.0, 6, 19);
        for shards in [1usize, 2, 4] {
            let mut sg = ShardedGraph::partition(&g0, shards);
            let mut e = ShardedEngine::new();
            let mut st = e.tc_static(&sg);
            assert_eq!(st.triangles, triangle::static_tc(&g0).triangles, "static");
            for (d, a) in dels.iter().zip(&adds) {
                let mut dels_by = vec![Vec::new(); shards];
                let mut adds_by = vec![Vec::new(); shards];
                sg.route(d, a, &mut dels_by, &mut adds_by);
                e.tc_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            }
            let end = sg.clone().into_dyn_graph();
            assert_eq!(
                st.triangles,
                triangle::static_tc(&end).triangles,
                "shards={shards}: delta counting must equal a full recount"
            );
        }
    }

    #[test]
    fn shard_epochs_stay_in_lockstep() {
        let g0 = generators::uniform_random(100, 500, 9, 23);
        let stream = UpdateStream::generate_percent(&g0, 10.0, 16, 9, 25);
        let mut sg = ShardedGraph::partition(&g0, 3);
        let mut e = ShardedEngine::new();
        let mut st = e.sssp_static(&sg, 0);
        for (i, (dels_by, adds_by)) in route_stream(&sg, &stream).into_iter().enumerate() {
            e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            let epochs = sg.shard_epochs();
            assert!(
                epochs.iter().all(|&ep| ep == epochs[0]),
                "epochs diverged after batch {i}: {epochs:?}"
            );
            assert_eq!(sg.epoch(), (i + 1) as u64, "one sealed epoch per batch");
        }
    }

    #[test]
    fn fleet_phases_match_spawn_per_phase_bitwise() {
        let g0 = generators::uniform_random(200, 1000, 9, 11);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 32, 9, 13);
        for shards in [2usize, 4] {
            // spawn-per-phase baseline
            let mut sg_a = ShardedGraph::partition(&g0, shards);
            let mut ea = ShardedEngine::new();
            let mut sa = ea.sssp_static(&sg_a, 0);
            // resident fleet with stealing on
            let mut sg_b = ShardedGraph::partition(&g0, shards);
            let mut eb = ShardedEngine::new();
            eb.attach_fleet(crate::util::ShardFleet::new(shards));
            eb.set_steal(true);
            let mut sb = eb.sssp_static(&sg_b, 0);
            assert_eq!(sb.dist, sa.dist, "static dist, shards={shards}");
            assert_eq!(sb.parent, sa.parent, "static parent, shards={shards}");
            for (dels_by, adds_by) in route_stream(&sg_a, &stream) {
                ea.sssp_dynamic_batch(&mut sg_a, &mut sa, &dels_by, &adds_by);
                eb.sssp_dynamic_batch(&mut sg_b, &mut sb, &dels_by, &adds_by);
            }
            assert_eq!(sb.dist, sa.dist, "dynamic dist, shards={shards}");
            assert_eq!(sb.parent, sa.parent, "dynamic parent, shards={shards}");
            assert_eq!(sg_b.edges_sorted(), sg_a.edges_sorted());
            // PR: same shard count and fold order on both substrates, so
            // the float results are bitwise equal too
            let mut pa = PrState::new(g0.num_nodes(), 1e-10, 0.85, 200);
            let mut pb = pa.clone();
            ea.pr_static(&sg_a, &mut pa);
            eb.pr_static(&sg_b, &mut pb);
            assert_eq!(pb.rank, pa.rank, "pr bitwise, shards={shards}");
        }
    }

    #[test]
    fn tracked_engine_is_bitwise_identical_and_records_phase_spans() {
        let g0 = generators::uniform_random(200, 1000, 9, 11);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 32, 9, 13);
        let shards = 2usize;
        // untracked reference
        let mut sg_a = ShardedGraph::partition(&g0, shards);
        let mut ea = ShardedEngine::new();
        let mut sa = ea.sssp_static(&sg_a, 0);
        // tracked fleet engine: phase spans + barrier spans on one timeline
        let tracer = crate::telemetry::Tracer::new();
        let tracks: Vec<_> =
            (0..shards).map(|r| tracer.track(&format!("shard-{r}"), 4096)).collect();
        let mut sg_b = ShardedGraph::partition(&g0, shards);
        let mut eb = ShardedEngine::new();
        eb.attach_fleet(crate::util::ShardFleet::with_tracks(shards, tracks.clone()));
        eb.set_tracks(tracks);
        eb.set_steal(true);
        let mut sb = eb.sssp_static(&sg_b, 0);
        for (dels_by, adds_by) in route_stream(&sg_a, &stream) {
            ea.sssp_dynamic_batch(&mut sg_a, &mut sa, &dels_by, &adds_by);
            eb.sssp_dynamic_batch(&mut sg_b, &mut sb, &dels_by, &adds_by);
        }
        assert_eq!(sb.dist, sa.dist, "tracing must not perturb the fixed point");
        assert_eq!(sb.parent, sa.parent, "tracing must not perturb parents");
        assert!(eb.relay_secs() > 0.0, "gather wall time accumulates");
        assert!(eb.barrier_wait_secs() > 0.0, "fleet barrier idle accumulates");
        drop(eb); // joins the fleet: snapshots are safe
        for t in tracer.tracks() {
            let snap = t.snapshot();
            assert!(snap.total > 0, "{} recorded no spans", t.name());
            assert!(
                snap.events.iter().any(|e| matches!(
                    e.stage,
                    Stage::Scatter | Stage::Gather | Stage::Pull | Stage::Barrier
                )),
                "{} has no phase spans",
                t.name()
            );
        }
    }

    #[test]
    fn stealing_keeps_relay_bitwise_and_counts_steals() {
        // Hub fan-out: vertex 0 reaches 4096 vertices that all live in the
        // upper shards' ranges, so the round-2 frontier splits into dozens
        // of chunks on a few shards while the hub's own shard idles at
        // scatter — a guaranteed steal opportunity.
        let n = 5120usize;
        let mut edges: Vec<(NodeId, NodeId, Weight)> = Vec::new();
        for v in 1024..n as NodeId {
            edges.push((0, v, 1));
            edges.push((v, v % 1024, 2));
        }
        let g = DynGraph::from_edges(n, &edges);
        let cpu = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
        let want = cpu.sssp_static(&g, 0);
        for shards in [2usize, 4] {
            let sg = ShardedGraph::partition(&g, shards);
            let mut e = ShardedEngine::new();
            e.attach_fleet(crate::util::ShardFleet::new(shards));
            e.set_steal(true);
            let st = e.sssp_static(&sg, 0);
            assert_eq!(st.dist, want.dist, "shards={shards}");
            assert_eq!(st.parent, want.parent, "shards={shards} parents");
            let stats = e.relay_stats();
            assert!(stats.steals > 0, "idle shards must steal chunks (shards={shards})");
            let (donated, received) = e.shard_steals();
            assert_eq!(donated.iter().sum::<u64>(), stats.steals, "donated sums to total");
            assert_eq!(received.iter().sum::<u64>(), stats.steals, "received sums to total");
        }
    }

    #[test]
    fn rebalance_migrates_rows_and_preserves_results() {
        use crate::graph::{Update, UpdateKind};
        let g0 = generators::uniform_random(300, 1200, 9, 51);
        // hub storm: 500 fresh edges whose sources all sit in the first
        // owner's range, skewing its edge mass
        let mut present: std::collections::HashSet<(NodeId, NodeId)> =
            g0.edges_sorted().iter().map(|&(u, v, _)| (u, v)).collect();
        let mut updates = Vec::new();
        let mut k = 0u32;
        while updates.len() < 500 {
            let u = (k * 13) % 20;
            let v = 20 + (k * 37) % 280;
            k += 1;
            if u == v || present.contains(&(u, v)) {
                continue;
            }
            present.insert((u, v));
            updates.push(Update {
                kind: UpdateKind::Add,
                src: u,
                dst: v,
                weight: 1 + (k % 9) as Weight,
            });
        }
        let stream = UpdateStream::new(updates, 100);
        // single-engine reference
        let cpu = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
        let mut gref = g0.clone();
        let mut want = cpu.sssp_static(&gref, 0);
        for b in stream.batches() {
            cpu.sssp_dynamic_batch(&mut gref, &mut want, &b);
        }
        // sharded with a live mid-stream rebalance
        let mut sg = ShardedGraph::partition(&g0, 4);
        let mut e = ShardedEngine::new();
        let mut st = e.sssp_static(&sg, 0);
        let mut rebalanced = false;
        for (i, (dels_by, adds_by)) in route_stream(&sg, &stream).into_iter().enumerate() {
            // NB: route once up-front is fine here — the pre-rebalance
            // owner still *stores* those vertices' rows until migration,
            // and this loop re-routes nothing after the move because the
            // remaining batches were routed against the old map; to stay
            // faithful to the service (which routes per batch against the
            // live map) we re-route below.
            let mut d2 = vec![Vec::new(); 4];
            let mut a2 = vec![Vec::new(); 4];
            let flat_d: Vec<_> = dels_by.iter().flatten().copied().collect();
            let flat_a: Vec<_> = adds_by.iter().flatten().copied().collect();
            sg.route(&flat_d, &flat_a, &mut d2, &mut a2);
            e.sssp_dynamic_batch(&mut sg, &mut st, &d2, &a2);
            if i == 2 {
                let epoch_before = sg.epoch();
                let edges_before = sg.edges_sorted();
                let imb_before = sg.imbalance();
                assert!(imb_before > 1.1, "hub storm must skew mass: {imb_before}");
                let (moved_v, moved_e) = sg.rebalance();
                rebalanced = true;
                assert!(moved_v > 0, "boundaries must move");
                assert!(moved_e > 0, "rows must migrate");
                assert_eq!(sg.epoch(), epoch_before, "migration is epoch-neutral");
                assert_eq!(sg.edges_sorted(), edges_before, "edge set preserved");
                assert!(
                    sg.imbalance() < imb_before,
                    "rebalance must reduce skew: {} -> {}",
                    imb_before,
                    sg.imbalance()
                );
                for v in 0..g0.num_nodes() as NodeId {
                    assert_eq!(sg.out_degree(v), gref_degree_at(&edges_before, v), "deg({v})");
                }
            }
        }
        assert!(rebalanced);
        assert_eq!(sg.edges_sorted(), gref.edges_sorted());
        assert_eq!(st.dist, want.dist, "dist bitwise across a live migration");
        assert_eq!(st.parent, want.parent, "parent bitwise across a live migration");
    }

    fn gref_degree_at(edges: &[(NodeId, NodeId, Weight)], v: NodeId) -> u32 {
        edges.iter().filter(|&&(u, _, _)| u == v).count() as u32
    }

    #[test]
    fn merge_shards_with_merges_only_flagged() {
        let g0 = generators::uniform_random(300, 1500, 9, 61);
        let stream = UpdateStream::generate_percent(&g0, 25.0, 64, 9, 63);
        let mut sg = ShardedGraph::partition(&g0, 3);
        let mut e = ShardedEngine::new();
        let mut st = e.sssp_static(&sg, 0);
        for (dels_by, adds_by) in route_stream(&sg, &stream) {
            e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
        }
        let before: Vec<usize> = (0..3).map(|r| sg.shard(r).diff_chain_len()).collect();
        assert!(before.iter().all(|&c| c > 0), "churn must dirty every shard: {before:?}");
        let edges = sg.edges_sorted();
        let merged = sg.merge_shards_with(None, &[false, true, false]);
        assert_eq!(merged, 1);
        assert_eq!(sg.shard(1).diff_chain_len(), 0, "flagged shard compacts");
        assert_eq!(sg.shard(0).diff_chain_len(), before[0], "unflagged shard untouched");
        assert_eq!(sg.shard(2).diff_chain_len(), before[2], "unflagged shard untouched");
        assert_eq!(sg.edges_sorted(), edges, "edge set preserved");
    }

    #[test]
    fn merge_all_preserves_graph_and_resets_signals() {
        let g0 = generators::uniform_random(150, 900, 9, 41);
        let stream = UpdateStream::generate_percent(&g0, 25.0, 64, 9, 43);
        let mut sg = ShardedGraph::partition(&g0, 4);
        let mut e = ShardedEngine::new();
        let mut st = e.sssp_static(&sg, 0);
        for (dels_by, adds_by) in route_stream(&sg, &stream) {
            e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
        }
        assert!(sg.diff_live_edges() > 0, "churn must dirty some chain");
        let before = sg.edges_sorted();
        sg.merge_all();
        assert_eq!(sg.edges_sorted(), before);
        assert_eq!(sg.diff_chain_len(), 0);
        assert_eq!(sg.overflow_fraction(), 0.0);
        assert_eq!(sg.diff_live_edges(), 0);
    }
}
