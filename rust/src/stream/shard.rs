//! Graph sharding across engine threads: the scale-out substrate of the
//! sharded streaming service (ROADMAP "streaming layer scale-out").
//!
//! [`ShardedGraph`] splits one logical dynamic graph over N shards by
//! **vertex ownership**: shard `r` owns the contiguous vertex block of an
//! edge-mass-balanced [`PartitionMap`] (degree-weighted boundaries — the
//! degree-balanced follow-up to the PR 3 partition contract) and stores
//! exactly the edges whose *source* it owns, as a full-vertex-space
//! [`DynGraph`] — the same owner-computes convention as the `dist`
//! backend's MPI partitioning (§3.6: "a process stores only those edges
//! for which the source node is owned by that process"). Because every
//! shard keeps its own diff-CSR, batch application — including
//! `seal_batch` — is **shard-local**: shards mutate their structures
//! concurrently with no sharing at all.
//!
//! [`ShardedEngine`] runs the dynamic pipelines over the sharded graph in
//! bulk-synchronous rounds, one OS thread per shard per round
//! (`std::thread::scope`; the join is the superstep barrier — the same
//! spawn-per-call model `util::threadpool` uses):
//!
//! * **push phases** (incremental SSSP) walk owned frontier out-edges and
//!   emit `(dst, candidate)` relax messages bucketed by the destination's
//!   owner — the in-process mirror of the `dist` backend's halo exchange.
//!   Messages are exchanged *between* rounds; each shard then drains its
//!   inbox with exclusive ownership of its distance block, so no phase
//!   ever takes a lock or issues an atomic on the property arrays;
//! * **pull phases** (decremental SSSP, PR sweeps, parent repair) are
//!   owner-writes: shard `r` writes only its contiguous block
//!   (`split_at_mut`-partitioned, safe Rust) while reading the previous
//!   round's values and any shard's adjacency immutably. A vertex's
//!   in-edges live with their *source* owners, so a pull over `v` chains
//!   `in_neighbors(v)` across every shard's transpose;
//! * **reductions** (TC wedge counts, PR convergence deltas) fold
//!   per-shard partials in shard order, so results are deterministic for
//!   a fixed shard count.
//!
//! Equivalence is pinned by `tests/stream_equivalence.rs`: SSSP and TC
//! end-states are *bitwise* equal to the single-engine service and the
//! offline batch pipeline across shards ∈ {1, 2, 4} (SSSP's fixed point
//! is unique and the parent repair is a deterministic argmin; TC counts
//! are order-independent integers), and PR is oracle-equal within the
//! convergence tolerance (float sums reassociate across shard
//! boundaries).
//!
//! The shard fleet is deliberately *not* a `backend::DynamicEngine`
//! instance: its entry points take per-shard routed buffers, not whole
//! batches, and its parallelism is the partition itself. The
//! single-engine [`GraphService`](super::GraphService) is the
//! trait-backed flavor (`serve --backend {serial,cpu,dist,xla}`);
//! running *this* fleet over non-cpu engines — or heterogeneous shards —
//! is the ROADMAP "streaming backends" follow-up.

use crate::algorithms::{pagerank, sssp, PrState, SsspState, TcState, INF};
use crate::graph::partition::PartitionMap;
use crate::graph::{DynGraph, NodeId, Weight};
use std::collections::HashSet;

/// Split `data` into per-rank mutable blocks following the partition's
/// contiguous ownership ranges (rank order). The returned slices are
/// disjoint, so shard threads may write their own block concurrently —
/// owner-writes with no unsafe.
pub(crate) fn split_blocks<'a, T>(pm: &PartitionMap, data: &'a mut [T]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(pm.ranks);
    let mut rest = data;
    let mut consumed = 0usize;
    for r in 0..pm.ranks {
        let range = pm.owned_range(r);
        debug_assert_eq!(range.start, consumed, "ranges contiguous in rank order");
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(range.end - consumed);
        out.push(head);
        rest = tail;
        consumed = range.end;
    }
    debug_assert!(rest.is_empty());
    out
}

/// One logical dynamic graph stored as N owner-computes shards.
#[derive(Debug, Clone)]
pub struct ShardedGraph {
    pm: PartitionMap,
    /// Shard `r` holds exactly the edges whose source `r` owns, over the
    /// full vertex-id space (so per-shard diff-CSRs never translate ids).
    shards: Vec<DynGraph>,
    n: usize,
}

impl ShardedGraph {
    /// Partition `g` into `shards` owner-computes shards with edge-mass
    /// balanced block boundaries (out-degree prefix sums of the seed
    /// graph).
    pub fn partition(g: &DynGraph, shards: usize) -> Self {
        let n = g.num_nodes();
        let nshards = shards.max(1);
        let degrees: Vec<u32> = (0..n as NodeId).map(|v| g.out_degree(v)).collect();
        let pm = PartitionMap::edge_balanced(n, nshards, &degrees);
        let mut buckets: Vec<Vec<(NodeId, NodeId, Weight)>> = vec![Vec::new(); nshards];
        for (u, v, w) in g.edges_sorted() {
            buckets[pm.owner(u)].push((u, v, w));
        }
        let shards = buckets
            .into_iter()
            .map(|edges| {
                let mut sg = DynGraph::from_edges(n, &edges);
                // the service owns the merge schedule; shard merges run
                // inside their own thread (already parallel across shards)
                sg.merge_period = 0;
                sg
            })
            .collect();
        ShardedGraph { pm, shards, n }
    }

    #[inline]
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Live edge count across all shards.
    pub fn num_edges(&self) -> usize {
        self.shards.iter().map(|s| s.num_edges()).sum()
    }

    #[inline]
    pub fn owner(&self, v: NodeId) -> usize {
        self.pm.owner(v)
    }

    pub fn partition_map(&self) -> &PartitionMap {
        &self.pm
    }

    /// Borrow one shard's graph (tests / stats).
    pub fn shard(&self, r: usize) -> &DynGraph {
        &self.shards[r]
    }

    /// Out-neighbors of `v` — complete, served by the owner's shard.
    #[inline]
    pub fn out_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.shards[self.owner(v)].out_neighbors(v)
    }

    /// In-neighbors of `v` — the union over every shard's transpose (a
    /// vertex's in-edges live with their source owners).
    #[inline]
    pub fn in_neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        self.shards.iter().flat_map(move |s| s.in_neighbors(v))
    }

    /// Live out-degree of `v` (owner-exact).
    #[inline]
    pub fn out_degree(&self, v: NodeId) -> u32 {
        self.shards[self.owner(v)].out_degree(v)
    }

    /// `is_an_edge(u, v)` — one probe in the owner's shard.
    #[inline]
    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.shards[self.owner(u)].has_edge(u, v)
    }

    /// Graph epoch. Every shard applies (and seals) every batch — empty
    /// addition sets included — so shard epochs advance in lockstep; this
    /// is the invariant the epoch-stitched snapshot publishes.
    pub fn epoch(&self) -> u64 {
        let e = self.shards[0].epoch();
        debug_assert!(
            self.shards.iter().all(|s| s.epoch() == e),
            "shard epochs diverged"
        );
        e
    }

    /// Per-shard graph epochs (the stamps the stitched snapshot carries).
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch()).collect()
    }

    /// Route flat deletion/addition buffers into per-shard buffers by the
    /// *source* owner (the shard that stores the edge). The per-shard
    /// buffers are caller-owned and reused across batches.
    pub fn route(
        &self,
        dels: &[(NodeId, NodeId)],
        adds: &[(NodeId, NodeId, Weight)],
        dels_by: &mut [Vec<(NodeId, NodeId)>],
        adds_by: &mut [Vec<(NodeId, NodeId, Weight)>],
    ) {
        debug_assert_eq!(dels_by.len(), self.num_shards());
        debug_assert_eq!(adds_by.len(), self.num_shards());
        for b in dels_by.iter_mut() {
            b.clear();
        }
        for b in adds_by.iter_mut() {
            b.clear();
        }
        for &(u, v) in dels {
            dels_by[self.owner(u)].push((u, v));
        }
        for &(u, v, w) in adds {
            adds_by[self.owner(u)].push((u, v, w));
        }
    }

    /// `updateCSRDel`, owner-routed: every shard applies its own deletion
    /// buffer concurrently (shard-local structures, no sharing).
    pub fn apply_deletions_routed(&mut self, dels_by: &[Vec<(NodeId, NodeId)>]) {
        std::thread::scope(|sc| {
            for (sg, dels) in self.shards.iter_mut().zip(dels_by) {
                sc.spawn(move || {
                    sg.apply_deletions(dels);
                });
            }
        });
    }

    /// `updateCSRAdd`, owner-routed. Every shard calls `apply_additions`
    /// even with an empty buffer: the seal is shard-local and the epoch
    /// bump keeps all shard epochs in lockstep (the stitch invariant).
    pub fn apply_additions_routed(&mut self, adds_by: &[Vec<(NodeId, NodeId, Weight)>]) {
        std::thread::scope(|sc| {
            for (sg, adds) in self.shards.iter_mut().zip(adds_by) {
                sc.spawn(move || {
                    sg.apply_additions(adds);
                });
            }
        });
    }

    /// Aggregate overflow heat: flagged sources / n. Shard bitmaps flag
    /// only owned sources, so the per-shard counts are disjoint and sum
    /// to the global count.
    pub fn overflow_fraction(&self) -> f64 {
        let touched: usize = self.shards.iter().map(|s| s.overflow_touched()).sum();
        touched as f64 / self.n.max(1) as f64
    }

    /// Deepest per-shard diff chain — the read-cost signal a merge
    /// decision keys on (a reader pays the chain of the owner it hits).
    pub fn diff_chain_len(&self) -> usize {
        self.shards.iter().map(|s| s.diff_chain_len()).max().unwrap_or(0)
    }

    /// Live edges outside the base CSRs, across all shards.
    pub fn diff_live_edges(&self) -> usize {
        self.shards.iter().map(|s| s.diff_live_edges()).sum()
    }

    /// Compact every shard's diff chain, shards in parallel (each merge is
    /// serial *within* its shard thread — shard-local by construction).
    pub fn merge_all(&mut self) {
        std::thread::scope(|sc| {
            for sg in self.shards.iter_mut() {
                sc.spawn(move || {
                    sg.merge();
                });
            }
        });
    }

    /// All live edges, sorted (tests / oracles / report conversion).
    pub fn edges_sorted(&self) -> Vec<(NodeId, NodeId, Weight)> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.edges_sorted());
        }
        out.sort_unstable();
        out
    }

    /// Collapse the shards back into one `DynGraph` (report conversion —
    /// the diff/tombstone structure is not preserved, the edge set is).
    pub fn into_dyn_graph(self) -> DynGraph {
        let n = self.n;
        let edges = self.edges_sorted();
        DynGraph::from_edges(n, &edges)
    }
}

/// Relay traffic counters (cumulative per engine): messages that stayed on
/// the emitting shard vs messages that crossed a shard boundary, and BSP
/// rounds executed. Benches and tests read this to confirm the frontier
/// actually spills across shards.
#[derive(Debug, Default, Clone, Copy)]
pub struct RelayStats {
    pub rounds: u64,
    pub local_msgs: u64,
    pub cross_msgs: u64,
}

/// Persistent per-engine work buffers, grown once and reused across
/// batches — the sharded mirror of the single engine's `EngineScratch`
/// contract, so the steady-state batch loop doesn't re-allocate O(n)
/// buffers per batch. Contents are garbage between uses; every consumer
/// fully writes what it later reads.
#[derive(Debug, Default)]
struct ShardScratch {
    /// SP-tree child index (head pointer per vertex).
    child_head: Vec<i64>,
    /// SP-tree child index (next-sibling list).
    child_next: Vec<i64>,
    /// Decremental pull-phase Jacobi buffer.
    next_dist: Vec<i64>,
    /// Restricted PR-sweep Jacobi buffer.
    next_rank: Vec<f64>,
}

/// Bulk-synchronous multi-shard engine: one thread per shard per phase,
/// message relay between push rounds, owner-writes pulls. See the module
/// docs for the execution model and the determinism argument.
#[derive(Debug, Default)]
pub struct ShardedEngine {
    stats: RelayStats,
    scratch: ShardScratch,
}

impl ShardedEngine {
    pub fn new() -> Self {
        ShardedEngine::default()
    }

    /// Cumulative relay counters since engine creation.
    pub fn relay_stats(&self) -> RelayStats {
        self.stats
    }

    // ------------------------------------------------------------ SSSP

    /// Static SSSP: relay push fixed point from the source, then the
    /// deterministic owner-writes parent repair.
    pub fn sssp_static(&mut self, g: &ShardedGraph, source: NodeId) -> SsspState {
        let n = g.num_nodes();
        let mut st = SsspState::new(n, source);
        let mut seed = vec![false; n];
        seed[source as usize] = true;
        self.relax_relay(g, &mut st.dist, &seed);
        self.repair_parents(g, &mut st);
        st
    }

    /// One dynamic batch through the sharded pipeline: OnDelete →
    /// updateCSRDel (shard-parallel) → decremental cascade + BSP pull →
    /// OnAdd → updateCSRAdd (shard-parallel, shard-local seals) →
    /// incremental relay push → parent repair. Deletion/addition buffers
    /// arrive pre-routed by source owner (see [`ShardedGraph::route`]).
    pub fn sssp_dynamic_batch(
        &mut self,
        g: &mut ShardedGraph,
        st: &mut SsspState,
        dels_by: &[Vec<(NodeId, NodeId)>],
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        let n = g.num_nodes();

        // OnDelete preprocessing (serial: batch-sized, not graph-sized).
        let mut modified = sssp::on_delete_iter(st, dels_by.iter().flatten().copied());
        g.apply_deletions_routed(dels_by);

        // Decremental phase 1: cascade invalidation down the former SP
        // tree via a child index (serial — the single-engine path is
        // serial here too; the tree lives in global state, not the graph).
        let mut affected: Vec<NodeId> =
            (0..n).filter(|&v| modified[v]).map(|v| v as NodeId).collect();
        if !affected.is_empty() {
            let ShardScratch { child_head, child_next, .. } = &mut self.scratch;
            child_head.resize(n, -1);
            child_next.resize(n, -1);
            child_head[..n].fill(-1);
            child_next[..n].fill(-1);
            for v in 0..n {
                let p = st.parent[v];
                if p > -1 {
                    child_next[v] = child_head[p as usize];
                    child_head[p as usize] = v as i64;
                }
            }
            let mut queue = affected.clone();
            while let Some(v) = queue.pop() {
                let mut c = child_head[v as usize];
                while c > -1 {
                    let cv = c as usize;
                    if !modified[cv] {
                        modified[cv] = true;
                        st.dist[cv] = INF;
                        st.parent[cv] = -1;
                        affected.push(cv as NodeId);
                        queue.push(cv as NodeId);
                    }
                    c = child_next[cv];
                }
            }
        }

        // Decremental phase 2: BSP Jacobi pull over the affected set.
        // Owner-writes into the next-distance blocks; reads of the stable
        // previous round cross shards freely (shared-memory "window
        // reads"). Identical arithmetic to the single-engine pull — mins
        // only, no float sums — so per-round values are bitwise equal.
        if !affected.is_empty() {
            let pm = g.partition_map();
            let mut affected_by: Vec<Vec<NodeId>> = vec![Vec::new(); g.num_shards()];
            for &v in &affected {
                affected_by[g.owner(v)].push(v);
            }
            // Jacobi buffer from scratch: only affected slots are written
            // (every round) and read (the copy), so stale content is fine.
            let next_dist = &mut self.scratch.next_dist;
            next_dist.resize(n, 0);
            loop {
                let changed = {
                    let dist_ro: &[i64] = &st.dist;
                    let gr: &ShardedGraph = g;
                    let blocks = split_blocks(pm, &mut next_dist[..n]);
                    let mut any = false;
                    std::thread::scope(|sc| {
                        let mut handles = Vec::new();
                        for (r, block) in blocks.into_iter().enumerate() {
                            let aff = &affected_by[r];
                            let lo = pm.owned_range(r).start;
                            handles.push(sc.spawn(move || {
                                let mut ch = false;
                                for &v in aff {
                                    let mut best = dist_ro[v as usize];
                                    for (u, w) in gr.in_neighbors(v) {
                                        let du = dist_ro[u as usize];
                                        if du < INF && du + (w as i64) < best {
                                            best = du + w as i64;
                                        }
                                    }
                                    block[v as usize - lo] = best;
                                    if best < dist_ro[v as usize] {
                                        ch = true;
                                    }
                                }
                                ch
                            }));
                        }
                        for h in handles {
                            any |= h.join().expect("shard pull thread panicked");
                        }
                    });
                    any
                };
                if !changed {
                    break;
                }
                for &v in &affected {
                    st.dist[v as usize] = next_dist[v as usize];
                }
            }
        }

        // OnAdd + shard-local updateCSRAdd + incremental relay push.
        let seed = sssp::on_add_iter(st, adds_by.iter().flatten().copied());
        g.apply_additions_routed(adds_by);
        self.relax_relay(g, &mut st.dist, &seed);
        self.repair_parents(g, st);
    }

    /// BSP push relaxation with the cross-shard relay — the halo
    /// exchange. Each round has two barrier-separated phases:
    ///
    /// * **scatter**: shard `r` walks its owned frontier's out-edges
    ///   (read-only on `dist`) and emits `(dst, candidate)` messages into
    ///   per-destination-owner outboxes;
    /// * **gather**: shard `r` — now exclusive owner of its distance
    ///   block — drains every sender's messages addressed to it, applies
    ///   the min, and collects the vertices it lowered as its next
    ///   frontier (sorted + dedup'd, so rounds are fully deterministic).
    ///
    /// `min` is commutative, so message order never matters; the fixed
    /// point is the unique shortest-distance solution, which is why the
    /// sharded end-state is bitwise equal to the single-engine one.
    fn relax_relay(&mut self, g: &ShardedGraph, dist: &mut [i64], seed: &[bool]) {
        let nshards = g.num_shards();
        let pm = g.partition_map();
        let mut frontiers: Vec<Vec<NodeId>> = (0..nshards)
            .map(|r| pm.owned_range(r).filter(|&v| seed[v]).map(|v| v as NodeId).collect())
            .collect();
        while frontiers.iter().any(|f| !f.is_empty()) {
            self.stats.rounds += 1;
            // scatter
            let dist_ro: &[i64] = dist;
            let outboxes: Vec<Vec<Vec<(NodeId, i64)>>> = std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for frontier in &frontiers {
                    handles.push(sc.spawn(move || {
                        let mut out: Vec<Vec<(NodeId, i64)>> = vec![Vec::new(); nshards];
                        for &v in frontier {
                            let dv = dist_ro[v as usize];
                            if dv >= INF {
                                continue;
                            }
                            for (nbr, w) in g.out_neighbors(v) {
                                let alt = dv + w as i64;
                                // read-only prune; the owner re-checks
                                // against its authoritative block
                                if alt < dist_ro[nbr as usize] {
                                    out[g.owner(nbr)].push((nbr, alt));
                                }
                            }
                        }
                        out
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard scatter thread panicked"))
                    .collect()
            });
            for (sender, boxes) in outboxes.iter().enumerate() {
                for (dest, msgs) in boxes.iter().enumerate() {
                    if dest == sender {
                        self.stats.local_msgs += msgs.len() as u64;
                    } else {
                        self.stats.cross_msgs += msgs.len() as u64;
                    }
                }
            }
            // gather
            let blocks = split_blocks(pm, dist);
            frontiers = std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for (r, block) in blocks.into_iter().enumerate() {
                    let lo = pm.owned_range(r).start;
                    let inbox: Vec<&[(NodeId, i64)]> =
                        outboxes.iter().map(|ob| ob[r].as_slice()).collect();
                    handles.push(sc.spawn(move || {
                        let mut lowered = Vec::new();
                        for msgs in inbox {
                            for &(v, alt) in msgs {
                                let slot = &mut block[v as usize - lo];
                                if alt < *slot {
                                    *slot = alt;
                                    lowered.push(v);
                                }
                            }
                        }
                        lowered.sort_unstable();
                        lowered.dedup();
                        lowered
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard gather thread panicked"))
                    .collect()
            });
        }
    }

    /// Deterministic parent repair, owner-writes: shard `r` recomputes
    /// `parent[v] = argmin_u (dist[u] + w(u,v) == dist[v], smallest u)`
    /// for its owned block, pulling in-edges from every shard. Bitwise
    /// identical to the single-engine repair (min over a set).
    fn repair_parents(&mut self, g: &ShardedGraph, st: &mut SsspState) {
        let pm = g.partition_map();
        let source = st.source;
        let dist_ro: &[i64] = &st.dist;
        let blocks = split_blocks(pm, &mut st.parent);
        std::thread::scope(|sc| {
            for (r, block) in blocks.into_iter().enumerate() {
                let lo = pm.owned_range(r).start;
                sc.spawn(move || {
                    for (i, slot) in block.iter_mut().enumerate() {
                        let v = (lo + i) as NodeId;
                        let mut best = -1i64;
                        if v != source && dist_ro[v as usize] < INF {
                            for (u, w) in g.in_neighbors(v) {
                                let du = dist_ro[u as usize];
                                if du < INF && du + w as i64 == dist_ro[v as usize] {
                                    let cand = u as i64;
                                    if best == -1 || cand < best {
                                        best = cand;
                                    }
                                }
                            }
                        }
                        *slot = best;
                    }
                });
            }
        });
    }

    // ------------------------------------------------------------ PR

    /// Static PageRank: BSP Jacobi — each round, shard `r` pulls its
    /// owned block from the stable previous ranks and accumulates its
    /// convergence delta; deltas fold in shard order (deterministic for a
    /// fixed shard count; float reassociation keeps cross-shard-count
    /// equality at tolerance, not bitwise).
    pub fn pr_static(&mut self, g: &ShardedGraph, st: &mut PrState) -> usize {
        let n = g.num_nodes();
        let nf = n as f64;
        st.rank.clear();
        st.rank.resize(n, 1.0 / nf);
        let mut next = vec![0.0f64; n];
        let pm = g.partition_map();
        let mut iters = 0;
        loop {
            let diffs: Vec<f64> = {
                let rank_ro: &[f64] = &st.rank;
                let delta = st.delta;
                let blocks = split_blocks(pm, &mut next);
                std::thread::scope(|sc| {
                    let mut handles = Vec::new();
                    for (r, block) in blocks.into_iter().enumerate() {
                        let lo = pm.owned_range(r).start;
                        handles.push(sc.spawn(move || {
                            let mut dacc = 0.0;
                            for (i, slot) in block.iter_mut().enumerate() {
                                let v = (lo + i) as NodeId;
                                let mut sum = 0.0;
                                for (nbr, _) in g.in_neighbors(v) {
                                    let d = g.out_degree(nbr);
                                    if d > 0 {
                                        sum += rank_ro[nbr as usize] / d as f64;
                                    }
                                }
                                let val = (1.0 - delta) / nf + delta * sum;
                                dacc += (val - rank_ro[v as usize]).abs();
                                *slot = val;
                            }
                            dacc
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("shard pr thread panicked"))
                        .collect()
                })
            };
            let diff: f64 = diffs.iter().sum();
            std::mem::swap(&mut st.rank, &mut next);
            iters += 1;
            if diff <= st.beta || iters >= st.max_iter {
                return iters;
            }
        }
    }

    /// One dynamic PR batch: flag → BFS closure → updateCSRDel →
    /// restricted sweeps, then the same for additions (Fig. 20 order, the
    /// closure computed on the pre-update graph exactly like the
    /// single-engine path).
    pub fn pr_dynamic_batch(
        &mut self,
        g: &mut ShardedGraph,
        st: &mut PrState,
        dels_by: &[Vec<(NodeId, NodeId)>],
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        let n = g.num_nodes();

        let mut modified = vec![false; n];
        for &(_, v) in dels_by.iter().flatten() {
            modified[v as usize] = true;
        }
        propagate_flags(g, &mut modified);
        g.apply_deletions_routed(dels_by);
        self.recompute_flagged(g, st, &modified);

        let mut modified_add = vec![false; n];
        for &(_, v, _) in adds_by.iter().flatten() {
            modified_add[v as usize] = true;
        }
        propagate_flags(g, &mut modified_add);
        g.apply_additions_routed(adds_by);
        self.recompute_flagged(g, st, &modified_add);
    }

    /// Restricted Jacobi sweeps over the flagged set (the dynamic-PR
    /// propagate body), owner-writes like [`Self::pr_static`].
    fn recompute_flagged(&mut self, g: &ShardedGraph, st: &mut PrState, flags: &[bool]) -> usize {
        let n = g.num_nodes();
        let nf = n as f64;
        let pm = g.partition_map();
        let mut active_by: Vec<Vec<NodeId>> = vec![Vec::new(); g.num_shards()];
        let mut active: Vec<NodeId> = Vec::new();
        for v in 0..n as NodeId {
            if flags[v as usize] {
                active_by[g.owner(v)].push(v);
                active.push(v);
            }
        }
        if active.is_empty() {
            return 0;
        }
        // Jacobi buffer from scratch: only active slots are written (every
        // round) and read (the copy), so stale content is fine.
        let next = &mut self.scratch.next_rank;
        next.resize(n, 0.0);
        let mut iters = 0;
        loop {
            let diffs: Vec<f64> = {
                let rank_ro: &[f64] = &st.rank;
                let delta = st.delta;
                let blocks = split_blocks(pm, &mut next[..n]);
                std::thread::scope(|sc| {
                    let mut handles = Vec::new();
                    for (r, block) in blocks.into_iter().enumerate() {
                        let act = &active_by[r];
                        let lo = pm.owned_range(r).start;
                        handles.push(sc.spawn(move || {
                            let mut dacc = 0.0;
                            for &v in act {
                                let mut sum = 0.0;
                                for (nbr, _) in g.in_neighbors(v) {
                                    let d = g.out_degree(nbr);
                                    if d > 0 {
                                        sum += rank_ro[nbr as usize] / d as f64;
                                    }
                                }
                                let val = (1.0 - delta) / nf + delta * sum;
                                dacc += (val - rank_ro[v as usize]).abs();
                                block[v as usize - lo] = val;
                            }
                            dacc
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("shard pr thread panicked"))
                        .collect()
                })
            };
            let diff: f64 = diffs.iter().sum();
            for &v in &active {
                st.rank[v as usize] = next[v as usize];
            }
            iters += 1;
            if diff <= st.beta || iters >= st.max_iter {
                return iters;
            }
        }
    }

    // ------------------------------------------------------------ TC

    /// Static TC: each shard counts the wedges of its owned vertices
    /// (membership probes cross shards through the owner), partials sum
    /// in shard order — integer counts, bitwise equal to single-engine.
    pub fn tc_static(&mut self, g: &ShardedGraph) -> TcState {
        let pm = g.partition_map();
        let counts: Vec<i64> = std::thread::scope(|sc| {
            let mut handles = Vec::new();
            for r in 0..g.num_shards() {
                let range = pm.owned_range(r);
                handles.push(sc.spawn(move || {
                    let mut local = 0i64;
                    for v in range {
                        let v = v as NodeId;
                        for (u, _) in g.out_neighbors(v) {
                            if u >= v {
                                continue;
                            }
                            for (w, _) in g.out_neighbors(v) {
                                if w <= v {
                                    continue;
                                }
                                if g.has_edge(u, w) {
                                    local += 1;
                                }
                            }
                        }
                    }
                    local
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("shard tc thread panicked"))
                .collect()
        });
        TcState { triangles: counts.iter().sum() }
    }

    /// Dynamic TC batch (Fig. 19 order): delta-count deletions while the
    /// graph still holds them, apply both update kinds, delta-count the
    /// additions. Arc lists arrive pre-routed by `v1`'s owner, which is
    /// exactly the shard that can enumerate `v1`'s adjacency locally.
    pub fn tc_dynamic_batch(
        &mut self,
        g: &mut ShardedGraph,
        st: &mut TcState,
        dels_by: &[Vec<(NodeId, NodeId)>],
        adds_by: &[Vec<(NodeId, NodeId, Weight)>],
    ) {
        let del_set: HashSet<(NodeId, NodeId)> =
            dels_by.iter().flatten().copied().collect();
        st.triangles -= self.delta_count(g, dels_by, &del_set);
        g.apply_deletions_routed(dels_by);
        g.apply_additions_routed(adds_by);
        let add_arcs_by: Vec<Vec<(NodeId, NodeId)>> = adds_by
            .iter()
            .map(|adds| adds.iter().map(|&(u, v, _)| (u, v)).collect())
            .collect();
        let add_set: HashSet<(NodeId, NodeId)> =
            add_arcs_by.iter().flatten().copied().collect();
        st.triangles += self.delta_count(g, &add_arcs_by, &add_set);
    }

    /// Sharded delta counting: per-shard (c1, c2, c3) partials over the
    /// shard's own arcs, folded globally *before* the 1/2, 1/4, 1/6
    /// multiplicity division (the division only distributes over the
    /// global sums).
    fn delta_count(
        &self,
        g: &ShardedGraph,
        arcs_by: &[Vec<(NodeId, NodeId)>],
        modified: &HashSet<(NodeId, NodeId)>,
    ) -> i64 {
        let is_mod =
            |a: NodeId, b: NodeId| modified.contains(&(a, b)) || modified.contains(&(b, a));
        let partials: Vec<(i64, i64, i64)> = std::thread::scope(|sc| {
            let mut handles = Vec::new();
            for arcs in arcs_by {
                let is_mod = &is_mod;
                handles.push(sc.spawn(move || {
                    let (mut c1, mut c2, mut c3) = (0i64, 0i64, 0i64);
                    for &(v1, v2) in arcs {
                        if v1 == v2 {
                            continue;
                        }
                        for (v3, _) in g.out_neighbors(v1) {
                            if v3 == v1 || v3 == v2 {
                                continue;
                            }
                            if !g.has_edge(v2, v3) && !g.has_edge(v3, v2) {
                                continue;
                            }
                            let mut k = 1;
                            if is_mod(v1, v3) {
                                k += 1;
                            }
                            if is_mod(v2, v3) {
                                k += 1;
                            }
                            match k {
                                1 => c1 += 1,
                                2 => c2 += 1,
                                _ => c3 += 1,
                            }
                        }
                    }
                    (c1, c2, c3)
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("shard tc thread panicked"))
                .collect()
        });
        let (c1, c2, c3) = partials
            .iter()
            .fold((0i64, 0i64, 0i64), |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2));
        c1 / 2 + c2 / 4 + c3 / 6
    }
}

/// BFS closure of the flagged set along out-edges over the sharded graph
/// (`propagateNodeFlags`). Serial like the reference — the flag array is
/// global state; adjacency reads go through the owners. One shared body
/// with the single-graph flavor ([`pagerank::propagate_flags_with`]), so
/// the two can never drift apart semantically.
pub fn propagate_flags(g: &ShardedGraph, flags: &mut [bool]) -> usize {
    pagerank::propagate_flags_with(g.num_nodes(), flags, |v| {
        g.out_neighbors(v).map(|(nbr, _)| nbr)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{pagerank, triangle};
    use crate::backend::cpu::CpuEngine;
    use crate::graph::{generators, UpdateStream};
    use crate::util::threadpool::Sched;

    fn route_stream(
        g: &ShardedGraph,
        stream: &UpdateStream,
    ) -> Vec<(Vec<Vec<(NodeId, NodeId)>>, Vec<Vec<(NodeId, NodeId, Weight)>>)> {
        let s = g.num_shards();
        let mut out = Vec::new();
        for b in stream.batches() {
            let dels: Vec<_> = b.deletions().collect();
            let adds: Vec<_> = b.additions().collect();
            let mut dels_by = vec![Vec::new(); s];
            let mut adds_by = vec![Vec::new(); s];
            g.route(&dels, &adds, &mut dels_by, &mut adds_by);
            out.push((dels_by, adds_by));
        }
        out
    }

    #[test]
    fn partition_covers_edges_and_owner_serves_adjacency() {
        let g = generators::rmat(8, 1500, 0.57, 0.19, 0.19, 5);
        for shards in [1usize, 2, 4] {
            let sg = ShardedGraph::partition(&g, shards);
            assert_eq!(sg.num_shards(), shards);
            assert_eq!(sg.num_edges(), g.num_edges());
            assert_eq!(sg.edges_sorted(), g.edges_sorted());
            for v in 0..g.num_nodes() as NodeId {
                assert_eq!(sg.out_degree(v), g.out_degree(v), "out_degree({v})");
                let mut got: Vec<_> = sg.out_neighbors(v).collect();
                let mut want: Vec<_> = g.out_neighbors(v).collect();
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want, "out_neighbors({v})");
                let mut gin: Vec<_> = sg.in_neighbors(v).collect();
                let mut win: Vec<_> = g.in_neighbors(v).collect();
                gin.sort_unstable();
                win.sort_unstable();
                assert_eq!(gin, win, "in_neighbors({v})");
            }
        }
    }

    #[test]
    fn route_sends_every_update_to_the_source_owner() {
        let g0 = generators::uniform_random(120, 700, 9, 31);
        let sg = ShardedGraph::partition(&g0, 4);
        let stream = UpdateStream::generate_percent(&g0, 15.0, 32, 9, 33);
        let dels: Vec<_> = stream.batches().next().unwrap().deletions().collect();
        let adds: Vec<_> = stream.batches().next().unwrap().additions().collect();
        let mut dels_by = vec![Vec::new(); 4];
        let mut adds_by = vec![Vec::new(); 4];
        sg.route(&dels, &adds, &mut dels_by, &mut adds_by);
        assert_eq!(dels_by.iter().map(|b| b.len()).sum::<usize>(), dels.len());
        assert_eq!(adds_by.iter().map(|b| b.len()).sum::<usize>(), adds.len());
        for (r, b) in dels_by.iter().enumerate() {
            for &(u, _) in b {
                assert_eq!(sg.owner(u), r, "deletion routed off-owner");
            }
        }
        for (r, b) in adds_by.iter().enumerate() {
            for &(u, _, _) in b {
                assert_eq!(sg.owner(u), r, "addition routed off-owner");
            }
        }
    }

    #[test]
    fn sharded_static_sssp_bitwise_matches_cpu_engine() {
        let g = generators::rmat(8, 1200, 0.57, 0.19, 0.19, 3);
        let cpu = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
        let want = cpu.sssp_static(&g, 0);
        for shards in [1usize, 2, 4] {
            let sg = ShardedGraph::partition(&g, shards);
            let mut e = ShardedEngine::new();
            let st = e.sssp_static(&sg, 0);
            assert_eq!(st.dist, want.dist, "shards={shards}");
            assert_eq!(st.parent, want.parent, "shards={shards} parents");
        }
    }

    #[test]
    fn sharded_dynamic_sssp_bitwise_matches_single_engine() {
        let g0 = generators::uniform_random(200, 1000, 9, 11);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 32, 9, 13);
        // single-engine reference
        let cpu = CpuEngine::new(2, Sched::Dynamic { chunk: 64 });
        let mut gref = g0.clone();
        let mut want = cpu.sssp_static(&gref, 0);
        for b in stream.batches() {
            cpu.sssp_dynamic_batch(&mut gref, &mut want, &b);
        }
        for shards in [1usize, 2, 4] {
            let mut sg = ShardedGraph::partition(&g0, shards);
            let mut e = ShardedEngine::new();
            let mut st = e.sssp_static(&sg, 0);
            for (dels_by, adds_by) in route_stream(&sg, &stream) {
                e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            }
            assert_eq!(sg.edges_sorted(), gref.edges_sorted(), "shards={shards}");
            assert_eq!(st.dist, want.dist, "shards={shards} dist");
            assert_eq!(st.parent, want.parent, "shards={shards} parent");
            assert_eq!(st.dist, sssp::dijkstra_oracle(&gref, 0), "oracle");
            if shards > 1 {
                assert!(
                    e.relay_stats().cross_msgs > 0,
                    "frontier never spilled across shards"
                );
            }
        }
    }

    #[test]
    fn sharded_pr_tracks_reference_fixed_point() {
        let g0 = generators::rmat(7, 600, 0.57, 0.19, 0.19, 7);
        let n = g0.num_nodes();
        let stream = UpdateStream::generate_percent(&g0, 10.0, 16, 9, 9);
        let mut gref = g0.clone();
        let mut truth = PrState::new(n, 1e-10, 0.85, 300);
        pagerank::static_pagerank(&gref, &mut truth);
        for b in stream.batches() {
            pagerank::dynamic_batch(&mut gref, &mut truth, &b);
        }
        for shards in [1usize, 2, 4] {
            let mut sg = ShardedGraph::partition(&g0, shards);
            let mut e = ShardedEngine::new();
            let mut st = PrState::new(n, 1e-10, 0.85, 300);
            e.pr_static(&sg, &mut st);
            for (dels_by, adds_by) in route_stream(&sg, &stream) {
                e.pr_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            }
            let l1: f64 =
                st.rank.iter().zip(&truth.rank).map(|(a, b)| (a - b).abs()).sum();
            assert!(l1 < 1e-7, "shards={shards} diverged from reference: l1={l1}");
        }
    }

    #[test]
    fn sharded_tc_counts_bitwise() {
        let g0 = triangle::symmetrize(&generators::uniform_random(60, 360, 5, 17));
        let (dels, adds) = triangle::symmetric_updates(&g0, 12.0, 6, 19);
        for shards in [1usize, 2, 4] {
            let mut sg = ShardedGraph::partition(&g0, shards);
            let mut e = ShardedEngine::new();
            let mut st = e.tc_static(&sg);
            assert_eq!(st.triangles, triangle::static_tc(&g0).triangles, "static");
            for (d, a) in dels.iter().zip(&adds) {
                let mut dels_by = vec![Vec::new(); shards];
                let mut adds_by = vec![Vec::new(); shards];
                sg.route(d, a, &mut dels_by, &mut adds_by);
                e.tc_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            }
            let end = sg.clone().into_dyn_graph();
            assert_eq!(
                st.triangles,
                triangle::static_tc(&end).triangles,
                "shards={shards}: delta counting must equal a full recount"
            );
        }
    }

    #[test]
    fn shard_epochs_stay_in_lockstep() {
        let g0 = generators::uniform_random(100, 500, 9, 23);
        let stream = UpdateStream::generate_percent(&g0, 10.0, 16, 9, 25);
        let mut sg = ShardedGraph::partition(&g0, 3);
        let mut e = ShardedEngine::new();
        let mut st = e.sssp_static(&sg, 0);
        for (i, (dels_by, adds_by)) in route_stream(&sg, &stream).into_iter().enumerate() {
            e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
            let epochs = sg.shard_epochs();
            assert!(
                epochs.iter().all(|&ep| ep == epochs[0]),
                "epochs diverged after batch {i}: {epochs:?}"
            );
            assert_eq!(sg.epoch(), (i + 1) as u64, "one sealed epoch per batch");
        }
    }

    #[test]
    fn merge_all_preserves_graph_and_resets_signals() {
        let g0 = generators::uniform_random(150, 900, 9, 41);
        let stream = UpdateStream::generate_percent(&g0, 25.0, 64, 9, 43);
        let mut sg = ShardedGraph::partition(&g0, 4);
        let mut e = ShardedEngine::new();
        let mut st = e.sssp_static(&sg, 0);
        for (dels_by, adds_by) in route_stream(&sg, &stream) {
            e.sssp_dynamic_batch(&mut sg, &mut st, &dels_by, &adds_by);
        }
        assert!(sg.diff_live_edges() > 0, "churn must dirty some chain");
        let before = sg.edges_sorted();
        sg.merge_all();
        assert_eq!(sg.edges_sorted(), before);
        assert_eq!(sg.diff_chain_len(), 0);
        assert_eq!(sg.overflow_fraction(), 0.0);
        assert_eq!(sg.diff_live_edges(), 0);
    }
}
