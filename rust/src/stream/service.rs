//! [`GraphService`]: the continuously-running streaming facade over the
//! batch pipeline.
//!
//! Wiring: N producers → [`Ingest`] (sharded, bounded, coalescing) →
//! [`Batcher`] (size-or-deadline batch formation + merge policy) → one
//! engine thread driving dynamic batches through a
//! [`DynamicEngine`] trait object (any backend: `serial`, `cpu`, `dist`,
//! `xla` — built by [`backend::make_engine`](crate::backend::make_engine)
//! from `cfg.backend` + `cfg.engine`) → [`SnapshotCell`] (epoch
//! double-buffered property publication) ← M readers.
//!
//! The engine thread owns the [`DynGraph`], the algorithm state, *and the
//! engine itself* outright — the engine is constructed inside the thread
//! (which is also what lets non-`Send` engines like `XlaEngine` serve) —
//! so no lock is ever taken on the graph and reader queries (served from
//! the published snapshot) proceed at full speed while a batch
//! propagates. Producers feel backpressure only through the bounded
//! ingest shards.

use super::batcher::{Batcher, CloseReason, MergeGovernor, MergePolicy};
use super::checkpoint::{self, Checkpoint};
use super::ingest::{DrainTimeout, Ingest, SubmitError};
use super::shard::{RelayStats, ShardedEngine, ShardedGraph};
use super::snapshot::{PropTable, SnapshotCell};
use super::wal::{self, FsyncPolicy, WalWriter};
use crate::algorithms::{PrState, SsspState, TcState};
use crate::backend::{make_engine, BackendKind, DynamicEngine, EngineOpts};
use crate::coordinator::Algo;
use crate::graph::{DynGraph, NodeId, Update, UpdateKind, Weight};
use crate::telemetry::{
    Counter, Gauge, LogHistogram, MetricsRegistry, Stage, TelemetryConfig, Track,
    SHARD_TRACK_CAP, TRACK_CAP,
};
use crate::util::error::{anyhow, bail, Result};
use crate::util::failpoint;
use crate::util::stats::percentile_sorted;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Streaming service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub algo: Algo,
    /// SSSP source vertex.
    pub source: NodeId,
    /// Which backend propagates batches (single-engine service;
    /// [`ShardedService`] runs its own BSP shard fleet and accepts only
    /// the default `cpu` here).
    pub backend: BackendKind,
    /// Engine construction knobs, validated by the factory against the
    /// chosen backend (threads/sched/direction for `cpu`, ranks for
    /// `dist`; explicitly-set knobs a backend lacks are startup errors).
    pub engine: EngineOpts,
    /// Ingest shard count (producer-side queue sharding; orthogonal to
    /// the engine sharding below).
    pub shards: usize,
    /// Live updates each shard holds before producers block.
    pub shard_capacity: usize,
    /// Engine shard count for [`ShardedService`]: the graph is split over
    /// this many engine shards (vertex-block ownership, edge-mass-balanced
    /// boundaries) that propagate each batch concurrently. `1` keeps the
    /// single-engine pipeline; [`GraphService`] ignores this knob.
    pub engine_shards: usize,
    /// Batch closes at this many updates…
    pub batch_capacity: usize,
    /// …or when its oldest update has waited this long.
    pub batch_deadline: Duration,
    pub merge_policy: MergePolicy,
    /// Run the sharded service on the persistent shard fleet (resident
    /// pinned workers + reusable phase barrier) instead of spawning scoped
    /// threads for every BSP phase. On by default; `false` keeps the
    /// spawn-per-phase execution for A/B benchmarking. Ignored by
    /// [`GraphService`] and at `engine_shards <= 1`.
    pub persistent: bool,
    /// In-phase work stealing for the push/relax scatter: idle shard
    /// workers claim frontier chunks from the most loaded shard (messages
    /// are still applied by their owners, so results are bitwise
    /// unchanged). Sharded service only.
    pub steal: bool,
    /// Churn-driven rebalancing threshold: when the max-shard edge mass
    /// exceeds this multiple of the ideal (total/shards), recompute the
    /// `edge_balanced` boundaries online and migrate the moved vertices'
    /// diff-CSR rows at the batch boundary. `None` disables. Sharded
    /// service only; sensible values start around `1.5`.
    pub rebalance: Option<f64>,
    /// Treat each submitted update as an undirected edge (both arcs
    /// applied per batch) — the TC protocol. Defaults to true for TC.
    pub symmetric: bool,
    /// Observability: span tracing (`--trace-out`), histogram-backed
    /// percentiles (on by default), and the `--stats-every` sampler.
    /// Instrumentation is wall-clock-only — it never perturbs results.
    pub telemetry: TelemetryConfig,
    /// PR convergence parameters.
    pub pr_beta: f64,
    pub pr_delta: f64,
    pub pr_max_iter: usize,
    /// Durability & supervision: WAL + checkpoints + bounded engine
    /// restarts (`serve --wal`). Defaults keep everything off — a service
    /// without a WAL dir is exactly the old volatile pipeline.
    pub durability: DurabilityConfig,
    /// When set, the coordinator's load harness submits with this
    /// patience bound and sheds on timeout (`serve --shed-ms`) instead of
    /// blocking producers indefinitely. Library users call
    /// [`GraphService::submit_deadline`] directly.
    pub submit_deadline: Option<Duration>,
    /// When set, the engine serves this lowered DSL program instead of
    /// the built-in `algo` kernels (`serve --program`): the Init segment
    /// seeds the state, the OnBatch segment propagates every batch.
    /// Requires a backend with `supports_programs` (serial/cpu); `algo`
    /// is ignored. Incompatible with `--wal` (program state is not
    /// checkpointable) and with the sharded service.
    pub program: Option<ProgramConfig>,
}

/// A lowered DSL program plus the scalar arguments to bind at seed time
/// (see [`ServiceConfig::program`]).
#[derive(Debug, Clone)]
pub struct ProgramConfig {
    pub prog: Arc<crate::dsl::bytecode::Program>,
    pub args: Vec<(String, crate::dsl::bytecode::ScalarVal)>,
}

impl ServiceConfig {
    pub fn new(algo: Algo) -> Self {
        ServiceConfig {
            algo,
            source: 0,
            backend: BackendKind::Cpu,
            engine: EngineOpts::default(),
            shards: 4,
            shard_capacity: 4096,
            engine_shards: 1,
            batch_capacity: 512,
            batch_deadline: Duration::from_millis(10),
            merge_policy: MergePolicy::default(),
            persistent: true,
            steal: false,
            rebalance: None,
            symmetric: algo == Algo::Tc,
            telemetry: TelemetryConfig::default(),
            pr_beta: 1e-3,
            pr_delta: 0.85,
            pr_max_iter: 100,
            durability: DurabilityConfig::default(),
            submit_deadline: None,
            program: None,
        }
    }
}

/// Durability + supervision knobs. With `wal_dir` unset nothing is ever
/// written, and the supervisor degrades the service to read-only on the
/// first engine panic instead of restarting (there is nothing durable to
/// recover the lost graph/state from).
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// WAL + checkpoint directory. `None` disables durability.
    pub wal_dir: Option<PathBuf>,
    /// When sealed-batch appends reach stable storage ([`FsyncPolicy`]).
    pub fsync: FsyncPolicy,
    /// Checkpoint every this many applied batches (0 = only the seed
    /// checkpoint; the WAL then carries the whole history).
    pub checkpoint_every: u64,
    /// Engine panics tolerated (recover from checkpoint + WAL, restart)
    /// before the service degrades to read-only.
    pub max_restarts: u32,
    /// Base supervisor backoff before a restart, doubled per consecutive
    /// attempt.
    pub restart_backoff: Duration,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        DurabilityConfig {
            wal_dir: None,
            fsync: FsyncPolicy::default(),
            checkpoint_every: 64,
            max_restarts: 3,
            restart_backoff: Duration::from_millis(25),
        }
    }
}

/// The algorithm state the engine thread evolves batch by batch.
#[derive(Debug, Clone)]
pub enum AlgoState {
    Sssp(SsspState),
    Pr(PrState),
    Tc(TcState),
    /// A lowered DSL program's live state (`serve --program`): the
    /// bytecode (shared with the config) and its property/register file.
    Program {
        prog: Arc<crate::dsl::bytecode::Program>,
        st: crate::dsl::bytecode::ProgState,
    },
}

/// Per-shard load telemetry (sharded service): lets skew, stealing, and
/// merge traffic be read off the serve printout / stats JSON without a
/// profiler.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    pub shard: usize,
    /// Live edges currently owned by this shard.
    pub edge_mass: u64,
    /// Relax-frontier chunks this shard's workers gave up to thieves.
    pub steals_donated: u64,
    /// Relax-frontier chunks this shard's worker claimed from victims.
    pub steals_received: u64,
    /// Shard-local merges performed by the per-shard governor.
    pub merges: u64,
}

/// Cumulative per-stage batch-lifecycle seconds (the latency
/// decomposition). Stages are wall-clock on the coordinating engine
/// thread except `barrier`, which sums every shard worker's idle time
/// at the phase barrier (it can exceed wall), and `relay` ⊆ `compute`
/// (the gather half of the BSP rounds). See the README's latency-stage
/// glossary.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageSecs {
    /// Oldest update's enqueue → batch close.
    pub queue_wait: f64,
    /// Draining the sealed batch into update buffers (+ the WAL append,
    /// when durability is on).
    pub form: f64,
    /// Engine propagation (owner routing + all BSP rounds, for the
    /// sharded service).
    pub compute: f64,
    /// Summed shard-worker idle at the phase barrier.
    pub barrier: f64,
    /// Cross-shard relay: the gather/owner-apply half of push rounds.
    pub relay: f64,
    /// Diff-CSR merge compaction.
    pub merge: f64,
    /// Epoch snapshot publish.
    pub publish: f64,
}

impl StageSecs {
    /// Scale every stage to mean milliseconds per batch (the shape the
    /// serve printout and the bench JSON report).
    pub fn per_batch_ms(&self, batches: u64) -> StageSecs {
        if batches == 0 {
            return StageSecs::default();
        }
        let k = 1e3 / batches as f64;
        StageSecs {
            queue_wait: self.queue_wait * k,
            form: self.form * k,
            compute: self.compute * k,
            barrier: self.barrier * k,
            relay: self.relay * k,
            merge: self.merge * k,
            publish: self.publish * k,
        }
    }
}

/// Point-in-time service statistics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    /// Updates cancelled by coalescing (ingest window + batch close).
    pub coalesced: u64,
    pub batches: u64,
    pub closed_by_size: u64,
    pub closed_by_deadline: u64,
    pub closed_by_drain: u64,
    pub merges: u64,
    /// Human-readable merge policy (for dashboards / bench JSON).
    pub policy: String,
    /// Overflow-bitmap heat at the last batch boundary.
    pub overflow_fraction: f64,
    /// Smoothed per-read diff-chain depth (the merge governor's
    /// traversal-cost EWMA) at the last batch boundary.
    pub chain_depth_ewma: f64,
    /// Modeled communication seconds drained from the engine across all
    /// batches (dist backend; 0 elsewhere). Serving-latency comparisons
    /// across backends must add this to the wall-clock numbers, exactly
    /// like the offline cells add `Cell::{static,dynamic}_comm_secs`.
    pub modeled_comm_secs: f64,
    /// Online rebalances performed (sharded service; see
    /// [`ServiceConfig::rebalance`]).
    pub rebalances: u64,
    /// Vertices whose rows migrated between shards across all rebalances.
    pub migrated_vertices: u64,
    /// Per-shard load at the last batch boundary (sharded service; empty
    /// for [`GraphService`]).
    pub shard_loads: Vec<ShardLoad>,
    /// Published snapshot epoch.
    pub epoch: u64,
    /// Batch latency (enqueue of oldest update → snapshot publish), secs.
    /// Histogram-backed by default (±1.6% quantization, accurate p999);
    /// reservoir-backed when `TelemetryConfig::histograms` is off.
    pub batch_latency_p50: f64,
    pub batch_latency_p99: f64,
    pub batch_latency_p999: f64,
    pub batch_latency_mean: f64,
    /// Cumulative per-stage latency decomposition (secs; see
    /// [`StageSecs`] for the glossary and `per_batch_ms` for the
    /// per-batch shape).
    pub stages: StageSecs,
    /// Push/pull traversal telemetry from the engine, when the backend
    /// reports it (the cpu engine's direction-optimizing fixed points).
    pub direction: Option<crate::backend::cpu::DirectionStats>,
    /// Submissions shed: deadline-bounded [`GraphService::submit_deadline`]
    /// calls that timed out under backpressure, plus `enqueue` failpoint
    /// rejections. Shed updates are never counted as submitted.
    pub shed: u64,
    /// Engine crashes caught by the supervisor. Each one either restarted
    /// the engine from checkpoint + WAL or — on the last allowed attempt,
    /// or without a WAL — degraded the service.
    pub restarts: u64,
    /// Batches replayed from the WAL across this service's recoveries
    /// (startup recovery plus any supervised in-process restarts; 0 for a
    /// fresh start).
    pub recovered_batches: u64,
    /// Engine dead past recovery: reads keep serving the last published
    /// epoch, writes are rejected with [`SubmitError::Poisoned`].
    pub degraded: bool,
    /// Wall-clock seconds since service start.
    pub wall_secs: f64,
}

impl ServiceStats {
    /// Applied updates per wall-clock second.
    pub fn updates_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.completed as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

/// Everything the engine thread hands back at shutdown.
#[derive(Debug)]
pub struct ServiceReport {
    pub graph: DynGraph,
    pub state: AlgoState,
    pub stats: ServiceStats,
}

impl ServiceReport {
    pub fn sssp(&self) -> Option<&SsspState> {
        match &self.state {
            AlgoState::Sssp(st) => Some(st),
            _ => None,
        }
    }

    pub fn pr(&self) -> Option<&PrState> {
        match &self.state {
            AlgoState::Pr(st) => Some(st),
            _ => None,
        }
    }

    pub fn tc(&self) -> Option<&TcState> {
        match &self.state {
            AlgoState::Tc(st) => Some(st),
            _ => None,
        }
    }

    /// The served DSL program's final state (`serve --program`).
    pub fn program(&self) -> Option<&crate::dsl::bytecode::ProgState> {
        match &self.state {
            AlgoState::Program { st, .. } => Some(st),
            _ => None,
        }
    }
}

/// Cap on retained latency samples in the fallback reservoir.
const MAX_LATENCY_SAMPLES: usize = 65_536;

/// Uniform sampling reservoir (Vitter's Algorithm R): the first `cap`
/// samples are kept outright; the `n`-th sample thereafter is accepted
/// with probability `cap / n` into a uniformly random slot, so at any
/// point every sample seen so far is retained with equal probability
/// `cap / n`. (The previous scheme replaced a random slot on *every*
/// overflow, which biases the reservoir toward recent samples — old
/// ones survive each round only with probability `1 - 1/cap`, so their
/// retention decays geometrically.) Deterministic LCG, no `rand` dep.
#[derive(Debug)]
struct Reservoir {
    cap: usize,
    seen: usize,
    samples: Vec<f64>,
    lcg: u64,
}

impl Reservoir {
    fn new(cap: usize) -> Reservoir {
        Reservoir { cap, seen: 0, samples: Vec::new(), lcg: 0x9e3779b97f4a7c15 }
    }

    fn next_u64(&mut self) -> u64 {
        self.lcg = self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.lcg >> 33
    }

    fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // accept with probability cap/seen: j uniform in [0, seen)
            let j = (self.next_u64() as usize) % self.seen;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }
}

impl Default for Reservoir {
    fn default() -> Reservoir {
        Reservoir::new(MAX_LATENCY_SAMPLES)
    }
}

#[derive(Debug, Default)]
struct StatsInner {
    batches: u64,
    closed_by_size: u64,
    closed_by_deadline: u64,
    closed_by_drain: u64,
    merges: u64,
    batch_coalesced: u64,
    comm_secs: f64,
    overflow_fraction: f64,
    chain_depth_ewma: f64,
    rebalances: u64,
    migrated_vertices: u64,
    shard_loads: Vec<ShardLoad>,
    direction: Option<crate::backend::cpu::DirectionStats>,
    latencies: Reservoir,
}

impl StatsInner {
    fn push_latency(&mut self, secs: f64) {
        self.latencies.push(secs);
    }
}

/// Stage indices into [`ServiceTelemetry::stage`] (registration order =
/// [`StageSecs`] field order).
const ST_QUEUE_WAIT: usize = 0;
const ST_FORM: usize = 1;
const ST_COMPUTE: usize = 2;
const ST_BARRIER: usize = 3;
const ST_RELAY: usize = 4;
const ST_MERGE: usize = 5;
const ST_PUBLISH: usize = 6;
const STAGE_NAMES: [&str; 7] =
    ["queue_wait", "form", "compute", "barrier", "relay", "merge", "publish"];

/// The lock-free half of the stats surface: metric handles cloned out
/// of one [`MetricsRegistry`] at startup. The engine loop bumps these
/// with relaxed atomics (never the registry lock), and the
/// `--stats-every` sampler thread reads them without ever touching the
/// engine's `Mutex<StatsInner>` — the hot path cannot block on it.
struct ServiceTelemetry {
    registry: Arc<MetricsRegistry>,
    batches: Counter,
    merges: Counter,
    epoch: Gauge,
    stage: Vec<Counter>,
    latency: Arc<LogHistogram>,
    /// Serve percentiles from `latency` (accurate p999); when off, the
    /// Algorithm-R reservoir in `StatsInner` answers instead.
    histograms: bool,
}

impl ServiceTelemetry {
    fn new(histograms: bool) -> ServiceTelemetry {
        let registry = MetricsRegistry::new();
        let batches = registry.counter("batches");
        let merges = registry.counter("merges");
        let epoch = registry.gauge("epoch");
        let stage =
            STAGE_NAMES.iter().map(|n| registry.counter(&format!("stage_{n}_ns"))).collect();
        let latency = registry.histogram("batch_latency");
        ServiceTelemetry { registry, batches, merges, epoch, stage, latency, histograms }
    }

    #[inline]
    fn add_stage(&self, idx: usize, d: Duration) {
        self.stage[idx].add(d.as_nanos() as u64);
    }

    fn stage_secs(&self) -> StageSecs {
        let s = |i: usize| self.stage[i].get() as f64 / 1e9;
        StageSecs {
            queue_wait: s(ST_QUEUE_WAIT),
            form: s(ST_FORM),
            compute: s(ST_COMPUTE),
            barrier: s(ST_BARRIER),
            relay: s(ST_RELAY),
            merge: s(ST_MERGE),
            publish: s(ST_PUBLISH),
        }
    }
}

struct Shared {
    stop: AtomicBool,
    /// Engine dead past recovery; reads keep serving, writes rejected.
    degraded: AtomicBool,
    /// Engine crashes caught by the supervisor.
    restarts: AtomicU64,
    /// WAL batches replayed across this service's recoveries.
    recovered_batches: AtomicU64,
    /// Raw update count of the batch currently inside the engine loop
    /// (0 between batches). The supervisor completes it after a caught
    /// panic so ingest accounting stays balanced across restarts —
    /// otherwise `drain()` would wait forever on updates that died with
    /// the loop (recovery re-applies the WAL'd ones).
    inflight: AtomicU64,
    stats: Mutex<StatsInner>,
    telem: ServiceTelemetry,
    started: Instant,
}

impl Shared {
    fn new(histograms: bool) -> Shared {
        Shared {
            stop: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            restarts: AtomicU64::new(0),
            recovered_batches: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            stats: Mutex::new(StatsInner::default()),
            telem: ServiceTelemetry::new(histograms),
            started: Instant::now(),
        }
    }
}

/// What a degraded service (engine dead past recovery) still hands back
/// at shutdown: the final stats. Graph and algorithm state died with the
/// engine — with a WAL they are on disk, and a fresh service recovers
/// them.
#[derive(Debug)]
pub struct DegradedReport {
    pub stats: ServiceStats,
}

/// Why `try_shutdown` produced no report. Shutdown is idempotent: the
/// first call takes the engine thread's handle and joins it; every later
/// call observes the empty slot and gets `AlreadyShutDown` instead of
/// the panic the old `expect("shutdown called once")` raised.
#[derive(Debug)]
pub enum ShutdownError {
    /// A previous `shutdown`/`try_shutdown` call already joined the
    /// engine and took the report.
    AlreadyShutDown,
    /// Engine dead past recovery: only the final stats survive.
    Degraded(DegradedReport),
}

impl std::fmt::Display for ShutdownError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShutdownError::AlreadyShutDown => {
                write!(f, "shutdown already performed on this service")
            }
            ShutdownError::Degraded(d) => write!(
                f,
                "engine degraded after {} caught crash(es); graph and state \
                 died with the engine",
                d.stats.restarts
            ),
        }
    }
}

impl std::error::Error for ShutdownError {}

/// Handle to a running streaming service. Clone-free: share via `Arc`.
pub struct GraphService {
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    worker: Mutex<Option<JoinHandle<Option<(DynGraph, AlgoState)>>>>,
    sampler: Mutex<Option<JoinHandle<()>>>,
}

/// Run the configured backend's initial static solve (the seed state the
/// engine thread evolves batch by batch). `g` is mutable because a DSL
/// program's Init segment runs through the same bytecode interpreter as
/// its batch segment (graph-mutating instructions and all); the built-in
/// kernels never touch it here.
fn seed_state(
    engine: &dyn DynamicEngine,
    g: &mut DynGraph,
    cfg: &ServiceConfig,
) -> Result<AlgoState> {
    use crate::dsl::bytecode::{Phase, ProgState};
    if let Some(pc) = &cfg.program {
        // Admission before any state is built: the analysis certificate
        // names the construct a non-program backend has no lowering for.
        let caps = engine.capabilities();
        pc.prog.facts.admit(caps.name, caps.supports_programs)?;
        let mut st = ProgState::new(&pc.prog, g.num_nodes(), &pc.args)?;
        engine.run_program(&pc.prog, Phase::Init, g, &mut st)?;
        return Ok(AlgoState::Program { prog: Arc::clone(&pc.prog), st });
    }
    Ok(match cfg.algo {
        Algo::Sssp => AlgoState::Sssp(engine.sssp_static(g, cfg.source)?),
        Algo::Pr => {
            let mut st = PrState::new(g.num_nodes(), cfg.pr_beta, cfg.pr_delta, cfg.pr_max_iter);
            engine.pr_static(g, &mut st)?;
            AlgoState::Pr(st)
        }
        Algo::Tc => AlgoState::Tc(engine.tc_static(g)?),
    })
}

impl GraphService {
    /// [`try_start`](Self::try_start), panicking on startup failure —
    /// the ergonomic entry for cpu-backed services, whose construction
    /// cannot fail.
    pub fn start(g: DynGraph, cfg: ServiceConfig) -> Self {
        Self::try_start(g, cfg).expect("GraphService failed to start")
    }

    /// Seed the service: build the configured backend's engine *inside*
    /// the engine thread (non-`Send` engines like xla's stay thread-local
    /// for their whole life), run the initial static solve on `g`,
    /// publish it as epoch 1, then enter the batch loop. Returns once the
    /// first snapshot is published, or with the startup error (unknown
    /// knob combination, xla without PJRT, failed static solve).
    pub fn try_start(mut g: DynGraph, cfg: ServiceConfig) -> Result<Self> {
        if cfg.program.is_some() && cfg.durability.wal_dir.is_some() {
            bail!(
                "serve --program does not support --wal: DSL program state is \
                 not checkpointable; drop --wal or serve a built-in algorithm"
            );
        }
        // The service owns the merge schedule (policy-driven, from the
        // batcher's seat) — disable the graph's built-in period.
        g.merge_period = 0;
        let snapshots = Arc::new(SnapshotCell::new());
        let mut ingest_raw = Ingest::new(cfg.shards, cfg.shard_capacity, cfg.symmetric);
        if let Some(tracer) = &cfg.telemetry.tracer {
            ingest_raw.set_tracks(
                (0..cfg.shards.max(1))
                    .map(|i| tracer.track(&format!("ingest-{i}"), TRACK_CAP))
                    .collect(),
            );
        }
        let ingest = Arc::new(ingest_raw);
        let shared = Arc::new(Shared::new(cfg.telemetry.histograms));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let worker = {
            let ingest = Arc::clone(&ingest);
            let snapshots = Arc::clone(&snapshots);
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                supervise_single(g, ingest, snapshots, shared, cfg, ready_tx)
            })
        };

        match ready_rx.recv() {
            Ok(Ok(())) => {
                let sampler = cfg.telemetry.stats_every.map(|every| {
                    spawn_sampler(
                        every,
                        Arc::clone(&ingest),
                        Arc::clone(&snapshots),
                        Arc::clone(&shared),
                    )
                });
                Ok(GraphService {
                    ingest,
                    snapshots,
                    shared,
                    cfg,
                    worker: Mutex::new(Some(worker)),
                    sampler: Mutex::new(sampler),
                })
            }
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                Err(anyhow!("service engine thread died during startup"))
            }
        }
    }

    /// Submit one update (blocking under backpressure). Returns `false`
    /// once the service is shutting down.
    pub fn submit(&self, upd: Update) -> bool {
        self.ingest.submit(upd)
    }

    /// Submit with a patience bound: block under backpressure at most
    /// `deadline`, then shed with [`SubmitError::Shed`] (counted in
    /// [`ServiceStats::shed`], never in `submitted`).
    pub fn submit_deadline(&self, upd: Update, deadline: Duration) -> Result<(), SubmitError> {
        self.ingest.submit_deadline(upd, deadline)
    }

    /// Convenience: submit an edge insertion.
    pub fn insert(&self, src: NodeId, dst: NodeId, weight: Weight) -> bool {
        self.submit(Update { kind: UpdateKind::Add, src, dst, weight })
    }

    /// Convenience: submit an edge deletion.
    pub fn remove(&self, src: NodeId, dst: NodeId) -> bool {
        self.submit(Update { kind: UpdateKind::Delete, src, dst, weight: 0 })
    }

    /// Block until every submitted update has been applied (or coalesced)
    /// and its snapshot published. Producers must pause first.
    pub fn drain(&self) {
        self.ingest.wait_quiescent();
    }

    /// [`drain`](Self::drain) with a bound: `Err(DrainTimeout)` if the
    /// backlog has not flushed within `timeout` (a stalled engine would
    /// otherwise spin the caller forever).
    pub fn drain_timeout(&self, timeout: Duration) -> Result<(), DrainTimeout> {
        self.ingest.wait_quiescent_timeout(timeout)
    }

    /// Engine dead past recovery: reads keep serving the last published
    /// epoch, writes are rejected with [`SubmitError::Poisoned`].
    pub fn degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::Acquire)
    }

    /// Latest published snapshot epoch.
    pub fn epoch(&self) -> u64 {
        self.snapshots.epoch()
    }

    /// Run `f` against the current published snapshot (never blocks on the
    /// engine; see [`SnapshotCell`]).
    pub fn with_snapshot<R>(&self, f: impl FnOnce(&PropTable) -> R) -> R {
        self.snapshots.read(f)
    }

    /// SSSP distance of `v` in the published snapshot.
    pub fn dist(&self, v: NodeId) -> Option<i64> {
        self.with_snapshot(|t| t.dist.get(v as usize).copied())
    }

    /// PageRank of `v` in the published snapshot.
    pub fn rank(&self, v: NodeId) -> Option<f64> {
        self.with_snapshot(|t| t.rank.get(v as usize).copied())
    }

    /// Triangle count in the published snapshot (TC services).
    pub fn triangles(&self) -> Option<i64> {
        if self.cfg.algo == Algo::Tc {
            Some(self.with_snapshot(|t| t.triangles))
        } else {
            None
        }
    }

    /// Current service statistics. The engine takes the same stats lock
    /// after every batch, so the latency samples are cloned out and sorted
    /// *outside* the critical section (one sort serves every percentile).
    pub fn stats(&self) -> ServiceStats {
        collect_stats(&self.ingest, &self.snapshots, &self.shared, &self.cfg.merge_policy)
    }

    /// Stop the service: reject new submissions, flush the backlog through
    /// the engine, join, and hand back graph + state + final stats.
    /// Panics if the engine degraded mid-stream or shutdown already ran;
    /// [`try_shutdown`](Self::try_shutdown) reports both cases as values.
    pub fn shutdown(&self) -> ServiceReport {
        self.try_shutdown().unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`shutdown`](Self::shutdown) that surfaces engine death — and
    /// repeated shutdown — as values instead of panicking: a degraded
    /// service yields [`ShutdownError::Degraded`] carrying the final
    /// stats; any call after the first yields
    /// [`ShutdownError::AlreadyShutDown`].
    pub fn try_shutdown(&self) -> std::result::Result<ServiceReport, ShutdownError> {
        let Some(handle) = self.worker.lock().unwrap().take() else {
            return Err(ShutdownError::AlreadyShutDown);
        };
        self.shared.stop.store(true, Ordering::Release);
        self.ingest.stop();
        let out = handle.join().expect("engine supervisor panicked");
        if let Some(s) = self.sampler.lock().unwrap().take() {
            let _ = s.join();
        }
        let stats = self.stats();
        match out {
            Some((graph, state)) => Ok(ServiceReport { graph, state, stats }),
            None => Err(ShutdownError::Degraded(DegradedReport { stats })),
        }
    }
}

/// The stats-collection body both service flavors share (the latency
/// sort runs outside the stats lock; see [`GraphService::stats`]).
fn collect_stats(
    ingest: &Ingest,
    snapshots: &SnapshotCell,
    shared: &Shared,
    policy: &MergePolicy,
) -> ServiceStats {
    let c = ingest.counters();
    let mut out = ServiceStats {
        submitted: c.submitted,
        completed: c.completed,
        coalesced: c.coalesced,
        shed: c.shed,
        restarts: shared.restarts.load(Ordering::SeqCst),
        recovered_batches: shared.recovered_batches.load(Ordering::SeqCst),
        degraded: shared.degraded.load(Ordering::Acquire),
        policy: policy.describe(),
        epoch: snapshots.epoch(),
        wall_secs: shared.started.elapsed().as_secs_f64(),
        ..ServiceStats::default()
    };
    let mut lat = {
        let inner = shared.stats.lock().unwrap();
        out.coalesced += inner.batch_coalesced;
        out.batches = inner.batches;
        out.closed_by_size = inner.closed_by_size;
        out.closed_by_deadline = inner.closed_by_deadline;
        out.closed_by_drain = inner.closed_by_drain;
        out.merges = inner.merges;
        out.modeled_comm_secs = inner.comm_secs;
        out.overflow_fraction = inner.overflow_fraction;
        out.chain_depth_ewma = inner.chain_depth_ewma;
        out.rebalances = inner.rebalances;
        out.migrated_vertices = inner.migrated_vertices;
        out.shard_loads = inner.shard_loads.clone();
        out.direction = inner.direction;
        inner.latencies.samples.clone()
    };
    out.stages = shared.telem.stage_secs();
    let hist = &shared.telem.latency;
    if shared.telem.histograms && hist.count() > 0 {
        out.batch_latency_p50 = hist.percentile_secs(0.50);
        out.batch_latency_p99 = hist.percentile_secs(0.99);
        out.batch_latency_p999 = hist.percentile_secs(0.999);
        out.batch_latency_mean = hist.mean_secs();
    } else if !lat.is_empty() {
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.batch_latency_p50 = percentile_sorted(&lat, 0.50);
        out.batch_latency_p99 = percentile_sorted(&lat, 0.99);
        out.batch_latency_p999 = percentile_sorted(&lat, 0.999);
        out.batch_latency_mean = lat.iter().sum::<f64>() / lat.len() as f64;
    }
    out
}

/// One `--stats-every` line: uptime + ingest counters + the metrics
/// registry snapshot, as a single JSON object on stdout. Reads only
/// atomics (and the registry's name table) — never the engine's stats
/// lock, so sampling cannot stall the batch loop.
fn emit_stats_line(ingest: &Ingest, snapshots: &SnapshotCell, shared: &Shared) {
    let c = ingest.counters();
    println!(
        "{{\"t_secs\":{:.3},\"submitted\":{},\"completed\":{},\"coalesced\":{},\
         \"inflight\":{},\"epoch\":{},\"shed\":{},\"restarts\":{},\
         \"recovered_batches\":{},\"degraded\":{},\"metrics\":{}}}",
        shared.started.elapsed().as_secs_f64(),
        c.submitted,
        c.completed,
        c.coalesced,
        c.submitted.saturating_sub(c.completed),
        snapshots.epoch(),
        c.shed,
        shared.restarts.load(Ordering::Relaxed),
        shared.recovered_batches.load(Ordering::Relaxed),
        shared.degraded.load(Ordering::Relaxed),
        shared.telem.registry.snapshot_json(),
    );
}

/// Spawn the periodic stats sampler. It emits one line per `every`
/// interval and one final line when it observes shutdown (so even runs
/// shorter than the interval produce a snapshot), then exits.
fn spawn_sampler(
    every: Duration,
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("stats-sampler".into())
        .spawn(move || {
            let tick = Duration::from_millis(20).min(every.max(Duration::from_millis(1)));
            let mut next = Instant::now() + every;
            loop {
                if shared.stop.load(Ordering::Acquire) {
                    emit_stats_line(&ingest, &snapshots, &shared);
                    return;
                }
                if Instant::now() >= next {
                    emit_stats_line(&ingest, &snapshots, &shared);
                    next += every;
                }
                std::thread::sleep(tick);
            }
        })
        .expect("spawn stats sampler")
}

/// Copy the algorithm state's property arrays into a snapshot table
/// (buffers reused across publishes).
fn fill_props(t: &mut PropTable, state: &AlgoState) {
    match state {
        AlgoState::Sssp(st) => {
            t.dist.clear();
            t.dist.extend_from_slice(&st.dist);
            t.parent.clear();
            t.parent.extend_from_slice(&st.parent);
        }
        AlgoState::Pr(st) => {
            t.rank.clear();
            t.rank.extend_from_slice(&st.rank);
        }
        AlgoState::Tc(st) => {
            t.triangles = st.triangles;
        }
        AlgoState::Program { prog, st } => {
            use crate::dsl::bytecode::Ty;
            t.prog_ints.clear();
            t.prog_floats.clear();
            for p in &prog.props {
                match p.ty {
                    Ty::Int => {
                        if let Some(v) = st.prop_i64(prog, &p.name) {
                            t.prog_ints.push((p.name.clone(), v));
                        }
                    }
                    Ty::Float => {
                        if let Some(v) = st.prop_f64(prog, &p.name) {
                            t.prog_floats.push((p.name.clone(), v));
                        }
                    }
                    // transient convergence flags — not part of the answer
                    Ty::Bool => {}
                }
            }
            t.prog_result = st.result(prog);
        }
    }
}

fn publish_state(cell: &SnapshotCell, g: &DynGraph, state: &AlgoState) {
    cell.publish(|t| {
        t.graph_epoch = g.epoch();
        t.shard_epochs.clear(); // single engine: no shard stamps
        t.num_nodes = g.num_nodes();
        t.num_edges = g.num_edges();
        fill_props(t, state);
    });
}

/// Epoch-stitched publication for the sharded service: one all-or-nothing
/// table carrying every shard's property block *and* every shard's graph
/// epoch stamp. Readers either see the whole previous epoch or the whole
/// next one — never shard A at epoch `e` next to shard B at `e + 1`.
fn publish_sharded(cell: &SnapshotCell, g: &ShardedGraph, state: &AlgoState) {
    cell.publish(|t| {
        t.graph_epoch = g.epoch();
        t.shard_epochs.clear();
        t.shard_epochs.extend((0..g.num_shards()).map(|r| g.shard(r).epoch()));
        t.num_nodes = g.num_nodes();
        t.num_edges = g.num_edges();
        fill_props(t, state);
    });
}

// ------------------------------------------------- durability + supervision

/// Live durability handle threaded through an engine loop: the open WAL
/// writer plus checkpoint-cadence bookkeeping.
struct Durable {
    wal: WalWriter,
    dir: PathBuf,
    /// Checkpoint every this many applied batches (0 = seed only).
    every: u64,
    /// Sequence number of the last batch handed to the WAL.
    seq: u64,
    since_checkpoint: u64,
}

impl Durable {
    fn open(dir: &std::path::Path, cfg: &DurabilityConfig, seq: u64) -> Result<Durable> {
        Ok(Durable {
            wal: WalWriter::open(dir, cfg.fsync, seq + 1)?,
            dir: dir.to_path_buf(),
            every: cfg.checkpoint_every,
            seq,
            since_checkpoint: 0,
        })
    }

    /// Write-ahead: called after seal, before compute. On return the
    /// batch is durable (fsynced under `SealFsync`); a crash anywhere
    /// later in the pipeline replays it.
    fn append(&mut self, dels: &[(NodeId, NodeId)], adds: &[(NodeId, NodeId, Weight)]) {
        self.seq += 1;
        if let Err(e) = self.wal.append(self.seq, dels, adds) {
            panic!("WAL append failed at seq {}: {e}", self.seq);
        }
    }

    /// Checkpoint cadence: after `every` applied batches, image the state
    /// via `capture`, keep the newest two checkpoints, and drop WAL
    /// segments the new one supersedes. A failed write panics into the
    /// supervisor — recovery then falls back to the previous checkpoint
    /// plus a longer WAL replay, which is state-equivalent.
    fn maybe_checkpoint(&mut self, capture: impl FnOnce(u64) -> Checkpoint) {
        self.since_checkpoint += 1;
        if self.every == 0 || self.since_checkpoint < self.every {
            return;
        }
        let ck = capture(self.seq);
        if let Err(e) = ck.write(&self.dir) {
            panic!("checkpoint write failed at seq {}: {e}", self.seq);
        }
        self.since_checkpoint = 0;
        let _ = checkpoint::prune(&self.dir, 2);
        let _ = self.wal.prune_below(self.seq);
    }
}

/// Failpoint sites living in non-`Result` stretches of the engine loops:
/// `err` and `panic` actions both crash the hosting thread (the
/// supervisor catches either), `delay` stalls in place.
fn chaos(site: &str) {
    if let Err(e) = failpoint::hit(site) {
        panic!("{e}");
    }
}

/// Enter read-only degraded mode: the last published epoch keeps serving
/// queries while producers — including ones parked in backpressure — get
/// [`SubmitError::Poisoned`] and `drain()` callers unblock. Both service
/// flavors funnel engine death through here; the sharded service used to
/// leave its ingest live and panic the caller at shutdown's join.
fn degrade(ingest: &Ingest, shared: &Shared) {
    shared.degraded.store(true, Ordering::Release);
    ingest.poison();
}

/// Supervisor bookkeeping after a caught engine panic: reconcile the
/// in-flight batch's completion accounting (its updates died with the
/// loop; recovery re-applies the WAL'd ones, and without a WAL the loss
/// is the documented volatile window), bump the crash counter, and decide
/// whether another attempt is allowed. Returns `false` — after degrading
/// the service — when restarts are exhausted, shutdown already began, or
/// there is no WAL to recover from; otherwise sleeps the exponential
/// backoff and returns `true`.
fn note_crash_and_backoff(
    ingest: &Ingest,
    shared: &Shared,
    cfg: &ServiceConfig,
    attempt: &mut u32,
) -> bool {
    let inflight = shared.inflight.swap(0, Ordering::SeqCst);
    if inflight > 0 {
        ingest.complete(inflight);
    }
    shared.restarts.fetch_add(1, Ordering::SeqCst);
    let recoverable = cfg.durability.wal_dir.is_some()
        && *attempt < cfg.durability.max_restarts
        && !shared.stop.load(Ordering::Acquire);
    if !recoverable {
        degrade(ingest, shared);
        return false;
    }
    let backoff = cfg.durability.restart_backoff.saturating_mul(1u32 << (*attempt).min(16));
    *attempt += 1;
    std::thread::sleep(backoff);
    true
}

/// One sealed batch through the single-engine pipeline — shared verbatim
/// between the live loop and WAL replay, so recovery replays through the
/// code path it is recovering. `dels` arrives as sealed (pre-filter, the
/// shape the WAL records); TC's liveness filter runs here against the
/// same graph state either way.
fn apply_single_batch(
    engine: &dyn DynamicEngine,
    g: &mut DynGraph,
    state: &mut AlgoState,
    dels: &mut Vec<(NodeId, NodeId)>,
    adds: &[(NodeId, NodeId, Weight)],
) -> Result<()> {
    failpoint::hit("compute")?;
    match state {
        AlgoState::Sssp(st) => engine.sssp_dynamic_batch_parts(g, st, dels, adds),
        AlgoState::Pr(st) => engine.pr_dynamic_batch_parts(g, st, dels, adds).map(|_| ()),
        AlgoState::Tc(st) => {
            // TC's decremental delta counting assumes deleted arcs are
            // live (Fig. 19 runs it *before* updateCSRDel); coalescing
            // keeps deletes whose insert was cancelled, so deletes of
            // absent arcs are legal here — drop them before counting.
            dels.retain(|&(u, v)| g.has_edge(u, v));
            engine.tc_dynamic_batch(g, st, dels, adds)
        }
        AlgoState::Program { prog, st } => engine.run_program(
            prog,
            crate::dsl::bytecode::Phase::Batch { dels, adds },
            g,
            st,
        ),
    }
}

/// Build (or rebuild, after a supervised restart) the single-engine
/// world: engine + graph + state + durability handle. `seed` carries the
/// caller's graph on the first call; a WAL dir holding a checkpoint
/// supersedes it — the image is restored and the WAL tail past its `seq`
/// replays through [`apply_single_batch`]. A fresh durable start writes
/// the seed checkpoint at seq 0 up front, so a crash before the first
/// periodic checkpoint still recovers.
fn init_single(
    seed: &mut Option<DynGraph>,
    cfg: &ServiceConfig,
    shared: &Shared,
) -> Result<(Box<dyn DynamicEngine>, DynGraph, AlgoState, Option<Durable>)> {
    let engine = make_engine(cfg.backend, &cfg.engine)?;
    if let Some(dir) = &cfg.durability.wal_dir {
        if let Some(ck) = checkpoint::load_latest(dir)? {
            let mut g = ck.restore_graph();
            // The service owns the merge schedule (see try_start).
            g.merge_period = 0;
            engine.prepare_graph(&mut g);
            let mut state = ck.state.clone();
            let (records, _info) = wal::replay(dir, ck.seq)?;
            let mut seq = ck.seq;
            let mut replayed = 0u64;
            for rec in records {
                let mut dels = rec.dels;
                apply_single_batch(&*engine, &mut g, &mut state, &mut dels, &rec.adds)?;
                seq = rec.seq;
                replayed += 1;
                // Bound replay-time diff-chain depth; merges never change
                // results, so cadence differences from the live run are
                // invisible to the equivalence checks.
                if replayed % 64 == 0 {
                    g.merge();
                }
            }
            engine.drain_comm_secs();
            shared.recovered_batches.fetch_add(replayed, Ordering::SeqCst);
            let durable = Durable::open(dir, &cfg.durability, seq)?;
            return Ok((engine, g, state, Some(durable)));
        }
    }
    let mut g = seed
        .take()
        .ok_or_else(|| anyhow!("engine restart requires a WAL checkpoint to recover from"))?;
    engine.prepare_graph(&mut g);
    let state = seed_state(&*engine, &mut g, cfg)?;
    // Seeding solve comm is not counted, mirroring the offline cells'
    // protocol (the dynamic measurement starts here).
    engine.drain_comm_secs();
    let durable = match &cfg.durability.wal_dir {
        Some(dir) => {
            Checkpoint::capture(0, &g, &state).write(dir)?;
            Some(Durable::open(dir, &cfg.durability, 0)?)
        }
        None => None,
    };
    Ok((engine, g, state, durable))
}

/// The single-engine thread body: init (or recover), publish, run the
/// batch loop under `catch_unwind`, and on a caught crash either restart
/// from checkpoint + WAL or degrade to read-only. Returns `None` when the
/// service degraded (or never started).
fn supervise_single(
    g: DynGraph,
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    ready_tx: mpsc::Sender<Result<()>>,
) -> Option<(DynGraph, AlgoState)> {
    let mut seed = Some(g);
    let mut ready = Some(ready_tx);
    let mut attempt = 0u32;
    loop {
        let (engine, g, state, mut durable) = match init_single(&mut seed, &cfg, &shared) {
            Ok(parts) => parts,
            Err(e) => {
                match ready.take() {
                    // Startup: report to try_start's caller.
                    Some(tx) => {
                        let _ = tx.send(Err(e));
                    }
                    // Mid-life rebuild failed (e.g. unreadable WAL dir):
                    // nothing left to serve writes with.
                    None => degrade(&ingest, &shared),
                }
                return None;
            }
        };
        // Epoch continuity: a recovered fresh process resumes the epoch
        // line at its recovered batch seq (≥ anything the dead process
        // published); a no-op after the first publish.
        if let Some(d) = &durable {
            snapshots.resume_from(d.seq);
        }
        publish_state(&snapshots, &g, &state);
        if let Some(tx) = ready.take() {
            let _ = tx.send(Ok(()));
        }
        let run = catch_unwind(AssertUnwindSafe(|| {
            engine_loop(
                g,
                state,
                &*engine,
                Arc::clone(&ingest),
                Arc::clone(&snapshots),
                Arc::clone(&shared),
                cfg.clone(),
                &mut durable,
            )
        }));
        match run {
            Ok(done) => return Some(done),
            Err(_) => {
                if !note_crash_and_backoff(&ingest, &shared, &cfg, &mut attempt) {
                    return None;
                }
            }
        }
    }
}

/// The batch loop: any backend, through the engine contract. Engine
/// errors mid-stream panic the loop; the supervisor above catches the
/// unwind and either recovers from checkpoint + WAL or poisons the
/// ingest and degrades the service to read-only — every snapshot
/// published before the crash stays consistent either way.
#[allow(clippy::too_many_arguments)]
fn engine_loop(
    mut g: DynGraph,
    mut state: AlgoState,
    engine: &dyn DynamicEngine,
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    durable: &mut Option<Durable>,
) -> (DynGraph, AlgoState) {
    let mut batcher = Batcher::new(cfg.batch_capacity, cfg.batch_deadline, cfg.symmetric);
    let mut dels: Vec<(NodeId, NodeId)> = Vec::new();
    let mut adds: Vec<(NodeId, NodeId, Weight)> = Vec::new();
    let mut governor = MergeGovernor::new(cfg.merge_policy);
    let telem = &shared.telem;
    // Span tracks for this thread (the batcher "runs" on the engine
    // thread, but batch formation vs propagation read better as two
    // Perfetto tracks).
    let trk_batcher = cfg.telemetry.tracer.as_ref().map(|t| t.track("batcher", TRACK_CAP));
    let trk_engine = cfg.telemetry.tracer.as_ref().map(|t| t.track("engine", TRACK_CAP));

    loop {
        let idle_from = Instant::now();
        let Some(meta) = batcher.next_batch(&ingest, &shared.stop) else { break };
        let closed_at = Instant::now();
        if let Some(t) = &trk_batcher {
            t.record_between(Stage::Form, idle_from, closed_at);
        }
        let queue_wait =
            meta.oldest.map(|o| closed_at.saturating_duration_since(o)).unwrap_or_default();

        // The batch is now inside the loop: if a crash lands anywhere
        // before its completion accounting below, the supervisor settles
        // the balance (see `Shared::inflight`).
        shared.inflight.store(meta.raw_len as u64, Ordering::SeqCst);
        batcher.take_into(&mut dels, &mut adds);
        chaos("seal");
        // Write-ahead at the seal boundary: the sealed batch is the unit
        // of durability. A crash between seal and append loses exactly
        // this batch (accepted-but-volatile window); any crash after the
        // append replays it.
        if let Some(d) = durable.as_mut() {
            d.append(&dels, &adds);
        }
        let formed_at = Instant::now();
        if let Some(t) = &trk_engine {
            t.record_between(Stage::Seal, closed_at, formed_at);
        }

        if let Err(e) = apply_single_batch(engine, &mut g, &mut state, &mut dels, &adds) {
            // Crash into the supervisor: it reconciles the accounting,
            // then restarts from checkpoint + WAL or degrades.
            panic!("{} engine failed mid-stream: {e}", engine.capabilities().name);
        }
        let computed_at = Instant::now();
        if let Some(t) = &trk_engine {
            t.record_between(Stage::Compute, formed_at, computed_at);
        }

        // one bitmap scan per batch: the governor folds the instantaneous
        // per-read chain depth into its EWMA and decides; the stats record
        // the pre-merge signals, so dashboards see the heat that
        // *triggered* a merge rather than the post-merge 0
        let signal = governor.after_batch(&g);
        let merge_from = Instant::now();
        if signal.merge {
            chaos("merge");
            g.merge();
            if let Some(t) = &trk_engine {
                t.record(Stage::Merge, merge_from);
            }
        }
        let merged_at = Instant::now();

        chaos("publish");
        publish_state(&snapshots, &g, &state);
        let published_at = Instant::now();
        if let Some(t) = &trk_engine {
            t.record_between(Stage::Publish, merged_at, published_at);
        }

        let latency = meta
            .oldest
            .map(|o| published_at.saturating_duration_since(o).as_secs_f64())
            .unwrap_or(0.0);
        telem.latency.record_secs(latency);
        telem.batches.inc();
        if signal.merge {
            telem.merges.inc();
        }
        telem.epoch.set(snapshots.epoch() as f64);
        telem.add_stage(ST_QUEUE_WAIT, queue_wait);
        telem.add_stage(ST_FORM, formed_at.saturating_duration_since(closed_at));
        telem.add_stage(ST_COMPUTE, computed_at.saturating_duration_since(formed_at));
        telem.add_stage(ST_MERGE, merged_at.saturating_duration_since(merge_from));
        telem.add_stage(ST_PUBLISH, published_at.saturating_duration_since(merged_at));

        let comm = engine.drain_comm_secs();
        {
            let mut s = shared.stats.lock().unwrap();
            s.batches += 1;
            s.comm_secs += comm;
            match meta.reason {
                CloseReason::Size => s.closed_by_size += 1,
                CloseReason::Deadline => s.closed_by_deadline += 1,
                CloseReason::Drain => s.closed_by_drain += 1,
            }
            if signal.merge {
                s.merges += 1;
            }
            s.batch_coalesced += meta.coalesced as u64;
            s.overflow_fraction = signal.overflow_fraction;
            s.chain_depth_ewma = signal.ewma_depth;
            s.direction = engine.direction_stats();
            s.push_latency(latency);
        }
        if let Some(d) = durable.as_mut() {
            d.maybe_checkpoint(|seq| Checkpoint::capture(seq, &g, &state));
        }
        // Completion accounting last: `drain()` returning guarantees the
        // matching snapshot is already published.
        ingest.complete(meta.raw_len as u64);
        shared.inflight.store(0, Ordering::SeqCst);
    }
    (g, state)
}

// ------------------------------------------------------------ sharded

/// Everything the sharded engine thread hands back at shutdown.
#[derive(Debug)]
pub struct ShardedReport {
    pub graph: ShardedGraph,
    pub state: AlgoState,
    pub stats: ServiceStats,
    /// Cumulative halo-exchange traffic (push rounds, local vs
    /// shard-crossing relax messages).
    pub relay: RelayStats,
}

impl ShardedReport {
    pub fn sssp(&self) -> Option<&SsspState> {
        match &self.state {
            AlgoState::Sssp(st) => Some(st),
            _ => None,
        }
    }

    pub fn pr(&self) -> Option<&PrState> {
        match &self.state {
            AlgoState::Pr(st) => Some(st),
            _ => None,
        }
    }

    pub fn tc(&self) -> Option<&TcState> {
        match &self.state {
            AlgoState::Tc(st) => Some(st),
            _ => None,
        }
    }

    /// Collapse into the single-engine report shape (the graph is rebuilt
    /// from the shard edge sets; diff/tombstone layout is not preserved,
    /// the edge set and every property are) so shared tooling — the
    /// coordinator's stream cells, the benches — can consume either
    /// service flavor.
    pub fn into_service_report(self) -> ServiceReport {
        ServiceReport { graph: self.graph.into_dyn_graph(), state: self.state, stats: self.stats }
    }
}

/// The sharded streaming facade: the same ingest → batcher front as
/// [`GraphService`], but each batch propagates across
/// `cfg.engine_shards` engine shards concurrently
/// ([`ShardedEngine`]; see `stream::shard` for the BSP/relay execution
/// model), and every published snapshot is **epoch-stitched** — one
/// all-or-nothing table carrying per-shard epoch stamps, so readers never
/// observe two shards at different epochs.
pub struct ShardedService {
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    worker: Mutex<Option<JoinHandle<Option<(ShardedGraph, AlgoState, RelayStats)>>>>,
    sampler: Mutex<Option<JoinHandle<()>>>,
}

impl ShardedService {
    /// [`try_start`](Self::try_start), panicking on startup failure.
    pub fn start(g: DynGraph, cfg: ServiceConfig) -> Self {
        Self::try_start(g, cfg).expect("ShardedService failed to start")
    }

    /// Partition `g` over `cfg.engine_shards` shards (edge-mass-balanced
    /// vertex blocks), run the initial static solve across the shards,
    /// publish it as epoch 1, then start the coordinator thread.
    ///
    /// The shard fleet is its own BSP engine (one thread per shard with a
    /// cross-shard relay), not a [`DynamicEngine`] instance — so only the
    /// default `cpu` backend selector is accepted here; running the
    /// sharded service over non-cpu engines is a ROADMAP follow-up.
    pub fn try_start(g: DynGraph, cfg: ServiceConfig) -> Result<Self> {
        if cfg.backend != BackendKind::Cpu {
            bail!(
                "the sharded service (--shards > 1) runs its own BSP shard \
                 engine; --backend {} is only available on the single-engine \
                 service (drop --shards or use --backend cpu)",
                cfg.backend.name()
            );
        }
        if cfg.engine != EngineOpts::default() {
            bail!(
                "the sharded service ignores engine knobs \
                 (--threads/--sched/--direction/--ranks): its parallelism is \
                 the shard count and its schedule is the partition; drop the \
                 knobs or drop --shards"
            );
        }
        if cfg.program.is_some() {
            bail!(
                "serve --program runs on the single-engine service only; \
                 drop --engine-shards (or set it to 1) to serve a DSL program"
            );
        }
        let snapshots = Arc::new(SnapshotCell::new());
        let mut ingest_raw = Ingest::new(cfg.shards, cfg.shard_capacity, cfg.symmetric);
        if let Some(tracer) = &cfg.telemetry.tracer {
            ingest_raw.set_tracks(
                (0..cfg.shards.max(1))
                    .map(|i| tracer.track(&format!("ingest-{i}"), TRACK_CAP))
                    .collect(),
            );
        }
        let ingest = Arc::new(ingest_raw);
        let shared = Arc::new(Shared::new(cfg.telemetry.histograms));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let worker = {
            let ingest = Arc::clone(&ingest);
            let snapshots = Arc::clone(&snapshots);
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                supervise_sharded(g, ingest, snapshots, shared, cfg, ready_tx)
            })
        };

        match ready_rx.recv() {
            Ok(Ok(())) => {
                let sampler = cfg.telemetry.stats_every.map(|every| {
                    spawn_sampler(
                        every,
                        Arc::clone(&ingest),
                        Arc::clone(&snapshots),
                        Arc::clone(&shared),
                    )
                });
                Ok(ShardedService {
                    ingest,
                    snapshots,
                    shared,
                    cfg,
                    worker: Mutex::new(Some(worker)),
                    sampler: Mutex::new(sampler),
                })
            }
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                Err(anyhow!("sharded engine thread died during startup"))
            }
        }
    }

    /// Submit one update (blocking under backpressure). Returns `false`
    /// once the service is shutting down.
    pub fn submit(&self, upd: Update) -> bool {
        self.ingest.submit(upd)
    }

    /// Submit with a patience bound: block under backpressure at most
    /// `deadline`, then shed with [`SubmitError::Shed`] (counted in
    /// [`ServiceStats::shed`], never in `submitted`).
    pub fn submit_deadline(&self, upd: Update, deadline: Duration) -> Result<(), SubmitError> {
        self.ingest.submit_deadline(upd, deadline)
    }

    /// Convenience: submit an edge insertion.
    pub fn insert(&self, src: NodeId, dst: NodeId, weight: Weight) -> bool {
        self.submit(Update { kind: UpdateKind::Add, src, dst, weight })
    }

    /// Convenience: submit an edge deletion.
    pub fn remove(&self, src: NodeId, dst: NodeId) -> bool {
        self.submit(Update { kind: UpdateKind::Delete, src, dst, weight: 0 })
    }

    /// Block until every submitted update has been applied (or coalesced)
    /// and its stitched snapshot published. Producers must pause first.
    pub fn drain(&self) {
        self.ingest.wait_quiescent();
    }

    /// [`drain`](Self::drain) with a bound: `Err(DrainTimeout)` if the
    /// backlog has not flushed within `timeout`.
    pub fn drain_timeout(&self, timeout: Duration) -> Result<(), DrainTimeout> {
        self.ingest.wait_quiescent_timeout(timeout)
    }

    /// Engine dead past recovery: reads keep serving the last published
    /// epoch, writes are rejected with [`SubmitError::Poisoned`].
    pub fn degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::Acquire)
    }

    /// Latest published snapshot epoch.
    pub fn epoch(&self) -> u64 {
        self.snapshots.epoch()
    }

    /// Run `f` against the current published stitched snapshot (never
    /// blocks on the engine shards; see [`SnapshotCell`]). The table's
    /// `shard_epochs` carry one graph-epoch stamp per engine shard —
    /// always mutually equal, that is the stitch invariant.
    pub fn with_snapshot<R>(&self, f: impl FnOnce(&PropTable) -> R) -> R {
        self.snapshots.read(f)
    }

    /// SSSP distance of `v` in the published snapshot.
    pub fn dist(&self, v: NodeId) -> Option<i64> {
        self.with_snapshot(|t| t.dist.get(v as usize).copied())
    }

    /// PageRank of `v` in the published snapshot.
    pub fn rank(&self, v: NodeId) -> Option<f64> {
        self.with_snapshot(|t| t.rank.get(v as usize).copied())
    }

    /// Triangle count in the published snapshot (TC services).
    pub fn triangles(&self) -> Option<i64> {
        if self.cfg.algo == Algo::Tc {
            Some(self.with_snapshot(|t| t.triangles))
        } else {
            None
        }
    }

    /// Current service statistics (same shape as the single-engine
    /// service's — the benches compare the two directly).
    pub fn stats(&self) -> ServiceStats {
        collect_stats(&self.ingest, &self.snapshots, &self.shared, &self.cfg.merge_policy)
    }

    /// Stop the service: reject new submissions, flush the backlog through
    /// the shards, join, and hand back shards + state + stats + relay
    /// telemetry. Panics if the fleet degraded mid-stream or shutdown
    /// already ran; [`try_shutdown`](Self::try_shutdown) reports both
    /// cases as values.
    pub fn shutdown(&self) -> ShardedReport {
        self.try_shutdown().unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`shutdown`](Self::shutdown) that surfaces fleet death — and
    /// repeated shutdown — as values instead of panicking: a degraded
    /// service yields [`ShutdownError::Degraded`] carrying the final
    /// stats; any call after the first yields
    /// [`ShutdownError::AlreadyShutDown`].
    pub fn try_shutdown(&self) -> std::result::Result<ShardedReport, ShutdownError> {
        let Some(handle) = self.worker.lock().unwrap().take() else {
            return Err(ShutdownError::AlreadyShutDown);
        };
        self.shared.stop.store(true, Ordering::Release);
        self.ingest.stop();
        let out = handle.join().expect("sharded engine supervisor panicked");
        if let Some(s) = self.sampler.lock().unwrap().take() {
            let _ = s.join();
        }
        let stats = self.stats();
        match out {
            Some((graph, state, relay)) => Ok(ShardedReport { graph, state, stats, relay }),
            None => Err(ShutdownError::Degraded(DegradedReport { stats })),
        }
    }
}

/// One sealed batch through the sharded pipeline — shared between the
/// live loop and WAL replay: TC liveness filter, owner routing, BSP
/// propagation. `dels` arrives as sealed (pre-filter, the shape the WAL
/// records).
#[allow(clippy::too_many_arguments)]
fn apply_sharded_batch(
    engine: &mut ShardedEngine,
    g: &mut ShardedGraph,
    state: &mut AlgoState,
    dels: &mut Vec<(NodeId, NodeId)>,
    adds: &[(NodeId, NodeId, Weight)],
    dels_by: &mut Vec<Vec<(NodeId, NodeId)>>,
    adds_by: &mut Vec<Vec<(NodeId, NodeId, Weight)>>,
) -> Result<()> {
    failpoint::hit("compute")?;
    if matches!(state, AlgoState::Tc(_)) {
        // TC's decremental delta counting assumes deleted arcs are live
        // (Fig. 19 runs it *before* updateCSRDel); coalescing keeps
        // deletes whose insert was cancelled, so drop deletes of absent
        // arcs before counting — the owner answers.
        dels.retain(|&(u, v)| g.has_edge(u, v));
    }
    g.route(dels, adds, dels_by, adds_by);
    match state {
        AlgoState::Sssp(st) => engine.sssp_dynamic_batch(g, st, dels_by, adds_by),
        AlgoState::Pr(st) => engine.pr_dynamic_batch(g, st, dels_by, adds_by),
        AlgoState::Tc(st) => engine.tc_dynamic_batch(g, st, dels_by, adds_by),
        // ShardedService::try_start rejects program configs up front.
        AlgoState::Program { .. } => {
            bail!("the sharded service does not execute DSL bytecode programs")
        }
    }
    Ok(())
}

/// Build (or rebuild, after a supervised restart) the sharded world:
/// fleet engine + partitioned graph + state + durability handle. Same
/// contract as [`init_single`]: a WAL dir holding a checkpoint supersedes
/// the seed graph, and its WAL tail replays through
/// [`apply_sharded_batch`] — the live pipeline's own apply path.
fn init_sharded(
    seed: &mut Option<DynGraph>,
    cfg: &ServiceConfig,
    shared: &Shared,
) -> Result<(ShardedEngine, ShardedGraph, AlgoState, Option<Durable>)> {
    let build_engine = |nshards: usize| {
        let mut engine = ShardedEngine::new();
        // One span track per shard worker: phase closures record
        // scatter/steal/gather/pull spans from the worker thread that
        // runs them, and (on the persistent fleet) the same worker
        // records its barrier-wait spans — one thread, one track.
        let shard_tracks: Vec<Arc<Track>> = match &cfg.telemetry.tracer {
            Some(tracer) => (0..nshards)
                .map(|r| tracer.track(&format!("shard-{r}"), SHARD_TRACK_CAP))
                .collect(),
            None => Vec::new(),
        };
        // The persistent fleet is spawned once per engine life and lives
        // until shutdown (or a supervised restart rebuilds it); every BSP
        // phase — including the static seed solve — is a closure
        // delivered to the resident workers instead of a fresh
        // thread::scope.
        if cfg.persistent && nshards > 1 {
            engine.attach_fleet(crate::util::ShardFleet::with_tracks(
                nshards,
                shard_tracks.clone(),
            ));
        }
        engine.set_tracks(shard_tracks);
        engine.set_steal(cfg.steal);
        engine
    };
    if let Some(dir) = &cfg.durability.wal_dir {
        if let Some(ck) = checkpoint::load_latest(dir)? {
            let mut graph = ShardedGraph::partition(&ck.restore_graph(), cfg.engine_shards.max(1));
            let nshards = graph.num_shards();
            let mut engine = build_engine(nshards);
            let mut state = ck.state.clone();
            let (records, _info) = wal::replay(dir, ck.seq)?;
            let mut dels_by: Vec<Vec<(NodeId, NodeId)>> = vec![Vec::new(); nshards];
            let mut adds_by: Vec<Vec<(NodeId, NodeId, Weight)>> = vec![Vec::new(); nshards];
            let mut seq = ck.seq;
            let mut replayed = 0u64;
            for rec in records {
                let mut dels = rec.dels;
                apply_sharded_batch(
                    &mut engine,
                    &mut graph,
                    &mut state,
                    &mut dels,
                    &rec.adds,
                    &mut dels_by,
                    &mut adds_by,
                )?;
                seq = rec.seq;
                replayed += 1;
            }
            shared.recovered_batches.fetch_add(replayed, Ordering::SeqCst);
            let durable = Durable::open(dir, &cfg.durability, seq)?;
            return Ok((engine, graph, state, Some(durable)));
        }
    }
    let g = seed
        .take()
        .ok_or_else(|| anyhow!("engine restart requires a WAL checkpoint to recover from"))?;
    let graph = ShardedGraph::partition(&g, cfg.engine_shards.max(1));
    drop(g);
    let mut engine = build_engine(graph.num_shards());
    let state = match cfg.algo {
        Algo::Sssp => AlgoState::Sssp(engine.sssp_static(&graph, cfg.source)),
        Algo::Pr => {
            let mut st =
                PrState::new(graph.num_nodes(), cfg.pr_beta, cfg.pr_delta, cfg.pr_max_iter);
            engine.pr_static(&graph, &mut st);
            AlgoState::Pr(st)
        }
        Algo::Tc => AlgoState::Tc(engine.tc_static(&graph)),
    };
    let durable = match &cfg.durability.wal_dir {
        Some(dir) => {
            Checkpoint::capture_parts(0, graph.epoch(), graph.num_nodes(), graph.edges_sorted(), &state)
                .write(dir)?;
            Some(Durable::open(dir, &cfg.durability, 0)?)
        }
        None => None,
    };
    Ok((engine, graph, state, durable))
}

/// The sharded engine thread body: same supervision contract as
/// [`supervise_single`] — init (or recover), publish, run the loop under
/// `catch_unwind`, restart or degrade on a caught crash.
fn supervise_sharded(
    g: DynGraph,
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    ready_tx: mpsc::Sender<Result<()>>,
) -> Option<(ShardedGraph, AlgoState, RelayStats)> {
    let mut seed = Some(g);
    let mut ready = Some(ready_tx);
    let mut attempt = 0u32;
    loop {
        let (engine, graph, state, mut durable) = match init_sharded(&mut seed, &cfg, &shared) {
            Ok(parts) => parts,
            Err(e) => {
                match ready.take() {
                    Some(tx) => {
                        let _ = tx.send(Err(e));
                    }
                    None => degrade(&ingest, &shared),
                }
                return None;
            }
        };
        if let Some(d) = &durable {
            snapshots.resume_from(d.seq);
        }
        publish_sharded(&snapshots, &graph, &state);
        if let Some(tx) = ready.take() {
            let _ = tx.send(Ok(()));
        }
        let run = catch_unwind(AssertUnwindSafe(|| {
            sharded_engine_loop(
                graph,
                state,
                engine,
                Arc::clone(&ingest),
                Arc::clone(&snapshots),
                Arc::clone(&shared),
                cfg.clone(),
                &mut durable,
            )
        }));
        match run {
            Ok(done) => return Some(done),
            Err(_) => {
                if !note_crash_and_backoff(&ingest, &shared, &cfg, &mut attempt) {
                    return None;
                }
            }
        }
    }
}

/// The sharded coordinator loop: form a global batch (identical batcher
/// and coalescing semantics to the single-engine loop — an insert and its
/// delete share an edge key, hence a source owner, so routing can never
/// reorder a shard-crossing delete ahead of its insert), route it to the
/// owning shards, run the BSP propagation, stitch, publish.
#[allow(clippy::too_many_arguments)]
fn sharded_engine_loop(
    mut g: ShardedGraph,
    mut state: AlgoState,
    mut engine: ShardedEngine,
    ingest: Arc<Ingest>,
    snapshots: Arc<SnapshotCell>,
    shared: Arc<Shared>,
    cfg: ServiceConfig,
    durable: &mut Option<Durable>,
) -> (ShardedGraph, AlgoState, RelayStats) {
    let mut batcher = Batcher::new(cfg.batch_capacity, cfg.batch_deadline, cfg.symmetric);
    let mut dels: Vec<(NodeId, NodeId)> = Vec::new();
    let mut adds: Vec<(NodeId, NodeId, Weight)> = Vec::new();
    let nshards = g.num_shards();
    let mut dels_by: Vec<Vec<(NodeId, NodeId)>> = vec![Vec::new(); nshards];
    let mut adds_by: Vec<Vec<(NodeId, NodeId, Weight)>> = vec![Vec::new(); nshards];
    // One merge governor per shard: a deep-chained shard merges alone
    // instead of one hot shard forcing a global merge_all.
    let mut governors: Vec<MergeGovernor> =
        (0..nshards).map(|_| MergeGovernor::new(cfg.merge_policy)).collect();
    let mut merges_by: Vec<u64> = vec![0; nshards];
    let telem = &shared.telem;
    let trk_batcher = cfg.telemetry.tracer.as_ref().map(|t| t.track("batcher", TRACK_CAP));
    let trk_engine = cfg.telemetry.tracer.as_ref().map(|t| t.track("engine", TRACK_CAP));
    // The engine accumulates barrier-wait and relay (gather) time across
    // its whole life; the loop turns them into per-batch stage deltas.
    let mut barrier_seen = 0.0f64;
    let mut relay_seen = 0.0f64;

    loop {
        let idle_from = Instant::now();
        let Some(meta) = batcher.next_batch(&ingest, &shared.stop) else { break };
        let closed_at = Instant::now();
        if let Some(t) = &trk_batcher {
            t.record_between(Stage::Form, idle_from, closed_at);
        }
        let queue_wait =
            meta.oldest.map(|o| closed_at.saturating_duration_since(o)).unwrap_or_default();

        shared.inflight.store(meta.raw_len as u64, Ordering::SeqCst);
        batcher.take_into(&mut dels, &mut adds);
        chaos("seal");
        // Write-ahead at the seal boundary (the global pre-route batch is
        // what the WAL records; routing and the TC liveness filter re-run
        // identically during replay).
        if let Some(d) = durable.as_mut() {
            d.append(&dels, &adds);
        }
        let formed_at = Instant::now();
        if let Some(t) = &trk_engine {
            t.record_between(Stage::Seal, closed_at, formed_at);
        }

        if let Err(e) = apply_sharded_batch(
            &mut engine,
            &mut g,
            &mut state,
            &mut dels,
            &adds,
            &mut dels_by,
            &mut adds_by,
        ) {
            panic!("sharded engine failed mid-stream: {e}");
        }
        let computed_at = Instant::now();
        if let Some(t) = &trk_engine {
            t.record_between(Stage::Compute, formed_at, computed_at);
        }

        // Per-shard merge governance: each governor watches its own
        // shard's chain depth and overflow heat, and only the flagged
        // shards compact (in one fleet phase). Aggregate stats keep the
        // single-engine shape: global overflow fraction, max EWMA.
        let mut merge_flags = vec![false; nshards];
        let mut ewma_max = 0.0f64;
        let mut any_merge = false;
        for (r, gov) in governors.iter_mut().enumerate() {
            let sig =
                gov.observe(g.shard(r).diff_chain_len(), g.shard_overflow_fraction(r));
            ewma_max = ewma_max.max(sig.ewma_depth);
            if sig.merge {
                merge_flags[r] = true;
                merges_by[r] += 1;
                any_merge = true;
            }
        }
        let merge_from = Instant::now();
        if any_merge {
            chaos("merge");
        }
        let merged =
            if any_merge { g.merge_shards_with(engine.fleet(), &merge_flags) } else { 0 };
        let merged_at = Instant::now();
        if any_merge {
            if let Some(t) = &trk_engine {
                t.record_between(Stage::Merge, merge_from, merged_at);
            }
        }

        // Churn-driven rebalancing, still inside the batch boundary: if
        // skew crossed the threshold, recompute the edge-balanced
        // boundaries online and migrate the moved vertices' rows. The
        // stitched publish below makes the move invisible to readers.
        let mut moved_vertices = 0usize;
        if let Some(threshold) = cfg.rebalance {
            if g.imbalance() >= threshold {
                let rebalance_from = Instant::now();
                let (mv, _me) = g.rebalance();
                moved_vertices = mv;
                if let Some(t) = &trk_engine {
                    t.record(Stage::Rebalance, rebalance_from);
                }
            }
        }

        let publish_from = Instant::now();
        chaos("publish");
        publish_sharded(&snapshots, &g, &state);
        let published_at = Instant::now();
        if let Some(t) = &trk_engine {
            t.record_between(Stage::Publish, publish_from, published_at);
        }

        let latency = meta
            .oldest
            .map(|o| published_at.saturating_duration_since(o).as_secs_f64())
            .unwrap_or(0.0);
        telem.latency.record_secs(latency);
        telem.batches.inc();
        telem.merges.add(merged as u64);
        telem.epoch.set(snapshots.epoch() as f64);
        telem.add_stage(ST_QUEUE_WAIT, queue_wait);
        telem.add_stage(ST_FORM, formed_at.saturating_duration_since(closed_at));
        telem.add_stage(ST_COMPUTE, computed_at.saturating_duration_since(formed_at));
        telem.add_stage(ST_MERGE, merged_at.saturating_duration_since(merge_from));
        telem.add_stage(ST_PUBLISH, published_at.saturating_duration_since(publish_from));
        let barrier_total = engine.barrier_wait_secs();
        let relay_total = engine.relay_secs();
        telem.stage[ST_BARRIER]
            .add(((barrier_total - barrier_seen).max(0.0) * 1e9) as u64);
        telem.stage[ST_RELAY].add(((relay_total - relay_seen).max(0.0) * 1e9) as u64);
        barrier_seen = barrier_total;
        relay_seen = relay_total;
        {
            let mut s = shared.stats.lock().unwrap();
            s.batches += 1;
            match meta.reason {
                CloseReason::Size => s.closed_by_size += 1,
                CloseReason::Deadline => s.closed_by_deadline += 1,
                CloseReason::Drain => s.closed_by_drain += 1,
            }
            s.merges += merged as u64;
            if moved_vertices > 0 {
                s.rebalances += 1;
                s.migrated_vertices += moved_vertices as u64;
            }
            s.batch_coalesced += meta.coalesced as u64;
            s.overflow_fraction = g.overflow_fraction();
            s.chain_depth_ewma = ewma_max;
            // Per-shard load table for the serve printout / stats JSON.
            let masses = g.shard_edge_masses();
            let (donated, received) = engine.shard_steals();
            s.shard_loads.clear();
            for r in 0..nshards {
                s.shard_loads.push(ShardLoad {
                    shard: r,
                    edge_mass: masses[r] as u64,
                    steals_donated: donated.get(r).copied().unwrap_or(0),
                    steals_received: received.get(r).copied().unwrap_or(0),
                    merges: merges_by[r],
                });
            }
            s.push_latency(latency);
        }
        if let Some(d) = durable.as_mut() {
            d.maybe_checkpoint(|seq| {
                Checkpoint::capture_parts(seq, g.epoch(), g.num_nodes(), g.edges_sorted(), &state)
            });
        }
        ingest.complete(meta.raw_len as u64);
        shared.inflight.store(0, Ordering::SeqCst);
    }
    let relay = engine.relay_stats();
    (g, state, relay)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{sssp, triangle};
    use crate::backend::Direction;
    use crate::graph::{generators, UpdateStream};
    use crate::util::threadpool::Sched;

    fn cfg(algo: Algo) -> ServiceConfig {
        let mut c = ServiceConfig::new(algo);
        c.engine.threads = Some(2);
        c.shards = 2;
        c.batch_capacity = 64;
        c.batch_deadline = Duration::from_millis(2);
        c
    }

    /// Engine knobs are single-engine-only; the sharded fleet's
    /// parallelism is its shard count.
    fn sharded_cfg(algo: Algo) -> ServiceConfig {
        let mut c = cfg(algo);
        c.engine = EngineOpts::default();
        c
    }

    #[test]
    fn sssp_service_drains_and_matches_oracle() {
        let g0 = generators::uniform_random(200, 1000, 9, 11);
        let stream = UpdateStream::generate_percent(&g0, 10.0, 64, 9, 13);
        let svc = GraphService::start(g0.clone(), cfg(Algo::Sssp));
        assert_eq!(svc.epoch(), 1, "initial static solve published");
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let stats = svc.stats();
        assert_eq!(stats.submitted, stream.len() as u64);
        assert_eq!(stats.completed, stats.submitted);
        let report = svc.shutdown();
        let mut want = g0.clone();
        stream.apply_all_static(&mut want);
        assert_eq!(report.graph.edges_sorted(), want.edges_sorted());
        assert_eq!(report.sssp().unwrap().dist, sssp::dijkstra_oracle(&want, 0));
    }

    /// The streaming layer benefits from the new knobs too: a service
    /// pinned to dense pull + partition-affine scheduling must stay
    /// equivalent to the offline oracle.
    #[test]
    fn pull_partitioned_service_drains_and_matches_oracle() {
        let g0 = generators::uniform_random(150, 800, 9, 51);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 64, 9, 53);
        let mut c = cfg(Algo::Sssp);
        c.engine.sched = Some(Sched::Partitioned);
        c.engine.direction = Some(Direction::Pull);
        let svc = GraphService::start(g0.clone(), c);
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let report = svc.shutdown();
        let mut want = g0.clone();
        stream.apply_all_static(&mut want);
        assert_eq!(report.sssp().unwrap().dist, sssp::dijkstra_oracle(&want, 0));
    }

    #[test]
    fn snapshot_queries_never_block_and_stay_consistent() {
        let g0 = generators::uniform_random(150, 700, 9, 21);
        let n = g0.num_nodes();
        let stream = UpdateStream::generate_percent(&g0, 15.0, 64, 9, 23);
        let svc = Arc::new(GraphService::start(g0, cfg(Algo::Sssp)));
        let stop = Arc::new(AtomicBool::new(false));
        let reader = {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    svc.with_snapshot(|t| {
                        assert_eq!(t.dist.len(), n, "snapshot arrays always complete");
                        assert_eq!(t.parent.len(), n);
                        assert!(t.epoch >= 1);
                    });
                    reads += 1;
                }
                reads
            })
        };
        for u in &stream.updates {
            svc.submit(*u);
        }
        svc.drain();
        stop.store(true, Ordering::Relaxed);
        assert!(reader.join().unwrap() > 0);
        let Ok(svc) = Arc::try_unwrap(svc) else { panic!("sole owner after reader joined") };
        let report = svc.shutdown();
        assert!(report.stats.batches > 0);
    }

    #[test]
    fn tc_service_counts_exactly() {
        let g0 = triangle::symmetrize(&generators::uniform_random(60, 360, 5, 31));
        // one undirected update per submission; symmetric mode expands arcs
        let workload = crate::coordinator::stream_workload(Algo::Tc, &g0, 15.0, 33);
        let mut c = cfg(Algo::Tc);
        assert!(c.symmetric);
        c.batch_capacity = 8;
        let svc = GraphService::start(g0, c);
        for u in workload {
            assert!(svc.submit(u));
        }
        svc.drain();
        let report = svc.shutdown();
        assert_eq!(
            report.tc().unwrap().triangles,
            triangle::static_tc(&report.graph).triangles,
            "streamed delta counting must equal a full recount"
        );
    }

    #[test]
    fn sharded_service_drains_and_matches_oracle_across_shards() {
        let g0 = generators::uniform_random(200, 1000, 9, 61);
        let stream = UpdateStream::generate_percent(&g0, 12.0, 64, 9, 63);
        let mut want = g0.clone();
        stream.apply_all_static(&mut want);
        let oracle = sssp::dijkstra_oracle(&want, 0);
        for shards in [1usize, 2, 4] {
            let mut c = sharded_cfg(Algo::Sssp);
            c.engine_shards = shards;
            let svc = ShardedService::start(g0.clone(), c);
            assert_eq!(svc.epoch(), 1, "initial static solve published");
            for u in &stream.updates {
                assert!(svc.submit(*u));
            }
            svc.drain();
            let stats = svc.stats();
            assert_eq!(stats.submitted, stream.len() as u64);
            assert_eq!(stats.completed, stats.submitted);
            let report = svc.shutdown();
            assert_eq!(report.graph.edges_sorted(), want.edges_sorted(), "shards={shards}");
            assert_eq!(report.sssp().unwrap().dist, oracle, "shards={shards}");
            assert!(report.stats.batches > 0);
            if shards > 1 {
                assert!(report.relay.rounds > 0, "push phases must have run");
            }
        }
    }

    #[test]
    fn sharded_tc_service_counts_exactly() {
        let g0 = triangle::symmetrize(&generators::uniform_random(60, 360, 5, 67));
        let workload = crate::coordinator::stream_workload(Algo::Tc, &g0, 15.0, 69);
        let mut c = sharded_cfg(Algo::Tc);
        assert!(c.symmetric);
        c.engine_shards = 2;
        c.batch_capacity = 8;
        let svc = ShardedService::start(g0, c);
        for u in workload {
            assert!(svc.submit(u));
        }
        svc.drain();
        let rep = svc.shutdown().into_service_report();
        assert_eq!(
            rep.tc().unwrap().triangles,
            triangle::static_tc(&rep.graph).triangles,
            "sharded streamed delta counting must equal a full recount"
        );
    }

    /// Full persistent-runtime path: fleet on, stealing on, rebalancing
    /// armed, under hub-heavy skewed churn. Results must still match the
    /// offline oracle, and the stats surface must report the per-shard
    /// load table plus at least one live migration.
    #[test]
    fn sharded_service_steals_and_rebalances_under_skew() {
        let g0 = generators::uniform_random(400, 1600, 9, 81);
        let stream = UpdateStream::generate_count_skewed(&g0, 1200, 64, 9, 83, 12);
        let mut want = g0.clone();
        stream.apply_all_static(&mut want);
        let oracle = sssp::dijkstra_oracle(&want, 0);
        let mut c = sharded_cfg(Algo::Sssp);
        c.engine_shards = 4;
        c.steal = true;
        c.rebalance = Some(1.10);
        let svc = ShardedService::start(g0, c);
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let stats = svc.stats();
        assert_eq!(stats.shard_loads.len(), 4, "per-shard load table published");
        let mass: u64 = stats.shard_loads.iter().map(|l| l.edge_mass).sum();
        assert_eq!(mass as usize, want.num_edges());
        assert!(
            stats.rebalances >= 1 && stats.migrated_vertices > 0,
            "hub-heavy churn must trip a live migration (rebalances={}, moved={})",
            stats.rebalances,
            stats.migrated_vertices
        );
        let report = svc.shutdown();
        assert_eq!(report.graph.edges_sorted(), want.edges_sorted());
        assert_eq!(report.sssp().unwrap().dist, oracle);
        assert_eq!(report.sssp().unwrap().parent.len(), oracle.len());
    }

    /// A sharded reader must always see one stitched epoch: the published
    /// table's per-shard stamps never diverge, even while shards are
    /// mid-propagation on the next batch.
    #[test]
    fn sharded_snapshots_carry_uniform_stamps() {
        let g0 = generators::uniform_random(150, 700, 9, 71);
        let stream = UpdateStream::generate_percent(&g0, 15.0, 64, 9, 73);
        let mut c = sharded_cfg(Algo::Sssp);
        c.engine_shards = 3;
        let svc = Arc::new(ShardedService::start(g0, c));
        let stop = Arc::new(AtomicBool::new(false));
        let reader = {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    svc.with_snapshot(|t| {
                        assert_eq!(t.shard_epochs.len(), 3, "one stamp per shard");
                        assert!(
                            t.shard_epochs.iter().all(|&e| e == t.graph_epoch),
                            "stitch invariant violated: {:?} vs {}",
                            t.shard_epochs,
                            t.graph_epoch
                        );
                    });
                    reads += 1;
                }
                reads
            })
        };
        for u in &stream.updates {
            svc.submit(*u);
        }
        svc.drain();
        stop.store(true, Ordering::Relaxed);
        assert!(reader.join().unwrap() > 0);
        let Ok(svc) = Arc::try_unwrap(svc) else { panic!("sole owner after reader joined") };
        let report = svc.shutdown();
        assert!(report.stats.batches > 0);
    }

    #[test]
    fn adaptive_policy_reports_merges_in_stats() {
        let g0 = generators::uniform_random(300, 1500, 9, 41);
        let stream = UpdateStream::generate_percent(&g0, 20.0, 64, 9, 43);
        let mut c = cfg(Algo::Sssp);
        c.merge_policy =
            MergePolicy::Adaptive { hot_fraction: 0.01, max_chain: 4, depth_hot: 1.0 };
        c.batch_capacity = 32;
        let svc = GraphService::start(g0, c);
        for u in &stream.updates {
            svc.submit(*u);
        }
        svc.drain();
        let stats = svc.stats();
        assert!(stats.policy.starts_with("adaptive"));
        assert!(stats.merges > 0, "20% churn must trip the adaptive signal");
        let report = svc.shutdown();
        assert!(report.stats.batches > 0);
        assert!(report.stats.batch_latency_p99 >= report.stats.batch_latency_p50);
    }

    /// Algorithm R keeps every sample seen so far with equal probability
    /// `cap / seen`. With cap 100 over 10k samples, the retained share
    /// from the first half of the stream must sit near 50 — the old
    /// always-replace scheme decays old samples geometrically and leaves
    /// almost none there.
    #[test]
    fn reservoir_algorithm_r_is_unbiased() {
        let mut r = Reservoir::new(100);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.samples.len(), 100);
        assert_eq!(r.seen, 10_000);
        let first_half = r.samples.iter().filter(|&&x| x < 5_000.0).count();
        assert!(
            (25..=75).contains(&first_half),
            "expected ~50 of 100 retained samples from the first half of the \
             stream, got {first_half} (recency bias?)"
        );
        // and from the first tenth: expect ~10
        let first_tenth = r.samples.iter().filter(|&&x| x < 1_000.0).count();
        assert!((1..=30).contains(&first_tenth), "first tenth: {first_tenth}");
    }

    #[test]
    fn reservoir_keeps_everything_under_cap() {
        let mut r = Reservoir::new(8);
        for i in 0..5 {
            r.push(i as f64);
        }
        assert_eq!(r.seen, 5);
        assert_eq!(r.samples, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    /// End-to-end telemetry: a traced sharded run surfaces the stage
    /// decomposition, histogram-backed p999, per-shard span tracks, and
    /// a Perfetto-parsable export.
    #[test]
    fn telemetry_surfaces_stage_decomposition_and_spans() {
        let g0 = generators::uniform_random(200, 1000, 9, 91);
        let stream = UpdateStream::generate_percent(&g0, 10.0, 64, 9, 93);
        let tracer = crate::telemetry::Tracer::new();
        let mut c = sharded_cfg(Algo::Sssp);
        c.engine_shards = 2;
        c.telemetry.tracer = Some(Arc::clone(&tracer));
        let svc = ShardedService::start(g0, c);
        for u in &stream.updates {
            assert!(svc.submit(*u));
        }
        svc.drain();
        let report = svc.shutdown();
        let st = &report.stats;
        assert!(st.batches > 0);
        assert!(st.stages.compute > 0.0, "compute stage must accumulate");
        assert!(st.stages.publish > 0.0, "publish stage must accumulate");
        assert!(st.batch_latency_p999 >= st.batch_latency_p99);
        assert!(st.batch_latency_p99 >= st.batch_latency_p50);
        assert!(st.batch_latency_p50 > 0.0);
        let per_batch = st.stages.per_batch_ms(st.batches);
        assert!(per_batch.compute > 0.0);

        let tracks = tracer.tracks();
        assert!(tracks.iter().any(|t| t.name() == "engine"));
        assert!(tracks.iter().any(|t| t.name() == "batcher"));
        assert!(tracks.iter().any(|t| t.name() == "shard-0"));
        assert!(tracks.iter().any(|t| t.name() == "shard-1"));
        assert!(tracks.iter().any(|t| t.name().starts_with("ingest-")));
        let spans: usize = tracks.iter().map(|t| t.snapshot().events.len()).sum();
        assert!(spans > 0, "a traced run must record spans");
        let json = crate::telemetry::chrome_trace_json(&tracer);
        crate::telemetry::validate_json(&json).expect("trace JSON parses");
        assert!(json.contains("\"ph\":\"X\""));
    }
}
